"""§VI arithmetic-intensity / worker-selection table (paper numbers beside
ours)."""
from __future__ import annotations

import time

from repro.core import CGRA, analyze
from repro.core.roofline import worker_demand_gflops
from repro.core.spec import paper_stencil_1d, paper_stencil_2d

PAPER = {
    "stencil1d": {"ai": 2.06, "bw_peak": 206.0, "workers": 6, "demand": 237.6},
    "stencil2d": {"ai": 5.59, "bw_peak": 559.0, "workers": 5, "demand": 582.0},
}


def run() -> list[tuple[str, float, str]]:
    rows = []
    for name, spec in [("stencil1d", paper_stencil_1d()),
                       ("stencil2d", paper_stencil_2d())]:
        t0 = time.perf_counter()
        rep = analyze(spec, CGRA)
        us = (time.perf_counter() - t0) * 1e6
        p = PAPER[name]
        derived = (f"AI={rep.arithmetic_intensity:.3f}(paper {p['ai']}) "
                   f"BWpeak={rep.bw_bound_gflops:.1f}(paper {p['bw_peak']}) "
                   f"w*={rep.workers}(paper {p['workers']}) "
                   f"demand={worker_demand_gflops(spec, CGRA, rep.workers):.1f}"
                   f"(paper {p['demand']}) bound={rep.bound}")
        rows.append((f"ai_table/{name}", us, derived))
    return rows
