"""Compare two BENCH_*.json perf snapshots with per-kind tolerances.

    python benchmarks/bench_diff.py OLD.json NEW.json [--rtol 0.25] ...

The artifacts' deterministic counters (cycle counts, token hops, stall
cycles, fire/instruction counts — anything integer-valued) must match
**exactly**: the simulator is bit-reproducible, so any drift there is a
semantics change, not noise.  Float-valued keys (wall times, GFLOPS,
speedups) are machine-load measurements and compare under ``--rtol``/
``--atol``.  ``ci.sh`` uses this as the telemetry-overhead gate: the
refreshed BENCH_pr4 must keep identical cycle counts and wall times within
tolerance of the previous snapshot (telemetry detached = free).

Exit status: 0 when every shared case agrees, 1 on any violation (or on a
schema/config mismatch — comparing a smoke run against a full run is
meaningless).  Cases or keys present on only one side are reported as
warnings unless ``--strict`` makes them failures.
"""
from __future__ import annotations

import argparse
import json
import sys


def _is_int(v) -> bool:
    return isinstance(v, int) and not isinstance(v, bool)


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def diff_cases(old: dict, new: dict, rtol: float, atol: float,
               skip: frozenset[str] = frozenset(),
               float_keys: frozenset[str] = frozenset()):
    """Yield ``(kind, message)`` findings; kind is 'fail' or 'warn'.

    ``float_keys`` forces tolerance-compare on keys that would otherwise be
    integer-exact (e.g. a counter known to be load-dependent)."""
    for name in sorted(old.keys() | new.keys()):
        if name not in new:
            yield "warn", f"case {name!r} only in OLD"
            continue
        if name not in old:
            yield "warn", f"case {name!r} only in NEW"
            continue
        a, b = old[name], new[name]
        for key in sorted(a.keys() | b.keys()):
            if key in skip:
                continue
            if key not in b or key not in a:
                side = "OLD" if key in a else "NEW"
                yield "warn", f"{name}.{key} only in {side}"
                continue
            va, vb = a[key], b[key]
            if not (_is_num(va) and _is_num(vb)):
                if va != vb:
                    yield "warn", f"{name}.{key}: {va!r} != {vb!r}"
                continue
            if _is_int(va) and _is_int(vb) and key not in float_keys:
                if va != vb:
                    yield ("fail", f"{name}.{key}: deterministic counter "
                           f"changed {va} -> {vb}")
            else:
                lim = atol + rtol * max(abs(va), abs(vb))
                if abs(va - vb) > lim:
                    yield ("fail", f"{name}.{key}: {va} -> {vb} "
                           f"(|delta|={abs(va - vb):.4g} > {lim:.4g} "
                           f"at rtol={rtol} atol={atol})")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", metavar="OLD.json")
    ap.add_argument("new", metavar="NEW.json")
    ap.add_argument("--rtol", type=float, default=0.25,
                    help="relative tolerance for float-valued keys "
                    "(wall times etc.; default 0.25)")
    ap.add_argument("--atol", type=float, default=0.05,
                    help="absolute slack added to the tolerance band "
                    "(absorbs sub-tick walls; default 0.05)")
    ap.add_argument("--skip", action="append", default=[], metavar="KEY",
                    help="ignore this per-case key (repeatable)")
    ap.add_argument("--float-key", action="append", default=[],
                    metavar="KEY", help="tolerance-compare this integer key "
                    "instead of requiring exact equality (repeatable)")
    ap.add_argument("--strict", action="store_true",
                    help="missing cases/keys and non-numeric drift fail "
                    "instead of warning")
    args = ap.parse_args(argv)

    arts = []
    for path in (args.old, args.new):
        try:
            with open(path) as f:
                arts.append(json.load(f))
        except (OSError, ValueError) as e:
            print(f"bench_diff: cannot read {path}: {e}", file=sys.stderr)
            return 1
    old, new = arts
    fails = 0
    for meta in ("schema", "config"):
        if old.get(meta) != new.get(meta):
            print(f"FAIL: {meta} mismatch: "
                  f"{old.get(meta)!r} != {new.get(meta)!r}")
            fails += 1
    for side, art in (("OLD", old), ("NEW", new)):
        if art.get("errors"):
            print(f"FAIL: {side} is a partial artifact "
                  f"(errors on {sorted(art['errors'])})")
            fails += 1
    findings = list(diff_cases(old.get("cases", {}), new.get("cases", {}),
                               args.rtol, args.atol,
                               skip=frozenset(args.skip),
                               float_keys=frozenset(args.float_key)))
    for kind, msg in findings:
        if args.strict and kind == "warn":
            kind = "fail"
        print(f"{kind.upper()}: {msg}")
        fails += kind == "fail"
    n_cases = len(old.get("cases", {}).keys() & new.get("cases", {}).keys())
    if fails:
        print(f"bench_diff: {fails} failure(s) across {n_cases} shared "
              f"case(s)")
        return 1
    print(f"bench_diff: OK — {n_cases} shared case(s) agree "
          f"(rtol={args.rtol}, atol={args.atol})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
