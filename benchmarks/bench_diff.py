"""Compare BENCH_*.json perf snapshots — pairwise or against the history.

Snapshot mode (two artifacts)::

    python benchmarks/bench_diff.py OLD.json NEW.json [--rtol 0.25] ...

Trend mode (one artifact vs the append-only ``BENCH_history.jsonl``)::

    python benchmarks/bench_diff.py NEW.json --trend 5 [--history PATH]

Cases are flattened to dotted key paths (``best.cycles`` — the same
:func:`repro.telemetry.metrics.flatten_case` rule the history records use)
and compared on the **intersection** of keys; keys present on only one side
warn (``--strict`` fails), so artifacts are free to *grow* fields across
PRs without breaking the gate.  Two exceptions:

* each schema has an explicit **allowlist of required integer counters**
  (``REQUIRED_COUNTERS``) that must exist on both sides and match exactly —
  a snapshot that silently *lost* its cycle counts is a broken refresh, not
  a schema evolution;
* each schema has a **volatile** prefix set (``VOLATILE``) that is skipped
  entirely — e.g. the BENCH_pr5 explore artifacts carry the whole Pareto
  ``front``, cache ``stats`` and prune tallies, which legitimately change
  whenever the search trajectory does.

Everything else integer-valued must match exactly (the simulator is
bit-reproducible; integer drift is a semantics change, not noise).
Float-valued keys (wall times, GFLOPS) are machine-load measurements and
compare under ``--rtol``/``--atol``.

Trend mode gates each required counter of NEW against the last ``N``
history records of the same (schema, config, case): **fail** when the new
value is worse (greater) than *every* one of them — i.e. worse than
``max(last N)`` — warn when it merely changed vs the most recent record
but stays inside the envelope (so a blessed regression doesn't re-fire
forever).  Walls only warn in trend mode (``overhead_check.py`` owns the
wall-clock gate).  A case with no history yet passes with a warning —
the first CI run seeds the trend.

Exit status: 0 when every check passes, 1 on any failure (including a
schema/config mismatch or a partial artifact with an ``errors`` key).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

try:
    from repro.telemetry.metrics import (DEFAULT_HISTORY, case_records,
                                         flatten_case, history_for,
                                         load_history, trend_values)
except ImportError:                        # ran bare: python benchmarks/...
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "src"))
    from repro.telemetry.metrics import (DEFAULT_HISTORY, case_records,
                                         flatten_case, history_for,
                                         load_history, trend_values)

#: integer counters that must exist on both sides and match exactly —
#: per artifact schema; unknown schemas fall back to "no required set".
REQUIRED_COUNTERS = {
    "bench_pr2/v1": ("cycles_ideal", "cycles_routed", "pe_instructions",
                     "stall_cycles", "token_hops"),
    "bench_pr3/v1": ("cycles_fused_ideal", "cycles_fused_routed",
                     "cycles_separate_ideal", "cycles_separate_routed",
                     "pe_instructions", "stall_cycles", "token_hops",
                     "max_channel_load"),
    "bench_pr4/v1": ("cycles_ideal", "cycles_routed", "pe_instructions",
                     "stall_cycles", "token_hops"),
    "bench_pr5/v1": ("analytic.cycles", "best.cycles", "best.pes",
                     "best.max_channel_load"),
    # engine-agnostic on purpose: per-engine walls (interp/vector/jax) are
    # floats and therefore tolerance-compared / trend-warned, so artifacts
    # refreshed with --engine both vs all diff cleanly (new keys warn).
    "bench_pr9/v1": ("n_configs", "cycles_total"),
    "bench_pr10/v1": ("n_configs", "static_pruned", "deadlock_sims_avoided",
                      "survivors", "best_cycles"),
}

#: dotted-path prefixes skipped per schema: legitimately trajectory-
#: dependent structure (Pareto fronts, cache stats, prune tallies).
VOLATILE = {
    "bench_pr5/v1": ("front", "stats.", "pruned.", "n_points",
                     "analytic.cached", "best.cached"),
    # the walls measure how much wall the static gate saved this run —
    # machine-load noise; the avoided-simulation counts are the gated part
    "bench_pr10/v1": ("wall_on_s", "wall_off_s", "wall_saved_s"),
}


def _is_int(v) -> bool:
    return isinstance(v, int) and not isinstance(v, bool)


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _volatile(schema: str, key: str) -> bool:
    return any(key == p or key.startswith(p)
               for p in VOLATILE.get(schema, ()))


def diff_cases(old: dict, new: dict, rtol: float, atol: float,
               schema: str = "", skip: frozenset = frozenset(),
               float_keys: frozenset = frozenset()):
    """Yield ``(kind, message)`` findings; kind is 'fail' or 'warn'.

    ``float_keys`` forces tolerance-compare on keys that would otherwise be
    integer-exact (e.g. a counter known to be load-dependent)."""
    required = REQUIRED_COUNTERS.get(schema, ())
    for name in sorted(old.keys() | new.keys()):
        if name not in new:
            yield "warn", f"case {name!r} only in OLD"
            continue
        if name not in old:
            yield "warn", f"case {name!r} only in NEW"
            continue
        a, b = flatten_case(old[name]), flatten_case(new[name])
        for key in required:
            for side, d in (("OLD", a), ("NEW", b)):
                if key not in d:
                    yield ("fail", f"{name}.{key}: required counter missing "
                           f"in {side} (allowlist for {schema})")
        for key in sorted(a.keys() | b.keys()):
            if key in skip or _volatile(schema, key):
                continue
            if key not in b or key not in a:
                if key in required:
                    continue               # already failed above
                side = "OLD" if key in a else "NEW"
                yield "warn", f"{name}.{key} only in {side}"
                continue
            va, vb = a[key], b[key]
            if not (_is_num(va) and _is_num(vb)):
                if va != vb:
                    yield "warn", f"{name}.{key}: {va!r} != {vb!r}"
                continue
            if _is_int(va) and _is_int(vb) and key not in float_keys:
                if va != vb:
                    yield ("fail", f"{name}.{key}: deterministic counter "
                           f"changed {va} -> {vb}")
            else:
                lim = atol + rtol * max(abs(va), abs(vb))
                if abs(va - vb) > lim:
                    yield ("fail", f"{name}.{key}: {va} -> {vb} "
                           f"(|delta|={abs(va - vb):.4g} > {lim:.4g} "
                           f"at rtol={rtol} atol={atol})")


def trend_findings(artifact: dict, history: list[dict], last: int,
                   rtol: float, atol: float):
    """Yield ``(kind, message)`` gating ``artifact`` against the last
    ``last`` matching history records per case (see module docstring)."""
    schema = artifact.get("schema", "?")
    config = artifact.get("config", "?")
    required = REQUIRED_COUNTERS.get(schema, ())
    for rec in case_records(artifact):
        case = rec["case"]
        line = history_for(history, schema, config, case)
        if not line:
            yield ("warn", f"{case}: no history for ({schema}, {config}) — "
                   f"first record seeds the trend")
            continue
        for key in required:
            if key not in rec["counters"]:
                yield ("fail", f"{case}.{key}: required counter missing "
                       f"in NEW (allowlist for {schema})")
                continue
            recent = trend_values(line, key, last=last)
            if not recent:
                yield "warn", f"{case}.{key}: no history values yet"
                continue
            nv, worst = rec["counters"][key], max(recent)
            if nv > worst:
                yield ("fail", f"{case}.{key}: regression {nv} > "
                       f"max(last {len(recent)}) = {worst} "
                       f"(trend {recent} -> {nv})")
            elif nv != recent[-1]:
                yield ("warn", f"{case}.{key}: changed {recent[-1]} -> {nv} "
                       f"(within envelope, max(last {len(recent)}) = "
                       f"{worst})")
        for key, nv in sorted(rec["walls"].items()):
            recent = trend_values(line, key, last=last, kind="walls")
            if not recent:
                continue
            med = sorted(recent)[len(recent) // 2]
            lim = med * (1 + rtol) + atol
            if nv > lim:
                yield ("warn", f"{case}.{key}: wall {nv:.4g} above trend "
                       f"envelope {lim:.4g} (median of last "
                       f"{len(recent)} = {med:.4g})")


def _load(path: str):
    with open(path) as f:
        return json.load(f)


def _check_partial(art: dict, side: str) -> list:
    if art.get("errors"):
        return [("fail", f"{side} is a partial artifact "
                 f"(errors on {sorted(art['errors'])})")]
    return []


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", metavar="OLD.json",
                    help="previous snapshot, or the NEW artifact in "
                    "--trend mode")
    ap.add_argument("new", metavar="NEW.json", nargs="?",
                    help="refreshed snapshot (omit in --trend mode)")
    ap.add_argument("--trend", type=int, metavar="N",
                    help="gate OLD.json against the last N matching "
                    "history records instead of a second snapshot")
    ap.add_argument("--history", default=DEFAULT_HISTORY,
                    help=f"history file for --trend "
                    f"(default {DEFAULT_HISTORY})")
    ap.add_argument("--rtol", type=float, default=0.25,
                    help="relative tolerance for float-valued keys "
                    "(wall times etc.; default 0.25)")
    ap.add_argument("--atol", type=float, default=0.05,
                    help="absolute slack added to the tolerance band "
                    "(absorbs sub-tick walls; default 0.05)")
    ap.add_argument("--skip", action="append", default=[], metavar="KEY",
                    help="ignore this per-case key path (repeatable)")
    ap.add_argument("--float-key", action="append", default=[],
                    metavar="KEY", help="tolerance-compare this integer key "
                    "instead of requiring exact equality (repeatable)")
    ap.add_argument("--strict", action="store_true",
                    help="missing cases/keys and non-numeric drift fail "
                    "instead of warning")
    args = ap.parse_args(argv)

    if (args.new is None) == (args.trend is None):
        print("bench_diff: need either OLD.json NEW.json or "
              "NEW.json --trend N", file=sys.stderr)
        return 2

    try:
        first = _load(args.old)
    except (OSError, ValueError) as e:
        print(f"bench_diff: cannot read {args.old}: {e}", file=sys.stderr)
        return 1

    findings: list[tuple[str, str]] = []
    if args.trend is not None:
        findings += _check_partial(first, "NEW")
        history = load_history(args.history)
        findings += list(trend_findings(first, history, args.trend,
                                        args.rtol, args.atol))
        label = (f"trend gate vs last {args.trend} of "
                 f"{args.history} ({len(history)} records)")
        n_cases = len(first.get("cases", {}))
    else:
        try:
            second = _load(args.new)
        except (OSError, ValueError) as e:
            print(f"bench_diff: cannot read {args.new}: {e}",
                  file=sys.stderr)
            return 1
        for meta in ("schema", "config"):
            if first.get(meta) != second.get(meta):
                findings.append(("fail", f"{meta} mismatch: "
                                 f"{first.get(meta)!r} != "
                                 f"{second.get(meta)!r}"))
        findings += _check_partial(first, "OLD")
        findings += _check_partial(second, "NEW")
        findings += list(diff_cases(
            first.get("cases", {}), second.get("cases", {}),
            args.rtol, args.atol, schema=str(first.get("schema", "")),
            skip=frozenset(args.skip),
            float_keys=frozenset(args.float_key)))
        label = f"snapshot compare (rtol={args.rtol}, atol={args.atol})"
        n_cases = len(first.get("cases", {}).keys()
                      & second.get("cases", {}).keys())

    fails = 0
    for kind, msg in findings:
        if args.strict and kind == "warn":
            kind = "fail"
        print(f"{kind.upper()}: {msg}")
        fails += kind == "fail"
    if fails:
        print(f"bench_diff: {fails} failure(s) across {n_cases} case(s) — "
              f"{label}")
        return 1
    print(f"bench_diff: OK — {n_cases} case(s) agree; {label}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
