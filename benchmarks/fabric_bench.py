"""Fabric place-and-route benchmark: the paper's mappings on a 16x16 mesh.

Two parts per mapping (1D w=8, 2D w=8, 3D heat w=8 — the rank the
dimension-generic ``map_nd`` adds):
  * **place+route at paper scale** — the full-radius DFG (17-pt r=8 / 49-pt
    r=12) is placed and routed on the paper's 16x16 fabric; reports weighted
    hop count, link congestion (max channel load / hot-spots) and fabric
    utilization.
  * **ideal vs routed simulation** on a reduced grid — the same mapping
    structure simulated with free one-hop wires vs the routed network,
    reporting the cycle inflation the on-chip network actually costs.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import CGRA, map_1d, map_2d, map_3d, simulate
from repro.core.spec import heat_3d, paper_stencil_1d, paper_stencil_2d
from repro.fabric import FabricTopology, place, route


def run() -> list[tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)

    cases = [
        # (name, paper-scale spec, reduced-sim spec, mapper, workers)
        ("stencil1d_w8", paper_stencil_1d(n=194400, rx=8),
         paper_stencil_1d(n=2400, rx=8), map_1d, 8),
        ("stencil2d_w8", paper_stencil_2d(ny=449, nx=960, r=12),
         paper_stencil_2d(ny=32, nx=64, r=12), map_2d, 8),
        ("stencil3d_w8", heat_3d(64, 64, 64, dtype="float64"),
         heat_3d(10, 12, 16, dtype="float64"), map_3d, 8),
    ]
    for name, spec_full, spec_sim, mapper, w in cases:
        # --- place + route at paper scale --------------------------------
        t0 = time.perf_counter()
        plan = mapper(spec_full, workers=w)
        topo = FabricTopology.mesh(16, 16)
        rf = route(place(plan, topo, seed=0))
        us = (time.perf_counter() - t0) * 1e6
        s = rf.stats()
        hot = s["hotspots"][0] if s["hotspots"] else {}
        rows.append((
            f"fabric/pnr_{name}", us,
            f"nodes={len(plan.dfg.nodes)} hops_mean={s['hops_mean']} "
            f"hops_max={s['hops_max']} weighted_hops={s['weighted_hops']} "
            f"max_chan={s['max_channel_load']}/{s['channel_capacity']} "
            f"pe_util={s['pe_utilization']:.1%} "
            f"link_util={s['link_utilization']:.1%} "
            f"hotspot={hot.get('link', '-')}@{hot.get('trees', 0)}"))

        # --- ideal vs routed simulation on the reduced grid --------------
        x = rng.normal(size=spec_sim.grid_shape)
        t0 = time.perf_counter()
        ideal = simulate(mapper(spec_sim, workers=w), x, CGRA)
        plan_net = mapper(spec_sim, workers=w)
        rf_net = route(place(plan_net, topo, seed=0))
        routed = simulate(plan_net, x, CGRA, fabric=rf_net)
        us = (time.perf_counter() - t0) * 1e6
        assert np.array_equal(ideal.output, routed.output)
        rows.append((
            f"fabric/sim_{name}", us,
            f"ideal_cycles={ideal.cycles} routed_cycles={routed.cycles} "
            f"inflation={routed.cycles / ideal.cycles:.2f}x "
            f"token_hops={routed.fabric['token_hops']} "
            f"stall_cycles={routed.fabric['stall_cycles']} "
            f"bit_identical=True"))
    return rows
