"""Fig. 12 roofline curves: achievable GFLOPS vs arithmetic intensity for the
CGRA, with the two paper stencils placed on the curve, plus the TPU-v5e port
curve (DESIGN.md §3 constants)."""
from __future__ import annotations

import time

from repro.core import CGRA, TPU_V5E, analyze
from repro.core.spec import paper_stencil_1d, paper_stencil_2d


def run() -> list[tuple[str, float, str]]:
    rows = []
    t0 = time.perf_counter()
    pts = []
    for ai_x10 in (5, 10, 21, 41, 56, 62, 80, 120, 200):   # AI sweep x0.1
        ai = ai_x10 / 10
        g = min(CGRA.bw_gbps * ai, CGRA.peak_gflops)
        pts.append(f"{ai:.1f}:{g:.0f}")
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("fig12/cgra_curve", us, " ".join(pts)))

    for name, spec in [("stencil1d", paper_stencil_1d()),
                       ("stencil2d", paper_stencil_2d())]:
        t0 = time.perf_counter()
        c = analyze(spec, CGRA)
        v = analyze(spec, TPU_V5E)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig12/{name}", us,
                     f"CGRA={c.achievable_gflops:.0f}GF({c.bound}) "
                     f"TPUv5e={v.achievable_gflops/1000:.2f}TF({v.bound}) "
                     f"ridgeAI_cgra={CGRA.peak_gflops/CGRA.bw_gbps:.2f} "
                     f"ridgeAI_tpu={TPU_V5E.peak_gflops/TPU_V5E.bw_gbps:.1f}"))
    return rows
