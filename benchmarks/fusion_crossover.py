"""§IV temporal fusion (implemented beyond the paper): AI growth, the
memory->compute crossover, PE budget, and seam overhead per fused depth."""
from __future__ import annotations

import time

from repro.core import CGRA, TPU_V5E, crossover_timesteps, fusion_report
from repro.core.spec import paper_stencil_1d


def run() -> list[tuple[str, float, str]]:
    rows = []
    spec = paper_stencil_1d()
    for machine in (CGRA, TPU_V5E):
        t0 = time.perf_counter()
        rep = fusion_report(spec, machine, workers=6, max_t=8)
        cx = crossover_timesteps(spec, machine, workers=6)
        us = (time.perf_counter() - t0) * 1e6
        pts = " ".join(f"T{p.timesteps}:AI={p.arithmetic_intensity:.1f},"
                       f"{p.achievable_gflops:.0f}GF,{p.bound[:3]}"
                       f"{'' if p.fits_fabric else ',!fit'}"
                       for p in rep[:6])
        rows.append((f"fusion/{machine.name}", us,
                     f"crossover_T={cx} {pts}"))
    return rows
