"""Paper-style analytical roofline for the TPU Pallas kernels + measured
XLA-path wall time on this host (CPU) for scale.

The TPU numbers are structural (AI x BW vs peak — the same §VI method with
v5e constants); wall-clock MFU cannot be measured in this container.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TPU_V5E, analyze
from repro.core.spec import StencilSpec, paper_stencil_1d, paper_stencil_2d
from repro.kernels.stencil1d.ref import stencil1d_ref
from repro.kernels.stencil2d.ref import stencil2d_ref


def _time(f, *args, reps=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list[tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)

    # paper 1D stencil, fp32, T=1 and fused T=8 on TPU constants
    for t in (1, 4, 8):
        spec = dataclasses.replace(paper_stencil_1d(dtype="float32"),
                                   timesteps=t)
        rep = analyze(spec, TPU_V5E)
        x = jnp.asarray(rng.normal(size=(1, 194400)), jnp.float32)
        us = _time(jax.jit(lambda a: stencil1d_ref(a, spec.coeffs[0],
                                                   timesteps=t)), x)
        rows.append((f"kernel_roofline/stencil1d_T{t}", us,
                     f"AI={rep.arithmetic_intensity:.2f} "
                     f"v5e={rep.achievable_gflops/1000:.2f}TF "
                     f"bound={rep.bound} host_xla_us={us:.0f}"))

    spec2 = paper_stencil_2d(dtype="float32")
    rep2 = analyze(spec2, TPU_V5E)
    x2 = jnp.asarray(rng.normal(size=(1, 449, 960)), jnp.float32)
    us = _time(jax.jit(lambda a: stencil2d_ref(a, spec2.coeffs[0],
                                               spec2.coeffs[1])), x2)
    rows.append(("kernel_roofline/stencil2d", us,
                 f"AI={rep2.arithmetic_intensity:.2f} "
                 f"v5e={rep2.achievable_gflops/1000:.2f}TF "
                 f"bound={rep2.bound} host_xla_us={us:.0f}"))
    return rows
