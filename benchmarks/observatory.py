"""Trend + attribution reports over the append-only benchmark history.

    python benchmarks/observatory.py append BENCH_pr4.json [more.json ...]
    python benchmarks/observatory.py report [--last 8]

``append`` turns each BENCH_*.json artifact into fingerprinted records
(:mod:`repro.telemetry.metrics`) and appends them to ``BENCH_history.jsonl``
— ci.sh does this once per run, *after* the trend gate has passed, so the
history only accumulates blessed measurements.

``report`` renders the trajectory: one line per experiment (schema, config,
case) with the primary cycle counter's trend over the last N records, plus
— for cases that carry a ``stall_breakdown`` (the PR 8 attribution fields)
— the latest stall-cause shares, phase split and bottleneck label.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

try:
    from repro.telemetry.metrics import (DEFAULT_HISTORY, case_records,
                                         append_history, load_history,
                                         record_problem, trend_values)
except ImportError:                        # ran bare: python benchmarks/...
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "src"))
    from repro.telemetry.metrics import (DEFAULT_HISTORY, case_records,
                                         append_history, load_history,
                                         record_problem, trend_values)

#: first matching key is the experiment's headline counter
PRIMARY = ("cycles_routed", "cycles_fused_routed", "best.cycles", "cycles",
           "cycles_ideal")

_SPARK = "_.-~*#"


def _spark(vals: list[int]) -> str:
    lo, hi = min(vals), max(vals)
    if hi == lo:
        return _SPARK[0] * len(vals)
    return "".join(_SPARK[int((v - lo) / (hi - lo) * (len(_SPARK) - 1))]
                   for v in vals)


def _delta(vals: list) -> str:
    if len(vals) < 2 or vals[-2] == 0:
        return ""
    d = 100.0 * (vals[-1] - vals[-2]) / vals[-2]
    return f" ({d:+.1f}%)" if abs(d) >= 0.05 else " (=)"


def append_cmd(args) -> int:
    n = 0
    for path in args.artifacts:
        try:
            with open(path) as f:
                art = json.load(f)
        except (OSError, ValueError) as e:
            print(f"observatory: cannot read {path}: {e}", file=sys.stderr)
            return 1
        if art.get("errors"):
            print(f"observatory: refusing to append partial artifact "
                  f"{path} (errors on {sorted(art['errors'])})",
                  file=sys.stderr)
            return 1
        recs = case_records(art, source=pathlib.Path(path).name)
        n += append_history(args.history, recs)
        print(f"observatory: {path}: appended {len(recs)} record(s)")
    print(f"observatory: {args.history}: +{n} record(s)")
    return 0


def _attribution_lines(rec: dict) -> list[str]:
    """Latest attribution view of one record, if it carries the fields."""
    counters = rec.get("counters", {})
    bd = {k.split(".", 1)[1]: v for k, v in counters.items()
          if k.startswith("stall_breakdown.")}
    ph = {k.split(".", 1)[1]: v for k, v in counters.items()
          if k.startswith("phases.")}
    out = []
    if ph:
        tot = max(1, sum(ph.values()))
        out.append("      phases: " + "  ".join(
            f"{k}={v} ({100 * v / tot:.0f}%)" for k, v in ph.items()))
    if bd:
        tot = sum(bd.values())
        if tot:
            out.append("      stalls: " + "  ".join(
                f"{k}={100 * v / tot:.0f}%"
                for k, v in sorted(bd.items(), key=lambda kv: -kv[1])
                if v))
        else:
            out.append("      stalls: none recorded")
    label = rec.get("meta", {}).get("bottleneck")
    if label:
        out.append(f"      bottleneck: {label}")
    return out


def report_cmd(args) -> int:
    records = load_history(args.history)
    if not records:
        print(f"observatory: {args.history}: no records yet — run "
              f"`observatory.py append BENCH_*.json` first")
        return 0
    # unknown/partial record shapes (newer versions, payload-less
    # throughput records) skip with a named warning, never a KeyError
    skipped: dict[str, int] = {}
    kept = []
    for r in records:
        prob = record_problem(r)
        if prob is None:
            kept.append(r)
        else:
            skipped[prob] = skipped.get(prob, 0) + 1
    for prob, n in sorted(skipped.items()):
        print(f"observatory: WARNING — skipped {n} record(s): {prob}")
    records = kept
    lines = {}
    for r in records:
        key = (r.get("schema", "?"), r.get("config", "?"),
               r.get("case", "?"))
        lines.setdefault(key, []).append(r)
    print(f"observatory: {args.history} — {len(records)} record(s), "
          f"{len(lines)} experiment(s), last {args.last} shown per trend")
    last_group = None
    for (schema, config, case), recs in sorted(lines.items()):
        if (schema, config) != last_group:
            last_group = (schema, config)
            print(f"{schema} [{config}]")
        key = next((k for k in PRIMARY if k in recs[-1].get("counters", {})),
                   None)
        if key is None:
            print(f"  {case:<22} ({len(recs)} record(s), no primary "
                  f"counter)")
            continue
        vals = trend_values(recs, key, last=args.last)
        print(f"  {case:<22} {key}: {vals[-1]}{_delta(vals)}  "
              f"|{_spark(vals)}| min {min(vals)} max {max(vals)} "
              f"n={len(vals)}")
        if args.attribution:
            for ln in _attribution_lines(recs[-1]):
                print(ln)
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    a = sub.add_parser("append", help="append artifact cases to the history")
    a.add_argument("artifacts", nargs="+", metavar="BENCH.json")
    a.add_argument("--history", default=DEFAULT_HISTORY)
    a.set_defaults(fn=append_cmd)
    r = sub.add_parser("report", help="render the trend/attribution report")
    r.add_argument("--history", default=DEFAULT_HISTORY)
    r.add_argument("--last", type=int, default=8,
                   help="trend window per experiment (default 8)")
    r.add_argument("--no-attribution", dest="attribution",
                   action="store_false",
                   help="skip the per-case stall/phase/bottleneck lines")
    r.set_defaults(fn=report_cmd)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
