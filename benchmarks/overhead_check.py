"""Disabled-telemetry overhead gate: the ``telemetry=None`` path must not
creep.

    python benchmarks/overhead_check.py [--rtol 0.02] [--history PATH]

The telemetry contract (docs/telemetry.md) is *zero cost when absent*: with
``telemetry=None`` both engines take one ``is not None`` branch per probe
site and nothing else.  A single process cannot compare against a build
with the hooks compiled out, so this check gates the **trajectory**: it
times the routed smoke 2d case (vector engine, telemetry detached,
best-of-``--repeats`` wall so scheduler noise drops out) and fails when
that wall exceeds the median of its own last ``--last`` history records by
more than ``--rtol`` (default 2% — the documented overhead bound) plus
``--atol`` seconds of absolute slack.  On pass, the fresh measurement is
appended (schema ``overhead/v1``) so the envelope tracks the machine; the
first run on an empty history seeds it and passes trivially.

Exit status: 0 on pass, 1 when the wall breaches the envelope.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time

try:
    from repro.telemetry.metrics import (DEFAULT_HISTORY, append_history,
                                         case_records, history_for,
                                         load_history, record_problem,
                                         trend_values)
except ImportError:                        # ran bare: python benchmarks/...
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "src"))
    from repro.telemetry.metrics import (DEFAULT_HISTORY, append_history,
                                         case_records, history_for,
                                         load_history, record_problem,
                                         trend_values)

SCHEMA = "overhead/v1"
CASE = "2d_routed_vector"


def measure(repeats: int) -> tuple[float, int]:
    """Best-of-``repeats`` wall of the routed smoke 2d case with the sink
    detached (fresh plan per repeat: edge queues are runtime state)."""
    import numpy as np

    from repro.core import CGRA, map_2d, simulate
    from repro.core.spec import paper_stencil_2d
    from repro.fabric import FabricTopology, place, route

    spec = paper_stencil_2d(ny=30, nx=48, r=12)
    x = np.random.default_rng(0).normal(size=spec.grid_shape)
    topo = FabricTopology.mesh(16, 16)
    best, cycles = float("inf"), 0
    for _ in range(repeats):
        plan = map_2d(spec, workers=8)
        rf = route(place(plan, topo, seed=0))
        t0 = time.perf_counter()
        res = simulate(plan, x, CGRA, fabric=rf, engine="vector",
                       telemetry=None)
        best = min(best, time.perf_counter() - t0)
        cycles = res.cycles
    return best, cycles


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--history", default=DEFAULT_HISTORY)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--last", type=int, default=10,
                    help="history window for the median (default 10)")
    ap.add_argument("--rtol", type=float, default=0.02,
                    help="allowed relative creep over the trend median "
                    "(default 0.02 = the documented <2%% bound)")
    ap.add_argument("--atol", type=float, default=0.05,
                    help="absolute slack in seconds (absorbs timer "
                    "granularity on sub-second walls; default 0.05)")
    ap.add_argument("--no-append", action="store_true",
                    help="gate only; don't record this measurement")
    args = ap.parse_args(argv)

    wall, cycles = measure(args.repeats)
    history = history_for(load_history(args.history), SCHEMA, "smoke", CASE)
    # records with an unknown/partial shape (newer versions, payload-less
    # schemas) are skipped with a named warning, never a KeyError
    problems = sorted({p for p in map(record_problem, history)
                       if p is not None})
    if problems:
        n_bad = sum(record_problem(r) is not None for r in history)
        print(f"overhead_check: WARNING — skipped {n_bad} history "
              f"record(s): {'; '.join(problems)}")
        history = [r for r in history if record_problem(r) is None]
    recent = trend_values(history, "wall_s", last=args.last, kind="walls")

    status = 0
    if recent:
        med = sorted(recent)[len(recent) // 2]
        lim = med * (1 + args.rtol) + args.atol
        verdict = "OK" if wall <= lim else "FAIL"
        print(f"overhead_check: {verdict} — telemetry=None wall "
              f"{wall:.4f}s vs envelope {lim:.4f}s (median of last "
              f"{len(recent)} = {med:.4f}s, rtol={args.rtol}, "
              f"atol={args.atol}; {cycles} cycles)")
        status = 0 if wall <= lim else 1
    else:
        print(f"overhead_check: OK — first measurement seeds the trend "
              f"({wall:.4f}s, {cycles} cycles)")

    if status == 0 and not args.no_append:
        art = {"schema": SCHEMA, "config": "smoke",
               "cases": {CASE: {"cycles": cycles,
                                "wall_s": round(wall, 4),
                                "engine": "vector",
                                "repeats": args.repeats}}}
        append_history(args.history, case_records(
            art, source="overhead_check.py"))
    return status


if __name__ == "__main__":
    sys.exit(main())
