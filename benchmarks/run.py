"""Benchmark harness — one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV (assignment deliverable (d)).
  table1            — §VIII Table I  (CGRA sim vs V100 roofline)
  ai_table          — §VI arithmetic (AI, w*, demands)
  fig12_roofline    — §VI Fig. 12    (roofline curves, CGRA + TPU port)
  kernel_roofline   — TPU kernel rooflines (paper method, v5e constants)
  fusion_crossover  — §IV temporal fusion (beyond paper)
  vii_gpu_efficiency — §VII efficiency-vs-AI trend (incl. 3D stencils)
  fabric_bench      — place-and-route + network-aware sim on the 16x16 mesh

``--artifact PATH`` additionally writes a JSON perf snapshot (cycles, GFLOPS,
roofline %, fabric hop/stall stats for the 1D/2D/3D mappings) so the perf
trajectory accumulates across PRs; ``--program-artifact PATH`` writes the
program-pipeline snapshot (BENCH_pr3.json: fused multi-op DAGs vs separate
store-to-memory sweeps); ``--smoke`` shrinks the grids so CI can afford it
(ci.sh runs ``--artifact BENCH_pr2.json --program-artifact BENCH_pr3.json
--smoke --artifact-only`` — the artifact refresh, not the full CSV sweep).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
import traceback

if __package__ in (None, ""):      # script mode: `python benchmarks/run.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))


def artifact_cases(smoke: bool) -> dict:
    """One entry per rank: ideal + routed simulation on the 16x16 mesh."""
    import numpy as np

    from repro.core import CGRA, map_1d, map_2d, map_3d, simulate
    from repro.core.spec import heat_3d, paper_stencil_1d, paper_stencil_2d
    from repro.fabric import FabricTopology, place, route

    if smoke:
        specs = [("1d", paper_stencil_1d(n=1200, rx=8), map_1d, 8),
                 ("2d", paper_stencil_2d(ny=30, nx=48, r=12), map_2d, 8),
                 ("3d", heat_3d(10, 12, 16, dtype="float64"), map_3d, 8)]
    else:
        specs = [("1d", paper_stencil_1d(n=9720, rx=8), map_1d, 8),
                 ("2d", paper_stencil_2d(ny=64, nx=128, r=12), map_2d, 8),
                 ("3d", heat_3d(16, 24, 32, dtype="float64"), map_3d, 8)]

    rng = np.random.default_rng(0)
    topo = FabricTopology.mesh(16, 16)
    cases = {}
    for name, spec, mapper, w in specs:
        x = rng.normal(size=spec.grid_shape)
        plan_ideal = mapper(spec, workers=w)
        plan = mapper(spec, workers=w)
        rf = route(place(plan, topo, seed=0))
        t0 = time.perf_counter()
        ideal = simulate(plan_ideal, x, CGRA)
        routed = simulate(plan, x, CGRA, fabric=rf)
        wall_s = time.perf_counter() - t0      # the two simulate() calls only
        assert np.array_equal(ideal.output, routed.output)
        s = rf.stats()
        cases[name] = {
            "grid": list(spec.grid_shape), "radii": list(spec.radii),
            "workers": w, "pe_instructions": len(plan.dfg.nodes),
            "cycles_ideal": ideal.cycles, "cycles_routed": routed.cycles,
            "inflation": round(routed.cycles / ideal.cycles, 4),
            "gflops_ideal": round(ideal.gflops, 3),
            "gflops_routed": round(routed.gflops, 3),
            "pct_of_roofline_ideal": round(ideal.pct_of_roofline, 4),
            "pct_of_roofline_routed": round(routed.pct_of_roofline, 4),
            "hops_mean": s["hops_mean"], "hops_max": s["hops_max"],
            "weighted_hops": s["weighted_hops"],
            "max_channel_load": s["max_channel_load"],
            "pe_utilization": s["pe_utilization"],
            "token_hops": routed.fabric["token_hops"],
            "stall_cycles": routed.fabric["stall_cycles"],
            "sim_wall_s": round(wall_s, 3),
        }
    return cases


def program_artifact_cases(smoke: bool) -> dict:
    """Program pipelines: fused multi-op DAG (ideal + routed on the 16x16
    mesh) vs the same ops run as separate store-to-memory sweeps."""
    import numpy as np

    from repro.core import CGRA
    from repro.fabric import FabricTopology, place, route
    from repro.program import (StencilProgram, hdiff_program, lower,
                               simulate_program, two_stage_heat)

    if smoke:
        progs = [("heat2_pipeline", two_stage_heat(24, 32), 4),
                 ("hdiff", hdiff_program(24, 32), 4)]
    else:
        progs = [("heat2_pipeline", two_stage_heat(48, 64), 8),
                 ("hdiff", hdiff_program(48, 64), 8)]

    rng = np.random.default_rng(0)
    topo = FabricTopology.mesh(16, 16)
    cases = {}
    for name, prog, w in progs:
        inputs = {f: rng.normal(size=prog.grid_shape)
                  for f in prog.in_fields}
        ideal, _ = simulate_program(lower(prog, workers=w), inputs, CGRA)
        plan = lower(prog, workers=w)
        rf = route(place(plan, topo, seed=0))
        t0 = time.perf_counter()
        routed, _ = simulate_program(plan, inputs, CGRA, fabric=rf)
        wall_s = time.perf_counter() - t0
        assert np.array_equal(ideal.output, routed.output)
        # separate sweeps: every op as its own single-op program (each one a
        # full read-from/store-to-memory pass), ideal + routed cycles summed
        sep_ideal = sep_routed = 0
        for op in prog.schedule():
            solo = StencilProgram(f"solo_{op.name}", [op],
                                  grid_shape=prog.grid_shape,
                                  dtype=prog.dtype)
            ins = {f: rng.normal(size=prog.grid_shape)
                   for f in solo.in_fields}
            pl = lower(solo, workers=w)
            sep_ideal += simulate_program(pl, ins, CGRA)[0].cycles
            pl = lower(solo, workers=w)
            rfo = route(place(pl, topo, seed=0))
            sep_routed += simulate_program(pl, ins, CGRA,
                                           fabric=rfo)[0].cycles
        assert ideal.cycles < sep_ideal and routed.cycles < sep_routed
        s = rf.stats()
        cases[name] = {
            "grid": list(prog.grid_shape), "workers": w,
            "ops": [op.name for op in prog.schedule()],
            "pe_instructions": len(plan.dfg.nodes),
            "cycles_fused_ideal": ideal.cycles,
            "cycles_fused_routed": routed.cycles,
            "cycles_separate_ideal": sep_ideal,
            "cycles_separate_routed": sep_routed,
            "fusion_speedup_ideal": round(sep_ideal / ideal.cycles, 4),
            "fusion_speedup_routed": round(sep_routed / routed.cycles, 4),
            "gflops_fused_ideal": round(ideal.gflops, 3),
            "gflops_fused_routed": round(routed.gflops, 3),
            "hops_mean": s["hops_mean"], "hops_max": s["hops_max"],
            "max_channel_load": s["max_channel_load"],
            "pe_utilization": s["pe_utilization"],
            "token_hops": routed.fabric["token_hops"],
            "stall_cycles": routed.fabric["stall_cycles"],
            "sim_wall_s": round(wall_s, 3),
        }
    return cases


def write_artifact(path: str, smoke: bool) -> None:
    art = {
        "schema": "bench_pr2/v1",
        "config": "smoke" if smoke else "full",
        "fabric": "mesh16x16",
        "cases": artifact_cases(smoke),
    }
    with open(path, "w") as f:
        json.dump(art, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}", file=sys.stderr)


def write_program_artifact(path: str, smoke: bool) -> None:
    art = {
        "schema": "bench_pr3/v1",
        "config": "smoke" if smoke else "full",
        "fabric": "mesh16x16",
        "cases": program_artifact_cases(smoke),
    }
    with open(path, "w") as f:
        json.dump(art, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}", file=sys.stderr)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifact", metavar="PATH",
                    help="write the JSON perf snapshot to PATH")
    ap.add_argument("--program-artifact", metavar="PATH",
                    help="write the program-pipeline snapshot to PATH")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grids (fast CI configuration)")
    ap.add_argument("--artifact-only", action="store_true",
                    help="skip the CSV benchmark modules (needs --artifact)")
    args = ap.parse_args(argv)
    if args.artifact_only and not (args.artifact or args.program_artifact):
        ap.error("--artifact-only requires --artifact/--program-artifact")

    failed = 0
    if not args.artifact_only:
        from benchmarks import (ai_table, fabric_bench, fig12_roofline,
                                fusion_crossover, kernel_roofline, table1,
                                vii_gpu_efficiency)
        modules = [ai_table, fig12_roofline, table1, kernel_roofline,
                   fusion_crossover, vii_gpu_efficiency, fabric_bench]
        print("name,us_per_call,derived")
        for mod in modules:
            try:
                for name, us, derived in mod.run():
                    print(f"{name},{us:.1f},{derived}")
                    sys.stdout.flush()
            except Exception as e:
                failed += 1
                print(f"{mod.__name__},0,ERROR:{type(e).__name__}:{e}")
                traceback.print_exc(file=sys.stderr)

    if args.artifact:
        try:
            write_artifact(args.artifact, args.smoke)
        except Exception:
            failed += 1
            traceback.print_exc(file=sys.stderr)
    if args.program_artifact:
        try:
            write_program_artifact(args.program_artifact, args.smoke)
        except Exception:
            failed += 1
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
