"""Benchmark harness — one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV (assignment deliverable (d)).
  table1            — §VIII Table I  (CGRA sim vs V100 roofline)
  ai_table          — §VI arithmetic (AI, w*, demands)
  fig12_roofline    — §VI Fig. 12    (roofline curves, CGRA + TPU port)
  kernel_roofline   — TPU kernel rooflines (paper method, v5e constants)
  fusion_crossover  — §IV temporal fusion (beyond paper)
  vii_gpu_efficiency — §VII efficiency-vs-AI trend (incl. 3D stencils)
  fabric_bench      — place-and-route + network-aware sim on the 16x16 mesh

``--artifact PATH`` additionally writes a JSON perf snapshot (cycles, GFLOPS,
roofline %, fabric hop/stall stats for the 1D/2D/3D mappings) so the perf
trajectory accumulates across PRs; ``--program-artifact PATH`` writes the
program-pipeline snapshot (BENCH_pr3.json: fused multi-op DAGs vs separate
store-to-memory sweeps); ``--engine-artifact PATH`` writes the simulation-
engine comparison snapshot (BENCH_pr4.json: interpreter vs compiled vector
engine wall times + speedups, with a large vector-only case the interpreter
could not afford); ``--explore [PATH]`` runs the mapping auto-tuner
(``repro.explore``) on heat2d/star_3d/hdiff and writes the Pareto-front
snapshot (BENCH_pr5.json: measured fronts over cycles/PEs/channel-load vs
the analytical §VI baseline, evaluations cached in ``<PATH>.cache``);
``--trace PATH`` runs one routed case with a telemetry sink attached and
writes a Perfetto trace_event JSON (see ``docs/telemetry.md``);
``--smoke`` shrinks the grids so CI can afford it.

A case that fails inside an artifact no longer aborts the refresh: the
remaining cases still run, the partial artifact is written with an
``errors`` map, and the process exits nonzero.

``--engine {interp,vector,both,jax,all}`` selects the simulation backend
for the pr2/pr3/pr4 artifact cases — ``both`` times interp + vector,
asserts identical cycles/fires/outputs (CI's engine-drift gate) and
records per-engine wall times; ``jax`` additionally cross-checks the jax
engine's ideal-mode run (it cannot route) and records its wall; ``all`` =
``both`` + the jax cross-check.  ``--case NAME`` restricts every artifact
to one named case.

``--sweep-artifact PATH`` writes the batched-sweep snapshot
(BENCH_pr9.json): the auto-tuner's stage-1 ideal sweep on heat2d run
twice — sequential vector engine vs the batched jax engine
(``Budget.batch_size``) — with identical per-config cycles asserted and
the ≥3x throughput gate enforced at refresh time.

``--stress-artifact PATH`` writes the static-verifier prune snapshot
(BENCH_pr10.json): capacity-stressed heat2d/hdiff tuner sweeps run with
and without ``static_verify`` — identical survivors and ``static_pruned
== deadlock_sims_avoided`` asserted at refresh time.

ci.sh runs ``--artifact BENCH_pr2.json --program-artifact BENCH_pr3.json
--engine-artifact BENCH_pr4.json --explore BENCH_pr5.json
--sweep-artifact BENCH_pr9.json --stress-artifact BENCH_pr10.json
--engine all --smoke --artifact-only``.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
import traceback

if __package__ in (None, ""):      # script mode: `python benchmarks/run.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))


def _sim_pair(mk_plan, x, engine, topo):
    """Time one engine on a fresh plan: ideal + routed (the two simulate()
    calls only).  Returns ``(ideal, routed, routed_fabric, wall_ideal_s,
    wall_routed_s, plan)`` — the plan is handed back so callers can report
    its inventory without rebuilding it."""
    import numpy as np

    from repro.core import CGRA, simulate
    from repro.fabric import place, route

    plan_ideal = mk_plan()
    plan_routed = mk_plan()
    rf = route(place(plan_routed, topo, seed=0))
    t0 = time.perf_counter()
    ideal = simulate(plan_ideal, x, CGRA, engine=engine)
    t1 = time.perf_counter()
    routed = simulate(plan_routed, x, CGRA, fabric=rf, engine=engine)
    wall_routed = time.perf_counter() - t1
    wall_ideal = t1 - t0
    assert np.array_equal(ideal.output, routed.output)
    return ideal, routed, rf, wall_ideal, wall_routed, plan_ideal


def _assert_engines_agree(name, interp_pair, vector_pair):
    """CI gate: any drift between the backends fails the artifact refresh."""
    for tag, a, b in (("ideal", interp_pair[0], vector_pair[0]),
                      ("routed", interp_pair[1], vector_pair[1])):
        if (a.cycles != b.cycles or a.fires != b.fires
                or a.loads != b.loads or a.stores != b.stores
                or a.flops != b.flops
                or a.output.tobytes() != b.output.tobytes()):
            raise AssertionError(
                f"engine drift on {name}/{tag}: interp cycles={a.cycles} "
                f"vector cycles={b.cycles} (fires/outputs must be identical)")
    ra, rb = interp_pair[1], vector_pair[1]
    if (ra.fabric["token_hops"] != rb.fabric["token_hops"]
            or ra.fabric["stall_cycles"] != rb.fabric["stall_cycles"]):
        raise AssertionError(
            f"engine drift on {name}/network: "
            f"hops {ra.fabric['token_hops']}/{rb.fabric['token_hops']} "
            f"stalls {ra.fabric['stall_cycles']}/{rb.fabric['stall_cycles']}")


def artifact_cases(smoke: bool, engine: str = "interp",
                   case: str | None = None) -> dict:
    """One entry per rank: ideal + routed simulation on the 16x16 mesh."""
    import numpy as np

    from repro.core import map_1d, map_2d, map_3d
    from repro.core.spec import heat_3d, paper_stencil_1d, paper_stencil_2d
    from repro.fabric import FabricTopology

    if smoke:
        specs = [("1d", paper_stencil_1d(n=1200, rx=8), map_1d, 8),
                 ("2d", paper_stencil_2d(ny=30, nx=48, r=12), map_2d, 8),
                 ("3d", heat_3d(10, 12, 16, dtype="float64"), map_3d, 8)]
    else:
        specs = [("1d", paper_stencil_1d(n=9720, rx=8), map_1d, 8),
                 ("2d", paper_stencil_2d(ny=64, nx=128, r=12), map_2d, 8),
                 ("3d", heat_3d(16, 24, 32, dtype="float64"), map_3d, 8)]

    topo = FabricTopology.mesh(16, 16)
    base = "vector" if engine in ("vector", "jax") else "interp"
    cases = {}
    errors = {}
    for name, spec, mapper, w in specs:
        if case and name != case:
            continue
        try:
            _artifact_case(cases, name, spec, mapper, w, topo, base, engine)
        except Exception as e:                  # isolate: finish the rest
            errors[name] = f"{type(e).__name__}: {e}"
            traceback.print_exc(file=sys.stderr)
    return cases, errors


def _artifact_case(cases, name, spec, mapper, w, topo, base, engine):
    import numpy as np

    x = np.random.default_rng(0).normal(size=spec.grid_shape)
    mk = lambda: mapper(spec, workers=w)                # noqa: E731
    ideal, routed, rf, wi, wr, plan = _sim_pair(mk, x, base, topo)
    wall_s = wi + wr
    s = rf.stats()
    cases[name] = {
        "grid": list(spec.grid_shape), "radii": list(spec.radii),
        "workers": w, "pe_instructions": len(plan.dfg.nodes),
        "cycles_ideal": ideal.cycles, "cycles_routed": routed.cycles,
        "inflation": round(routed.cycles / ideal.cycles, 4),
        "gflops_ideal": round(ideal.gflops, 3),
        "gflops_routed": round(routed.gflops, 3),
        "pct_of_roofline_ideal": round(ideal.pct_of_roofline, 4),
        "pct_of_roofline_routed": round(routed.pct_of_roofline, 4),
        "hops_mean": s["hops_mean"], "hops_max": s["hops_max"],
        "weighted_hops": s["weighted_hops"],
        "max_channel_load": s["max_channel_load"],
        "pe_utilization": s["pe_utilization"],
        "token_hops": routed.fabric["token_hops"],
        "stall_cycles": routed.fabric["stall_cycles"],
        "sim_wall_s": round(wall_s, 3),
    }
    if engine in ("both", "all"):
        vi, vr, _, vwi, vwr, _ = _sim_pair(mk, x, "vector", topo)
        _assert_engines_agree(name, (ideal, routed), (vi, vr))
        cases[name]["sim_wall_s_vector"] = round(vwi + vwr, 3)
        cases[name]["vector_speedup"] = round(wall_s / (vwi + vwr), 2)
    if engine in ("jax", "all"):
        # jax parity gate: ideal-mode only (the jax engine cannot route);
        # cycles/fires/outputs must be bit-identical to the base engine
        from repro.core import CGRA, simulate
        plan_j = mk()
        t0 = time.perf_counter()
        jres = simulate(plan_j, x, CGRA, engine="jax")
        wall_j = time.perf_counter() - t0
        if (jres.cycles != ideal.cycles or jres.fires != ideal.fires
                or jres.output.tobytes() != ideal.output.tobytes()):
            raise AssertionError(
                f"engine drift on {name}/ideal: jax cycles={jres.cycles} "
                f"{base} cycles={ideal.cycles} (must be identical)")
        cases[name]["sim_wall_s_jax_ideal"] = round(wall_j, 3)
    # attribution fields (PR 8): one extra routed run with a counter-only
    # telemetry sink, after the timed runs so the walls stay uninstrumented
    from repro.core import CGRA, simulate
    from repro.fabric import place, route
    from repro.telemetry import Telemetry, attribute
    plan_a = mk()
    rfa = route(place(plan_a, topo, seed=0))
    mtel = Telemetry(timeline=False)
    res_a = simulate(plan_a, x, CGRA, fabric=rfa, engine="vector",
                     telemetry=mtel)
    acct = attribute(mtel, res_a)
    cases[name]["stall_breakdown"] = dict(acct.causes)
    cases[name]["phases"] = dict(acct.phases)
    cases[name]["bottleneck"] = acct.bottleneck


def program_artifact_cases(smoke: bool, engine: str = "interp",
                           case: str | None = None) -> dict:
    """Program pipelines: fused multi-op DAG (ideal + routed on the 16x16
    mesh) vs the same ops run as separate store-to-memory sweeps."""
    import numpy as np

    from repro.core import CGRA
    from repro.fabric import FabricTopology, place, route
    from repro.program import (StencilProgram, hdiff_program, lower,
                               simulate_program, two_stage_heat)

    if smoke:
        progs = [("heat2_pipeline", two_stage_heat(24, 32), 4),
                 ("hdiff", hdiff_program(24, 32), 4)]
    else:
        progs = [("heat2_pipeline", two_stage_heat(48, 64), 8),
                 ("hdiff", hdiff_program(48, 64), 8)]

    topo = FabricTopology.mesh(16, 16)
    base = "vector" if engine in ("vector", "jax") else "interp"
    cases = {}
    errors = {}

    def one(name, prog, w):
        rng = np.random.default_rng(0)
        inputs = {f: rng.normal(size=prog.grid_shape)
                  for f in prog.in_fields}
        mk = lambda: lower(prog, workers=w)             # noqa: E731
        plan = mk()
        x = plan.pack_inputs(inputs)
        rf = route(place(plan, topo, seed=0))
        ideal = simulate_program(mk(), inputs, CGRA, engine=base)[0]
        t0 = time.perf_counter()
        routed, _ = simulate_program(plan, inputs, CGRA, fabric=rf,
                                     engine=base)
        wall_s = time.perf_counter() - t0
        assert np.array_equal(ideal.output, routed.output)
        # separate sweeps: every op as its own single-op program (each one a
        # full read-from/store-to-memory pass), ideal + routed cycles summed
        sep_ideal = sep_routed = 0
        for op in prog.schedule():
            solo = StencilProgram(f"solo_{op.name}", [op],
                                  grid_shape=prog.grid_shape,
                                  dtype=prog.dtype)
            ins = {f: rng.normal(size=prog.grid_shape)
                   for f in solo.in_fields}
            pl = lower(solo, workers=w)
            sep_ideal += simulate_program(pl, ins, CGRA, engine=base)[0].cycles
            pl = lower(solo, workers=w)
            rfo = route(place(pl, topo, seed=0))
            sep_routed += simulate_program(pl, ins, CGRA, fabric=rfo,
                                           engine=base)[0].cycles
        assert ideal.cycles < sep_ideal and routed.cycles < sep_routed
        s = rf.stats()
        cases[name] = {
            "grid": list(prog.grid_shape), "workers": w,
            "ops": [op.name for op in prog.schedule()],
            "pe_instructions": len(plan.dfg.nodes),
            "cycles_fused_ideal": ideal.cycles,
            "cycles_fused_routed": routed.cycles,
            "cycles_separate_ideal": sep_ideal,
            "cycles_separate_routed": sep_routed,
            "fusion_speedup_ideal": round(sep_ideal / ideal.cycles, 4),
            "fusion_speedup_routed": round(sep_routed / routed.cycles, 4),
            "gflops_fused_ideal": round(ideal.gflops, 3),
            "gflops_fused_routed": round(routed.gflops, 3),
            "hops_mean": s["hops_mean"], "hops_max": s["hops_max"],
            "max_channel_load": s["max_channel_load"],
            "pe_utilization": s["pe_utilization"],
            "token_hops": routed.fabric["token_hops"],
            "stall_cycles": routed.fabric["stall_cycles"],
            "sim_wall_s": round(wall_s, 3),
        }
        if engine in ("both", "all"):
            vi, vr, _, _, vwr, _ = _sim_pair(mk, x, "vector", topo)
            _assert_engines_agree(name, (ideal, routed), (vi, vr))
            # comparable number: the routed sim alone, like sim_wall_s
            cases[name]["sim_wall_s_vector"] = round(vwr, 3)
            cases[name]["vector_speedup"] = round(wall_s / vwr, 2)
        if engine in ("jax", "all"):
            # jax parity gate on the program pipeline (ideal-mode only)
            from repro.core import simulate
            t0 = time.perf_counter()
            jres = simulate(mk(), x, CGRA, engine="jax")
            wall_j = time.perf_counter() - t0
            if (jres.cycles != ideal.cycles or jres.fires != ideal.fires
                    or jres.output.tobytes() != ideal.output.tobytes()):
                raise AssertionError(
                    f"engine drift on {name}/ideal: jax "
                    f"cycles={jres.cycles} {base} cycles={ideal.cycles}")
            cases[name]["sim_wall_s_jax_ideal"] = round(wall_j, 3)

    for name, prog, w in progs:
        if case and name != case:
            continue
        try:
            one(name, prog, w)
        except Exception as e:                  # isolate: finish the rest
            errors[name] = f"{type(e).__name__}: {e}"
            traceback.print_exc(file=sys.stderr)
    return cases, errors


def engine_artifact_cases(smoke: bool, case: str | None = None,
                          engine: str = "interp") -> dict:
    """BENCH_pr4: interpreter vs compiled vector engine, wall-clock and
    speedup on the pr2 single-op cases and the pr3 program pipelines (at
    their full 48x64/w8 size in every config — that is the paper-scale
    claim), plus one large program case only the vector engine runs.

    With ``--engine jax``/``all`` every case additionally runs the jax
    engine in ideal mode (bit-identical cycles/output asserted) and
    records ``jax_wall_s`` + ``jax_speedup`` (vector ideal wall / jax
    ideal wall) next to the interp/vector walls.  A single unbatched plan
    is *not* where the jax engine wins — that is the batched sweep
    (BENCH_pr9) — so these walls are recorded, not gated."""
    import numpy as np

    from repro.core import map_1d, map_2d, map_3d
    from repro.core.spec import heat_3d, paper_stencil_1d, paper_stencil_2d
    from repro.fabric import FabricTopology
    from repro.program import hdiff_program, lower, two_stage_heat

    topo = FabricTopology.mesh(16, 16)
    if smoke:
        singles = [("1d", paper_stencil_1d(n=1200, rx=8), map_1d, 8),
                   ("2d", paper_stencil_2d(ny=30, nx=48, r=12), map_2d, 8),
                   ("3d", heat_3d(10, 12, 16, dtype="float64"), map_3d, 8)]
    else:
        singles = [("1d", paper_stencil_1d(n=9720, rx=8), map_1d, 8),
                   ("2d", paper_stencil_2d(ny=64, nx=128, r=12), map_2d, 8),
                   ("3d", heat_3d(16, 24, 32, dtype="float64"), map_3d, 8)]
    progs = [("heat2_pipeline", two_stage_heat(48, 64), 8),
             ("hdiff", hdiff_program(48, 64), 8)]
    large_grid = (96, 128) if smoke else (256, 512)

    cases = {}
    errors = {}

    def record(name, kind, grid, w, mk, mk_x):
        if case and name != case:
            return
        try:
            _record(name, kind, grid, w, mk, mk_x)
        except Exception as e:                  # isolate: finish the rest
            errors[name] = f"{type(e).__name__}: {e}"
            traceback.print_exc(file=sys.stderr)

    def _record(name, kind, grid, w, mk, mk_x):
        plan0 = mk()
        x = mk_x(plan0)
        vi, vr, rf, vwi, vwr, _ = _sim_pair(mk, x, "vector", topo)
        wall_v = vwi + vwr
        entry = {
            "kind": kind, "grid": list(grid), "workers": w,
            "pe_instructions": len(plan0.dfg.nodes),
            "cycles_ideal": vi.cycles, "cycles_routed": vr.cycles,
            "token_hops": vr.fabric["token_hops"],
            "stall_cycles": vr.fabric["stall_cycles"],
            "vector_wall_s": round(wall_v, 3),
        }
        if engine in ("jax", "all"):
            from repro.core import CGRA, simulate
            t0 = time.perf_counter()
            jres = simulate(mk(), x, CGRA, engine="jax")
            wall_j = time.perf_counter() - t0
            if (jres.cycles != vi.cycles
                    or jres.output.tobytes() != vi.output.tobytes()):
                raise AssertionError(
                    f"engine drift on {name}/ideal: jax "
                    f"cycles={jres.cycles} vector cycles={vi.cycles}")
            entry["jax_wall_s"] = round(wall_j, 3)
            entry["jax_speedup"] = round(vwi / wall_j, 2)
        if kind == "large-vector-only":
            # the whole point of the compiled engine: this grid is out of
            # the interpreter's reach (≈25x the vector wall).
            entry["interp_wall_s"] = None
            entry["speedup"] = None
            entry["engines"] = ["vector"]
        else:
            ii, ir, _, iwi, iwr, _ = _sim_pair(mk, x, "interp", topo)
            wall_i = iwi + iwr
            _assert_engines_agree(name, (ii, ir), (vi, vr))
            entry["interp_wall_s"] = round(wall_i, 3)
            entry["speedup"] = round(wall_i / wall_v, 2)
            entry["engines"] = ["interp", "vector"]
        if "jax_wall_s" in entry:
            entry["engines"] = entry["engines"] + ["jax"]
        cases[name] = entry

    def prog_x(pl):
        ins = {f: np.random.default_rng(0).normal(size=pl.spec.grid_shape)
               for f in pl.in_fields}
        return pl.pack_inputs(ins)

    for name, spec, mapper, w in singles:
        record(name, "single-op", spec.grid_shape, w,
               lambda: mapper(spec, workers=w),
               lambda pl: np.random.default_rng(0).normal(
                   size=spec.grid_shape))
    for name, prog, w in progs:
        record(name, "program", prog.grid_shape, w,
               lambda: lower(prog, workers=w), prog_x)
    prog = two_stage_heat(*large_grid)
    record("large_heat2_pipeline", "large-vector-only", large_grid, 8,
           lambda: lower(prog, workers=8), prog_x)
    return cases, errors


def explore_artifact_cases(smoke: bool, case: str | None = None,
                           cache_path: str | None = None) -> dict:
    """BENCH_pr5: the mapping auto-tuner (repro.explore) vs the paper's
    analytical §VI worker choice, on heat2d, star_3d and the hdiff program
    pipeline.  Every front is verified internally non-dominated and the
    measured best must match or beat the analytical baseline's cycles."""
    from repro.core import CGRA
    from repro.core.spec import heat_2d, star_3d
    from repro.explore import (Budget, EvalCache, EvalPoint, SpaceOptions,
                               assert_non_dominated, explore, tile_candidates)
    from repro.program import hdiff_program

    mesh16 = (16, 16, "mesh")
    if smoke:
        heat = heat_2d(24, 48, dtype="float64")
        star = star_3d(10, 12, 16)
        hdiff = hdiff_program(24, 32)
        hdiff_workers = (2, 4, 8)
    else:
        heat = heat_2d(48, 96, dtype="float64")
        star = star_3d(16, 24, 32)
        hdiff = hdiff_program(48, 64)
        hdiff_workers = (2, 4, 8, 16)

    targets = {
        "heat2d": dict(
            target=heat, workload_timesteps=2,
            options=SpaceOptions(
                temporal=(1, 2), capacities=("auto", "unbounded"),
                tiles=(None,) + tuple(
                    t for t in tile_candidates(heat, (2048, 8192))
                    if t is not None),
                fabrics=(mesh16,), place_seeds=(0, 1))),
        "star_3d": dict(
            target=star, workload_timesteps=1,
            options=SpaceOptions(
                workers=(1, 2, 4, 8), capacities=("auto",),
                fabrics=(mesh16,), place_seeds=(0,))),
        "hdiff": dict(
            target=hdiff, workload_timesteps=1,
            options=SpaceOptions(
                workers=hdiff_workers, capacities=("auto", "unbounded"),
                fabrics=(mesh16,), place_seeds=(0,))),
    }

    cases = {}
    errors = {}

    def one(name, cfg):
        cache = EvalCache(cache_path) if cache_path else None
        res = explore(cfg["target"], CGRA, options=cfg["options"],
                      budget=Budget(routed_finalists=4),
                      workload_timesteps=cfg["workload_timesteps"],
                      cache=cache, verify=True)
        # the artifact's two hard claims, enforced at refresh time:
        assert_non_dominated(res.front, key=EvalPoint.objectives)
        best, analytic = res.best(), res.analytic
        assert analytic is not None, f"{name}: analytical baseline unmeasured"
        assert best.cycles <= analytic.cycles, (
            f"{name}: tuner best {best.cycles} cycles worse than analytical "
            f"{analytic.cycles}")
        cs = res.stats["cache"]
        print(f"explore[{name}]: cache hits={cs['hits']} "
              f"misses={cs['misses']} "
              f"failures_replayed={cs['failures_replayed']} "
              f"entries={cs['entries']}", file=sys.stderr)
        # the "why": attribution labels on the measured best vs the baseline
        print(f"explore[{name}]: best {best.cycles} cycles "
              f"[{best.bottleneck or 'unlabelled'}] vs analytic "
              f"{analytic.cycles} [{analytic.bottleneck or 'unlabelled'}]",
              file=sys.stderr)
        cases[name] = {
            **{k: v for k, v in res.to_json().items() if k != "failures"},
            "n_failures": len(res.failures),
            "margin_pct": round(
                100.0 * (analytic.cycles - best.cycles) / analytic.cycles, 2),
        }

    for name, cfg in targets.items():
        if case and name != case:
            continue
        try:
            one(name, cfg)
        except Exception as e:                  # isolate: finish the rest
            errors[name] = f"{type(e).__name__}: {e}"
            traceback.print_exc(file=sys.stderr)
    return cases, errors


def sweep_artifact_cases(smoke: bool, case: str | None = None) -> dict:
    """BENCH_pr9: batched-jax stage-1 tuner sweep throughput vs the
    sequential vector path (PR 9's headline).  The heat2d stage-1 ideal
    sweep runs twice through ``repro.explore`` — ``Budget(batch_size=...)``
    (jax engine, chunked one-device-call batches) vs the plain sequential
    vector loop — on fresh in-memory caches.  Per-config cycles must be
    identical, and the warm batched throughput (best of ``repeats``; the
    cold wall, which pays the jit compiles, is recorded separately) must
    beat the sequential throughput by >= 3x — the refresh *is* the gate."""
    from repro.core import CGRA
    from repro.core.spec import heat_2d
    from repro.explore import Budget, SpaceOptions, explore, tile_candidates

    heat = (heat_2d(24, 48, dtype="float64") if smoke
            else heat_2d(48, 96, dtype="float64"))
    opts = SpaceOptions(
        temporal=(1, 2), capacities=("auto", "unbounded"),
        tiles=(None,) + tuple(t for t in tile_candidates(heat, (2048, 8192))
                              if t is not None),
        fabrics=())                        # stage 1 only: the ideal sweep
    batch, repeats = 32, 2
    cases = {}
    errors = {}

    def sweep(batch_size):
        t0 = time.perf_counter()
        res = explore(heat, CGRA, options=opts,
                      budget=Budget(batch_size=batch_size),
                      workload_timesteps=2, engine="vector")
        return time.perf_counter() - t0, res

    def one(name):
        walls_v = []
        walls_j = []
        for r in range(repeats):
            wv, res_v = sweep(None)
            wj, res_j = sweep(batch)
            walls_v.append(wv)
            walls_j.append(wj)
            cyc_v = sorted(p.sim_cycles for p in res_v.ideal_points)
            cyc_j = sorted(p.sim_cycles for p in res_j.ideal_points)
            if cyc_v != cyc_j:
                raise AssertionError(
                    f"{name}: batched-jax per-config cycles diverge from "
                    f"sequential vector ({cyc_j} vs {cyc_v})")
        n = len(res_v.ideal_points)
        wall_v, wall_j = min(walls_v), min(walls_j)
        speedup = (n / wall_j) / (n / wall_v)
        if speedup < 3.0:
            raise AssertionError(
                f"{name}: batched stage-1 throughput speedup {speedup:.2f}x "
                f"< 3x gate (jax {n / wall_j:.0f} cfg/s vs vector "
                f"{n / wall_v:.0f} cfg/s)")
        cases[name] = {
            "grid": list(heat.grid_shape), "batch_size": batch,
            "n_configs": n,
            "cycles_total": sum(p.sim_cycles for p in res_v.ideal_points),
            "vector_wall_s": round(wall_v, 3),
            "jax_wall_s": round(wall_j, 3),
            "jax_cold_wall_s": round(walls_j[0], 3),
            "vector_configs_per_sec": round(n / wall_v, 1),
            "jax_configs_per_sec": round(n / wall_j, 1),
            "speedup": round(speedup, 2),
        }

    for name in ("heat2d_stage1_sweep",):
        if case and name != case:
            continue
        try:
            one(name)
        except Exception as e:                  # isolate: finish the rest
            errors[name] = f"{type(e).__name__}: {e}"
            traceback.print_exc(file=sys.stderr)
    return cases, errors


def stress_artifact_cases(smoke: bool, case: str | None = None) -> dict:
    """BENCH_pr10: deadlock simulations avoided by the static verifier
    (PR 10's headline).  Each case is a capacity-stressed tuner sweep —
    a config lattice deliberately including under-provisioned fixed queue
    capacities — run twice through ``repro.explore`` on fresh in-memory
    caches: ``static_verify=True`` (doomed configs pruned by the verifier,
    no engine cycles burnt) vs ``static_verify=False`` (every doomed config
    simulated until the engine proves the deadlock the expensive way).
    Survivors and their cycles must be identical — the gate only ever skips
    work, never changes results — and the on-run must avoid >= 1 doomed
    simulation, asserted at refresh time.  ``wall_saved_s`` is the wall the
    off-run spent discovering deadlocks dynamically minus the on-run's
    verifier cost (volatile; the counts are the trend-gated part)."""
    from repro.core import CGRA
    from repro.core.spec import heat_2d
    from repro.explore import Budget, EvalCache, SpaceOptions, explore
    from repro.program import hdiff_program

    if smoke:
        targets = {
            "heat2d_capacity_stress": dict(
                target=heat_2d(16, 24, dtype="float64"),
                options=SpaceOptions(workers=(2, 3),
                                     capacities=(1, 2, "auto"), fabrics=())),
            "hdiff_capacity_stress": dict(
                target=hdiff_program(20, 24),
                options=SpaceOptions(workers=(4,), capacities=(2, "auto"),
                                     fabrics=())),
        }
    else:
        targets = {
            "heat2d_capacity_stress": dict(
                target=heat_2d(32, 48, dtype="float64"),
                options=SpaceOptions(workers=(2, 3, 4),
                                     capacities=(1, 2, 3, "auto"),
                                     fabrics=())),
            "hdiff_capacity_stress": dict(
                target=hdiff_program(32, 48),
                options=SpaceOptions(workers=(4, 8),
                                     capacities=(2, "auto"), fabrics=())),
        }

    cases = {}
    errors = {}

    def sweep(cfg, static):
        t0 = time.perf_counter()
        res = explore(cfg["target"], CGRA, options=cfg["options"],
                      budget=Budget(), cache=EvalCache(),
                      static_verify=static)
        return time.perf_counter() - t0, res

    def one(name, cfg):
        wall_on, res_on = sweep(cfg, True)
        wall_off, res_off = sweep(cfg, False)
        surv_on = sorted((str(p.config.canonical()), p.cycles)
                         for p in res_on.points)
        surv_off = sorted((str(p.config.canonical()), p.cycles)
                          for p in res_off.points)
        if surv_on != surv_off:
            raise AssertionError(
                f"{name}: static gate changed the survivors "
                f"({surv_on} vs {surv_off})")
        pruned = res_on.stats["static_pruned"]
        # every statically-pruned config shows up in the off-run as an
        # engine-discovered deadlock: those are the simulations avoided
        avoided = sum(1 for f in res_off.failures
                      if f["reason"].startswith(("deadlock", "timeout")))
        if pruned < 1 or pruned != avoided:
            raise AssertionError(
                f"{name}: static gate pruned {pruned} config(s) but the "
                f"ungated run hit {avoided} engine deadlock(s) — the "
                f"verifier must reject exactly the doomed configs")
        best = min((p.cycles for p in res_on.points), default=0)
        cases[name] = {
            "grid": list(cfg["target"].grid_shape),
            "n_configs": res_on.stats["n_configs"],
            "static_pruned": pruned,
            "deadlock_sims_avoided": avoided,
            "survivors": len(res_on.points),
            "best_cycles": best,
            "wall_on_s": round(wall_on, 3),
            "wall_off_s": round(wall_off, 3),
            "wall_saved_s": round(wall_off - wall_on, 3),
        }

    for name, cfg in targets.items():
        if case and name != case:
            continue
        try:
            one(name, cfg)
        except Exception as e:                  # isolate: finish the rest
            errors[name] = f"{type(e).__name__}: {e}"
            traceback.print_exc(file=sys.stderr)
    return cases, errors


def _write_snapshot(path: str, schema: str, smoke: bool, case: str | None,
                    produced: tuple[dict, dict], **extra) -> None:
    """Shared artifact writer.  A ``--case`` filter that matches nothing in
    this artifact leaves the file untouched (the artifacts' case namespaces
    are disjoint, so a multi-artifact run with one --case is expected to
    skip the others).  Failed cases don't lose the rest: the artifact is
    written with whatever succeeded (tagged ``errors``), then the failure
    is re-raised so the run still exits nonzero."""
    cases, errors = produced
    if not cases and not errors:
        if case:
            print(f"--case {case!r}: no {schema} case matches; "
                  f"{path} left untouched", file=sys.stderr)
            return
        raise ValueError(f"no cases produced for {schema}")
    art = {"schema": schema, "config": "smoke" if smoke else "full",
           "fabric": "mesh16x16", **extra, "cases": cases}
    if errors:
        art["errors"] = errors
    with open(path, "w") as f:
        json.dump(art, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}", file=sys.stderr)
    if errors:
        raise RuntimeError(
            f"{schema}: {len(errors)} case(s) failed: {sorted(errors)} "
            f"(partial artifact written)")


def write_artifact(path: str, smoke: bool, engine: str = "interp",
                   case: str | None = None) -> None:
    _write_snapshot(path, "bench_pr2/v1", smoke, case,
                    artifact_cases(smoke, engine, case), engine=engine)


def write_program_artifact(path: str, smoke: bool, engine: str = "interp",
                           case: str | None = None) -> None:
    _write_snapshot(path, "bench_pr3/v1", smoke, case,
                    program_artifact_cases(smoke, engine, case),
                    engine=engine)


def write_engine_artifact(path: str, smoke: bool, case: str | None = None,
                          engine: str = "interp") -> None:
    _write_snapshot(
        path, "bench_pr4/v1", smoke, case,
        engine_artifact_cases(smoke, case, engine),
        note=("interp vs compiled vector engine; program cases run at "
              "the pr3 full size (48x64, w8) in every config; the large "
              "case is vector-only; jax_wall_s/jax_speedup (ideal-mode "
              "jax cross-check) appear when refreshed with --engine "
              "jax/all"))


def write_explore_artifact(path: str, smoke: bool,
                           case: str | None = None) -> None:
    _write_snapshot(
        path, "bench_pr5/v1", smoke, case,
        explore_artifact_cases(smoke, case, cache_path=f"{path}.cache"),
        note=("mapping auto-tuner (repro.explore) Pareto fronts over "
              "(cycles, PEs, max channel load) vs the analytical §VI "
              "worker choice; fronts verified non-dominated and best <= "
              "analytical cycles at refresh time; evals cached in "
              "<artifact>.cache"))


def write_sweep_artifact(path: str, smoke: bool,
                         case: str | None = None) -> None:
    _write_snapshot(
        path, "bench_pr9/v1", smoke, case, sweep_artifact_cases(smoke, case),
        note=("batched-jax stage-1 tuner sweep (Budget.batch_size, one "
              "jitted+vmapped device call per chunk) vs the sequential "
              "vector path on the heat2d ideal sweep; identical per-config "
              "cycles and >=3x warm throughput asserted at refresh time; "
              "jax_cold_wall_s includes the jit compiles"))


def write_stress_artifact(path: str, smoke: bool,
                          case: str | None = None) -> None:
    _write_snapshot(
        path, "bench_pr10/v1", smoke, case,
        stress_artifact_cases(smoke, case),
        note=("static-verifier prune gate (repro.analysis.static_verify) "
              "on capacity-stressed tuner sweeps: static_verify=True vs "
              "False on fresh caches; identical survivors and "
              "static_pruned == engine-discovered deadlocks asserted at "
              "refresh time; wall_saved_s is volatile, the counts are "
              "trend-gated"))


def write_trace_artifact(path: str, smoke: bool,
                         case: str | None = None) -> None:
    """``--trace``: one routed telemetry-on run (the pr2 2d case unless
    ``--case`` picks another rank) exported as a validated Perfetto JSON
    trace, with the text report on stderr.  See docs/telemetry.md."""
    import numpy as np

    from repro.core import CGRA, map_1d, map_2d, map_3d, simulate
    from repro.core.spec import heat_3d, paper_stencil_1d, paper_stencil_2d
    from repro.fabric import FabricTopology, place, route
    from repro.telemetry import (Telemetry, render_report, validate_trace,
                                 write_trace)

    if smoke:
        specs = {"1d": (paper_stencil_1d(n=1200, rx=8), map_1d, 8),
                 "2d": (paper_stencil_2d(ny=30, nx=48, r=12), map_2d, 8),
                 "3d": (heat_3d(10, 12, 16, dtype="float64"), map_3d, 8)}
    else:
        specs = {"1d": (paper_stencil_1d(n=9720, rx=8), map_1d, 8),
                 "2d": (paper_stencil_2d(ny=64, nx=128, r=12), map_2d, 8),
                 "3d": (heat_3d(16, 24, 32, dtype="float64"), map_3d, 8)}
    name = case or "2d"
    if name not in specs:
        raise ValueError(f"--trace has no case {name!r}; "
                         f"choose one of {sorted(specs)}")
    spec, mapper, w = specs[name]
    plan = mapper(spec, workers=w)
    rf = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
    x = np.random.default_rng(0).normal(size=spec.grid_shape)
    tel = Telemetry()
    simulate(plan, x, CGRA, fabric=rf, engine="vector", telemetry=tel)
    obj = write_trace(tel, path)
    n = validate_trace(obj)
    print(render_report(tel), file=sys.stderr)
    print(f"wrote {path} ({n} trace events; open in ui.perfetto.dev)",
          file=sys.stderr)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifact", metavar="PATH",
                    help="write the JSON perf snapshot to PATH")
    ap.add_argument("--program-artifact", metavar="PATH",
                    help="write the program-pipeline snapshot to PATH")
    ap.add_argument("--engine-artifact", metavar="PATH",
                    help="write the interp-vs-vector engine snapshot to PATH")
    ap.add_argument("--explore", metavar="PATH", nargs="?",
                    const="BENCH_pr5.json", default=None,
                    help="run the mapping auto-tuner (repro.explore) on "
                    "heat2d/star_3d/hdiff and write the Pareto-front "
                    "snapshot (default PATH: BENCH_pr5.json)")
    ap.add_argument("--trace", metavar="PATH",
                    help="run one routed smoke case with telemetry and "
                    "write a Perfetto trace_event JSON to PATH "
                    "(open in ui.perfetto.dev)")
    ap.add_argument("--sweep-artifact", metavar="PATH",
                    help="write the batched-jax tuner-sweep throughput "
                    "snapshot (BENCH_pr9.json) to PATH")
    ap.add_argument("--stress-artifact", metavar="PATH",
                    help="write the static-verifier prune snapshot "
                    "(BENCH_pr10.json: deadlock sims avoided on "
                    "capacity-stressed sweeps) to PATH")
    ap.add_argument("--engine",
                    choices=("interp", "vector", "both", "jax", "all"),
                    default="interp",
                    help="simulation backend for the pr2/pr3/pr4 artifacts; "
                    "'both' cross-validates interp+vector and records "
                    "per-engine walls; 'jax' adds the ideal-mode jax "
                    "cross-check; 'all' = both + jax")
    ap.add_argument("--case", metavar="NAME",
                    help="restrict artifacts to one named case")
    ap.add_argument("--history", metavar="PATH",
                    help="append fingerprinted records for every artifact "
                    "written this run to this BENCH_history.jsonl "
                    "(ci.sh appends only after its trend gate passes)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grids (fast CI configuration)")
    ap.add_argument("--artifact-only", action="store_true",
                    help="skip the CSV benchmark modules (needs an artifact)")
    args = ap.parse_args(argv)
    any_artifact = (args.artifact or args.program_artifact
                    or args.engine_artifact or args.explore or args.trace
                    or args.sweep_artifact or args.stress_artifact)
    if args.artifact_only and not any_artifact:
        ap.error("--artifact-only requires --artifact/--program-artifact/"
                 "--engine-artifact")

    failed = 0
    if not args.artifact_only:
        from benchmarks import (ai_table, fabric_bench, fig12_roofline,
                                fusion_crossover, kernel_roofline, table1,
                                vii_gpu_efficiency)
        modules = [ai_table, fig12_roofline, table1, kernel_roofline,
                   fusion_crossover, vii_gpu_efficiency, fabric_bench]
        print("name,us_per_call,derived")
        for mod in modules:
            try:
                for name, us, derived in mod.run():
                    print(f"{name},{us:.1f},{derived}")
                    sys.stdout.flush()
            except Exception as e:
                failed += 1
                print(f"{mod.__name__},0,ERROR:{type(e).__name__}:{e}")
                traceback.print_exc(file=sys.stderr)

    written: list[str] = []
    for path, writer in ((args.artifact, write_artifact),
                         (args.program_artifact, write_program_artifact)):
        if path:
            try:
                writer(path, args.smoke, args.engine, args.case)
                written.append(path)
            except Exception:
                failed += 1
                traceback.print_exc(file=sys.stderr)
    if args.engine_artifact:
        try:
            write_engine_artifact(args.engine_artifact, args.smoke,
                                  args.case, args.engine)
            written.append(args.engine_artifact)
        except Exception:
            failed += 1
            traceback.print_exc(file=sys.stderr)
    if args.sweep_artifact:
        try:
            write_sweep_artifact(args.sweep_artifact, args.smoke, args.case)
            written.append(args.sweep_artifact)
        except Exception:
            failed += 1
            traceback.print_exc(file=sys.stderr)
    if args.stress_artifact:
        try:
            write_stress_artifact(args.stress_artifact, args.smoke, args.case)
            written.append(args.stress_artifact)
        except Exception:
            failed += 1
            traceback.print_exc(file=sys.stderr)
    if args.explore:
        try:
            write_explore_artifact(args.explore, args.smoke, args.case)
            written.append(args.explore)
        except Exception:
            failed += 1
            traceback.print_exc(file=sys.stderr)
    if args.trace:
        try:
            write_trace_artifact(args.trace, args.smoke, args.case)
        except Exception:
            failed += 1
            traceback.print_exc(file=sys.stderr)
    if args.history and written:
        # only complete artifacts enter the trajectory (partial refreshes
        # never reached `written`); ci.sh orders this after its trend gate
        from repro.telemetry.metrics import append_history, case_records
        n = 0
        for path in written:
            with open(path) as f:
                art = json.load(f)
            n += append_history(args.history, case_records(
                art, source=pathlib.Path(path).name))
        print(f"appended {n} record(s) to {args.history}", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
