"""Benchmark harness — one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV (assignment deliverable (d)).
  table1            — §VIII Table I  (CGRA sim vs V100 roofline)
  ai_table          — §VI arithmetic (AI, w*, demands)
  fig12_roofline    — §VI Fig. 12    (roofline curves, CGRA + TPU port)
  kernel_roofline   — TPU kernel rooflines (paper method, v5e constants)
  fusion_crossover  — §IV temporal fusion (beyond paper)
  vii_gpu_efficiency — §VII efficiency-vs-AI trend (incl. 3D stencils)
  fabric_bench      — place-and-route + network-aware sim on the 16x16 mesh
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import (ai_table, fabric_bench, fig12_roofline,
                        fusion_crossover, kernel_roofline, table1,
                        vii_gpu_efficiency)

MODULES = [ai_table, fig12_roofline, table1, kernel_roofline,
           fusion_crossover, vii_gpu_efficiency, fabric_bench]


def main() -> None:
    print("name,us_per_call,derived")
    failed = 0
    for mod in MODULES:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception as e:
            failed += 1
            print(f"{mod.__name__},0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
