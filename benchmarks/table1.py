"""Table I: comparative analysis of stencils on CGRA vs V100.

Methodology (matching §VIII): simulate one CGRA tile cycle-accurately on a
reduced grid (utilization is scale-stable once startup is amortized — the
paper itself extrapolates 1 tile -> 16), apply the paper's 16-tile scaling,
and compare against the V100 roofline at the paper's measured efficiencies
(90% for 1D, 48% for 2D).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import CGRA, V100, analyze, map_1d, map_2d, simulate
from repro.core.spec import paper_stencil_1d, paper_stencil_2d

V100_EFF = {"stencil1d": 0.90, "stencil2d": 0.48,
            "stencil2d_conflict0.8": 0.48}          # paper Table I
PAPER_SPEEDUP = {"stencil1d": 1.9, "stencil2d": 3.03,
                 "stencil2d_conflict0.8": 3.03}
PAPER_PCT = {"stencil1d": 0.91, "stencil2d": 0.78,
             "stencil2d_conflict0.8": 0.78}


def run() -> list[tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)

    for name, spec, plan_fn, workers, mem_eff in [
        ("stencil1d", paper_stencil_1d(n=19440, rx=8), map_1d, 6, 1.0),
        ("stencil2d", paper_stencil_2d(ny=113, nx=240, r=12), map_2d, 5, 1.0),
        # the paper attributes its 2D gap to cache conflict misses; 0.80
        # effective memory bandwidth reproduces its cycle-accurate result.
        ("stencil2d_conflict0.8", paper_stencil_2d(ny=113, nx=240, r=12),
         map_2d, 5, 0.80),
    ]:
        t0 = time.perf_counter()
        plan = plan_fn(spec, workers=workers)
        x = rng.normal(size=spec.grid_shape)
        res = simulate(plan, x, CGRA, mem_efficiency=mem_eff)
        us = (time.perf_counter() - t0) * 1e6

        cgra16 = CGRA.scaled(16)
        cgra_gf = analyze(spec, cgra16).achievable_gflops * res.pct_of_roofline
        v100_gf = analyze(spec, V100).achievable_gflops * V100_EFF[name]
        speedup = cgra_gf / v100_gf
        rows.append((f"table1/{name}", us,
                     f"sim%roofline={res.pct_of_roofline:.1%}"
                     f"(paper {PAPER_PCT[name]:.0%}) "
                     f"16tiles={cgra_gf/1000:.2f}TF "
                     f"V100={v100_gf/1000:.2f}TF "
                     f"speedup={speedup:.2f}x(paper {PAPER_SPEEDUP[name]}x) "
                     f"cycles={res.cycles} loads={res.loads}"))
    return rows
