"""§VII: the paper's observation that V100 efficiency falls as arithmetic
intensity rises ("with the increase of arithmetic intensity ... the
efficiency of the stencil dropped on V100").

We reproduce the AI arithmetic for each §VII configuration and pair it with
the paper's measured efficiency — the trend (monotone drop with AI) is the
claim under validation; the CGRA keeps 77-91% across the same range (§VIII).
"""
from __future__ import annotations

import math
import time

from repro.core.spec import StencilSpec


def _star(grid, r, bytes_per_elem):
    ndim = len(grid)
    flops = (2 * (2 * r * ndim) + 1)
    interior = math.prod(n - 2 * r for n in grid)
    return flops * interior / (2 * math.prod(grid) * bytes_per_elem)


# (label, grid, radius, dtype bytes, paper-measured % of roofline peak)
CASES = [
    ("2d_r2_960x449_fp64", (449, 960), 2, 8, 0.87),
    ("2d_r12_960x449_fp64", (449, 960), 12, 8, 0.80),      # "80% ... double"
    ("3d_r8_384^3_fp32", (384, 384, 384), 8, 4, 0.56),
    ("3d_r12_512^3_fp32", (512, 512, 512), 12, 4, 0.36),
]


def run() -> list[tuple[str, float, str]]:
    rows = []
    prev_eff = 1.0
    monotone = True
    t0 = time.perf_counter()
    pts = []
    for label, grid, r, b, eff in CASES:
        ai = _star(grid, r, b)
        pts.append((ai, eff, label))
    pts.sort()
    for ai, eff, label in pts:
        if eff > prev_eff + 1e-9:
            monotone = False
        prev_eff = eff
    us = (time.perf_counter() - t0) * 1e6
    for ai, eff, label in pts:
        rows.append((f"vii/{label}", us / len(pts),
                     f"AI={ai:.2f} paper_eff={eff:.0%}"))
    rows.append(("vii/trend", us,
                 f"efficiency_monotone_decreasing_in_AI={monotone} "
                 f"(the paper's §VII claim; CGRA holds 77-91% over the "
                 f"same AI range)"))
    return rows
