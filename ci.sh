#!/usr/bin/env bash
# Tier-1 verify gate (see ROADMAP.md) — one command for CI and local use.
# Runs the test suite, then refreshes the perf-trajectory artifacts
# (BENCH_pr2.json single-op mappings, BENCH_pr3.json program pipelines)
# in the fast smoke configuration.
set -euo pipefail
cd "$(dirname "$0")"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --artifact BENCH_pr2.json \
    --program-artifact BENCH_pr3.json --smoke --artifact-only
