#!/usr/bin/env bash
# Tier-1 verify gate (see ROADMAP.md) — one command for CI and local use.
# Runs the test suite (includes the interp-vs-vector engine cross-validation
# in tests/test_engine.py; the property sweep runs under hypothesis when
# installed — see requirements-dev.txt — and under the in-tree
# repro.testing.minihyp shim otherwise, so it never skips), then refreshes
# the perf-trajectory artifacts (BENCH_pr2.json single-op mappings,
# BENCH_pr3.json program pipelines, BENCH_pr4.json interpreter-vs-vector
# engine comparison, BENCH_pr5.json mapping auto-tuner Pareto fronts) in
# the fast smoke configuration.  --engine both makes the pr2/pr3 refresh
# itself a drift gate: it fails if the vector engine's cycles/fires/outputs
# diverge from the interpreter's; the pr5 refresh asserts every front is
# non-dominated and the tuner's best never loses to the analytical §VI
# baseline (tuner evals cache in BENCH_pr5.json.cache, so reruns are cheap).
#
# The refresh also emits a Perfetto trace artifact for one routed smoke case
# (--trace; validated, open in ui.perfetto.dev) and then gates the refreshed
# BENCH_pr4 against the previous snapshot with benchmarks/bench_diff.py:
# every deterministic counter (cycles, token hops, stalls) must be identical
# — the telemetry hooks are opt-in and a detached sink must not perturb the
# simulation — and wall times must stay within a generous machine-noise
# tolerance (the disabled-telemetry overhead bound; the precise <2% claim is
# measured in docs/telemetry.md).
set -euo pipefail
cd "$(dirname "$0")"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

trace_out="${TRACE_OUT:-$(mktemp -d)/trace_2d.json}"
prev_pr4="$(mktemp -d)/BENCH_pr4.prev.json"
cp BENCH_pr4.json "$prev_pr4"

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --artifact BENCH_pr2.json \
    --program-artifact BENCH_pr3.json --engine-artifact BENCH_pr4.json \
    --explore BENCH_pr5.json --trace "$trace_out" \
    --engine both --smoke --artifact-only

python benchmarks/bench_diff.py "$prev_pr4" BENCH_pr4.json \
    --rtol 0.5 --atol 0.1
