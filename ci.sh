#!/usr/bin/env bash
# Tier-1 verify gate (see ROADMAP.md) — one command for CI and local use.
# Runs the test suite (includes the interp-vs-vector engine cross-validation
# in tests/test_engine.py; the property sweep runs under hypothesis when
# installed — see requirements-dev.txt — and under the in-tree
# repro.testing.minihyp shim otherwise, so it never skips), then refreshes
# the perf-trajectory artifacts (BENCH_pr2.json single-op mappings,
# BENCH_pr3.json program pipelines, BENCH_pr4.json interpreter-vs-vector
# engine comparison, BENCH_pr5.json mapping auto-tuner Pareto fronts) in
# the fast smoke configuration.  --engine both makes the pr2/pr3 refresh
# itself a drift gate: it fails if the vector engine's cycles/fires/outputs
# diverge from the interpreter's; the pr5 refresh asserts every front is
# non-dominated and the tuner's best never loses to the analytical §VI
# baseline (tuner evals cache in BENCH_pr5.json.cache, so reruns are cheap).
set -euo pipefail
cd "$(dirname "$0")"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --artifact BENCH_pr2.json \
    --program-artifact BENCH_pr3.json --engine-artifact BENCH_pr4.json \
    --explore BENCH_pr5.json \
    --engine both --smoke --artifact-only
