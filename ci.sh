#!/usr/bin/env bash
# Tier-1 verify gate (see ROADMAP.md) — one command for CI and local use.
#
# 1. pytest: the full suite (includes the interp-vs-vector engine
#    cross-validation; the property sweep runs under hypothesis when
#    installed and under the in-tree repro.testing.minihyp shim otherwise).
# 1b. Static lint: every examples/ file with a lint_plans() hook runs
#    through the static plan verifier (repro.analysis.lint --strict) —
#    a committed example that deadlocks or fails a structural lint fails CI
#    before any benchmark runs.
# 2. Artifact refresh (smoke configuration): BENCH_pr2 single-op mappings,
#    BENCH_pr3 program pipelines, BENCH_pr4 interp-vs-vector engine
#    comparison (+ jax ideal-mode walls), BENCH_pr5 auto-tuner Pareto
#    fronts, BENCH_pr9 batched-jax tuner-sweep throughput, BENCH_pr10
#    static-verifier prune counts on capacity-stressed sweeps, plus a
#    validated Perfetto trace for one routed case.  --engine all makes the
#    refresh itself a drift gate (identical cycles/fires/outputs across
#    interp/vector AND the ideal-mode jax engine — the jax parity gate);
#    the pr5 refresh asserts non-dominated fronts and tuner-best <=
#    analytical baseline; the pr9 refresh asserts identical per-config
#    cycles and the >=3x batched-sweep throughput gate; the pr10 refresh
#    asserts gated/ungated survivor parity and static_pruned ==
#    engine-discovered deadlocks.
# 3. Snapshot gate: the refreshed BENCH_pr4 vs the committed one —
#    deterministic counters exact, walls within machine-noise tolerance.
# 4. Trend gate: every refreshed artifact vs the last 5 records of
#    BENCH_history.jsonl (benchmarks/bench_diff.py --trend).  The gate runs
#    BEFORE the append on purpose: appending first would make every run
#    its own baseline and the gate vacuous.
# 5. Overhead gate: benchmarks/overhead_check.py re-times the routed smoke
#    2d case with telemetry=None and fails if the wall creeps >2% above
#    the rolling history median — the disabled-telemetry bound from
#    docs/telemetry.md as an explicit failing check.
# 6. History append + observatory report: the blessed measurements join
#    BENCH_history.jsonl and the trend/attribution report renders.
set -euo pipefail
cd "$(dirname "$0")"
# jax engine determinism pin: CPU backend only (no accidental device
# pickup).  The 64-bit pin is scoped to the benchmark refresh below — the
# seed model tests expect default-f32 promotion — and jax_engine enables
# x64 in-process regardless, so the parity/throughput gates are f64 either
# way; the env pin just makes the benchmark runs explicit about it.
export JAX_PLATFORMS=cpu
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.analysis.lint examples/ --strict

trace_out="${TRACE_OUT:-$(mktemp -d)/trace_2d.json}"
prev_pr4="$(mktemp -d)/BENCH_pr4.prev.json"
cp BENCH_pr4.json "$prev_pr4"

JAX_ENABLE_X64=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --artifact BENCH_pr2.json \
    --program-artifact BENCH_pr3.json --engine-artifact BENCH_pr4.json \
    --explore BENCH_pr5.json --sweep-artifact BENCH_pr9.json \
    --stress-artifact BENCH_pr10.json \
    --trace "$trace_out" \
    --engine all --smoke --artifact-only

python benchmarks/bench_diff.py "$prev_pr4" BENCH_pr4.json \
    --rtol 0.5 --atol 0.1

for art in BENCH_pr2.json BENCH_pr3.json BENCH_pr4.json BENCH_pr5.json \
    BENCH_pr9.json BENCH_pr10.json; do
    python benchmarks/bench_diff.py "$art" --trend 5 \
        --history BENCH_history.jsonl
done

python benchmarks/overhead_check.py --history BENCH_history.jsonl

python benchmarks/observatory.py append BENCH_pr2.json BENCH_pr3.json \
    BENCH_pr4.json BENCH_pr5.json BENCH_pr9.json BENCH_pr10.json \
    --history BENCH_history.jsonl
python benchmarks/observatory.py report --history BENCH_history.jsonl
