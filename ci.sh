#!/usr/bin/env bash
# Tier-1 verify gate (see ROADMAP.md) — one command for CI and local use.
set -euo pipefail
cd "$(dirname "$0")"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
