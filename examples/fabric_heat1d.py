"""Walkthrough: mapping a 1D heat stencil onto the physical PE fabric.

The full pipeline the paper implies but never shows end-to-end:

  spec -> map_1d -> place -> route -> per-PE config -> network-aware simulate

A 3-pt heat step is mapped with 4 workers, placed on an 8x8 mesh (memory
ports on the boundary), routed with XY multicast trees, exported as a per-PE
configuration, and simulated twice — with free one-hop wires (ideal) and on
the routed network — to show the on-chip network's real latency cost while
the numerics stay bit-identical.

Run:  PYTHONPATH=src python examples/fabric_heat1d.py
"""
import numpy as np

from repro.core import CGRA, map_1d, simulate
from repro.core.reference import stencil_reference_np
from repro.core.spec import StencilSpec
from repro.fabric import (FabricTopology, place, placed_assembly, placed_dot,
                          route)


def main():
    # 3-pt heat step u[i] += alpha * (u[i-1] - 2u[i] + u[i+1]), n=360
    alpha = 0.1
    spec = StencilSpec((360,), (1,), ((alpha, 1 - 2 * alpha, alpha),),
                       dtype="float64")
    plan = map_1d(spec, workers=4)
    print(f"logical mapping: {len(plan.dfg.nodes)} instructions, "
          f"{sum(1 for _ in plan.dfg.edges())} queues — {plan.notes}")

    # --- physical fabric: 8x8 mesh, memory ports on the boundary ----------
    topo = FabricTopology.mesh(8, 8)
    pl = place(plan, topo, seed=0)
    rf = route(pl)
    s = rf.stats()
    print(f"\nplaced on {topo!r}")
    print(f"  PEs used          {s['pes_used']}/{len(topo.pes)} "
          f"({s['pe_utilization']:.0%})")
    print(f"  hop count         mean={s['hops_mean']} max={s['hops_max']}")
    print(f"  links used        {s['links_used']}/{len(topo.links)} "
          f"({s['link_utilization']:.0%})")
    print(f"  max channel load  {s['max_channel_load']}/"
          f"{s['channel_capacity']}")
    print(f"  busiest link      {s['hotspots'][0]['link']} "
          f"({s['hotspots'][0]['trees']} trees)")

    # --- per-PE configuration (first worker's pipeline) -------------------
    print("\nper-PE configuration (excerpt):")
    for line in placed_assembly(rf).splitlines()[:10]:
        print(f"  {line}")

    # --- ideal vs network-aware simulation --------------------------------
    rng = np.random.default_rng(0)
    x = rng.normal(size=360)
    ideal = simulate(map_1d(spec, workers=4), x, CGRA)
    routed = simulate(plan, x, CGRA, fabric=rf)
    assert np.array_equal(ideal.output, routed.output)
    assert np.allclose(routed.output, stencil_reference_np(x, spec))
    print(f"\nideal (free wires):  {ideal.cycles} cycles")
    print(f"routed (8x8 mesh):   {routed.cycles} cycles "
          f"({routed.cycles / ideal.cycles:.2f}x, "
          f"{routed.fabric['token_hops']} token-hops, "
          f"{routed.fabric['stall_cycles']} link stalls)")
    print("outputs bit-identical; oracle check passed")

    with open("/tmp/fabric_heat1d.dot", "w") as f:
        f.write(placed_dot(rf))
    print("\nfloorplan dot written to /tmp/fabric_heat1d.dot "
          "(render: neato -Tpng)")


def lint_plans():
    """Static-verifier hook (``python -m repro.analysis.lint examples/``)."""
    alpha = 0.1
    spec = StencilSpec((360,), (1,), ((alpha, 1 - 2 * alpha, alpha),),
                       dtype="float64")
    plan = map_1d(spec, workers=4)
    yield plan                                     # ideal wires
    yield plan, route(place(plan, FabricTopology.mesh(8, 8), seed=0))


if __name__ == "__main__":
    main()
