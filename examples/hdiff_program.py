"""Walkthrough: a StencilFlow-style horizontal-diffusion *program* fused
into one spatial pipeline (laplacian → flux → output).

The paper maps one stencil; real weather/seismic kernels are DAGs of several.
``repro.program`` composes them: the IR infers per-field halos across the
DAG, the lowering splices every producer's worker streams directly into its
consumers' tap chains (no store/reload of ``lap`` or ``flx``), sizes the
inter-operator skew buffer that the ``inp`` fan-out needs to meet ``flx`` at
the final combine, and the whole thing places, routes, and simulates on the
paper's 16x16 mesh — bit-exact against the composed jnp oracle and faster
than running the three ops as separate store-to-memory sweeps.

Run:  PYTHONPATH=src python examples/hdiff_program.py
"""
import numpy as np

from repro.core import CGRA
from repro.fabric import FabricTopology, place, route
from repro.program import (StencilProgram, field_leads, hdiff_program, lower,
                           program_reference_np, simulate_program)


def main():
    prog = hdiff_program(48, 64)
    print(f"{prog!r}")
    leads = field_leads(prog)
    print("fields (margin = invalid rim per axis, lead = pipeline depth in "
          "sites):")
    for f, m in prog.margins().items():
        print(f"  {f:<4} margin={m} lead={leads[f]}")

    plan = lower(prog, workers=4, auto_capacity=True)
    print(f"\nlowered: {len(plan.dfg.nodes)} instructions, "
          f"{sum(1 for _ in plan.dfg.edges())} queues")
    print(f"  {plan.notes}")
    skew = max(plan.min_capacities.values())
    print(f"  largest computed skew buffer: {skew} tokens "
          f"(the 'inp' branch waiting for 'flx' at the combine)")

    # --- physical fabric: the paper's 16x16 mesh --------------------------
    topo = FabricTopology.mesh(16, 16)
    rf = route(place(plan, topo, seed=0))
    s = rf.stats()
    print(f"\nplaced on {topo!r}")
    print(f"  PEs used          {s['pes_used']}/{len(topo.pes)} "
          f"({s['pe_utilization']:.0%})")
    print(f"  hop count         mean={s['hops_mean']} max={s['hops_max']}")
    print(f"  max channel load  {s['max_channel_load']}/"
          f"{s['channel_capacity']}")

    # --- fused pipeline vs separate store-to-memory sweeps ----------------
    rng = np.random.default_rng(0)
    x = rng.normal(size=prog.grid_shape)
    ideal, _ = simulate_program(lower(prog, workers=4), {"inp": x}, CGRA)
    routed, fields = simulate_program(plan, {"inp": x}, CGRA, fabric=rf)
    assert np.array_equal(ideal.output, routed.output)
    ref = program_reference_np(prog, {"inp": x})
    assert np.allclose(fields["out"], ref["out"], atol=1e-9)

    separate = 0
    for op in prog.schedule():
        solo = StencilProgram(f"solo_{op.name}", [op],
                              grid_shape=prog.grid_shape, dtype=prog.dtype)
        ins = {f: rng.normal(size=prog.grid_shape) for f in solo.in_fields}
        separate += simulate_program(lower(solo, workers=4), ins,
                                     CGRA)[0].cycles
    print(f"\nfused pipeline (ideal wires):   {ideal.cycles} cycles")
    print(f"fused pipeline (routed mesh):   {routed.cycles} cycles "
          f"({routed.fabric['token_hops']} token-hops)")
    print(f"separate sweeps (3 memory round trips): {separate} cycles")
    print(f"fusion speedup: {separate / ideal.cycles:.2f}x — "
          "oracle check passed, outputs bit-identical ideal vs routed")


def lint_plans():
    """Static-verifier hook (``python -m repro.analysis.lint examples/``)."""
    yield lower(hdiff_program(24, 32), workers=4, auto_capacity=True)


if __name__ == "__main__":
    main()
