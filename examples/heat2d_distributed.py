import os
if __name__ == "__main__":
    # 8 fake devices for the multi-device demo — set before jax initializes.
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

"""Heat diffusion (the paper's application domain) end-to-end, multi-device.

A 2D heat equation is stepped with the 5-pt Jacobi stencil:
  * sharded over a (2, 4) device mesh with halo exchange (ppermute — the
    paper's PE-to-PE forwarding at chip scale),
  * T time-steps fused per exchange (§IV temporal pipelining),
  * validated against the single-device oracle every fused block.

Run:  PYTHONPATH=src python examples/heat2d_distributed.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.reference import stencil_reference_np
from repro.core.spec import heat_2d
from repro.distributed.halo import distributed_stencil2d, halo_bytes_per_step
from repro.distributed.sharding import make_mesh_compat


def main():
    mesh = make_mesh_compat((2, 4), ("pod", "data"))
    fuse_t = 4
    spec = dataclasses.replace(heat_2d(256, 512, alpha=0.12), timesteps=fuse_t)
    step = distributed_stencil2d(spec, mesh, axes=("pod", "data"))

    rng = np.random.default_rng(0)
    u = rng.normal(size=(256, 512)).astype(np.float32)
    u_ref = u.copy()
    ud = jnp.asarray(u)

    print(f"mesh {dict(mesh.shape)}; fusing T={fuse_t} steps per halo "
          f"exchange; halo traffic/exchange = "
          f"{halo_bytes_per_step(spec, (2, 4)) / 1024:.1f} KiB "
          f"(vs {256*512*4/1024:.0f} KiB full grid)")

    t0 = time.time()
    for block in range(3):
        ud = step(ud)
        u_ref = stencil_reference_np(u_ref, spec)
        err = float(np.abs(np.asarray(ud) - u_ref).max())
        print(f"fused block {block}: {fuse_t} steps, max err vs oracle "
              f"{err:.2e}")
        assert err < 1e-4
    print(f"done in {time.time() - t0:.2f}s — {3 * fuse_t} heat steps, "
          f"3 halo exchanges (4x fewer messages than unfused)")


if __name__ == "__main__":
    main()
