"""Walkthrough: a 3D heat stencil on the physical PE fabric via ``map_nd``.

The pre-refactor mapper special-cased 1D and 2D; the dimension-generic
worker pipeline makes rank 3 fall out of the same construction:

  heat_3d spec -> map_3d (= map_nd) -> place -> route -> network-aware sim

A 7-pt heat step is mapped with 8 workers — each compute worker carries
three tap chains (x: 3 taps from 3 readers; y and z: 2 taps each from the
column-owning reader) joined by an ADD tree — placed on the paper's 16x16
mesh, routed with XY multicast trees, and simulated twice (ideal wires vs
routed network).  The numerics stay bit-identical to the jnp oracle.

Run:  PYTHONPATH=src python examples/heat3d_fabric.py
"""
import numpy as np

from repro.core import CGRA, map_3d, simulate
from repro.core.reference import stencil_reference_np
from repro.core.spec import heat_3d
from repro.fabric import FabricTopology, place, placed_assembly, route


def main():
    spec = heat_3d(10, 12, 16, dtype="float64")
    plan = map_3d(spec, workers=8)
    print(f"logical mapping: {len(plan.dfg.nodes)} instructions, "
          f"{sum(1 for _ in plan.dfg.edges())} queues — {plan.notes}")
    print(f"per-worker pipeline: {plan.pe_counts['filter'] // 8} filters, "
          f"{plan.pe_counts['mul'] // 8} MUL + {plan.pe_counts['mac'] // 8} "
          f"MAC chains, {plan.pe_counts['add'] // 8} axis-combining ADDs")

    # --- physical fabric: the paper's 16x16 mesh --------------------------
    topo = FabricTopology.mesh(16, 16)
    rf = route(place(plan, topo, seed=0))
    s = rf.stats()
    print(f"\nplaced on {topo!r}")
    print(f"  PEs used          {s['pes_used']}/{len(topo.pes)} "
          f"({s['pe_utilization']:.0%})")
    print(f"  hop count         mean={s['hops_mean']} max={s['hops_max']}")
    print(f"  max channel load  {s['max_channel_load']}/"
          f"{s['channel_capacity']}")
    print(f"  busiest link      {s['hotspots'][0]['link']} "
          f"({s['hotspots'][0]['trees']} trees)")

    # --- per-PE configuration excerpt -------------------------------------
    print("\nper-PE configuration (excerpt):")
    for line in placed_assembly(rf).splitlines()[:8]:
        print(f"  {line}")

    # --- ideal vs network-aware simulation --------------------------------
    rng = np.random.default_rng(0)
    x = rng.normal(size=spec.grid_shape)
    ideal = simulate(map_3d(spec, workers=8), x, CGRA)
    routed = simulate(plan, x, CGRA, fabric=rf)
    assert np.array_equal(ideal.output, routed.output)
    assert np.allclose(routed.output, stencil_reference_np(x, spec))
    print(f"\nideal (free wires):  {ideal.cycles} cycles")
    print(f"routed (16x16 mesh): {routed.cycles} cycles "
          f"({routed.cycles / ideal.cycles:.2f}x, "
          f"{routed.fabric['token_hops']} token-hops, "
          f"{routed.fabric['stall_cycles']} link stalls)")
    print("outputs bit-identical; oracle check passed")


def lint_plans():
    """Static-verifier hook (``python -m repro.analysis.lint examples/``)."""
    plan = map_3d(heat_3d(8, 10, 12, dtype="float64"), workers=4)
    yield plan
    yield plan, route(place(plan, FabricTopology.mesh(16, 16), seed=0))


if __name__ == "__main__":
    main()
