"""Quickstart: the paper's pipeline end-to-end on one host.

1. define a stencil (spec)            4. roofline-select workers (§VI)
2. map it onto the CGRA (§III)        5. cycle-simulate + validate (§VIII)
3. emit the DFG (dot + assembly, §V)  6. run the TPU Pallas kernel (interpret)

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import CGRA, analyze, map_1d, simulate
from repro.core.reference import stencil_reference_np
from repro.core.spec import StencilSpec
from repro.kernels.stencil1d.ops import stencil1d


def main():
    # 1. a 5-pt smoothing stencil on a 6000-point grid
    spec = StencilSpec((6000,), (2,), ((0.1, 0.2, 0.4, 0.2, 0.1),),
                       dtype="float64")

    # 2-4. roofline -> workers -> CGRA mapping
    roof = analyze(spec, CGRA)
    print(f"AI={roof.arithmetic_intensity:.3f} flops/byte; "
          f"achievable {roof.achievable_gflops:.0f} GFLOPS ({roof.bound}-bound); "
          f"w*={roof.workers}")
    plan = map_1d(spec, workers=roof.workers)
    print(f"mapped: {plan.pe_counts}  ({plan.mac_pes} MAC-class PEs)")
    print(plan.dfg.to_assembly().splitlines()[0])

    # 5. simulate and validate against the oracle
    x = np.random.default_rng(0).normal(size=6000)
    res = simulate(plan, x, CGRA)
    ref = stencil_reference_np(x, spec)
    print(f"simulated: {res.summary()}")
    print(f"matches oracle: {np.allclose(res.output, ref)} "
          f"(loads == grid size: {res.loads == 6000})")

    # 6. the TPU kernel (interpret mode on CPU), fp32
    xf = jnp.asarray(x[None], jnp.float32)
    y = stencil1d(xf, spec.coeffs[0], backend="pallas")
    print("pallas kernel max err vs oracle:",
          float(np.abs(np.asarray(y[0]) - ref).max()))

    # dot file for visualization
    with open("/tmp/stencil1d.dot", "w") as f:
        f.write(plan.dfg.to_dot())
    print("DFG written to /tmp/stencil1d.dot (render with graphviz)")


def lint_plans():
    """Static-verifier hook (``python -m repro.analysis.lint examples/``)."""
    spec = StencilSpec((600,), (2,), ((0.1, 0.2, 0.4, 0.2, 0.1),),
                       dtype="float64")
    yield map_1d(spec, workers=4)


if __name__ == "__main__":
    main()
