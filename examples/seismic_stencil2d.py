"""The paper's §VI oil/gas seismic stencil (49-pt, rx=ry=12, 960x449) run
through every layer of the stack on one host:

  roofline (§VI) -> CGRA mapping (§III-B) -> cycle simulation (§VIII, reduced
  grid) -> TPU Pallas kernel (interpret) -> fused-timestep variant (§IV).

Run:  PYTHONPATH=src python examples/seismic_stencil2d.py
"""
import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core import CGRA, TPU_V5E, analyze, map_2d, simulate
from repro.core.reference import stencil_reference_np
from repro.core.spec import paper_stencil_2d
from repro.kernels.stencil2d.ops import plan_2d_blocks, stencil2d


def main():
    spec = paper_stencil_2d()                       # 960x449, r=12, fp64
    roof = analyze(spec, CGRA)
    print(f"[roofline] AI={roof.arithmetic_intensity:.2f} -> "
          f"{roof.achievable_gflops:.0f} GFLOPS on CGRA (w*={roof.workers}); "
          f"paper: 559 GFLOPS, 5 workers")

    # cycle-accurate simulation at 1/16 grid (utilization is scale-stable)
    small = paper_stencil_2d(ny=113, nx=240, r=12)
    plan = map_2d(small, workers=5)
    x = np.random.default_rng(0).normal(size=small.grid_shape)
    t0 = time.time()
    res = simulate(plan, x, CGRA)
    ok = np.allclose(res.output, stencil_reference_np(x, small))
    print(f"[simulate] {res.summary()}  exact={ok}  ({time.time()-t0:.1f}s)"
          f"  paper: 77-78% of peak")

    # TPU kernel, fp32, with the VMEM block planner (§III-B Blocking)
    spec32 = paper_stencil_2d(dtype="float32")
    blocks = plan_2d_blocks(449, 960, 12, 12, timesteps=1)
    xf = jnp.asarray(np.random.default_rng(1).normal(size=(1, 449, 960)),
                     jnp.float32)
    y = stencil2d(xf, spec32.coeffs[0], spec32.coeffs[1], backend="pallas",
                  block=(min(blocks[0], 64), min(blocks[1], 256)))
    ref = stencil_reference_np(np.asarray(xf[0]),
                               dataclasses.replace(spec32))
    print(f"[pallas] blocks={blocks} max err={np.abs(np.asarray(y[0])-ref).max():.2e}")

    # fused timesteps: where does the seismic stencil turn compute-bound?
    for t in (1, 2, 4):
        st = dataclasses.replace(spec32, timesteps=t)
        r = analyze(st, TPU_V5E)
        print(f"[fusion T={t}] AI={r.arithmetic_intensity:6.2f} -> "
              f"{r.achievable_gflops/1000:6.2f} TFLOPS on v5e ({r.bound})")


def lint_plans():
    """Static-verifier hook (``python -m repro.analysis.lint examples/``)."""
    yield map_2d(paper_stencil_2d(ny=30, nx=48, r=12), workers=8)


if __name__ == "__main__":
    main()
