"""Batched serving example (deliverable (b)): a reduced decoder-only LM
serving a queue of requests through the BatchEngine (fixed decode slots,
slot recycling, greedy sampling).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys


def main():
    from repro.launch.serve import main as serve_main
    raise SystemExit(serve_main([
        "--arch", "qwen2.5-3b", "--reduced", "--requests", "6",
        "--slots", "3", "--prompt-len", "10", "--max-new", "12",
        "--cache-len", "64"]))


if __name__ == "__main__":
    sys.path.insert(0, "src")
    main()
