"""End-to-end LM training driver (deliverable (b)): train a small LM for a
few hundred steps through the full substrate stack — synthetic data pipeline
with prefetch, AdamW + cosine schedule, remat, microbatch accumulation, async
checkpointing, resume, straggler watchdog.

Default: ~13M-param llama-family model sized for this CPU container.
``--scale 100m`` uses a ~100M config (same code path, proportionally slower).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--scale 100m]
"""
import argparse
import sys


def main():
    from repro.launch.train import main as train_main
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--scale", default="13m", choices=["13m", "100m"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    argv = ["--arch", "tinyllama-1.1b", "--reduced",
            "--steps", str(args.steps), "--batch", "8", "--seq", "256",
            "--lr", "1e-3", "--microbatches", "2", "--remat", "dots",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
            "--log-every", "20",
            "--override", "num_layers=6", "--override", "d_model=384",
            "--override", "num_heads=6", "--override", "num_kv_heads=2",
            "--override", "d_ff=1024", "--override", "vocab_size=8192"]
    if args.scale == "100m":
        argv = argv[:-12] + [
            "--override", "num_layers=12", "--override", "d_model=768",
            "--override", "num_heads=12", "--override", "num_kv_heads=4",
            "--override", "d_ff=2048", "--override", "vocab_size=32000"]
    raise SystemExit(train_main(argv))


if __name__ == "__main__":
    sys.path.insert(0, "src")
    main()
