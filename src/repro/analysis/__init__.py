"""Static analysis passes: plan verification, lints, throughput bounds.

``repro.analysis.static_verify`` is the front door (see docs/analysis.md);
``repro.analysis.hlo`` / ``rooflines`` are imported directly by their users
(they can pull heavyweight deps and are deliberately not re-exported here).
"""
from repro.analysis.static_verify import (STATIC_SEMANTICS,  # noqa: F401
                                          Counterexample, Finding,
                                          StaticDeadlock, StaticReport,
                                          ThroughputBound,
                                          apply_suggested_capacities,
                                          check_static, lint_plan,
                                          suggest_capacity_fix,
                                          throughput_bound, verify_plan)

__all__ = [
    "STATIC_SEMANTICS", "Counterexample", "Finding", "StaticDeadlock",
    "StaticReport", "ThroughputBound", "apply_suggested_capacities",
    "check_static", "lint_plan", "suggest_capacity_fix", "throughput_bound",
    "verify_plan",
]
