"""HLO post-compile analysis: collective-byte accounting for §Roofline.

``cost_analysis()`` gives flops/bytes but not collective traffic, so we parse
the *optimized* (SPMD-partitioned, per-device) HLO text and sum the result
shapes of every collective op.  Convention (documented in EXPERIMENTS.md):
the per-device wire bytes of one op are approximated by its result-shape
bytes (all-gather: received bytes; all-reduce/permute/all-to-all: payload;
reduce-scatter: its result is the post-scatter shard, multiply by
participants to approximate the ring traffic).  Global collective_bytes =
per-device bytes x chips.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(" + "|".join(
        re.escape(d) for d in _DTYPE_BYTES) + r")\[([0-9,]*)\][^=]*?)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Returns {'total_bytes': per-device bytes, 'by_op': {op: bytes},
    'counts': {op: n}}."""
    by_op: dict[str, int] = defaultdict(int)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        line = line.strip()
        hit = None
        for op in _COLLECTIVES:
            if f" {op}(" in line or f" {op}-start(" in line:
                hit = op
                break
        if hit is None:
            continue
        if hit == "all-reduce" and "all-reduce-done" in line:
            continue            # -done carries the same shape as -start
        if "-done(" in line:
            continue
        # result type = everything before the '=' is the name; shapes after
        lhs, _, rhs = line.partition("=")
        shapes = _SHAPE_RE.findall(rhs.split("(", 1)[0])
        if not shapes:          # tuple results keep shapes inside parens
            head = rhs.split(hit)[0]
            shapes = _SHAPE_RE.findall(head)
        b = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        by_op[hit] += b
        counts[hit] += 1
    return {"total_bytes": int(sum(by_op.values())),
            "by_op": dict(by_op), "counts": dict(counts)}


def remat_duplication(hlo_text: str) -> float:
    """Rough remat-waste probe: ratio of fusion/dot ops to unique ones by
    name stem (§Perf hint: count duplicate op names)."""
    names = re.findall(r"%([a-zA-Z0-9_.-]+) = ", hlo_text)
    dots = [n for n in names if n.startswith(("dot", "fusion", "convolution"))]
    stems = set(re.sub(r"[.\d]+$", "", n) for n in dots)
    return len(dots) / max(len(stems), 1)
