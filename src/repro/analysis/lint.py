"""Lint example/program files with the static verifier (CLI).

Usage::

    python -m repro.analysis.lint examples/ [more paths] [--strict]

Any ``.py`` file under the given paths that defines a ``lint_plans()``
function is imported and asked for its plans; each plan (or ``(plan,
routed_fabric)`` pair) runs through :func:`repro.analysis.verify_plan`.
Files without the hook are skipped *without being imported* — demo scripts
with heavyweight deps (serving, training) stay untouched.

Exit status: ``--strict`` fails (1) on any deadlock verdict or
error-severity finding; without it every report prints but only crashes
fail.  Warnings print either way and never gate.

The hook contract::

    def lint_plans():
        yield map_2d(heat_2d(18, 24), workers=3)          # a bare plan
        yield plan, routed_fabric                          # or with fabric
"""
from __future__ import annotations

import argparse
import importlib.util
import pathlib
import sys

from repro.analysis.static_verify import verify_plan

HOOK = "def lint_plans"


def iter_hook_files(paths: list[str]):
    """Yield ``.py`` files (under files/dirs in ``paths``) whose *text*
    contains the ``lint_plans`` hook — the no-import prefilter."""
    for raw in paths:
        p = pathlib.Path(raw)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            try:
                text = f.read_text()
            except OSError:
                continue
            if HOOK in text:
                yield f


def _load(path: pathlib.Path):
    name = f"_repro_lint_{path.stem}"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod               # dataclasses et al. need the entry
    try:
        spec.loader.exec_module(mod)
    finally:
        sys.modules.pop(name, None)
    return mod


def lint_paths(paths: list[str], out=sys.stdout) -> tuple[int, int]:
    """Lint every hooked file; returns ``(n_plans, n_failed)``."""
    n_plans = n_failed = 0
    for f in iter_hook_files(paths):
        try:
            mod = _load(f)
            plans = list(mod.lint_plans())
        except Exception as e:            # a broken example is a finding too
            print(f"{f}: FAIL — lint_plans() raised "
                  f"{type(e).__name__}: {e}", file=out)
            n_failed += 1
            continue
        for i, item in enumerate(plans):
            plan, fabric = item if isinstance(item, tuple) else (item, None)
            n_plans += 1
            rep = verify_plan(plan, fabric=fabric)
            bad = not rep.ok()
            n_failed += bad
            status = "FAIL" if bad else "ok"
            tag = f"{f.name}[{i}]"
            routed = " (routed)" if fabric is not None else ""
            print(f"{tag}: {status}{routed} — {rep.describe()}", file=out)
    return n_plans, n_failed


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+", metavar="PATH",
                    help="files or directories to scan for lint_plans()")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any deadlock verdict or error finding")
    args = ap.parse_args(argv)
    n_plans, n_failed = lint_paths(args.paths)
    print(f"lint: {n_plans} plan(s) checked, {n_failed} failed")
    if n_plans == 0:
        print("lint: no lint_plans() hooks found", file=sys.stderr)
        return 1
    return 1 if (args.strict and n_failed) else 0


if __name__ == "__main__":
    sys.exit(main())
