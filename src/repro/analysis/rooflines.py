"""Roofline report builder: aggregates results/dryrun/*.json into the
EXPERIMENTS.md §Dry-run and §Roofline tables.

Usage:  PYTHONPATH=src python -m repro.analysis.rooflines [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname: str) -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def fmt_s(x) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def dryrun_table(recs: list[dict], mesh: str | None = None) -> str:
    rows = ["| arch | shape | mesh | chips | params | param B/dev | peak mem/dev"
            " | HLO lines | compile | status |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for d in recs:
        if mesh and d.get("mesh") != mesh:
            continue
        if d.get("tag"):
            continue
        if not d.get("ok"):
            rows.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | - | - |"
                        f" - | - | - | - | FAIL: {d.get('error','')[:60]} |")
            continue
        mem = d.get("memory_analysis", {})
        peak = mem.get("peak_memory_in_bytes")
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d['chips']} | "
            f"{d['param_count']/1e9:.2f}B | "
            f"{fmt_bytes(d['param_bytes_per_device'])} | "
            f"{fmt_bytes(peak)} | {d['hlo_lines']} | {d['compile_s']:.0f}s | OK |")
    return "\n".join(rows)


def roofline_table(recs: list[dict]) -> str:
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "step(max) | MODEL/HLO flops | note |",
            "|---|---|---|---|---|---|---|---|---|"]
    for d in recs:
        if d.get("mesh") != "single" or not d.get("ok") or d.get("tag"):
            continue
        r = d["roofline"]
        ratio = d.get("useful_flops_ratio")
        note = _bottleneck_note(d)
        rows.append(
            f"| {d['arch']} | {d['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {fmt_s(r['step_time_s'])} | "
            f"{ratio:.2f} | {note} |")
    return "\n".join(rows)


def _bottleneck_note(d: dict) -> str:
    r = d["roofline"]
    dom = r["dominant"]
    ratio = d.get("useful_flops_ratio") or 0
    if dom == "memory" and d["kind"] == "decode":
        return "decode streams params+cache; batch up or quantize cache"
    if dom == "memory" and ratio < 0.3:
        return "low useful-flop ratio: cut dispatch/replicated compute"
    if dom == "memory":
        return "fuse more / bf16 master weights to cut HBM traffic"
    if dom == "collective":
        return "overlap or shrink collectives (compression, 2D sharding)"
    return "compute-bound: near roofline; tune MXU tiling"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--what", default="both",
                    choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    recs = load(args.dir)
    if args.what in ("dryrun", "both"):
        print("### Dry-run (single-pod)\n")
        print(dryrun_table(recs, "single"))
        print("\n### Dry-run (multi-pod 2x16x16)\n")
        print(dryrun_table(recs, "multi"))
    if args.what in ("roofline", "both"):
        print("\n### Roofline (single-pod, 256 chips)\n")
        print(roofline_table(recs))


if __name__ == "__main__":
    main()
