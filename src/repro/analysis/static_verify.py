"""Static plan verifier — deadlock-freedom and throughput bounds, no engine.

The engines (``repro.core.engine``) discover a bad mapping the expensive
way: simulate it until nothing can fire and raise :class:`SimDeadlock`.
This module answers the same question *statically*, in microseconds to
milliseconds, from the plan's DFG alone (StencilFlow ships the analogous
per-channel minimum-buffer-depth pass; see ``docs/analysis.md``):

* :func:`verify_plan` — proves deadlock-freedom (``verdict="safe"``) or
  produces a **named counterexample** (the blocked waits-for cycle or
  starvation chain) plus, when the deadlock is capacity-induced, the
  **minimal capacity bump** that provably breaks it
  (``suggested_capacities``, an ``{edge eid: capacity}`` map).
* :func:`lint_plan` — structural invariants that today fail deep inside
  engines: keep-mask/token-count consistency (reusing the exact topo
  token-count pass from ``engine/compile.py``), splice geometry,
  degenerate sync triggers, stale compiled tables, and — given a routed
  fabric — channel overflow and PE slot conflicts.
* :class:`ThroughputBound` — a static cycle/II lower bound with per-stage
  fill estimates, cross-checkable against the measured
  ``repro.telemetry.attribution`` accounting.

**How the deadlock proof works.**  Every edge has exactly one producer and
one consumer, so firing a node only pops its own inputs and pushes its own
outputs — it can never disable another enabled node.  That persistence
makes the token system *confluent*: from a given capacity assignment there
is exactly one quiescent marking, independent of schedule, and both
engines (which are fair, maximal schedulers of the same firing rules)
reach it.  The verifier therefore replays the plan's token flow in
token-count space (whole bursts per visit, no data, no cycle clock) until
it quiesces: all ``cmp`` nodes fired ⇒ every real engine completes;
blocked ⇒ every real engine deadlocks, and the blocked marking *is* the
counterexample.  Capacities only ever help (any fire sequence legal at
smaller queues is legal at larger ones), so the repair loop bumps the
full queues of output-blocked nodes by one and resumes from the same
marking until the flow completes (``static-capacity``) or no node is
output-blocked (``static-deadlock`` — structural, no bump can help).

Routed fabrics don't change the verdict: the network always delivers
(in-flight tokens drain into their destination queues unconditionally),
and a routed engine counts ``queue + transit`` occupancy against the same
capacity the abstract model counts — routed execution is just another
fair schedule of the same system.  The fabric is used for the routed
lints and for hop-aware latency in the throughput bound.

CLI: ``python -m repro.analysis.lint examples/ --strict`` (see ``lint.py``).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.engine.common import SimDeadlock, mem_elems_per_cycle
from repro.core.engine.compile import token_counts

#: version tag for the verifier's semantics — part of every EvalCache scope
#: (see ``repro.explore.search``) so a verifier upgrade can never replay a
#: stale static verdict from cache.
STATIC_SEMANTICS = "static-verify/v1"

_INF = 1 << 62
#: the default ``apply_min_capacities`` assigns to unsized edges
#: (``repro.core.mapping.nd``) — the fast-path certificate mirrors it.
_DEFAULT_MIN_CAP = 4
#: ops that pop both in-ports per fire (everything else pops port 0 only
#: and merely requires the other ports non-empty — interp.py ground truth).
_POP_BOTH = ("mac", "add", "store")


class StaticDeadlock(SimDeadlock):
    """A *proven* deadlock, raised before any engine ran (``simulate(...,
    verify="static")``).  Subclasses :class:`SimDeadlock` so existing
    handlers keep working; ``cycles`` is 0 (nothing was simulated) and
    ``suggested_capacities`` carries the repair hint when one exists."""

    def __init__(self, msg: str, *, report: "StaticReport"):
        super().__init__(
            msg, cycles=0, timed_out=False,
            suggested_capacities=report.suggested_capacities)
        self.report = report


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint finding.  ``severity`` is ``"error"`` (the plan cannot run
    correctly) or ``"warning"`` (suspicious but runnable)."""
    kind: str
    severity: str
    message: str
    nodes: tuple = ()
    edges: tuple = ()

    def __str__(self) -> str:
        return f"[{self.severity}] {self.kind}: {self.message}"


@dataclasses.dataclass(frozen=True)
class Counterexample:
    """The named witness of a blocked quiescent marking: either a waits-for
    cycle (node A waits on a full queue into B, B waits on an empty queue
    from C, … back to A) or a starvation chain ending at a node that has
    already produced every token it ever will."""
    kind: str                             # "waits-cycle" | "starvation-chain"
    nodes: tuple                          # node names along the walk
    edges: tuple                          # human-readable edge descriptions
    detail: str

    def describe(self) -> str:
        arrow = " ⇠waits-on⇠ ".join(self.nodes)
        return f"{self.kind}: {arrow} — {self.detail}"


@dataclasses.dataclass(frozen=True)
class ThroughputBound:
    """Static lower bounds on the run (sound: measured >= every field).

    ``cycles_lb``      max(memory bound, pipeline-depth bound)
    ``ii_lb``          cycles_lb / stores — initiation interval per output
    ``mem_cycles_lb``  required (loads+stores) / elements-per-cycle
    ``depth_cycles_lb``max over nodes of (pipeline depth + required fires)
    ``fill_lb``        min store depth: cycles before the first store *can*
                       fire — lower-bounds attribution's "fill" phase
    ``stage_fill``     per-stage minimum depth (attribution stage labels)
    """
    cycles_lb: int
    ii_lb: float
    mem_cycles_lb: int
    depth_cycles_lb: int
    loads: int
    stores: int
    fill_lb: int
    stage_fill: dict


@dataclasses.dataclass
class StaticReport:
    """Everything :func:`verify_plan` learned about one plan."""
    verdict: str                          # "safe" | "deadlock" | "unknown"
    reason: str | None                    # "static-capacity" (a bump fixes
                                          # it) | "static-deadlock"
                                          # (structural) | None when safe
    certificate: str | None               # "min-capacities" | "quiescence"
                                          # | "lint" — how safety/deadlock
                                          # was established
    findings: list[Finding]
    counterexample: Counterexample | None
    suggested_capacities: dict[int, int] | None
    bound: ThroughputBound | None
    stats: dict

    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def ok(self) -> bool:
        """Deadlock-free *and* lint-clean (the ``--strict`` CLI bar)."""
        return self.verdict == "safe" and not self.errors()

    def describe(self) -> str:
        parts = [f"verdict={self.verdict}"]
        if self.reason:
            parts.append(self.reason)
        if self.counterexample is not None:
            parts.append(self.counterexample.describe())
        if self.suggested_capacities:
            parts.append(f"suggested capacity bumps: "
                         f"{dict(sorted(self.suggested_capacities.items()))}")
        for f in self.findings:
            parts.append(str(f))
        return "; ".join(parts)


def _edge_desc(e, qlen: int, cap: int, state: str) -> str:
    c = "∞" if cap >= _INF else cap
    return (f"{e.src.name}->{e.dst.name}#p{e.dst_port} "
            f"(eid {e.eid}, {qlen}/{c} {state})")


def _fires_total(g, topo, emit) -> dict[int, int]:
    """Fires each node performs over a *full* run (every token consumed)."""
    ft: dict[int, int] = {}
    for nd in topo:
        ins = [emit[e.src.nid] for e in nd.in_edges]
        if nd.op == "addr":
            ft[nd.nid] = int(nd.params["count"])
        elif nd.op == "filter":
            ft[nd.nid] = ins[0] if ins else 0
        elif nd.op == "imux":
            ft[nd.nid] = sum(ins)
        elif nd.op == "sync":
            ft[nd.nid] = int(nd.params["expected"])
        elif nd.op == "cmp":
            ft[nd.nid] = 1
        else:
            ft[nd.nid] = min(ins) if ins else 0
    return ft


class _TokenFlow:
    """Token-count abstract interpreter (the quiescence engine).

    State is one integer per queue plus per-node progress counters; a
    ``run()`` sweeps the graph in topo order, letting every node fire its
    maximal burst under current queue space, until a full sweep makes no
    progress.  By confluence (module docstring) the final marking — and
    hence the complete/blocked verdict — is schedule-independent and
    matches what any engine reaches."""

    def __init__(self, g, emit, keeps, ft):
        self.g = g
        self.edges = g.finalize()
        self.topo = g.topo_order()
        self.caps = [(_INF if e.capacity is None else int(e.capacity))
                     for e in self.edges]
        self.qlen = [0] * len(self.edges)
        self.ft = ft
        self.fired = {n.nid: 0 for n in g.nodes}
        self.pos = {n.nid: 0 for n in g.nodes}     # addr/filter/imux progress
        self.sync_emitted = {n.nid: False for n in g.nodes if n.op == "sync"}
        self.cmp_done = {n.nid: False for n in g.nodes if n.op == "cmp"}
        self.n_cmp = len(self.cmp_done)
        self.done = 0
        self.keeps = keeps
        self.csum = {nid: np.concatenate(([0], np.cumsum(arr, dtype=np.int64)))
                     for nid, arr in keeps.items()}
        self.total_fires = sum(ft.values())
        self.sweeps = 0
        self.sweep_guard = self.total_fires + len(self.topo) + 64

    # ----- firing -----------------------------------------------------------
    def _space(self, nd) -> int:
        s = _INF
        for e in nd.out_edges:
            s = min(s, self.caps[e.eid] - self.qlen[e.eid])
        return s

    def _push(self, nd, b: int) -> None:
        for e in nd.out_edges:
            self.qlen[e.eid] += b

    def _step(self, nd) -> int:
        """Fire ``nd``'s maximal burst on the current marking; returns the
        number of fires (0 = nothing enabled)."""
        op, q = nd.op, self.qlen
        if op == "addr":
            b = min(int(nd.params["count"]) - self.pos[nd.nid],
                    self._space(nd))
            if b <= 0:
                return 0
            self.pos[nd.nid] += b
            self._push(nd, b)
            return b
        if op == "cmp":
            if self.cmp_done[nd.nid] or any(q[e.eid] == 0
                                            for e in nd.in_edges):
                return 0
            for e in nd.in_edges:
                q[e.eid] -= 1
            self.cmp_done[nd.nid] = True
            self.done += 1
            return 1
        if op == "sync":
            # pops port 0 only; the expected-th pop carries the one done
            # emission and is held until the out queue has room (the out
            # queue is necessarily empty before it, so nothing is lost)
            if self.sync_emitted[nd.nid] or any(q[e.eid] == 0
                                                for e in nd.in_edges):
                return 0
            exp = int(nd.params["expected"])
            in0 = nd.in_edges[0].eid
            fired = min(q[in0], exp - 1 - self.pos[nd.nid])
            if fired > 0:
                q[in0] -= fired
                self.pos[nd.nid] += fired
            if (self.pos[nd.nid] == exp - 1 and q[in0] > 0
                    and self._space(nd) >= 1):
                q[in0] -= 1
                self.pos[nd.nid] += 1
                self.sync_emitted[nd.nid] = True
                self._push(nd, 1)
                fired += 1
            return max(fired, 0)
        if op == "filter":
            in0 = nd.in_edges[0].eid
            csum = self.csum[nd.nid]
            k = self.pos[nd.nid]
            avail = min(q[in0], len(csum) - 1 - k)
            if avail <= 0:
                return 0
            space = self._space(nd)
            if space >= _INF:
                c = avail
            else:
                # largest c with (keeps in [k, k+c)) <= space: drops are
                # free, a keep holds until its broadcast has room
                c = int(np.searchsorted(csum, csum[k] + space,
                                        side="right")) - 1 - k
                c = max(0, min(c, avail))
            if c <= 0:
                return 0
            pushed = int(csum[k + c] - csum[k])
            q[in0] -= c
            self.pos[nd.nid] = k + c
            if pushed:
                self._push(nd, pushed)
            return c
        if op == "imux":
            pat = nd.params["pattern"]
            fired = 0
            while self.pos[nd.nid] < self.ft[nd.nid]:
                sel = nd.in_edges[pat[self.pos[nd.nid] % len(pat)]].eid
                if q[sel] == 0 or self._space(nd) < 1:
                    break
                q[sel] -= 1
                self._push(nd, 1)
                self.pos[nd.nid] += 1
                fired += 1
            return fired
        # load / mul / mac / add / store / mux / demux / copy: every in-port
        # must be non-empty per fire; pop port 0 (and port 1 for joins)
        ine = nd.in_edges
        if not ine or any(q[e.eid] == 0 for e in ine):
            return 0
        popped = ine[:2] if op in _POP_BOTH else ine[:1]
        b = min(min(q[e.eid] for e in popped), self._space(nd))
        if b <= 0:
            return 0
        for e in popped:
            q[e.eid] -= b
        self._push(nd, b)
        return b

    def run(self) -> str:
        """Sweep to quiescence: "complete" (all cmp fired), "blocked", or
        "budget" (the sweep guard tripped — defensive, should not happen
        on the worker-pipeline op vocabulary)."""
        while True:
            self.sweeps += 1
            if self.sweeps > self.sweep_guard:
                return "budget"
            progress = 0
            for nd in self.topo:
                f = self._step(nd)
                if f:
                    progress += f
                    self.fired[nd.nid] += f
            if self.done == self.n_cmp:
                return "complete"
            if progress == 0:
                return "blocked"

    # ----- diagnosis on a blocked marking -----------------------------------
    def _blocker(self, nd):
        """What prevents ``nd``'s next fire on this marking: ``("in", e)``
        (required empty input), ``("out", e)`` (full output), or ``None``
        when the node has nothing left to do."""
        q, op = self.qlen, nd.op

        def full_out():
            for e in nd.out_edges:
                if self.caps[e.eid] - q[e.eid] <= 0:
                    return e
            return None

        def empty_in(edges):
            for e in edges:
                if q[e.eid] == 0:
                    return e
            return None

        if op == "addr":
            if self.pos[nd.nid] >= int(nd.params["count"]):
                return None
            e = full_out()
            return ("out", e) if e is not None else None
        if op == "cmp":
            if self.cmp_done[nd.nid]:
                return None
            e = empty_in(nd.in_edges)
            return ("in", e) if e is not None else None
        if op == "sync":
            if self.sync_emitted[nd.nid]:
                return None
            e = empty_in(nd.in_edges)
            if e is not None:
                return ("in", e)
            e = full_out()
            return ("out", e) if e is not None else None
        if op == "filter":
            if self.pos[nd.nid] >= len(self.csum[nd.nid]) - 1:
                return None
            if q[nd.in_edges[0].eid] == 0:
                return ("in", nd.in_edges[0])
            e = full_out()
            return ("out", e) if e is not None else None
        if op == "imux":
            if self.pos[nd.nid] >= self.ft[nd.nid]:
                return None
            pat = nd.params["pattern"]
            sel = nd.in_edges[pat[self.pos[nd.nid] % len(pat)]]
            if q[sel.eid] == 0:
                return ("in", sel)
            e = full_out()
            return ("out", e) if e is not None else None
        if not nd.in_edges or self.fired[nd.nid] >= self.ft[nd.nid]:
            return None
        e = empty_in(nd.in_edges)
        if e is not None:
            return ("in", e)
        e = full_out()
        return ("out", e) if e is not None else None

    def output_blocked(self) -> set[int]:
        """Eids of full out-queues of nodes whose only blocker is a full
        output — the candidates a capacity bump can unstick."""
        cands: set[int] = set()
        for nd in self.topo:
            b = self._blocker(nd)
            if b is not None and b[0] == "out":
                for e in nd.out_edges:
                    if self.caps[e.eid] - self.qlen[e.eid] <= 0:
                        cands.add(e.eid)
        return cands

    def counterexample(self) -> Counterexample:
        """Walk the waits-for relation from an unfired cmp: blocked-on-empty
        goes to the producer, blocked-on-full to the consumer.  The walk
        either revisits a node (a waits-for cycle) or reaches a node with
        nothing left to produce (a starvation chain)."""
        start = next(nd for nd in self.topo
                     if nd.op == "cmp" and not self.cmp_done[nd.nid])
        names: list[str] = []
        edescs: list[str] = []
        seen: dict[int, int] = {}
        nd = start
        while nd.nid not in seen:
            seen[nd.nid] = len(names)
            names.append(f"{nd.name}({nd.op})")
            b = self._blocker(nd)
            if b is None:
                fired, total = self.fired[nd.nid], self.ft[nd.nid]
                return Counterexample(
                    kind="starvation-chain", nodes=tuple(names),
                    edges=tuple(edescs),
                    detail=(f"{nd.name} has already produced everything it "
                            f"ever will ({fired}/{total} fires); the tokens "
                            f"downstream is waiting for do not exist"))
            kind, e = b
            state = "empty" if kind == "in" else "full"
            edescs.append(_edge_desc(e, self.qlen[e.eid],
                                     self.caps[e.eid], state))
            nd = e.src if kind == "in" else e.dst
        i = seen[nd.nid]
        return Counterexample(
            kind="waits-cycle", nodes=tuple(names[i:]) + (names[i],),
            edges=tuple(edescs[i:]),
            detail="each node waits on the next; no fire can ever happen")


# ----- lints ----------------------------------------------------------------

def lint_plan(plan, fabric=None) -> list[Finding]:
    """Structural lints over ``plan.dfg`` (+ routed-fabric accounting when
    ``fabric`` is given).  Pure inspection — never mutates the plan."""
    g = plan.dfg
    findings: list[Finding] = []
    try:
        topo = g.topo_order()
    except ValueError as e:
        findings.append(Finding("cyclic-dfg", "error", str(e)))
        return findings
    if not any(nd.op == "cmp" for nd in g.nodes):
        findings.append(Finding(
            "no-cmp", "error",
            "graph has no completion (cmp) node — a run can never finish"))
    for e in g.finalize():
        if e.capacity is not None and e.capacity < 1:
            findings.append(Finding(
                "zero-capacity", "error",
                f"queue {_edge_desc(e, 0, e.capacity, 'declared')} can "
                f"never hold a token", edges=(e.eid,)))
    emit, keeps = token_counts(g)
    for nd in topo:
        ins = [emit[e.src.nid] for e in nd.in_edges]
        if nd.op == "cmp":
            for e in nd.in_edges:
                if emit[e.src.nid] == 0:
                    findings.append(Finding(
                        "cmp-starved", "error",
                        f"completion node {nd.name} port {e.dst_port} never "
                        f"receives a token from {e.src.name}({e.src.op})",
                        nodes=(nd.name, e.src.name)))
        elif nd.op == "sync":
            exp = int(nd.params["expected"])
            arriving = ins[0] if ins else 0
            if exp < 1:
                findings.append(Finding(
                    "sync-degenerate", "error",
                    f"sync {nd.name} expects {exp} tokens; its done trigger "
                    f"can never fire", nodes=(nd.name,)))
            elif arriving < exp:
                findings.append(Finding(
                    "sync-starved", "error",
                    f"sync {nd.name} expects {exp} done tokens but at most "
                    f"{arriving} will ever arrive", nodes=(nd.name,)))
            elif arriving > exp:
                findings.append(Finding(
                    "sync-excess", "warning",
                    f"sync {nd.name} expects {exp} done tokens but "
                    f"{arriving} arrive; {arriving - exp} are never "
                    f"consumed", nodes=(nd.name,)))
        elif nd.op == "filter":
            arr = keeps.get(nd.nid)
            if arr is not None and len(arr) and not arr.any():
                findings.append(Finding(
                    "filter-drops-all", "warning",
                    f"filter {nd.name} drops all {len(arr)} tokens it "
                    f"sees", nodes=(nd.name,)))
        elif nd.op == "imux":
            pat = list(nd.params["pattern"])
            bad = [p for p in pat if p < 0 or p >= len(nd.in_edges)]
            if bad or not pat:
                findings.append(Finding(
                    "splice-pattern", "error",
                    f"imux {nd.name} pattern {pat} references ports {bad} "
                    f"outside its {len(nd.in_edges)} inputs",
                    nodes=(nd.name,)))
            else:
                total = sum(ins)
                rounds, extra = divmod(total, len(pat))
                for port, have in enumerate(ins):
                    need = (rounds * pat.count(port)
                            + sum(1 for j in range(extra) if pat[j] == port))
                    if need != have:
                        findings.append(Finding(
                            "splice-geometry", "error",
                            f"imux {nd.name} pattern consumes {need} tokens "
                            f"from port {port} over {total} fires but "
                            f"{have} arrive", nodes=(nd.name,)))
        elif nd.op in _POP_BOTH and len(ins) >= 2 and ins[0] != ins[1]:
            findings.append(Finding(
                "join-imbalance", "warning",
                f"{nd.name}({nd.op}) joins streams of {ins[0]} vs {ins[1]} "
                f"tokens; the surplus is never consumed", nodes=(nd.name,)))
    cache = getattr(plan, "_compiled_cache", None)
    if cache:
        # entries are (fabric, CompiledPlan) pairs — see compiled_for()
        if any(not cp.is_current() for _fab, cp in cache.values()):
            findings.append(Finding(
                "stale-compile", "warning",
                "cached compiled tables predate a DFG mutation; engines "
                "will transparently recompile"))
    if fabric is not None:
        findings += _lint_fabric(g, fabric)
    return findings


def _lint_fabric(g, fabric) -> list[Finding]:
    """Routed-fabric accounting lints (``fabric`` is a ``RoutedFabric``)."""
    findings: list[Finding] = []
    topo = fabric.topo
    for lk, n in sorted(fabric.channel_load.items()):
        budget = topo.links[lk].channels
        if n > budget:
            findings.append(Finding(
                "channel-overflow", "error",
                f"link {lk[0]}->{lk[1]} carries {n} multicast trees over "
                f"{budget} channels"))
    per_pe: dict = {}
    for nid, coord in fabric.placement.coords.items():
        per_pe[coord] = per_pe.get(coord, 0) + 1
    for coord, n in sorted(per_pe.items()):
        slots = topo.pes[coord].slots
        if n > slots:
            findings.append(Finding(
                "slot-conflict", "error",
                f"PE {coord} holds {n} instructions over its {slots} "
                f"slots"))
    return findings


# ----- throughput bound -----------------------------------------------------

def _required_fires(g, topo, emit, keeps, ft) -> dict[int, int]:
    """Fires each node must perform *before the run can complete* (all cmp
    fired) — a reverse-topo demand pass.  Usually equal to ``ft``; smaller
    when excess tokens exist that completion never waits for."""
    demand: dict[int, int] = {}           # eid -> tokens required on edge
    req: dict[int, int] = {}
    for nd in reversed(topo):
        if nd.op == "cmp":
            r = 1
        else:
            t = max((demand.get(e.eid, 0) for e in nd.out_edges), default=0)
            t = min(t, emit[nd.nid])
            if t == 0:
                r = 0
            elif nd.op == "sync":
                r = int(nd.params["expected"])
            elif nd.op == "filter":
                kpos = np.flatnonzero(keeps[nd.nid])
                r = int(kpos[t - 1]) + 1
            else:
                r = t
        req[nd.nid] = min(r, ft[nd.nid])
        for i, e in enumerate(nd.in_edges):
            if req[nd.nid] == 0:
                d = 0
            elif nd.op == "cmp":
                d = 1
            elif nd.op == "imux":
                pat = nd.params["pattern"]
                d = sum(1 for j in range(req[nd.nid])
                        if pat[j % len(pat)] == i)
            elif nd.op in _POP_BOTH:
                d = req[nd.nid]
            elif i == 0:                  # pop-port-0 ops incl. filter/sync
                d = req[nd.nid]
            else:                         # gating port: one token suffices
                d = 1
            demand[e.eid] = max(demand.get(e.eid, 0), d)
    return req


def throughput_bound(plan, *, fabric=None, machine=None,
                     mem_efficiency: float = 1.0) -> ThroughputBound:
    """Static lower bound on a completing run's cycle count.

    A node at pipeline depth ``d`` (longest in-edge path; an edge costs
    ``1 + hops`` cycles routed, 1 ideal) cannot fire before cycle ``d+1``
    and fires at most once per cycle, so its ``m``-th required fire lands
    at cycle >= ``d+m``.  The memory bound charges every required
    load/store against the shared port's elements-per-cycle budget."""
    g = plan.dfg
    topo = g.topo_order()
    g.finalize()
    emit, keeps = token_counts(g)
    ft = _fires_total(g, topo, emit)
    req = _required_fires(g, topo, emit, keeps, ft)
    hops = {}
    if fabric is not None:
        for e in g.finalize():
            hops[e.eid] = fabric.hops(e)
    depth: dict[int, int] = {}
    for nd in topo:
        depth[nd.nid] = max(
            (depth[e.src.nid] + 1 + hops.get(e.eid, 0)
             for e in nd.in_edges), default=0)
    loads = sum(req[nd.nid] for nd in g.nodes if nd.op == "load")
    stores = sum(req[nd.nid] for nd in g.nodes if nd.op == "store")
    depth_lb = max((depth[nd.nid] + req[nd.nid] for nd in g.nodes),
                   default=0)
    mem_lb = 0
    spec = getattr(plan, "spec", None)
    if machine is not None and spec is not None:
        epc = mem_elems_per_cycle(spec, machine, mem_efficiency)
        if epc > 0:
            mem_lb = math.ceil((loads + stores) / epc)
    cycles_lb = max(depth_lb, mem_lb)
    stage_fill: dict[str, int] = {}
    from repro.telemetry.attribution import stage_label
    for nd in g.nodes:
        lbl = stage_label(nd.stage, nd.op)
        d = depth[nd.nid]
        stage_fill[lbl] = min(stage_fill.get(lbl, d), d)
    fill_lb = min((depth[nd.nid] for nd in g.nodes if nd.op == "store"),
                  default=0)
    return ThroughputBound(
        cycles_lb=cycles_lb, ii_lb=cycles_lb / max(1, stores),
        mem_cycles_lb=mem_lb, depth_cycles_lb=depth_lb,
        loads=loads, stores=stores, fill_lb=fill_lb, stage_fill=stage_fill)


# ----- the verifier ---------------------------------------------------------

def _capacity_certified(plan, findings) -> bool:
    """Fast-path safety certificate: the plan records its analytic per-edge
    minimum capacities (``plan.min_capacities``, the PR 2 mandatory-
    buffering / PR 3 skew-buffer formulas) and every bounded queue is at
    least that minimum (unrecorded edges: the ``apply_min_capacities``
    default).  Capacities only ever help, so any plan at least as large as
    the auto-sizing completes whenever the auto-sized plan does — O(E),
    no token replay needed."""
    mc = getattr(plan, "min_capacities", None)
    if not mc:
        return False
    if any(f.severity == "error" or f.kind in ("join-imbalance",
                                               "sync-excess")
           for f in findings):
        return False
    return all(e.capacity is None
               or e.capacity >= mc.get(id(e), _DEFAULT_MIN_CAP)
               for e in plan.dfg.edges())


def verify_plan(plan, *, fabric=None, machine=None,
                mem_efficiency: float = 1.0) -> StaticReport:
    """Statically verify ``plan`` (optionally placed+routed on ``fabric``):
    lints, deadlock verdict with counterexample + capacity repair, and —
    when the plan can complete — the throughput bound.  Never mutates the
    plan and never runs an engine."""
    findings = lint_plan(plan, fabric)
    if any(f.kind == "cyclic-dfg" for f in findings):
        return StaticReport(
            verdict="deadlock", reason="static-deadlock", certificate="lint",
            findings=findings, counterexample=None,
            suggested_capacities=None, bound=None, stats={})
    g = plan.dfg
    topo = g.topo_order()
    g.finalize()
    emit, keeps = token_counts(g)
    ft = _fires_total(g, topo, emit)
    stats: dict = {"nodes": len(g.nodes), "edges": len(g.finalize()),
                   "total_fires": sum(ft.values())}

    def bound():
        return throughput_bound(plan, fabric=fabric, machine=machine,
                                mem_efficiency=mem_efficiency)

    if not any(nd.op == "cmp" for nd in g.nodes):
        # nothing ever signals completion — structurally stuck by definition
        return StaticReport(
            verdict="deadlock", reason="static-deadlock", certificate="lint",
            findings=findings, counterexample=None,
            suggested_capacities=None, bound=None, stats=stats)
    if _capacity_certified(plan, findings):
        stats["certificate"] = "min-capacities"
        return StaticReport(
            verdict="safe", reason=None, certificate="min-capacities",
            findings=findings, counterexample=None,
            suggested_capacities=None, bound=bound(), stats=stats)

    flow = _TokenFlow(g, emit, keeps, ft)
    status = flow.run()
    counter = None
    suggested: dict[int, int] | None = None
    if status == "blocked":
        counter = flow.counterexample()
        # capacity repair: bump every output-blocked full queue by one and
        # resume — tokens only move forward, so the partial marking stays
        # valid under the larger capacities.  Terminates: total tokens are
        # finite, so either the flow completes or nothing is output-blocked.
        suggested = {}
        rounds = 0
        guard = flow.total_fires + len(flow.edges) + 64
        while status == "blocked":
            cands = flow.output_blocked()
            if not cands:
                suggested = None          # structural: no bump can help
                break
            rounds += 1
            if rounds > guard:
                status = "budget"
                break
            for eid in cands:
                flow.caps[eid] += 1
                suggested[eid] = flow.caps[eid]
            status = flow.run()
        stats["bump_rounds"] = rounds
    stats["sweeps"] = flow.sweeps
    if status == "budget":
        return StaticReport(
            verdict="unknown", reason=None, certificate=None,
            findings=findings, counterexample=counter,
            suggested_capacities=None, bound=None, stats=stats)
    if counter is not None:
        reason = ("static-capacity" if suggested else "static-deadlock")
        return StaticReport(
            verdict="deadlock", reason=reason, certificate="quiescence",
            findings=findings, counterexample=counter,
            suggested_capacities=suggested or None, bound=None, stats=stats)
    stats["certificate"] = "quiescence"
    return StaticReport(
        verdict="safe", reason=None, certificate="quiescence",
        findings=findings, counterexample=None, suggested_capacities=None,
        bound=bound(), stats=stats)


def suggest_capacity_fix(plan) -> dict[int, int] | None:
    """The verifier's repair hint for a deadlocking plan: an ``{eid:
    capacity}`` map proven sufficient for completion, or ``None`` when the
    plan is safe, structurally stuck, or unanalyzable."""
    try:
        report = verify_plan(plan)
    except Exception:                     # diagnosis must never mask errors
        return None
    return report.suggested_capacities


def apply_suggested_capacities(plan, suggested: dict) -> int:
    """Grow the plan's queues to a ``suggested_capacities`` hint (eid keys;
    JSON-string keys from cache records accepted).  Returns the number of
    edges grown; marks the DFG mutated so compiled tables invalidate."""
    edges = plan.dfg.finalize()
    grown = 0
    for eid, cap in suggested.items():
        e = edges[int(eid)]
        if e.capacity is not None and e.capacity < int(cap):
            e.capacity = int(cap)
            grown += 1
    if grown:
        plan.dfg.mark_mutated()
    return grown


def check_static(plan, *, fabric=None, machine=None,
                 mem_efficiency: float = 1.0) -> StaticReport:
    """``simulate(..., verify="static")`` pre-flight: run the verifier and
    raise :class:`StaticDeadlock` (with the repair hint attached) when the
    plan provably cannot complete.  Returns the report otherwise."""
    report = verify_plan(plan, fabric=fabric, machine=machine,
                         mem_efficiency=mem_efficiency)
    if report.verdict == "deadlock":
        raise StaticDeadlock(
            f"static verifier rejected the plan before simulation: "
            f"{report.describe()}", report=report)
    return report
