"""Checkpoint manager: atomic, async-capable, keep-N, mesh-agnostic.

Layout:  <dir>/step_<k>/  { manifest.json, arr_<i>.npy ... }
  * arrays are written with ``jax.device_get`` (host, unsharded) and a JSON
    manifest of the flattened tree paths — resuming onto a *different* mesh
    just re-shards at load (elastic scaling; DESIGN.md §6).
  * writes go to ``<dir>/.tmp_step_<k>`` then ``os.rename`` — a crash mid-write
    can never corrupt the latest checkpoint (restart-safety).
  * ``save(..., blocking=False)`` hands the host arrays to a writer thread so
    the train loop overlaps checkpoint I/O with compute.
  * data-pipeline state (step counter etc.) rides in the manifest, so a
    restore resumes the exact batch sequence.

At 1000+-node scale this single-writer host format is replaced by per-host
shard files (same manifest schema, ``shard_<host>`` suffix); the tree/path
logic below is unchanged — noted in README §Scale.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    # jax.tree.flatten_with_path only exists on newer jax; tree_util spelling
    # is available everywhere.
    flatten_with_path = getattr(jax.tree, "flatten_with_path",
                                jax.tree_util.tree_flatten_with_path)
    leaves, treedef = flatten_with_path(tree)
    named = [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves]
    return named, treedef


# numpy can't serialize bf16/fp8 — store them as same-width uints and keep
# the logical dtype in the manifest.
_UINT_VIEW = {1: np.uint8, 2: np.uint16, 4: np.uint32}


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    dt = str(arr.dtype)
    try:
        np.dtype(dt)
        if arr.dtype.kind in "fiub":
            return arr, dt
    except TypeError:
        pass
    return arr.view(_UINT_VIEW[arr.dtype.itemsize]), dt


def _decode(arr: np.ndarray, logical_dtype: str) -> np.ndarray:
    if str(arr.dtype) == logical_dtype:
        return arr
    import ml_dtypes  # bundled with jax
    return arr.view(np.dtype(getattr(ml_dtypes, logical_dtype)))


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ----- save -------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None,
             blocking: bool = True) -> None:
        self.wait()   # never two writers at once (blocking save could race
                      # an in-flight async save of the same step)
        named, _ = _flatten(tree)
        host = [(name, np.asarray(jax.device_get(leaf)))
                for name, leaf in named]
        if blocking:
            self._write(step, host, extra or {})
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: list, extra: dict) -> None:
        tmp = os.path.join(self.dir, f".tmp_step_{step:08d}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra, "arrays": []}
        for i, (name, arr) in enumerate(host):
            fn = f"arr_{i:05d}.npy"
            enc, logical = _encode(arr)
            np.save(os.path.join(tmp, fn), enc)
            manifest["arrays"].append(
                {"name": name, "file": fn, "shape": list(arr.shape),
                 "dtype": logical})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ----- restore ------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any | None = None
                ) -> tuple[Any, dict]:
        """Restore into the structure of ``like`` (a tree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching tree of
        NamedShardings — arrays go straight to their (possibly different)
        mesh placement: elastic resume."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_name = {a["name"]: a for a in manifest["arrays"]}
        named, treedef = _flatten(like)
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(named))
        vals = []
        for (name, leaf), shard in zip(named, shard_leaves):
            a = by_name[name]
            arr = _decode(np.load(os.path.join(d, a["file"])), a["dtype"])
            expect = tuple(leaf.shape)
            if tuple(arr.shape) != expect:
                raise ValueError(f"ckpt shape mismatch for {name}: "
                                 f"{arr.shape} vs {expect}")
            if shard is not None:
                vals.append(jax.device_put(arr, shard))
            else:
                vals.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        return jax.tree.unflatten(treedef, vals), manifest["extra"]
