"""Architecture configs + assigned input shapes.

Every assigned architecture has its own ``configs/<id>.py`` declaring the
exact published config; this module holds the :class:`ArchConfig` schema, the
shape table, and the ``--arch`` registry.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


# Assigned LM shape set (same four for every arch; applicability filtered by
# arch family — see cells()).
SHAPES: dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads

    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    window: int = 0                   # sliding-window size for "local" blocks
    mrope_sections: Optional[tuple[int, int, int]] = None   # M-RoPE (t,h,w)
    attn_logit_softcap: float = 0.0

    # block pattern, cycled over layers: "attn" | "local" | "rglru" | "rwkv"
    block_pattern: tuple[str, ...] = ("attn",)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 512

    # recurrent blocks
    lru_width: int = 0                # 0 -> d_model
    conv_width: int = 4

    # encoder-decoder (audio) / frontend stubs
    encoder_layers: int = 0
    encoder_seq: int = 0              # stub frame count fed to the encoder
    vision_tokens: int = 0            # stub patch-embedding count (vlm)

    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"
    sub_quadratic: bool = False       # may run long_500k
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def kind_of_layer(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def supports_shape(self, shape: ShapeSpec) -> bool:
        if shape.name == "long_500k":
            return self.sub_quadratic
        return True

    def params_billion_estimate(self) -> float:
        """Rough N for 6*N*D roofline accounting (model body, active experts
        counted for MoE)."""
        d, f, L = self.d_model, self.d_ff, self.num_layers
        hd = self.resolved_head_dim
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + \
            self.num_heads * hd * d
        if self.num_experts:
            mlp = 3 * d * f * self.experts_per_token + d * self.num_experts
        else:
            mlp = 3 * d * f
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        enc = self.encoder_layers * (attn + 3 * d * f)
        return (L * (attn + mlp) + emb + enc) / 1e9


_REGISTRY: dict[str, str] = {
    "recurrentgemma-2b":    "repro.configs.recurrentgemma_2b",
    "tinyllama-1.1b":       "repro.configs.tinyllama_1_1b",
    "qwen3-32b":            "repro.configs.qwen3_32b",
    "command-r-plus-104b":  "repro.configs.command_r_plus_104b",
    "qwen2.5-3b":           "repro.configs.qwen2_5_3b",
    "qwen2-vl-2b":          "repro.configs.qwen2_vl_2b",
    "rwkv6-7b":             "repro.configs.rwkv6_7b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "whisper-tiny":         "repro.configs.whisper_tiny",
}


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return importlib.import_module(_REGISTRY[name]).CONFIG


def get_reduced_config(name: str) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    return importlib.import_module(_REGISTRY[name]).reduced()


def cells() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, with family-based skips applied."""
    out = []
    for arch in list_archs():
        cfg = get_config(arch)
        for sname, sh in SHAPES.items():
            if cfg.supports_shape(sh):
                out.append((arch, sname))
    return out
