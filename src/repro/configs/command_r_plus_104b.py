"""command-r-plus-104b [dense] — GQA, no-bias.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000
[hf CohereForAI/c4ai-command-r-plus; unverified tier per assignment].
Cohere ties input/output embeddings and uses parallel attn+FFN residual
blocks; we keep the standard sequential block (config dims are what is
assigned).  Pure full attention -> long_500k skipped.
"""
from repro.configs import ArchConfig
import dataclasses

CONFIG = ArchConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12_288, num_heads=96, num_kv_heads=8,
    d_ff=33_792, vocab_size=256_000, rope_theta=75_000_000.0,
    qkv_bias=False, tie_embeddings=True, act="silu", sub_quadratic=False)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=96, num_heads=6, num_kv_heads=2,
        d_ff=256, vocab_size=512, dtype="float32")
