"""granite-moe-1b-a400m [moe] — 32 experts top-8.

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 32e top-8
[hf ibm-granite/granite-3.0-1b-a400m-base].
GShard-style top-k routing with capacity factor; experts shard on the model
axis (EP).  Pure full attention -> long_500k skipped.
"""
from repro.configs import ArchConfig
import dataclasses

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49_155, num_experts=32, experts_per_token=8,
    rope_theta=10_000.0, tie_embeddings=True, act="silu",
    sub_quadratic=False)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=64, vocab_size=512, num_experts=4, experts_per_token=2,
        dtype="float32")
