"""granite-moe-3b-a800m [moe] — 40 experts top-8.

32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 40e top-8
[hf ibm-granite/granite-3.0-3b-a800m-base].
NOTE: the assignment line says "MoE 40e top-8" while its trailing comment says
32 experts; we follow the config line (40).  40 % 16 != 0, so EP falls back to
replicated experts with d_ff TP — exactly the divisibility-fallback case the
sharding rules exist for (DESIGN.md §7).
"""
from repro.configs import ArchConfig
import dataclasses

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49_155, num_experts=40, experts_per_token=8,
    rope_theta=10_000.0, tie_embeddings=True, act="silu",
    sub_quadratic=False)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=64, vocab_size=512, num_experts=4, experts_per_token=2,
        dtype="float32")
