"""qwen2.5-3b [dense] — GQA, QKV bias.

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936
[hf Qwen/Qwen2.5-3B; assignment dims].
Pure full attention -> long_500k skipped.
"""
from repro.configs import ArchConfig
import dataclasses

CONFIG = ArchConfig(
    name="qwen2.5-3b", family="dense",
    num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
    d_ff=11_008, vocab_size=151_936, qkv_bias=True,
    rope_theta=1_000_000.0, tie_embeddings=True, act="silu",
    sub_quadratic=False)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=160, vocab_size=512, dtype="float32")
