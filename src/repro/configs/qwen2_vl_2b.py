"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (frontend stubbed).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
[arXiv:2409.12191; hf Qwen/Qwen2-VL-2B].
The vision tower is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings (vision_tokens x d_model) that are scatter-merged
into the token stream; the backbone applies M-RoPE with (t, h, w) sections
(16, 24, 24) over head_dim=128.
"""
from repro.configs import ArchConfig
import dataclasses

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    head_dim=128, d_ff=8960, vocab_size=151_936, qkv_bias=True,
    rope_theta=1_000_000.0, mrope_sections=(16, 24, 24),
    vision_tokens=256, tie_embeddings=True, act="silu",
    sub_quadratic=False)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=160, vocab_size=512, vision_tokens=16,
        mrope_sections=(2, 3, 3), dtype="float32")
