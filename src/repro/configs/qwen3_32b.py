"""qwen3-32b [dense] — qk_norm, GQA.

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936
[hf Qwen/Qwen3-32B family; config per assignment].
head_dim=128, QK-RMSNorm per head, no QKV bias (Qwen3 dropped biases).
Pure full attention -> long_500k skipped.
"""
from repro.configs import ArchConfig
import dataclasses

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=25_600, vocab_size=151_936,
    qk_norm=True, qkv_bias=False, rope_theta=1_000_000.0,
    tie_embeddings=False, act="silu", sub_quadratic=False)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=160, vocab_size=512, dtype="float32")
