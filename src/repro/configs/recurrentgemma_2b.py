"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 ratio.

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, window 2048
[arXiv:2402.19427 (Griffin); hf google/recurrentgemma-2b].
Block pattern (rglru, rglru, local) cycled — two recurrent blocks per local
attention block.  Sub-quadratic: runs long_500k (LRU state is O(1), local
attention cache is window-bounded).
"""
from repro.configs import ArchConfig
import dataclasses

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    head_dim=256, d_ff=7680, vocab_size=256_000,
    block_pattern=("rglru", "rglru", "local"), window=2048,
    lru_width=2560, conv_width=4, rope_theta=10_000.0,
    tie_embeddings=True, act="gelu", sub_quadratic=True,
    notes="Griffin-style hybrid; MQA on local-attn layers; RG-LRU c=8.")


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=6, d_model=64, num_heads=2, num_kv_heads=1,
        head_dim=32, d_ff=128, vocab_size=512, window=32, lru_width=64,
        dtype="float32")
