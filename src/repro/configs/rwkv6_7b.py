"""rwkv6-7b [ssm] — Finch, data-dependent decay; attention-free.

32L d_model=4096 d_ff=14336 vocab=65536, head_dim=64 (64 WKV heads)
[arXiv:2404.05892; hf RWKV/rwkv-6-world-7b].
Recurrent (O(1)-state) -> runs long_500k.  The paper's stencil mapping applies
to the token-shift (radius-1 stencil); the WKV scan itself is a wavefront
recurrence (DESIGN.md §Arch-applicability).
"""
from repro.configs import ArchConfig
import dataclasses

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    head_dim=64, d_ff=14_336, vocab_size=65_536,
    block_pattern=("rwkv",), tie_embeddings=False, act="relu",
    sub_quadratic=True,
    notes="num_heads here = WKV heads (d_model / 64); attention-free.")


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=160, vocab_size=512, dtype="float32")
