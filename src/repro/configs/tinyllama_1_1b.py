"""tinyllama-1.1b [dense] — llama2-arch small.

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000
[arXiv:2401.02385; hf TinyLlama/TinyLlama-1.1B].
Pure full attention -> long_500k skipped (quadratic).
"""
from repro.configs import ArchConfig
import dataclasses

CONFIG = ArchConfig(
    name="tinyllama-1.1b", family="dense",
    num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=5632, vocab_size=32_000, rope_theta=10_000.0,
    tie_embeddings=False, act="silu", sub_quadratic=False)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=160, vocab_size=512, dtype="float32")
