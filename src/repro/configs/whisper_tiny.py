"""whisper-tiny [audio] — encoder-decoder, conv frontend stubbed.

4L encoder + 4L decoder, d_model=384 6H (MHA kv=6) d_ff=1536 vocab=51865
[arXiv:2212.04356; unverified tier].
The conv frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (encoder_seq=1500 x d_model).  Decode shapes
exercise the *decoder backbone* with the assigned KV length even though the
real model caps positions at 448 (DESIGN.md §Arch-applicability).
Full attention -> long_500k skipped.
"""
from repro.configs import ArchConfig
import dataclasses

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51_865, encoder_layers=4, encoder_seq=1500,
    rope_theta=0.0,              # whisper uses learned/sinusoidal positions
    tie_embeddings=True, act="gelu", sub_quadratic=False)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, encoder_layers=2, encoder_seq=32,
        dtype="float32")
