"""The paper's contribution: stencil specs, CGRA mapping, simulation, roofline."""
from repro.core.spec import (StencilSpec, heat_2d, heat_3d, paper_stencil_1d,
                             paper_stencil_2d, star_3d)
from repro.core.reference import stencil_reference, stencil_reference_np
from repro.core.roofline import CGRA, TPU_V5E, V100, Machine, analyze, TpuRooflineTerms
from repro.core.mapping import (BlockPlan, MappingPlan, map_1d, map_2d,
                                map_3d, map_nd, plan_blocks)
from repro.core.simulator import SimDeadlock, SimResult, simulate
from repro.core.temporal import crossover_timesteps, fusion_report

__all__ = ["StencilSpec", "heat_2d", "heat_3d", "paper_stencil_1d",
           "paper_stencil_2d", "star_3d", "stencil_reference",
           "stencil_reference_np", "CGRA", "TPU_V5E", "V100", "Machine",
           "analyze", "TpuRooflineTerms", "BlockPlan", "MappingPlan",
           "map_1d", "map_2d", "map_3d", "map_nd", "plan_blocks",
           "SimDeadlock", "SimResult", "simulate", "crossover_timesteps",
           "fusion_report"]
