"""The paper's contribution: stencil specs, CGRA mapping, simulation, roofline."""
from repro.core.spec import StencilSpec, heat_2d, paper_stencil_1d, paper_stencil_2d
from repro.core.reference import stencil_reference, stencil_reference_np
from repro.core.roofline import CGRA, TPU_V5E, V100, Machine, analyze, TpuRooflineTerms
from repro.core.mapping import MappingPlan, map_1d, map_2d, plan_blocks
from repro.core.simulator import SimDeadlock, SimResult, simulate
from repro.core.temporal import crossover_timesteps, fusion_report

__all__ = ["StencilSpec", "heat_2d", "paper_stencil_1d", "paper_stencil_2d",
           "stencil_reference", "stencil_reference_np", "CGRA", "TPU_V5E",
           "V100", "Machine", "analyze", "TpuRooflineTerms", "MappingPlan",
           "map_1d", "map_2d", "plan_blocks", "SimDeadlock", "SimResult",
           "simulate", "crossover_timesteps", "fusion_report"]
