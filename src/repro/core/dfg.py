"""Dataflow-graph DSL (paper §V).

An algorithm for the CGRA is a graph whose nodes are instructions mapped to
PEs and whose edges are producer→consumer queues.  The paper built a C-based
DSL that creates each pipeline stage (control / reader / compute / writer /
sync workers) parametrically, auto-connects ports by name, emits a high-level
assembly program, and renders Graphviz dot.  This module is that tool in
Python.

Node op vocabulary (matches the paper's Fig. 7 legend):
  ``load``/``store``      memory ops (rate-limited by the memory model)
  ``mul``/``mac``/``add`` arithmetic PEs (1 / 2 / 1 flops per fire)
  ``filter``              data-filtering PE (0^m 1^n 0^p patterns, §III-A)
  ``addr``                address/index generator (control unit)
  ``sync``                store counter -> done trigger
  ``mux``/``demux``/``copy``/``cmp``  pass-through utility ops
  ``imux``                pattern-driven interleaving mux (program-graph
                          re-interleave buffers, ``repro.program.lower``)
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Optional

FLOPS_PER_OP = {"mul": 1, "mac": 2, "add": 1}

# dot colours follow the paper's Fig. 7 legend.
_DOT_COLORS = {
    "mux": "lightyellow", "imux": "lightyellow", "mul": "orange", "mac": "red",
    "demux": "lightblue",
    "add": "green", "addr": "cyan", "load": "palegreen", "store": "plum",
    "filter": "gray80", "sync": "gold", "copy": "gray90", "cmp": "gray90",
}


@dataclasses.dataclass
class Edge:
    """A producer→consumer queue."""
    src: "Node"
    dst: "Node"
    dst_port: int
    capacity: Optional[int] = None       # None = unbounded
    q: deque = dataclasses.field(default_factory=deque)
    max_occupancy: int = 0
    eid: int = -1                        # dense id, assigned by DFG.finalize()

    def full(self) -> bool:
        return self.capacity is not None and len(self.q) >= self.capacity

    def push(self, v) -> None:
        self.q.append(v)
        if len(self.q) > self.max_occupancy:
            self.max_occupancy = len(self.q)


@dataclasses.dataclass
class Node:
    """One instruction mapped to one PE."""
    nid: int
    op: str
    name: str
    stage: str = ""                      # reader|compute|writer|sync|control
    worker: int = -1                     # logical worker id
    params: dict = dataclasses.field(default_factory=dict)
    in_edges: list = dataclasses.field(default_factory=list)   # port-ordered
    out_edges: list = dataclasses.field(default_factory=list)  # broadcast set
    fires: int = 0


class DFG:
    """Builder + container.  ``add``/``connect`` mirror the paper's DSL API."""

    def __init__(self, name: str = "dfg"):
        self.name = name
        self.nodes: list[Node] = []
        self._ids = itertools.count()
        self._version = 0                 # bumped on add/connect
        self._finalized_version = -1
        self._edge_list: list[Edge] = []

    # ----- construction -----------------------------------------------------
    def add(self, op: str, name: str = "", *, stage: str = "", worker: int = -1,
            **params) -> Node:
        n = Node(nid=next(self._ids), op=op, name=name or f"{op}{worker}",
                 stage=stage, worker=worker, params=params)
        self.nodes.append(n)
        self._version += 1
        return n

    def connect(self, src: Node, dst: Node, port: int | None = None,
                capacity: Optional[int] = None) -> Edge:
        port = len(dst.in_edges) if port is None else port
        e = Edge(src=src, dst=dst, dst_port=port, capacity=capacity)
        src.out_edges.append(e)
        # keep in_edges port-ordered
        dst.in_edges.append(e)
        dst.in_edges.sort(key=lambda ee: ee.dst_port)
        self._version += 1
        return e

    # ----- compile hooks (repro.core.engine) ---------------------------------
    @property
    def version(self) -> int:
        """Monotone mutation counter — compiled tables key on it so stale
        compiles are detected (see ``repro.core.engine.compile``)."""
        return self._version

    def mark_mutated(self) -> None:
        """Record an out-of-band mutation (e.g. edge-capacity rewrites by
        ``apply_min_capacities``) so cached compiled plans invalidate."""
        self._version += 1

    def finalize(self) -> list[Edge]:
        """Assign dense ``Edge.eid`` ids (producer order, then port order) and
        return the edge list.  Idempotent until the graph is mutated again;
        node ``nid``s are already dense by construction."""
        if self._finalized_version != self._version:
            self._edge_list = []
            for n in self.nodes:
                for e in n.out_edges:
                    e.eid = len(self._edge_list)
                    self._edge_list.append(e)
            self._finalized_version = self._version
        return self._edge_list

    def topo_order(self) -> list[Node]:
        """Kahn topological order (worker pipelines are feed-forward DAGs)."""
        indeg = {n.nid: len(n.in_edges) for n in self.nodes}
        by_nid = {n.nid: n for n in self.nodes}
        ready = [n for n in self.nodes if not indeg[n.nid]]
        out: list[Node] = []
        while ready:
            n = ready.pop()
            out.append(n)
            for e in n.out_edges:
                indeg[e.dst.nid] -= 1
                if indeg[e.dst.nid] == 0:
                    ready.append(by_nid[e.dst.nid])
        if len(out) != len(self.nodes):
            raise ValueError(f"DFG {self.name!r} has a cycle; cannot compile")
        return out

    # ----- inventory ---------------------------------------------------------
    def pe_counts(self) -> dict[str, int]:
        c: dict[str, int] = {}
        for n in self.nodes:
            c[n.op] = c.get(n.op, 0) + 1
        return c

    def mac_pes(self) -> int:
        """MAC-slot PEs the roofline counts (mul+mac+add occupy MAC-capable PEs)."""
        return sum(1 for n in self.nodes if n.op in FLOPS_PER_OP)

    def edges(self):
        for n in self.nodes:
            yield from n.out_edges

    # ----- emitters (paper §V: dot + high-level assembly) --------------------
    def to_dot(self) -> str:
        lines = [f'digraph "{self.name}" {{', "  rankdir=LR;", "  node [style=filled];"]
        stages = {}
        for n in self.nodes:
            stages.setdefault((n.stage, n.worker), []).append(n)
        for (stage, worker), ns in sorted(stages.items()):
            lines.append(f'  subgraph "cluster_{stage}_{worker}" {{')
            lines.append(f'    label="{stage} worker {worker}";')
            for n in ns:
                color = _DOT_COLORS.get(n.op, "white")
                lines.append(
                    f'    n{n.nid} [label="{n.name}\\n{n.op}", fillcolor="{color}"];')
            lines.append("  }")
        for e in self.edges():
            cap = "" if e.capacity is None else f' [label="q={e.capacity}"]'
            lines.append(f"  n{e.src.nid} -> n{e.dst.nid}{cap};")
        lines.append("}")
        return "\n".join(lines)

    def to_assembly(self) -> str:
        """High-level assembly: one line per PE instruction, named ports."""
        out = [f"; {self.name}: {len(self.nodes)} PEs, "
               f"{sum(1 for _ in self.edges())} queues"]
        for n in self.nodes:
            srcs = ",".join(f"n{e.src.nid}.out" for e in n.in_edges) or "-"
            dsts = ",".join(f"n{e.dst.nid}.p{e.dst_port}" for e in n.out_edges) or "-"
            ps = " ".join(f"{k}={v}" for k, v in n.params.items()
                          if not callable(v) and not isinstance(v, (list, dict)))
            out.append(f"PE{n.nid:<5} {n.op:<7} dst=[{dsts}] src=[{srcs}] "
                       f"stage={n.stage}/{n.worker} {ps}")
        return "\n".join(out)
