"""Simulation backends: reference interpreter + compiled vector engine.

``repro.core.simulator.simulate(..., engine="interp"|"vector")`` dispatches
here.  Both backends implement identical semantics over the same
:class:`~repro.core.engine.common.RawStats` contract; the vector engine
compiles the DFG once into struct-of-arrays tables
(:mod:`repro.core.engine.compile`) and runs each cycle as a handful of
vectorized numpy passes (:mod:`repro.core.engine.vector`).
"""
from repro.core.engine.common import RawStats, SimDeadlock
from repro.core.engine.compile import (CompiledPlan, StaleCompiledPlanError,
                                       compile_plan, compiled_for)

__all__ = ["RawStats", "SimDeadlock", "CompiledPlan",
           "StaleCompiledPlanError", "compile_plan", "compiled_for"]
