"""Simulation backends: reference interpreter, compiled vector engine, and
the jitted/batched jax engine.

``repro.core.simulator.simulate(..., engine="interp"|"vector"|"jax")``
dispatches here.  All backends implement identical semantics over the same
:class:`~repro.core.engine.common.RawStats` contract; the vector engine
compiles the DFG once into struct-of-arrays tables
(:mod:`repro.core.engine.compile`) and runs each cycle as a handful of
vectorized numpy passes (:mod:`repro.core.engine.vector`); the jax engine
(:mod:`repro.core.engine.jax_engine`, imported lazily — it pulls in jax)
runs the same tables as a jitted ``lax.while_loop`` fixed point and can
``vmap`` a whole batch of plans into one device call.

``ENGINE_SEMANTICS`` names each backend's cycle-semantics version.  It is
part of the auto-tuner's EvalCache scope key, so measurements taken by one
engine are never replayed as another's (and a semantics bump invalidates
that engine's cached evals only).
"""
from repro.core.engine.common import RawStats, SimDeadlock
from repro.core.engine.compile import (CompiledPlan, StaleCompiledPlanError,
                                       compile_plan, compiled_for)

#: engine name -> semantics version tag (EvalCache scope component).
#: "jax-batch/v1" is mirrored by ``jax_engine.SEMANTICS`` — keep in sync.
ENGINE_SEMANTICS = {"interp": "interp/v1", "vector": "vector-soa/v1",
                    "jax": "jax-batch/v1"}

__all__ = ["RawStats", "SimDeadlock", "CompiledPlan",
           "StaleCompiledPlanError", "compile_plan", "compiled_for",
           "ENGINE_SEMANTICS"]
