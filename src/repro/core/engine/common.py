"""Shared contract between the simulation backends (interp & vector).

Both engines consume the same inputs — a plan (``MappingPlan`` or program
``ProgramPlan``), a flat input image, a preallocated flat output image, the
per-cycle memory-element budget — and return the same :class:`RawStats`.
``repro.core.simulator.simulate`` turns RawStats into the public
:class:`~repro.core.simulator.SimResult`; the engines themselves never touch
roofline math or result formatting, so the two backends can be compared
field-for-field in tests.
"""
from __future__ import annotations

import dataclasses


class SimDeadlock(RuntimeError):
    """Raised on deadlock or a ``max_cycles`` overrun.  ``cycles`` carries
    how many cycles were simulated before giving up (budget accounting in
    ``repro.explore``); ``timed_out`` distinguishes the overrun case.

    ``stall_summary`` is the stall-attribution diagnostic (which nodes were
    blocked on what — see ``repro.telemetry``): the last-N-cycle window when
    a telemetry sink was attached, the final-cycle classification otherwise.
    Both engines embed its rendered form in the exception message.

    ``suggested_capacities`` is the static verifier's repair hint
    (``repro.analysis.static_verify``): an ``{edge eid: capacity}`` map
    proven sufficient for the plan to complete, or ``None`` when the
    deadlock is structural (no capacity bump helps) or the hint was never
    computed (e.g. a timeout).  The stall table says *where* the pipeline
    stuck; this says *how to fix it*."""

    def __init__(self, msg: str, *, cycles: int = 0,
                 timed_out: bool = False,
                 stall_summary: dict | None = None,
                 suggested_capacities: dict | None = None):
        super().__init__(msg)
        self.cycles = cycles
        self.timed_out = timed_out
        self.stall_summary = stall_summary
        self.suggested_capacities = suggested_capacities


@dataclasses.dataclass
class RawStats:
    """Engine-agnostic simulation outcome (the cross-validated surface)."""
    cycles: int
    flops: int
    loads: int
    stores: int
    fires: dict[str, int]
    max_queue_total: int
    token_hops: int = 0              # network-aware mode only
    stall_cycles: int = 0


def mem_elems_per_cycle(spec, machine, mem_efficiency: float) -> float:
    """Element-ops per cycle the shared memory port sustains (fractional
    credit is carried across cycles by the engines)."""
    return mem_efficiency * machine.bw_gbps / machine.clock_ghz / (
        8 if spec.dtype == "float64" else spec.bytes_per_elem)


def deadlock_message(cycles: int, nodes) -> str:
    """The diagnostic both engines raise on deadlock: names + queue states of
    (up to 8) nodes that hold input tokens but cannot fire."""
    stuck = [f"{nd.name}({nd.op}) in={[len(e.q) for e in nd.in_edges]} "
             f"outfull={[e.full() for e in nd.out_edges]}"
             for nd in nodes if any(e.q for e in nd.in_edges)][:8]
    return f"deadlock at cycle {cycles}; sample blocked nodes: {stuck}"
