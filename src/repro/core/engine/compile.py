"""Compile a plan's DFG once into struct-of-arrays tables (vector backend).

The vector engine never touches ``Node``/``Edge`` objects in its cycle loop.
:func:`compile_plan` flattens the graph into dense numpy tables keyed by the
node's ``nid`` and the edge's ``eid`` (both dense — see ``DFG.finalize``):

* **op-kind buckets** — index arrays per executable kind (``addr``, memory,
  linear arithmetic, ``filter``, ``sync``, ``cmp``, ``imux``) with aligned
  parameter arrays (coefficients, flop weights, expected counts, …).
* **edge matrices + CSR** — a padded ``in_mat``/``out_mat`` (node × port) for
  one-gather eligibility snapshots, plus a CSR ``out_start``/``out_flat`` for
  broadcast expansion.  A sentinel edge (id ``n_edges``) pads ragged rows:
  it always looks non-empty for input checks and never-full for output
  checks, and its ring slot reads 0.0.
* **ring-buffer pool** — every queue lives in one preallocated float64 pool
  with per-edge ``base``/``phys`` (physical size) and runtime ``head``/``len``
  arrays; unbounded queues start small and the pool is regrown (amortized
  doubling) when one fills.
* **keep-mask arrays** — each filter's ``0^m 1^n 0^p`` pattern is evaluated
  for every stream position it will ever see, vectorized from the compiled
  ``keep_vec`` (digit windows) / ``keep_mod`` (re-interleave stride) params
  the mapper attaches; the token-count topo pass computes how many tokens
  each queue carries over a full run (also the exact per-filter horizon).
* **memory-op tables** — per-node load/store flat-index tables concatenated
  into one array with offsets, in rotating-arbiter bucket order.

Linear arithmetic is unified: ``v = A*front(in0) [+ B*front(in1)]`` covers
``mul`` (A=coeff), ``mac`` (A=1, B=coeff), ``add`` (A=B=1) and the
pass-throughs (A=1) — with the B term applied only where present, so results
stay bit-identical to the interpreter's scalar expressions.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import numpy as np

from repro.core.dfg import DFG, FLOPS_PER_OP, Node

if TYPE_CHECKING:  # pragma: no cover - avoids core <-> fabric import cycle
    from repro.fabric.route import RoutedFabric

UNBOUNDED = 1 << 62
LIN_OPS = ("mul", "mac", "add", "copy", "mux", "demux")
SLOT_BITS = 44            # link booking key = (link id << SLOT_BITS) | slot


class StaleCompiledPlanError(RuntimeError):
    """The plan's DFG (topology or queue capacities) changed after
    ``compile_plan()``; the compiled tables no longer describe it."""


def _keep_array(nd: Node, T: int) -> np.ndarray:
    """``keep(s)`` for every stream position ``s < T``, vectorized when the
    mapper attached compiled pattern params (callable fallback otherwise)."""
    p = nd.params
    if T <= 0:
        return np.zeros(0, dtype=bool)
    s = np.arange(T, dtype=np.int64)
    kv = p.get("keep_vec")
    if kv is not None:                       # N-D digit windows (band_keep)
        windows, counts = kv["windows"], kv["counts"]
        if len(windows) == 1:
            ilo, ihi = windows[0]
            return (s >= ilo) & (s < ihi)
        ok = np.ones(T, dtype=bool)
        for cnt, (ilo, ihi) in list(zip(counts, windows))[1:][::-1]:
            s, d = np.divmod(s, cnt)
            ok &= (d >= ilo) & (d < ihi)
        olo, ohi = windows[0]
        return ok & (s >= olo) & (s < ohi)
    km = p.get("keep_mod")
    if km is not None:                       # re-interleave row stride
        return ((km["off"] + (s % km["cnt"]) * km["step"]) % km["mod"]) == 0
    keep = p["keep"]
    return np.fromiter((keep(k) for k in range(T)), dtype=bool, count=T)


def _token_counts(g: DFG) -> tuple[dict[int, int], dict[int, np.ndarray]]:
    """Tokens each node emits per out-edge over a full run (exact for the
    worker-pipeline op vocabulary), plus per-filter keep-mask arrays sized to
    the producer's emission count."""
    emit: dict[int, int] = {}
    keeps: dict[int, np.ndarray] = {}
    for nd in g.topo_order():
        ins = [emit[e.src.nid] for e in nd.in_edges]
        op = nd.op
        if op == "addr":
            t = int(nd.params["count"])
        elif op == "filter":
            arr = _keep_array(nd, ins[0] if ins else 0)
            keeps[nd.nid] = arr
            t = int(arr.sum())
        elif op == "imux":             # forwards every popped input token
            t = sum(ins)
        elif op == "sync":
            t = 1
        elif op == "cmp":
            t = 0
        else:  # load/mul/mac/add/store/copy/...: one fire per input set
            t = min(ins) if ins else 0
        emit[nd.nid] = t
    return emit, keeps


# public names for the static verifier (repro.analysis.static_verify): the
# token-count topo pass *is* the shared ground truth for how many tokens
# every queue carries over a full run — the analyzer must not fork it.
token_counts = _token_counts
keep_array = _keep_array


@dataclasses.dataclass
class CompiledNetwork:
    """Static route tables for network-aware vector simulation."""
    book: list                           # nid -> [(eid, (step, …)), …]; a
                                         # step is a bare booking key when
                                         # wpc1, else a (key, wpc) pair
    loc_start: np.ndarray                # CSR over *local* out-edges only
    loc_flat: np.ndarray
    loc_py: list                         # nid -> [local eids] (sparse path)
    wpc1: bool                           # every link has words_per_cycle 1


# op-kind codes for the sparse (scalar) execute path
K_ADDR, K_LOAD, K_STORE, K_LIN, K_FLT, K_SYNC, K_CMP, K_IMUX = range(8)
_KIND_OF_OP = {"addr": K_ADDR, "load": K_LOAD, "store": K_STORE,
               "filter": K_FLT, "sync": K_SYNC, "cmp": K_CMP,
               "imux": K_IMUX, **{op: K_LIN for op in LIN_OPS}}


@dataclasses.dataclass
class CompiledPlan:
    plan: object
    g: DFG
    nodes: list[Node]
    edges: list
    n_nodes: int
    n_edges: int
    n_cmp: int
    # edge tables (all sized n_edges+1; the last row is the sentinel edge)
    cap: np.ndarray
    phys0: np.ndarray
    pop_first: np.ndarray
    # eligibility matrices + broadcast CSR
    in_mat: np.ndarray
    out_mat: np.ndarray
    capmat: np.ndarray                   # cap[out_mat], hoisted
    out_start: np.ndarray
    out_flat: np.ndarray
    # initial per-node masks
    active0: np.ndarray
    out_opt0: np.ndarray                 # out-space optional for next fire
    pos_other: np.ndarray                # static execute position (non-mem)
    # op-kind buckets + aligned tables
    addr_ids: np.ndarray
    addr_cnt: np.ndarray
    mem_ids: np.ndarray
    is_load: np.ndarray
    mem_in0: np.ndarray
    mem_in1: np.ndarray
    midx_off: np.ndarray
    midx_flat: np.ndarray
    lin_ids: np.ndarray
    lin_a: np.ndarray
    lin_b: np.ndarray
    lin_hasb: np.ndarray
    lin_in0: np.ndarray
    lin_in1: np.ndarray
    lin_fw: np.ndarray
    flt_ids: np.ndarray
    flt_in0: np.ndarray
    keep_flat: np.ndarray
    flt_koff: np.ndarray
    flt_klen: np.ndarray
    flt_nodes: list                      # for the (rare) overflow fallback
    sync_ids: np.ndarray
    sync_in0: np.ndarray
    sync_exp: np.ndarray
    cmp_ids: np.ndarray
    cmp_in: list
    imux_ids: np.ndarray
    imux_pat: list                       # per imux: np.int64 pattern array
    imux_port_eids: list                 # per imux: np.int64 port -> eid
    imux_sel0: np.ndarray
    # sparse-path dispatch tables
    kind_of: np.ndarray = None           # nid -> K_* code
    bidx: np.ndarray = None              # nid -> index into its kind bucket
    out_py: list = None                  # nid -> [out eids] (python ints)
    net: CompiledNetwork | None = None
    # staleness tracking: the DFG mutation counter and queue-capacity
    # signature observed at compile time (see compiled_for / is_current)
    dfg_version: int = -1
    cap_sig: tuple = ()

    def is_current(self) -> bool:
        """Do the compiled tables still describe the plan's DFG?  False after
        any graph mutation — including capacity rewrites applied *without*
        ``DFG.mark_mutated()`` (the capacity signature catches those)."""
        return (self.g.version == self.dfg_version
                and _cap_signature(self.edges) == self.cap_sig)

    def require_current(self) -> "CompiledPlan":
        if not self.is_current():
            raise StaleCompiledPlanError(
                f"compiled tables for DFG {self.g.name!r} are stale "
                f"(compiled at version {self.dfg_version}, graph now at "
                f"{self.g.version} or queue capacities changed); recompile "
                f"with compile_plan()/compiled_for() after mutating a plan")
        return self


def _cap_signature(edges) -> tuple:
    return tuple(e.capacity for e in edges)


def compiled_for(plan, fabric: "RoutedFabric | None" = None) -> CompiledPlan:
    """Compile-once cache: return the plan's cached :class:`CompiledPlan`
    for ``fabric``, recompiling when the DFG mutated since (new nodes/edges,
    or queue capacities rewritten by ``apply_min_capacities`` — the
    compile-then-mutate hazard).  The cache lives on the plan object, one
    entry per fabric identity (``None`` = ideal mode)."""
    cache = getattr(plan, "_compiled_cache", None)
    if cache is None:
        cache = {}
        plan._compiled_cache = cache
    key = id(fabric) if fabric is not None else None
    ent = cache.get(key)
    if ent is not None:
        cached_fabric, cp = ent
        if cached_fabric is fabric and cp.is_current():
            return cp
    cp = compile_plan(plan, fabric)
    cache[key] = (fabric, cp)
    return cp


def compile_network(g: DFG, fabric: "RoutedFabric") -> CompiledNetwork:
    from repro.fabric.route import edge_key  # deferred: no import cycle
    link_id = fabric.link_index()
    wpc = fabric.words_per_cycle()
    edges = g.finalize()
    route_of: dict[int, tuple] = {}
    for e in edges:
        route_of[e.eid] = tuple(link_id[lk]
                                for lk in fabric.routes[edge_key(e)])
    book: list = [None] * len(g.nodes)
    loc_start = np.zeros(len(g.nodes) + 1, dtype=np.int64)
    loc_flat: list[int] = []
    loc_py: list = [None] * len(g.nodes)
    wpc1 = all(w == 1 for w in wpc)
    for n in g.nodes:
        # routed out-edges carry their hop sequence as precomputed booking
        # keys; the general (mixed words-per-cycle) form pairs each key with
        # the link's bandwidth, the wpc==1 fast path needs only the key.
        if wpc1:
            bk = [(e.eid, tuple(lk << SLOT_BITS for lk in route_of[e.eid]))
                  for e in n.out_edges if route_of[e.eid]]
        else:
            bk = [(e.eid, tuple((lk << SLOT_BITS, wpc[lk])
                                for lk in route_of[e.eid]))
                  for e in n.out_edges if route_of[e.eid]]
        if bk:
            book[n.nid] = bk
        loc = [e.eid for e in n.out_edges if not route_of[e.eid]]
        loc_py[n.nid] = loc
        loc_flat.extend(loc)
        loc_start[n.nid + 1] = len(loc_flat)
    return CompiledNetwork(
        book=book, loc_start=loc_start,
        loc_flat=np.asarray(loc_flat, dtype=np.int64),
        loc_py=loc_py, wpc1=wpc1)


def compile_plan(plan, fabric: "RoutedFabric | None" = None) -> CompiledPlan:
    g: DFG = plan.dfg
    nodes = g.nodes
    edges = g.finalize()
    nN, nE = len(nodes), len(edges)
    sent = nE                                  # sentinel edge id
    assert all(nodes[i].nid == i for i in range(nN)), "nids must be dense"

    n_cmp = sum(1 for n in nodes if n.op == "cmp")
    assert n_cmp, "graph has no completion (cmp) node"

    cap = np.full(nE + 1, UNBOUNDED, dtype=np.int64)
    for e in edges:
        if e.capacity is not None:
            cap[e.eid] = e.capacity
    min_caps = getattr(plan, "min_capacities", None) or {}
    hint = {e.eid: min_caps.get(id(e), 0) for e in edges}
    # presize rings to the analytic minimum occupancy plus the edge's routed
    # transit depth (hops), with headroom: a token spends `hops` cycles in
    # link buffers before it is consumable, so routed steady-state occupancy
    # exceeds the ideal-mode bound by exactly that much.  Unbounded rings
    # regrow on demand anyway, so this only trims reallocation churn.
    if fabric is not None:
        from repro.fabric.route import edge_key
        hop = {e.eid: len(fabric.routes.get(edge_key(e), ()))
               for e in edges}
    else:
        hop = {e.eid: 0 for e in edges}
    phys0 = np.array(
        [min(cap[e.eid], max(16, 2 * hint[e.eid] + hop[e.eid]))
         for e in edges] + [1],
        dtype=np.int64)

    # static execute order: memory ops first (rotated at runtime), then the
    # rest in graph order — pop-before-push resolution for max_occupancy.
    mem_like = {n.nid for n in nodes if n.op in ("load", "store")}
    pos_other = np.zeros(nN, dtype=np.int64)
    k = 0
    for n in nodes:
        if n.nid not in mem_like:
            pos_other[n.nid] = k
            k += 1
    pop_first = np.zeros(nE + 1, dtype=bool)
    for e in edges:
        s_mem, d_mem = e.src.nid in mem_like, e.dst.nid in mem_like
        assert not (s_mem and d_mem), \
            "memory->memory queues would make pop order rotation-dependent"
        if d_mem and not s_mem:
            pop_first[e.eid] = True
        elif not d_mem and not s_mem:
            pop_first[e.eid] = pos_other[e.dst.nid] < pos_other[e.src.nid]

    # eligibility matrices + broadcast CSR -----------------------------------
    max_in = max((len(n.in_edges) for n in nodes), default=0) or 1
    max_out = max((len(n.out_edges) for n in nodes), default=0) or 1
    in_mat = np.full((nN, max_in), sent, dtype=np.int64)
    out_mat = np.full((nN, max_out), sent, dtype=np.int64)
    out_start = np.zeros(nN + 1, dtype=np.int64)
    out_flat: list[int] = []
    for n in nodes:
        if n.op != "imux":                  # imux eligibility is per-port
            for j, e in enumerate(n.in_edges):
                in_mat[n.nid, j] = e.eid
        for j, e in enumerate(n.out_edges):
            out_mat[n.nid, j] = e.eid
        out_flat.extend(e.eid for e in n.out_edges)
        out_start[n.nid + 1] = len(out_flat)

    emit, keeps = _token_counts(g)

    active0 = np.ones(nN, dtype=bool)
    out_opt0 = np.zeros(nN, dtype=bool)

    addr_ids, addr_cnt = [], []
    mem_ids, is_load, mem_in0, mem_in1, midx_off = [], [], [], [], []
    midx_parts: list[np.ndarray] = []
    lin_ids, lin_a, lin_b, lin_hasb, lin_in0, lin_in1, lin_fw = \
        [], [], [], [], [], [], []
    flt_ids, flt_in0, flt_koff, flt_klen, flt_nodes = [], [], [], [], []
    keep_parts: list[np.ndarray] = []
    sync_ids, sync_in0, sync_exp = [], [], []
    cmp_ids, cmp_in = [], []
    imux_ids, imux_pat, imux_port_eids, imux_sel0 = [], [], [], []
    kind_of = np.zeros(nN, dtype=np.int64)
    bidx = np.zeros(nN, dtype=np.int64)
    out_py = [[e.eid for e in n.out_edges] for n in nodes]
    koff = moff = 0
    for n in nodes:
        op = n.op
        kind_of[n.nid] = _KIND_OF_OP[op]
        if op == "addr":
            bidx[n.nid] = len(addr_ids)
            addr_ids.append(n.nid)
            addr_cnt.append(int(n.params["count"]))
            if n.params["count"] <= 0:
                active0[n.nid] = False
        elif op in ("load", "store"):
            bidx[n.nid] = len(mem_ids)
            mem_ids.append(n.nid)
            is_load.append(op == "load")
            mem_in0.append(n.in_edges[0].eid)
            mem_in1.append(n.in_edges[1].eid if op == "store" else sent)
            idx = np.asarray(n.params["indices"], dtype=np.int64)
            midx_parts.append(idx)
            midx_off.append(moff)
            moff += len(idx)
        elif op in LIN_OPS:
            bidx[n.nid] = len(lin_ids)
            lin_ids.append(n.nid)
            lin_fw.append(FLOPS_PER_OP.get(op, 0))
            if op == "mul":
                lin_a.append(float(n.params["coeff"]))
                lin_b.append(0.0)
                lin_hasb.append(False)
                lin_in0.append(n.in_edges[0].eid)
                lin_in1.append(sent)
            elif op == "mac":
                lin_a.append(1.0)
                lin_b.append(float(n.params["coeff"]))
                lin_hasb.append(True)
                lin_in0.append(n.in_edges[0].eid)
                lin_in1.append(n.in_edges[1].eid)
            elif op == "add":
                lin_a.append(1.0)
                lin_b.append(1.0)
                lin_hasb.append(True)
                lin_in0.append(n.in_edges[0].eid)
                lin_in1.append(n.in_edges[1].eid)
            else:                            # copy/mux/demux pass-through
                lin_a.append(1.0)
                lin_b.append(0.0)
                lin_hasb.append(False)
                lin_in0.append(n.in_edges[0].eid)
                lin_in1.append(sent)
        elif op == "filter":
            arr = keeps[n.nid]
            bidx[n.nid] = len(flt_ids)
            flt_ids.append(n.nid)
            flt_in0.append(n.in_edges[0].eid)
            flt_klen.append(len(arr))
            flt_nodes.append(n)
            if len(arr) == 0:                # never fires; pad for gathers
                arr = np.zeros(1, dtype=bool)
            keep_parts.append(arr)
            flt_koff.append(koff)
            koff += len(arr)
            out_opt0[n.nid] = not bool(arr[0])
        elif op == "sync":
            bidx[n.nid] = len(sync_ids)
            sync_ids.append(n.nid)
            sync_in0.append(n.in_edges[0].eid)
            sync_exp.append(int(n.params["expected"]))
            out_opt0[n.nid] = True
        elif op == "cmp":
            bidx[n.nid] = len(cmp_ids)
            cmp_ids.append(n.nid)
            cmp_in.append(np.asarray([e.eid for e in n.in_edges],
                                     dtype=np.int64))
            out_opt0[n.nid] = True
        elif op == "imux":
            bidx[n.nid] = len(imux_ids)
            imux_ids.append(n.nid)
            pat = np.asarray(n.params["pattern"], dtype=np.int64)
            ports = np.asarray([e.eid for e in n.in_edges], dtype=np.int64)
            imux_pat.append(pat)
            imux_port_eids.append(ports)
            imux_sel0.append(int(ports[pat[0]]))
        else:
            raise ValueError(f"cannot compile op {op!r} (node {n.name!r})")

    arr64 = lambda xs: np.asarray(xs, dtype=np.int64)
    cp = CompiledPlan(
        plan=plan, g=g, nodes=nodes, edges=edges, n_nodes=nN, n_edges=nE,
        n_cmp=n_cmp, cap=cap, phys0=phys0, pop_first=pop_first,
        in_mat=in_mat, out_mat=out_mat, capmat=cap[out_mat],
        out_start=out_start, out_flat=arr64(out_flat),
        active0=active0, out_opt0=out_opt0, pos_other=pos_other,
        addr_ids=arr64(addr_ids), addr_cnt=arr64(addr_cnt),
        mem_ids=arr64(mem_ids), is_load=np.asarray(is_load, dtype=bool),
        mem_in0=arr64(mem_in0), mem_in1=arr64(mem_in1),
        midx_off=arr64(midx_off),
        midx_flat=(np.concatenate(midx_parts) if midx_parts
                   else np.zeros(0, dtype=np.int64)),
        lin_ids=arr64(lin_ids), lin_a=np.asarray(lin_a, dtype=np.float64),
        lin_b=np.asarray(lin_b, dtype=np.float64),
        lin_hasb=np.asarray(lin_hasb, dtype=bool),
        lin_in0=arr64(lin_in0), lin_in1=arr64(lin_in1), lin_fw=arr64(lin_fw),
        flt_ids=arr64(flt_ids), flt_in0=arr64(flt_in0),
        keep_flat=(np.concatenate(keep_parts) if keep_parts
                   else np.zeros(0, dtype=bool)),
        flt_koff=arr64(flt_koff), flt_klen=arr64(flt_klen),
        flt_nodes=flt_nodes,
        sync_ids=arr64(sync_ids), sync_in0=arr64(sync_in0),
        sync_exp=arr64(sync_exp),
        cmp_ids=arr64(cmp_ids), cmp_in=cmp_in,
        imux_ids=arr64(imux_ids), imux_pat=imux_pat,
        imux_port_eids=imux_port_eids, imux_sel0=arr64(imux_sel0),
        kind_of=kind_of, bidx=bidx, out_py=out_py,
        net=compile_network(g, fabric) if fabric is not None else None,
        dfg_version=g.version, cap_sig=_cap_signature(edges))
    return cp
