"""Reference interpreter backend: one Python pass over every node per cycle.

This is the oracle the compiled vector engine (:mod:`repro.core.engine.vector`)
is cross-validated against — semantics are specified here, speed there.  The
loop models the TIA firing rule with synchronous two-phase semantics: firing
decisions for cycle ``t`` use queue state at the start of ``t`` (push+pop on
the same queue in one cycle is allowed, a push into a queue that was full at
cycle start is not).  Loads/stores arbitrate for the shared memory-port
budget with rotating (fair round-robin) priority.

Fire accounting: *every* token consumption counts as one fire on both the
per-node counter (``Node.fires``) and the per-op aggregate — including filter
drops and sync count-ticks (whose ``done`` emission is part of the same fire,
not a second one).  The two views are kept consistent so per-PE utilization
can be derived from either.
"""
from __future__ import annotations

from collections import deque
from typing import TYPE_CHECKING

import numpy as np

from repro.core.dfg import DFG, Edge, Node
from repro.core.engine.common import (RawStats, SimDeadlock, deadlock_message)
from repro.telemetry.probe import (ST_FIRED, ST_INACTIVE, ST_INPUT_STARVED,
                                   ST_MEM_ARB, ST_NET_WAIT,
                                   ST_OUTPUT_BLOCKED, format_stall_summary,
                                   summary_from_state)

if TYPE_CHECKING:  # pragma: no cover - avoids core <-> fabric import cycle
    from repro.fabric.route import RoutedFabric
    from repro.telemetry import Telemetry


class _Network:
    """Per-simulation on-chip network state (network-aware mode).

    Tokens pushed onto a routed edge ride through a transit pipeline:
    arrival = injection cycle + hops, plus any store-and-forward stalls when
    a link's words-per-cycle budget is already spoken for in a cycle.  A
    producer's fan-out is one multicast: shared tree links are crossed once
    per token (booked once per firing), not once per edge.
    """

    def __init__(self, fabric: "RoutedFabric", g: DFG,
                 telemetry: "Telemetry | None" = None):
        from repro.fabric.route import edge_key  # deferred: no import cycle
        self.wpc = {k: l.words_per_cycle for k, l in
                    fabric.topo.links.items()}
        self.routes: dict[int, tuple] = {}
        self.edge_by_id: dict[int, Edge] = {}
        for e in g.edges():
            self.routes[id(e)] = fabric.routes[edge_key(e)]
            self.edge_by_id[id(e)] = e
        self.transit: dict[int, deque] = {eid: deque() for eid in self.routes}
        self.used: dict[tuple, int] = {}     # (link, cycle) -> words in flight
        self.last_arrival: dict[int, int] = {}
        self.token_hops = 0
        self.stall_cycles = 0            # link-contention wait, summed
        self.tel = telemetry
        self.lid = telemetry.link_ids if telemetry is not None else None

    def broadcast(self, nd: Node, v, cycle: int) -> None:
        tel = self.tel
        booked: dict[tuple, int] = {}    # link -> slot of this token's copy
        for e in nd.out_edges:
            links = self.routes[id(e)]
            if not links:                # co-resident PEs: ideal local queue
                e.push(v)
                continue
            t = cycle
            for lk in links:
                if lk in booked:         # ride the multicast copy
                    t = booked[lk] + 1
                    continue
                cap = self.wpc[lk]
                slot = t
                while self.used.get((lk, slot), 0) >= cap:
                    slot += 1
                self.stall_cycles += slot - t
                self.used[(lk, slot)] = self.used.get((lk, slot), 0) + 1
                booked[lk] = slot
                self.token_hops += 1
                if tel is not None:
                    tel.link_book(self.lid[lk], slot, slot - t)
                t = slot + 1
            arr = max(t, self.last_arrival.get(id(e), 0))  # FIFO per edge
            self.last_arrival[id(e)] = arr
            self.transit[id(e)].append((arr, v))

    def deliver(self, cycle: int) -> None:
        # slot searches always start at the current cycle, so bookings for
        # past cycles can never be read again — drop them periodically to
        # keep memory flat over long simulations.
        if cycle % 4096 == 0 and self.used:
            self.used = {k: v for k, v in self.used.items() if k[1] >= cycle}
        for eid, dq in self.transit.items():
            if dq and dq[0][0] <= cycle:
                e = self.edge_by_id[eid]
                while dq and dq[0][0] <= cycle:
                    e.push(dq.popleft()[1])

    def edge_full(self, e: Edge) -> bool:
        return e.capacity is not None and \
            len(e.q) + len(self.transit[id(e)]) >= e.capacity

    def in_flight(self) -> bool:
        return any(self.transit.values())


def run(plan, flat_in, flat_out, elems_per_cycle: float,
        max_cycles: int = 50_000_000,
        fabric: "RoutedFabric | None" = None,
        telemetry: "Telemetry | None" = None) -> RawStats:
    """Run the per-cycle interpreter; mutates ``flat_out`` in place."""
    g = plan.dfg

    # queues live on the Edge objects: a completed run drains them, but a
    # deadlocked/timed-out one leaves tokens behind — start every run from
    # the quiescent marking so fix-and-retry on the same plan is valid.
    for nd in g.nodes:
        for e in nd.out_edges:
            e.q.clear()

    # per-node runtime state ---------------------------------------------------
    state: dict[int, dict] = {}
    done_pending = 0
    for nd in g.nodes:
        st: dict = {"k": 0}
        if nd.op == "sync":
            st["count"] = 0
            st["emitted"] = False
        elif nd.op == "cmp":
            st["fired"] = False
            done_pending += 1
        state[nd.nid] = st
    assert done_pending, "graph has no completion (cmp) node"

    net = _Network(fabric, g, telemetry) if fabric is not None else None

    credit = 0.0
    cycles = 0
    fires: dict[str, int] = {}
    loads = stores = flops = 0
    finished = False

    # memory ops arbitrate for bandwidth with *rotating* priority (fair
    # round-robin, like the CGRA's memory-port arbiter); everything else is
    # order-independent because eligibility is snapshotted per cycle.
    mem_nodes = [nd for nd in g.nodes if nd.op in ("load", "store")]
    other_nodes = [nd for nd in g.nodes if nd.op not in ("load", "store")]
    n_mem = max(1, len(mem_nodes))

    nodes = g.nodes
    # hot-loop records: (node, nid, op, state, in_edges, out_edges) resolved
    # once — the edge lists are stable for the whole simulation, and skipping
    # the per-cycle attribute lookups is a measurable win on large graphs.
    # Eligibility snapshots are flat lists indexed by nid (nids are dense).
    rec = {nd.nid: (nd, nd.nid, nd.op, state[nd.nid], nd.in_edges,
                    nd.out_edges) for nd in nodes}
    # imux pops exactly one (pattern-selected) port per firing; snapshotting
    # all-ports-nonempty would both stall it and deadlock re-interleaves.
    snap_recs = [rec[nd.nid] for nd in nodes if nd.op != "imux"]
    imux_recs = [rec[nd.nid] for nd in nodes if nd.op == "imux"]
    mem_recs = [rec[nd.nid] for nd in mem_nodes]
    other_recs = [rec[nd.nid] for nd in other_nodes]
    n_ids = 1 + max(nd.nid for nd in nodes)
    in_avail = [False] * n_ids
    out_free = [False] * n_ids

    tel = telemetry
    all_recs = snap_recs + imux_recs
    prev_fires = [0] * n_ids
    if tel is not None:
        for nd in nodes:           # plans can be re-simulated; fires persist
            prev_fires[nd.nid] = nd.fires

    def _classify(no_fires: bool = False) -> np.ndarray:
        """One exclusive ``ST_*`` code per node for the cycle just executed,
        derived from this cycle's eligibility snapshot plus fire deltas.
        Mirrors the vector engine's classification exactly (parity-gated in
        tests/test_telemetry.py); ``no_fires`` skips the delta check on the
        deadlock path, where by definition nothing fired."""
        stb = np.empty(n_ids, dtype=np.int64)
        for nd, nid, op, stx, ine, _ in all_recs:
            if not no_fires and nd.fires > prev_fires[nid]:
                prev_fires[nid] = nd.fires
                stb[nid] = ST_FIRED
            elif (op == "addr" and stx["k"] >= nd.params["count"]) \
                    or (op == "sync" and stx["emitted"]) \
                    or (op == "cmp" and stx["fired"]):
                stb[nid] = ST_INACTIVE
            elif not in_avail[nid]:
                if net is None:
                    stb[nid] = ST_INPUT_STARVED
                else:
                    if op == "imux":
                        pat = nd.params["pattern"]
                        waiting = bool(
                            net.transit[id(ine[pat[stx["k"] % len(pat)]])])
                    else:
                        waiting = any(net.transit[id(e)] for e in ine)
                    stb[nid] = ST_NET_WAIT if waiting else ST_INPUT_STARVED
            elif not out_free[nid] and not (
                    op in ("sync", "cmp")
                    or (op == "filter" and not nd.params["keep"](stx["k"]))):
                # output space is optional for sync/cmp (emission rides the
                # fire) and for a filter whose next token will be dropped —
                # same out_opt semantics as the compiled plan's.
                stb[nid] = ST_OUTPUT_BLOCKED
            else:           # eligible but lost the memory-port arbitration
                stb[nid] = ST_MEM_ARB
        return stb

    def _final_cycle_summary() -> dict:
        names = [""] * n_ids
        ops = [""] * n_ids
        for nd in nodes:
            names[nd.nid] = nd.name
            ops[nd.nid] = nd.op
        return summary_from_state(_classify(no_fires=True), names, ops)

    while not finished:
        if cycles >= max_cycles:
            if tel is not None:
                tel.finish(cycles)
                summ = tel.stall_summary(window=64)
                raise SimDeadlock(f"exceeded max_cycles={max_cycles}"
                                  + format_stall_summary(summ),
                                  cycles=cycles, timed_out=True,
                                  stall_summary=summ)
            raise SimDeadlock(f"exceeded max_cycles={max_cycles}",
                              cycles=cycles, timed_out=True)
        cycles += 1
        credit = min(credit + elems_per_cycle, 4 * elems_per_cycle)
        if net is not None:
            net.deliver(cycles)          # arrivals land before the snapshot
        # phase 1: snapshot eligibility -----------------------------------
        if net is None:
            for _, nid, _, _, ine, oute in snap_recs:
                in_avail[nid] = all(e.q for e in ine)
                out_free[nid] = all(not e.full() for e in oute)
        else:
            for _, nid, _, _, ine, oute in snap_recs:
                in_avail[nid] = all(e.q for e in ine)
                out_free[nid] = all(not net.edge_full(e) for e in oute)
        for nd_, nid, _, stx, ine, oute in imux_recs:
            pat = nd_.params["pattern"]
            in_avail[nid] = bool(ine[pat[stx["k"] % len(pat)]].q)
            out_free[nid] = (all(not e.full() for e in oute) if net is None
                             else all(not net.edge_full(e) for e in oute))
        any_fired = False
        # phase 2: execute. Memory nodes first in rotated order (fair
        # bandwidth arbitration), then the rest.
        rot = cycles % n_mem
        ordered = mem_recs[rot:] + mem_recs[:rot] + other_recs
        for nd, nid, op, st, in_edges, out_edges in ordered:
            if op == "addr":
                if st["k"] >= nd.params["count"] or not out_free[nid]:
                    continue
                v = st["k"]
                st["k"] += 1
            elif op == "load":
                if not (in_avail[nid] and out_free[nid] and credit >= 1.0):
                    continue
                a = in_edges[0].q.popleft()
                v = float(flat_in[nd.params["indices"][a]])
                credit -= 1.0
                loads += 1
            elif op == "store":
                if not (in_avail[nid] and out_free[nid] and credit >= 1.0):
                    continue
                a = in_edges[0].q.popleft()
                val = in_edges[1].q.popleft()
                flat_out[nd.params["indices"][a]] = val
                credit -= 1.0
                stores += 1
                v = 1  # done token to sync
            elif op == "filter":
                if not in_avail[nid]:
                    continue
                keep = nd.params["keep"](st["k"])
                if keep and not out_free[nid]:
                    continue  # must hold the token until downstream has space
                tok = in_edges[0].q.popleft()
                st["k"] += 1
                if not keep:
                    nd.fires += 1        # a drop is a fire: the token was consumed
                    fires[op] = fires.get(op, 0) + 1
                    any_fired = True
                    continue
                v = tok
            elif op == "mul":
                if not (in_avail[nid] and out_free[nid]):
                    continue
                v = nd.params["coeff"] * in_edges[0].q.popleft()
                flops += 1
            elif op == "mac":
                if not (in_avail[nid] and out_free[nid]):
                    continue
                p = in_edges[0].q.popleft()
                v = p + nd.params["coeff"] * in_edges[1].q.popleft()
                flops += 2
            elif op == "add":
                if not (in_avail[nid] and out_free[nid]):
                    continue
                v = in_edges[0].q.popleft() + in_edges[1].q.popleft()
                flops += 1
            elif op == "sync":
                if st["emitted"] or not in_avail[nid]:
                    continue
                in_edges[0].q.popleft()
                st["count"] += 1
                nd.fires += 1            # each count-tick is one fire …
                fires[op] = fires.get(op, 0) + 1
                any_fired = True
                if st["count"] == nd.params["expected"] and out_free[nid]:
                    st["emitted"] = True  # … and the done emission rides it
                    if net is None:
                        for e in out_edges:
                            e.push(1)
                    else:
                        net.broadcast(nd, 1, cycles)
                continue
            elif op == "imux":  # re-interleave: pop the pattern-selected port
                if not (in_avail[nid] and out_free[nid]):
                    continue
                pat = nd.params["pattern"]
                v = in_edges[pat[st["k"] % len(pat)]].q.popleft()
                st["k"] += 1
            elif op == "cmp":  # a done-combiner (programs may carry several)
                if st["fired"] or not in_avail[nid]:
                    continue
                for e in in_edges:
                    e.q.popleft()
                st["fired"] = True
                done_pending -= 1
                if done_pending == 0:
                    finished = True
                nd.fires += 1
                fires[op] = fires.get(op, 0) + 1
                any_fired = True
                continue
            else:  # mux/demux/copy pass-through
                if not (in_avail[nid] and out_free[nid]):
                    continue
                v = in_edges[0].q.popleft()
            nd.fires += 1
            fires[op] = fires.get(op, 0) + 1
            any_fired = True
            if net is None:
                for e in out_edges:
                    e.push(v)
            else:
                net.broadcast(nd, v, cycles)
        if tel is not None:
            tel.observe(cycles, _classify())
        if not any_fired and not finished:
            if net is not None and net.in_flight():
                continue                 # tokens still riding the network
            if tel is not None:
                tel.finish(cycles)
                summ = tel.stall_summary(window=64)
            else:
                summ = _final_cycle_summary()
            raise SimDeadlock(deadlock_message(cycles, nodes)
                              + format_stall_summary(summ),
                              cycles=cycles, stall_summary=summ)

    if tel is not None:
        tel.finish(cycles)
    return RawStats(
        cycles=cycles, flops=flops, loads=loads, stores=stores, fires=fires,
        max_queue_total=sum(e.max_occupancy for e in g.edges()),
        token_hops=net.token_hops if net is not None else 0,
        stall_cycles=net.stall_cycles if net is not None else 0)
