"""JAX engine: the compiled cycle loop as a jitted, vmappable fixed point.

The third backend (``simulate(..., engine="jax")``).  The vector engine
already lowers a DFG to struct-of-arrays tables and runs each cycle as a
handful of dense numpy passes; this module takes the remaining step and
expresses one cycle as a **pure array function** ``carry -> carry`` that
``lax.while_loop`` iterates to the fixed point (all ``cmp`` nodes fired,
deadlock, or ``max_cycles``).  Because the step is pure and fixed-shape it
jits once per padded-shape bucket and — the actual point — ``vmap``s across
a *batch* of independently-lowered plans, so the auto-tuner's stage-1 ideal
sweep becomes one device call instead of B sequential ``vector.run`` calls
(``repro.explore.search``, ``Budget.batch_size``; BENCH_pr9.json).

**Timing/value decoupling.**  The firing rule is value-independent: whether
a node fires depends only on queue *lengths*, counters and the memory
credit, never on token values.  The device loop therefore carries only
small integer state — ``qlen`` per edge, ``active``/``fires`` per node,
``maxocc`` per edge, the float64 memory credit, the cycle counter and a
status code — and no ring-buffer pool at all (the vector engine's dynamic
ring regrowth has no static-shape equivalent).  Output values are produced
afterwards by a bit-exact numpy *value pass* over the DFG in topo order
(each node's whole token stream as one array op, stores written in
address-stream order), using the same float64 expressions as the other two
engines, so output grids match bitwise.

**Per-node counters collapse into ``fires``.**  Every auxiliary counter the
vector engine keeps (addr index, filter position, sync count, imux pattern
index) equals the node's fire count, so the carry holds one array and the
step *derives* filter keep-masks, imux port selection and sync emission
from it each cycle.

**Padding semantics** (how B different graphs share one shape): node index
``N`` and edge index ``E`` are sentinels — the sentinel node is never
active, the sentinel edge reads "never empty, never full" (``qlen`` big,
capacity bigger) exactly like the vector engine's sentinel ring.  Padded
bucket slots point at the sentinels, padded edges hang off the sentinel
node at both ends, and the memory arbiter ranks real nodes by rotated
position with padded lanes keyed to infinity.  A lane that finishes (or deadlocks)
early freezes — ``vmap`` of ``while_loop`` runs until every lane's
predicate drops — without perturbing siblings.

Not supported here (use ``engine="vector"``): network-aware mode
(``fabric=``) and telemetry sinks.  ``run`` raises ``NotImplementedError``
for those; the tuner routes stage-2 finalists through the vector engine.

Determinism: everything is integer except the memory credit, which must be
float64 (``elems_per_cycle`` ≈ 10.41̅6 on the paper CGRA).  The module
evaluates under ``jax.experimental.enable_x64`` so the credit walk is
bit-identical to the other engines' python-float walk: for f64 ``x >= 1``,
``x - 1.0`` is exact, hence subtracting the fired count equals the
interpreter's repeated ``-= 1.0``.  Pin ``JAX_PLATFORMS=cpu`` for
cross-machine reproducibility in CI (ci.sh does).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine.common import RawStats, SimDeadlock
from repro.core.engine.compile import (CompiledPlan, _keep_array,
                                       compiled_for)
from repro.telemetry.probe import (ST_INACTIVE, ST_INPUT_STARVED, ST_MEM_ARB,
                                   ST_OUTPUT_BLOCKED, format_stall_summary,
                                   summary_from_state)

try:                                        # gate, don't hard-require:
    import jax                              # the rest of repro.core works
    import jax.numpy as jnp                 # without jax installed
    from jax import lax
    _JAX_ERR = None
except Exception as _e:                     # pragma: no cover - env-specific
    jax = jnp = lax = None
    _JAX_ERR = _e

__all__ = ["SEMANTICS", "JaxLoweringError", "run", "run_compiled_batch"]

#: semantics version of this lowering — part of the EvalCache scope key so
#: batched-jax measurements can never be replayed as vector ones (or vice
#: versa) across a semantics bump.  Bump on any change to the cycle step.
SEMANTICS = "jax-batch/v1"

# status codes of the while_loop carry
_RUNNING, _FINISHED, _DEADLOCKED = 0, 1, 2

_QBIG = 1 << 29          # sentinel/pad queue length: "never empty"
_CAPBIG = 1 << 30        # clamped UNBOUNDED capacity: "never full" (> _QBIG)
_CNTBIG = 1 << 30        # "never reached" fire limits / sync expectations


class JaxLoweringError(NotImplementedError):
    """The plan uses a feature the jax lowering does not express (network
    mode, telemetry, or a shape the padding can't absorb).  Callers that
    batch (the tuner) catch this per lane and fall back to the vector
    engine."""


def _require_jax() -> None:
    if jax is None:                        # pragma: no cover - env-specific
        raise JaxLoweringError(
            f"engine='jax' needs the jax package (import failed: {_JAX_ERR!r})"
            "; use engine='vector'")


def _bucket(n: int, lo: int = 8) -> int:
    """Round a dimension up to a bucket so plans of similar size share one
    jit cache entry instead of compiling per plan.  Buckets use ~1/8-octave
    granularity (next multiple of a power of two >= n/8), so padding wastes
    at most ~12% of the hot per-cycle arrays — a pure power-of-two ladder
    would waste up to 2x, which is real wall-clock on a gather-bound step."""
    if n <= lo:
        return lo
    g = lo
    while g * 8 < n:
        g *= 2
    return -(-n // g) * g


# ---------------------------------------------------------------------------
# lowering: CompiledPlan -> padded numpy tables


@dataclasses.dataclass
class LoweredPlan:
    """One plan's padded array tables (numpy, host-side) plus the metadata
    the finalizer needs.  ``dims`` is the shared padded shape tuple."""
    cp: CompiledPlan
    dims: tuple
    tables: dict


def _natural_dims(cp: CompiledPlan) -> tuple:
    # (N, E, IN, OUT, M, F, KL, X, PL, P, C, INC).  IN deliberately
    # excludes cmp and imux in-degrees (both O(workers)): cmp eligibility
    # runs over its own tiny (C, INC) matrix and imux over the
    # dynamically-selected port, so the hot (N+1, IN) gather stays at the
    # compute-node fan-in (<= 2 for this op vocabulary).
    in_main, inc = 1, 1
    for nd in cp.nodes:
        d = len(nd.in_edges)
        if nd.op == "cmp":
            inc = max(inc, d)
        elif nd.op != "imux":
            in_main = max(in_main, d)
    return (cp.n_nodes, cp.n_edges,
            in_main, cp.out_mat.shape[1],
            max(1, len(cp.mem_ids)), max(1, len(cp.flt_ids)),
            max(1, int(cp.flt_klen.max()) if len(cp.flt_ids) else 1),
            max(1, len(cp.imux_ids)),
            max(1, max((len(p) for p in cp.imux_pat), default=1)),
            max(1, max((len(p) for p in cp.imux_port_eids), default=1)),
            max(1, len(cp.cmp_ids)), inc)


#: dims-tuple positions that are per-node *widths* (IN, OUT, PL, P, INC) —
#: bucketed from 2 so narrow matrices stay narrow; count-like dims keep the
#: coarser lo=8 buckets for jit-cache sharing.
_WIDTH_DIMS = frozenset({2, 3, 8, 9, 11})


def shared_dims(cps: list[CompiledPlan]) -> tuple:
    """Elementwise max of every plan's natural dims, bucket-rounded."""
    nat = [_natural_dims(cp) for cp in cps]
    return tuple(_bucket(max(d[i] for d in nat),
                         lo=2 if i in _WIDTH_DIMS else 8)
                 for i in range(len(nat[0])))


def lower(cp: CompiledPlan, dims: tuple | None = None) -> LoweredPlan:
    """Lower one compiled plan into padded pure-array tables (see the
    module docstring for the sentinel/padding rules)."""
    _require_jax()
    if cp.net is not None:
        raise JaxLoweringError(
            "engine='jax' is ideal-mode only (no network-aware simulation); "
            "use engine='vector' for routed plans")
    dims = dims or shared_dims([cp])
    N, E, IN, OUT, M, F, KL, X, PL, P, C, INC = dims
    nN, nE = cp.n_nodes, cp.n_edges
    if any(a > b for a, b in zip(_natural_dims(cp), dims)):
        raise JaxLoweringError(f"plan dims {_natural_dims(cp)} exceed padded "
                               f"dims {dims}")
    i32 = np.int32

    def remap(a):                          # actual sentinel nE -> padded E
        return np.where(a == nE, E, a).astype(i32)

    # narrow in-matrix: compute-node fan-in only.  imux rows stay
    # all-sentinel (their one live port is tested via ``sel_edge``) and cmp
    # rows live in their own (C, INC) matrix; both fold back into in_ok by
    # *gathers through static slot tables* — the hot path has no scatters,
    # which cost ~50ns/element on XLA CPU vs <1ns for gathers.
    in_mat = np.full((N + 1, IN), E, dtype=i32)
    cmp_in = np.full((C, INC), E, dtype=i32)
    cmp_slot = np.full(N + 1, C, dtype=i32)
    ci = 0
    for nd in cp.nodes:
        eids = [e.eid for e in nd.in_edges]
        if nd.op == "cmp":
            cmp_slot[nd.nid] = ci
            cmp_in[ci, :len(eids)] = remap(np.asarray(eids, dtype=i32))
            ci += 1
        elif nd.op != "imux" and eids:
            in_mat[nd.nid, :len(eids)] = remap(np.asarray(eids, dtype=i32))
    out_mat = np.full((N + 1, OUT), E, dtype=i32)
    out_mat[:nN, :cp.out_mat.shape[1]] = remap(cp.out_mat)
    capmat = np.full((N + 1, OUT), _CAPBIG, dtype=i32)
    capmat[:nN, :cp.capmat.shape[1]] = np.minimum(cp.capmat,
                                                  _CAPBIG).astype(i32)

    active0 = np.zeros(N + 1, dtype=bool)
    active0[:nN] = cp.active0
    out_opt_static = np.zeros(N + 1, dtype=bool)
    out_opt_static[cp.sync_ids] = True
    out_opt_static[cp.cmp_ids] = True
    is_mem = np.zeros(N + 1, dtype=bool)
    is_mem[cp.mem_ids] = True
    is_sync = np.zeros(N + 1, dtype=bool)
    is_sync[cp.sync_ids] = True
    is_cmp = np.zeros(N + 1, dtype=i32)
    is_cmp[cp.cmp_ids] = 1
    sync_exp = np.full(N + 1, _CNTBIG, dtype=i32)
    sync_exp[cp.sync_ids] = np.minimum(cp.sync_exp, _CNTBIG)
    limit = np.full(N + 1, _CNTBIG, dtype=i32)
    limit[cp.addr_ids] = np.clip(cp.addr_cnt, 0, _CNTBIG)
    limit[cp.cmp_ids] = 1

    esrc = np.full(E + 1, N, dtype=i32)
    edst = np.full(E + 1, N, dtype=i32)    # pads/sentinel -> never-firing N
    epop_static = np.zeros(E + 1, dtype=bool)
    for e in cp.edges:
        esrc[e.eid] = e.src.nid
        edst[e.eid] = e.dst.nid
        # every edge has exactly one consumer, so pops are per-edge tests:
        # a non-imux dst consumes all its in-edges on fire; an imux dst
        # only the per-cycle selected port (checked against sel_edge)
        epop_static[e.eid] = e.dst.op != "imux"
    pop_first = np.zeros(E + 1, dtype=bool)
    pop_first[:nE] = cp.pop_first[:nE]
    qlen0 = np.zeros(E + 1, dtype=i32)
    qlen0[nE:] = _QBIG                     # pads + sentinel: never empty

    mem_ids = np.full(M, N, dtype=i32)
    mem_ids[:len(cp.mem_ids)] = cp.mem_ids
    # static node -> bucket-slot tables (pad slot = bucket length): the
    # step extends each per-bucket result with one neutral pad entry and
    # gathers it back per node, instead of scattering into a node array
    mem_slot = np.full(N + 1, M, dtype=i32)
    mem_slot[cp.mem_ids] = np.arange(len(cp.mem_ids), dtype=i32)
    flt_slot = np.full(N + 1, F, dtype=i32)
    flt_slot[cp.flt_ids] = np.arange(len(cp.flt_ids), dtype=i32)
    imux_slot = np.full(N + 1, X, dtype=i32)
    imux_slot[cp.imux_ids] = np.arange(len(cp.imux_ids), dtype=i32)

    flt_ids = np.full(F, N, dtype=i32)
    flt_klen = np.ones(F, dtype=i32)
    keep_mat = np.zeros((F, KL), dtype=bool)
    for j, nid in enumerate(cp.flt_ids):
        flt_ids[j] = nid
        kl = max(1, int(cp.flt_klen[j]))   # 0-length keeps were padded to 1
        flt_klen[j] = kl
        off = int(cp.flt_koff[j])
        keep_mat[j, :kl] = cp.keep_flat[off:off + kl]

    imux_ids = np.full(X, N, dtype=i32)
    imux_pat = np.zeros((X, PL), dtype=i32)
    imux_plen = np.ones(X, dtype=i32)
    imux_ports = np.full((X, P), E, dtype=i32)
    for j, nid in enumerate(cp.imux_ids):
        imux_ids[j] = nid
        pat = cp.imux_pat[j]
        imux_pat[j, :len(pat)] = pat
        imux_plen[j] = len(pat)
        imux_ports[j, :len(cp.imux_port_eids[j])] = remap(
            cp.imux_port_eids[j])

    tables = dict(
        in_mat=in_mat, out_mat=out_mat, capmat=capmat, qlen0=qlen0,
        cmp_in=cmp_in, cmp_slot=cmp_slot,
        active0=active0, out_opt_static=out_opt_static, is_mem=is_mem,
        is_sync=is_sync, is_cmp=is_cmp, sync_exp=sync_exp,
        limit=limit, esrc=esrc, edst=edst, epop_static=epop_static,
        pop_first=pop_first, mem_ids=mem_ids, mem_slot=mem_slot,
        n_mem=np.int32(max(1, len(cp.mem_ids))),
        n_cmp=np.int32(cp.n_cmp),
        flt_ids=flt_ids, flt_slot=flt_slot, flt_klen=flt_klen,
        keep_mat=keep_mat,
        imux_ids=imux_ids, imux_slot=imux_slot, imux_pat=imux_pat,
        imux_plen=imux_plen, imux_ports=imux_ports)
    return LoweredPlan(cp=cp, dims=dims, tables=tables)


# ---------------------------------------------------------------------------
# the jitted cycle step + fixed-point loop


def _cycle_step(t: dict, carry: tuple) -> tuple:
    """One simulator cycle over one lane's tables.  Mirrors the vector
    engine's dense path pass-for-pass (parity-gated in tests/test_jax_engine)
    with all per-kind counters derived from ``fires``."""
    qlen, active, fires, maxocc, credit, cycles, status = carry
    cycles = cycles + 1
    credit = jnp.minimum(credit + t["epc"], t["cap4"])

    # dynamic per-cycle state derived from fire counts --------------------
    # NO SCATTERS anywhere in this step (XLA CPU scatters cost ~50ns/elt,
    # gathers <1ns): each small bucket's per-cycle result is extended with
    # one neutral pad entry and gathered back per node/edge through the
    # static ``*_slot`` tables.
    X = t["imux_ids"].shape[0]
    ik = fires[t["imux_ids"]]
    sel_port = t["imux_pat"][jnp.arange(X), ik % t["imux_plen"]]
    sel_eid = t["imux_ports"][jnp.arange(X), sel_port]
    sentE = jnp.full((1,), qlen.shape[0] - 1, dtype=jnp.int32)
    # per-node selected in-edge; sentinel ("never empty") for non-imux
    sel_edge = jnp.concatenate([sel_eid, sentE])[t["imux_slot"]]

    F = t["flt_ids"].shape[0]
    fk = jnp.clip(fires[t["flt_ids"]], 0, t["flt_klen"] - 1)
    keep_now = t["keep_mat"][jnp.arange(F), fk]
    # per-node "filter drops its current token" (False for non-filters)
    flt_drop = ~jnp.concatenate([keep_now,
                                 jnp.ones(1, bool)])[t["flt_slot"]]
    out_opt = t["out_opt_static"] | flt_drop

    # phase 1: snapshot eligibility ---------------------------------------
    # imux rows of in_mat are all-sentinel (the live port is sel_edge);
    # cmp rows likewise, folded in from the tiny (C, INC) matrix
    in_ok = ((qlen[t["in_mat"]] > 0).all(axis=1) & (qlen[sel_edge] > 0))
    cmp_ok = (qlen[t["cmp_in"]] > 0).all(axis=1)
    in_ok = in_ok & jnp.concatenate([cmp_ok,
                                     jnp.ones(1, bool)])[t["cmp_slot"]]
    out_ok = (qlen[t["out_mat"]] < t["capmat"]).all(axis=1)
    elig = in_ok & (out_ok | out_opt) & active

    # memory arbiter: rank-based rotation (vmap-friendly equivalent of the
    # vector engine's roll+cumsum: fire iff the count of eligible memory
    # nodes at-or-before you in rotated order fits the integer credit)
    M = t["mem_ids"].shape[0]
    pos = jnp.arange(M, dtype=jnp.int32)
    valid = pos < t["n_mem"]
    em = elig[t["mem_ids"]] & valid
    rot = (cycles % t["n_mem"]).astype(jnp.int32)
    key = jnp.where(valid, (pos - rot) % t["n_mem"], jnp.int32(_CNTBIG))
    before = (em[None, :] & (key[None, :] < key[:, None])).sum(
        axis=1).astype(jnp.int32)
    fire_mem = em & (before < jnp.floor(credit).astype(jnp.int32))
    # f64 x - 1.0 is exact for x >= 1, so one subtraction of the fired
    # count is bit-identical to the interpreter's per-fire -= 1.0 walk
    credit = credit - fire_mem.sum().astype(credit.dtype)
    fired = (elig & ~t["is_mem"]) | jnp.concatenate(
        [fire_mem, jnp.zeros(1, bool)])[t["mem_slot"]]

    # emission gates: filters drop unkept tokens, syncs emit only on the
    # expected-count tick with output space, cmp has no out-edges anyway
    sync_gate = jnp.where(t["is_sync"],
                          (fires + 1 == t["sync_exp"]) & out_ok, True)
    emits = fired & sync_gate & ~flt_drop

    # phase 2: commit pops then pushes ------------------------------------
    # every edge has exactly one consumer, so pops are a pure gather: an
    # edge pops iff its dst fired and — for imux dsts — it is the cycle's
    # selected port
    eidx = jnp.arange(qlen.shape[0], dtype=jnp.int32)
    dst = t["edst"]
    popped = fired[dst] & (t["epop_static"] | (sel_edge[dst] == eidx))
    qlen2 = qlen - popped.astype(jnp.int32)
    pushed = emits[t["esrc"]]
    qlen3 = qlen2 + pushed.astype(jnp.int32)

    # interpreter-exact occupancy sampling (see vector._expand_push): the
    # push saw this cycle's pop only where the consumer executes earlier
    occ_c = qlen + 1 - (t["pop_first"] & popped).astype(jnp.int32)
    maxocc = jnp.where(pushed, jnp.maximum(maxocc, occ_c), maxocc)

    fires2 = fires + fired.astype(jnp.int32)
    active2 = active & (fires2 < t["limit"]) & ~(emits & t["is_sync"])

    finished = (fires2 * t["is_cmp"]).sum() >= t["n_cmp"]
    status = jnp.where(finished, _FINISHED,
                       jnp.where(fired.any(), _RUNNING,
                                 _DEADLOCKED)).astype(jnp.int32)
    return (qlen3, active2, fires2, maxocc, credit, cycles, status)


def _run_single(t: dict, max_cycles):
    carry0 = (t["qlen0"],
              t["active0"],
              jnp.zeros_like(t["active0"], dtype=jnp.int32),   # fires
              jnp.zeros_like(t["qlen0"], dtype=jnp.int32),     # maxocc
              jnp.float64(0.0),                                # credit
              jnp.int32(0),                                    # cycles
              jnp.int32(_RUNNING))

    def cond(c):
        return (c[6] == _RUNNING) & (c[5] < max_cycles)

    return lax.while_loop(cond, lambda c: _cycle_step(t, c), carry0)


_sweep_fn = None


def _sweep(stacked: dict, max_cycles):
    """Jitted vmap of the fixed-point loop; cached per padded-shape bucket
    by jax's own jit cache."""
    global _sweep_fn
    if _sweep_fn is None:
        _sweep_fn = jax.jit(
            lambda s, mc: jax.vmap(lambda t: _run_single(t, mc))(s))
    return _sweep_fn(stacked, max_cycles)


# ---------------------------------------------------------------------------
# host-side finalization: numpy value pass + diagnostics


def _value_pass(cp: CompiledPlan, flat_in, flat_out) -> None:
    """Bit-exact output values for a *finished* run, computed per node as
    whole token streams in topo order.  Uses the same float64 expressions
    as the scalar/vector engines (``1.0*p + coeff*q`` etc.), and writes
    stores through fancy indexing in address-stream order, so duplicate
    addresses resolve last-wins exactly like sequential store fires."""
    stream: dict[int, np.ndarray] = {}
    for nd in cp.g.topo_order():
        ins = [stream[e.src.nid] for e in nd.in_edges]
        op, p = nd.op, nd.params
        if op == "addr":
            s = np.arange(max(0, int(p["count"])), dtype=np.float64)
        elif op == "load":
            idx = np.asarray(p["indices"], dtype=np.int64)
            s = flat_in[idx[ins[0].astype(np.int64)]]
        elif op == "store":
            idx = np.asarray(p["indices"], dtype=np.int64)
            n = min(len(ins[0]), len(ins[1]))
            flat_out[idx[ins[0][:n].astype(np.int64)]] = ins[1][:n]
            s = np.ones(n, dtype=np.float64)
        elif op == "mul":
            s = float(p["coeff"]) * ins[0]
        elif op == "mac":
            n = min(len(ins[0]), len(ins[1]))
            s = 1.0 * ins[0][:n] + float(p["coeff"]) * ins[1][:n]
        elif op == "add":
            n = min(len(ins[0]), len(ins[1]))
            s = 1.0 * ins[0][:n] + 1.0 * ins[1][:n]
        elif op == "filter":
            s = ins[0][_keep_array(nd, len(ins[0]))]
        elif op == "sync":
            s = np.ones(1, dtype=np.float64)
        elif op == "cmp":
            s = np.zeros(0, dtype=np.float64)
        elif op == "imux":
            pat = np.asarray(p["pattern"], dtype=np.int64)
            T = sum(len(v) for v in ins)
            order = np.resize(pat, T) if T else pat[:0]
            s = np.empty(T, dtype=np.float64)
            for port, v in enumerate(ins):
                at = np.nonzero(order == port)[0]
                s[at[:len(v)]] = v
        else:                              # copy/mux/demux pass-throughs
            s = 1.0 * ins[0]
        stream[nd.nid] = s


def _final_state_summary(cp: CompiledPlan, qlen_full, active, fires) -> dict:
    """The vector engine's final-cycle stall classification, recomputed on
    the host from the frozen carry (nothing fired in the deadlock cycle, so
    the final state *is* that cycle's snapshot)."""
    nN = cp.n_nodes
    emat = cp.in_mat.copy()
    for j, nid in enumerate(cp.imux_ids):
        pat = cp.imux_pat[j]
        port = pat[int(fires[nid]) % len(pat)]
        emat[nid, 0] = cp.imux_port_eids[j][port]
    out_opt = np.zeros(nN, dtype=bool)
    out_opt[cp.sync_ids] = True
    out_opt[cp.cmp_ids] = True
    for j, nid in enumerate(cp.flt_ids):
        k = int(fires[nid])
        if k < int(cp.flt_klen[j]):
            keep = bool(cp.keep_flat[int(cp.flt_koff[j]) + k])
        else:                              # past the analytic horizon
            keep = bool(cp.flt_nodes[j].params["keep"](k))
        out_opt[nid] = not keep
    in_ok = (qlen_full[emat] > 0).all(axis=1)
    out_ok = (qlen_full[cp.out_mat] < cp.capmat).all(axis=1)
    elig = in_ok & (out_ok | out_opt) & active[:nN]
    state = np.full(nN, ST_INACTIVE, dtype=np.int64)
    rest = active[:nN]
    state[rest & ~in_ok] = ST_INPUT_STARVED
    state[rest & in_ok & ~elig] = ST_OUTPUT_BLOCKED
    state[rest & elig] = ST_MEM_ARB
    names, ops = [""] * nN, [""] * nN
    for nd in cp.nodes:
        names[nd.nid] = nd.name
        ops[nd.nid] = nd.op
    return summary_from_state(state, names, ops)


def _deadlock_msg(cp: CompiledPlan, qlen_full, cycles: int) -> str:
    stuck = []
    for nd in cp.nodes:
        ine = [int(qlen_full[e.eid]) for e in nd.in_edges]
        if any(ine):
            outfull = [e.capacity is not None
                       and int(qlen_full[e.eid]) >= e.capacity
                       for e in nd.out_edges]
            stuck.append(f"{nd.name}({nd.op}) in={ine} outfull={outfull}")
        if len(stuck) >= 8:
            break
    return f"deadlock at cycle {cycles}; sample blocked nodes: {stuck}"


def _finalize(cp: CompiledPlan, flat_in, flat_out, lane: dict,
              max_cycles: int) -> RawStats | SimDeadlock:
    nN, nE = cp.n_nodes, cp.n_edges
    fires = lane["fires"][:nN].astype(np.int64)
    cycles = int(lane["cycles"])
    status = int(lane["status"])
    if status != _FINISHED:
        # reconstruct the full-length qlen the diagnostics index by eid
        qlen_full = np.concatenate(
            [lane["qlen"][:nE].astype(np.int64), [1 << 60]])
        if status == _RUNNING:
            return SimDeadlock(f"exceeded max_cycles={max_cycles}",
                               cycles=cycles, timed_out=True)
        summ = _final_state_summary(cp, qlen_full, lane["active"], fires)
        return SimDeadlock(_deadlock_msg(cp, qlen_full, cycles)
                           + format_stall_summary(summ),
                           cycles=cycles, stall_summary=summ)

    _value_pass(cp, flat_in, flat_out)

    fires_by_op: dict[str, int] = {}
    for nd in cp.nodes:
        f = int(fires[nd.nid])
        if f:
            nd.fires += f
            fires_by_op[nd.op] = fires_by_op.get(nd.op, 0) + f
    maxocc = lane["maxocc"][:nE].astype(np.int64)
    for e in cp.edges:
        mo = int(maxocc[e.eid])
        if mo > e.max_occupancy:
            e.max_occupancy = mo
    loads = int(fires[cp.mem_ids[cp.is_load]].sum()) if len(cp.mem_ids) else 0
    stores = (int(fires[cp.mem_ids[~cp.is_load]].sum())
              if len(cp.mem_ids) else 0)
    flops = int((fires[cp.lin_ids] * cp.lin_fw).sum()) if len(cp.lin_ids) \
        else 0
    return RawStats(
        cycles=cycles, flops=flops, loads=loads, stores=stores,
        fires=fires_by_op,
        max_queue_total=sum(e.max_occupancy for e in cp.g.edges()))


# ---------------------------------------------------------------------------
# public entry points


#: lanes per device dispatch.  A vmapped ``while_loop`` runs every lane in
#: lockstep until the *slowest* finishes, so cycle-similar lanes are grouped
#: into sub-dispatches — a fast lane never idles thousands of cycles behind
#: a slow sibling in another group, and each group gets its own (tighter)
#: padded dims.
_GROUP = 8


def run_compiled_batch(items: list[tuple[CompiledPlan, np.ndarray, np.ndarray,
                                         float]],
                       max_cycles: int = 50_000_000
                       ) -> list[RawStats | SimDeadlock | JaxLoweringError]:
    """Simulate B compiled plans in one batched device call per lane group
    (``_GROUP`` cycle-similar lanes each).

    ``items``: ``(compiled_plan, flat_in, flat_out, elems_per_cycle)`` per
    lane.  Returns one entry per lane, aligned: ``RawStats`` on success
    (with ``flat_out`` filled and per-node ``fires``/``max_occupancy``
    written back), a ``SimDeadlock`` *value* (not raised) for lanes that
    deadlock or time out, or a ``JaxLoweringError`` value for lanes the
    lowering rejects — one bad lane never poisons its siblings."""
    _require_jax()
    max_cycles = min(int(max_cycles), (1 << 31) - 2)   # int32 cycle counter
    results: list = [None] * len(items)
    good: list[tuple[int, CompiledPlan, float]] = []
    for i, (cp, _fi, _fo, epc) in enumerate(items):
        try:
            cp.require_current()           # stale tables: surface per lane
            if cp.net is not None:
                raise JaxLoweringError(
                    "engine='jax' is ideal-mode only (no network-aware "
                    "simulation); use engine='vector' for routed plans")
            good.append((i, cp, float(epc)))
        except JaxLoweringError as e:
            results[i] = e
        except Exception as e:
            results[i] = JaxLoweringError(str(e))
    if not good:
        return results

    # node count is a cheap monotone proxy for a lane's cycle count within
    # a sweep (fewer workers => fewer nodes => a longer pipeline run), so
    # sorting clusters similar-length lanes into the same lockstep group
    good.sort(key=lambda t: t[1].n_nodes)

    with jax.experimental.enable_x64():
        for g0 in range(0, len(good), _GROUP):
            grp = good[g0:g0 + _GROUP]
            dims = shared_dims([cp for _, cp, _ in grp])
            lows: list[tuple[int, LoweredPlan, float]] = []
            for i, cp, epc in grp:
                try:
                    lows.append((i, lower(cp, dims), epc))
                except JaxLoweringError as e:
                    results[i] = e
            if not lows:
                continue
            stacked = {k: np.stack([lp.tables[k] for _, lp, _ in lows])
                       for k in lows[0][1].tables}
            stacked["epc"] = np.asarray([epc for _, _, epc in lows],
                                        dtype=np.float64)
            stacked["cap4"] = 4.0 * stacked["epc"]
            out = _sweep({k: jnp.asarray(v) for k, v in stacked.items()},
                         jnp.int32(max_cycles))
            qlen, active, fires, maxocc, _credit, cycles, status = \
                [np.asarray(a) for a in out]
            for j, (i, lp, _epc) in enumerate(lows):
                lane = {"qlen": qlen[j], "active": active[j],
                        "fires": fires[j], "maxocc": maxocc[j],
                        "cycles": cycles[j], "status": status[j]}
                results[i] = _finalize(lp.cp, items[i][1], items[i][2],
                                       lane, max_cycles)
    return results


def run(plan, flat_in, flat_out, elems_per_cycle: float,
        max_cycles: int = 50_000_000, fabric=None, telemetry=None) -> RawStats:
    """Single-plan entry with the same signature/contract as
    ``interp.run``/``vector.run`` (a batch of one; the jit cache makes the
    padded-shape bucket warm across calls).  Ideal mode only."""
    _require_jax()
    if fabric is not None:
        raise NotImplementedError(
            "engine='jax' does not simulate routed fabrics; use "
            "engine='vector' for network-aware mode")
    if telemetry is not None:
        raise NotImplementedError(
            "engine='jax' has no telemetry probes; use engine='vector' "
            "or engine='interp' with a telemetry sink")
    cp = compiled_for(plan, None)
    [res] = run_compiled_batch([(cp, flat_in, flat_out, elems_per_cycle)],
                               max_cycles=max_cycles)
    if isinstance(res, Exception):
        raise res
    return res
