"""Compiled vectorized simulation backend.

Runs the exact semantics of :mod:`repro.core.engine.interp` — same firing
rule, same two-phase FIFO snapshots, same rotating memory arbiter, same
network contention — but over the :class:`~repro.core.engine.compile.
CompiledPlan` struct-of-arrays tables instead of ``Node``/``Edge`` objects:

* **snapshot**: one gather + ``all``-reduce over the padded in/out edge
  matrices yields every node's eligibility at once (queue lengths live in a
  flat ``qlen`` array indexed by dense edge id; queue storage in one
  ring-buffer pool).  When every queue is unbounded — the mapper's default —
  output-space checks are constant-true and skipped wholesale.
* **dense cycles** (many eligible nodes): per op-kind bucket, all eligible
  nodes fire together — fronts gathered from the ring pool, values computed
  array-wide (the unified ``A*front0 [+ B*front1]`` form is bit-identical to
  the interpreter's scalar expressions), pops/pushes applied as batched ring
  updates, broadcast expanded through the out-edge CSR.  The rotating memory
  arbiter is a rolled mask + cumsum against the fractional credit
  (decremented 1.0 at a time so the float trajectory matches exactly).
* **sparse cycles** (a handful eligible — the common shape once network
  contention spreads fires out): the same tables are executed scalar-wise
  over just the eligible nodes, in the interpreter's execute order, through
  memoryview mirrors of the ring arrays (python-int indexing, no per-access
  numpy scalar boxing).  Both paths leave identical state, so the engine
  switches freely per cycle.
* **network**: in-flight tokens sit in per-arrival-cycle buckets behind a
  heap of bucket keys, so delivery is a heap-front check per cycle and the
  next-event time is O(1) (buckets pop in arrival order and keep send order,
  preserving per-edge FIFO); link booking replaces the interpreter's linear
  full-slot walk with flat integer-keyed route-step state
  (``(link << B) | slot``) threaded by a next-free-slot chain with path
  compression, so each hop books in amortized ~O(1) while producing the
  identical slot assignments, stalls and arrivals.
* **event skip**: a cycle in which nothing fired and tokens are only riding
  the network fast-forwards to the next arrival (or memory-credit) event —
  state provably cannot change in between, so cycle counts are unaffected.

Max-occupancy bookkeeping replicates the interpreter's push-time sampling:
whether the consumer's pop lands before the producer's push inside one cycle
is a static property of the execute order (memory ops first, then graph
order), precompiled into the per-edge ``pop_first`` flag (the sparse path
simply executes in that order and samples directly).
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.core.engine.common import RawStats, SimDeadlock
from repro.core.engine.compile import (CompiledPlan, K_ADDR, K_CMP, K_FLT,
                                       K_LIN, K_LOAD, K_STORE, K_SYNC,
                                       SLOT_BITS, UNBOUNDED, compile_plan,
                                       compiled_for)
from repro.telemetry.probe import (ST_FIRED, ST_INACTIVE, ST_INPUT_STARVED,
                                   ST_MEM_ARB, ST_NET_WAIT,
                                   ST_OUTPUT_BLOCKED, format_stall_summary,
                                   summary_from_state)

_BIG = 1 << 60
_SPARSE_MAX = 96          # eligible-node count at or below which the scalar
                          # path beats the fixed cost of the bucket passes
                          # (measured crossover on program pipelines; routed
                          # contention keeps most cycles well under this)


class _Rings:
    """All queues in one float64 pool: per-edge base/phys + head/len.

    The numpy arrays are the single source of truth (the dense path updates
    them with fancy indexing); the ``*_mv`` memoryviews alias the same
    buffers for the sparse path's python-int scalar access.
    """

    def __init__(self, cap: np.ndarray, phys0: np.ndarray):
        self.cap = cap
        self.n = len(cap)                  # n_edges + 1 (sentinel last)
        self.phys = phys0.astype(np.int64).copy()
        self.head = np.zeros(self.n, dtype=np.int64)
        self.qlen = np.zeros(self.n, dtype=np.int64)
        self.qlen[-1] = _BIG               # sentinel: never empty …
        self.phys_mv = memoryview(self.phys)
        self.head_mv = memoryview(self.head)
        self.qlen_mv = memoryview(self.qlen)
        self._rebase()
        # … and the sentinel's ring slot reads 0.0 (pool stays zeroed there).

    def _rebase(self) -> None:
        self.base = np.zeros(self.n, dtype=np.int64)
        np.cumsum(self.phys[:-1], out=self.base[1:])
        self.pool = np.zeros(int(self.base[-1] + self.phys[-1]),
                             dtype=np.float64)
        self.base_mv = memoryview(self.base)
        self.pool_mv = memoryview(self.pool)

    def front(self, eids: np.ndarray) -> np.ndarray:
        return self.pool[self.base[eids] + self.head[eids]]

    def pop(self, eids: np.ndarray) -> None:
        h = self.head[eids] + 1
        ph = self.phys[eids]
        h[h == ph] = 0
        self.head[eids] = h
        self.qlen[eids] -= 1

    def push(self, eids: np.ndarray, vals: np.ndarray) -> None:
        full = self.qlen[eids] >= self.phys[eids]
        if full.any():
            self._grow(np.unique(eids[full]))
        pos = self.head[eids] + self.qlen[eids]
        ph = self.phys[eids]
        wrap = pos >= ph
        pos[wrap] -= ph[wrap]
        self.pool[self.base[eids] + pos] = vals
        self.qlen[eids] += 1

    def _grow(self, eids) -> None:
        """Amortized-doubling regrow of (logically unbounded) rings."""
        old_base, old_pool, old_phys = self.base, self.pool, self.phys.copy()
        for e in eids:
            self.phys[e] = int(min(self.cap[e], max(4 * old_phys[e], 8)))
        self._rebase()
        for e in range(self.n - 1):        # sentinel ring stays zeroed
            q = int(self.qlen[e])
            if not q:
                self.head[e] = 0
                continue
            h, p = int(self.head[e]), int(old_phys[e])
            ob, nb = int(old_base[e]), int(self.base[e])
            first = min(q, p - h)
            self.pool[nb:nb + first] = old_pool[ob + h:ob + h + first]
            if q > first:
                self.pool[nb + first:nb + q] = old_pool[ob:ob + q - first]
            self.head[e] = 0


def run(plan, flat_in, flat_out, elems_per_cycle: float,
        max_cycles: int = 50_000_000, fabric=None, telemetry=None) -> RawStats:
    """Compile ``plan`` (+ routes) and run the vectorized cycle loop;
    mutates ``flat_out`` in place.  Results match ``engine.interp`` exactly.

    Compiles are cached on the plan (``compiled_for``): re-simulating the
    same plan skips the flatten, and a plan mutated after compilation —
    ``apply_min_capacities`` after a prior run, the auto-tuner's recapacity
    path — transparently recompiles instead of using stale tables."""
    cp = compiled_for(plan, fabric)
    return _run_compiled(cp.require_current(), flat_in, flat_out,
                         elems_per_cycle, max_cycles, telemetry)


def _deadlock_msg(cp: CompiledPlan, rings: _Rings, cycles: int) -> str:
    qlen = rings.qlen
    stuck = []
    for nd in cp.nodes:
        ine = [int(qlen[e.eid]) for e in nd.in_edges]
        if any(ine):
            outfull = [e.capacity is not None
                       and int(qlen[e.eid]) >= e.capacity
                       for e in nd.out_edges]
            stuck.append(f"{nd.name}({nd.op}) in={ine} outfull={outfull}")
        if len(stuck) >= 8:
            break
    return f"deadlock at cycle {cycles}; sample blocked nodes: {stuck}"


def _expand_push(start, flat, nids, vals, rings, qstart, pop_first,
                 popped_stamp, maxocc, cycles) -> None:
    """Broadcast: expand fired nodes over their (CSR) out-edges and push."""
    deg = start[nids + 1] - start[nids]
    tot = int(deg.sum())
    if not tot:
        return
    cum = np.cumsum(deg)
    idx = np.arange(tot, dtype=np.int64) + np.repeat(start[nids] - cum + deg,
                                                     deg)
    eids = flat[idx]
    rings.push(eids, np.repeat(vals, deg))
    # interpreter-exact occupancy sampling: the push saw the consumer's pop
    # only if the consumer executes earlier in the (static) order.
    occ_c = qstart[eids] + 1 - (pop_first[eids]
                                & (popped_stamp[eids] == cycles))
    maxocc[eids] = np.maximum(maxocc[eids], occ_c)


def _run_compiled(cp: CompiledPlan, flat_in, flat_out,
                  elems_per_cycle: float, max_cycles: int,
                  tel=None) -> RawStats:
    nN, nE = cp.n_nodes, cp.n_edges
    telon = tel is not None
    rings = _Rings(cp.cap, cp.phys0)
    qlen = rings.qlen
    in_mat, out_mat, capmat = cp.in_mat, cp.out_mat, cp.capmat
    out_start, out_flat = cp.out_start, cp.out_flat
    pop_first = cp.pop_first
    # the mapper's default leaves every queue unbounded: output space is
    # then constant-true and the whole occupancy check drops out.
    all_unbounded = bool((cp.cap[:nE] == UNBOUNDED).all())
    true_arr = np.ones(nN, dtype=bool)

    active = cp.active0.copy()
    out_opt = cp.out_opt0.copy()
    fires_arr = np.zeros(nN, dtype=np.int64)
    maxocc = np.zeros(nE + 1, dtype=np.int64)
    popped_stamp = np.full(nE + 1, -1, dtype=np.int64)
    active_mv = memoryview(active)
    out_opt_mv = memoryview(out_opt)
    fires_mv = memoryview(fires_arr)
    maxocc_mv = memoryview(maxocc)

    addr_ids, addr_cnt = cp.addr_ids, cp.addr_cnt
    addr_k = np.zeros(len(addr_ids), dtype=np.int64)
    addr_k_mv = memoryview(addr_k)
    mem_ids, is_load = cp.mem_ids, cp.is_load
    mem_in0, mem_in1 = cp.mem_in0, cp.mem_in1
    midx_off, midx_flat = cp.midx_off, cp.midx_flat
    midx_mv = memoryview(midx_flat)
    flat_in_mv = memoryview(flat_in)
    flat_out_mv = memoryview(flat_out)
    n_mem = max(1, len(mem_ids))
    lin_ids, lin_a, lin_b = cp.lin_ids, cp.lin_a, cp.lin_b
    lin_hasb, lin_in0, lin_in1, lin_fw = \
        cp.lin_hasb, cp.lin_in0, cp.lin_in1, cp.lin_fw
    flt_ids, flt_in0 = cp.flt_ids, cp.flt_in0
    keep_flat, flt_koff, flt_klen = cp.keep_flat, cp.flt_koff, cp.flt_klen
    flt_k = np.zeros(len(flt_ids), dtype=np.int64)
    flt_k_mv = memoryview(flt_k)
    flt_next = (keep_flat[flt_koff].copy() if len(flt_ids)
                else np.zeros(0, dtype=bool))
    flt_next_mv = memoryview(flt_next)
    sync_ids, sync_in0, sync_exp = cp.sync_ids, cp.sync_in0, cp.sync_exp
    sync_cnt = np.zeros(len(sync_ids), dtype=np.int64)
    sync_cnt_mv = memoryview(sync_cnt)
    cmp_ids, cmp_in = cp.cmp_ids, cp.cmp_in
    imux_ids = cp.imux_ids
    n_imux = len(imux_ids)
    imux_k = np.zeros(n_imux, dtype=np.int64)
    imux_k_mv = memoryview(imux_k)
    imux_sel = cp.imux_sel0.copy()
    imux_sel_mv = memoryview(imux_sel)

    # python mirrors for the sparse (scalar) path
    kind_l = cp.kind_of.tolist()
    is_mem_l = [k in (K_LOAD, K_STORE) for k in kind_l]
    bidx_l = cp.bidx.tolist()
    out_py = cp.out_py
    addr_cnt_l = addr_cnt.tolist()
    mem_in0_l, mem_in1_l = mem_in0.tolist(), mem_in1.tolist()
    midx_off_l = midx_off.tolist()
    lin_a_l, lin_b_l = lin_a.tolist(), lin_b.tolist()
    lin_hasb_l = lin_hasb.tolist()
    lin_in0_l, lin_in1_l = lin_in0.tolist(), lin_in1.tolist()
    lin_fw_l = lin_fw.tolist()
    flt_in0_l = flt_in0.tolist()
    flt_koff_l, flt_klen_l = flt_koff.tolist(), flt_klen.tolist()
    keep_l = keep_flat.tolist()
    sync_in0_l, sync_exp_l = sync_in0.tolist(), sync_exp.tolist()
    cmp_in_l = [a.tolist() for a in cmp_in]
    imux_pat_l = [p.tolist() for p in cp.imux_pat]
    imux_ports_l = [p.tolist() for p in cp.imux_port_eids]

    net = cp.net
    if net is not None:
        book = net.book
        loc_py = net.loc_py
        loc_start, loc_flat = net.loc_start, net.loc_flat
        used: dict = {}                    # (link<<B)|slot -> words booked
        nxt_free: dict = {}                # full slot -> next candidate slot
        wpc1 = net.wpc1
        last_arr = [0] * (nE + 1)
        arrivals: dict = {}                # cycle -> [(eid, value), …] in
        arr_heap: list = []                # send order; heap of bucket keys
        tlen = np.zeros(nE + 1, dtype=np.int64)
        tlen_mv = memoryview(tlen)
        track_occ = not all_unbounded      # occ only matters for bounded
        # telemetry needs in-flight counts too (net-wait classification)
        track_tlen = track_occ or telon

    token_hops = stall_cycles = 0
    credit = 0.0
    cap4 = 4 * elems_per_cycle
    cycles = 0
    loads = stores = flops = 0
    done_pending = cp.n_cmp
    finished = False
    pos_other = cp.pos_other

    def _transit(eid: int, arr: float, v: float) -> None:
        """Queue an arrival: per-edge FIFO holds because buckets deliver in
        ascending arrival order and each bucket keeps send order."""
        lst = arrivals.get(arr)
        if lst is None:
            arrivals[arr] = [(eid, v)]
            heapq.heappush(arr_heap, arr)
        else:
            lst.append((eid, v))
        if track_tlen:
            tlen_mv[eid] += 1

    def send_routed(nid: int, v: float) -> None:
        """Book one multicast over the node's routed out-edges: identical
        slot assignment to the interpreter's linear search, but the first
        free slot >= t is found through a next-free-slot chain with path
        compression (amortized ~O(1) per hop even under heavy contention,
        where the interpreter walks every full slot).  With every link at
        words-per-cycle 1 (``wpc1``) the chain doubles as the booking table;
        the general variant below tracks per-slot word counts too."""
        nonlocal token_hops, stall_cycles
        nf_get = nxt_free.get
        bk = book[nid]
        multi = len(bk) > 1                # multicast: dedupe shared links
        booked: dict = {} if multi else None
        for eid, links in bk:
            t = cycles
            for key in links:
                if multi:
                    bs = booked.get(key)
                    if bs is not None:
                        t = bs + 1
                        continue
                s = t
                ns = nf_get(key + s)
                if ns is not None:           # hop over the known-full band
                    chain = []
                    while ns is not None:
                        chain.append(s)
                        s = ns
                        ns = nf_get(key + s)
                    for cs in chain:         # path compression
                        nxt_free[key + cs] = s
                stall_cycles += s - t
                nxt_free[key + s] = s + 1    # wpc 1: slot fills at once
                if multi:
                    booked[key] = s
                token_hops += 1
                if telon:
                    tel.link_book(key >> SLOT_BITS, s, s - t)
                t = s + 1
            la = last_arr[eid]
            arr = t if t > la else la
            last_arr[eid] = arr
            _transit(eid, arr, v)

    def send_routed_general(nid: int, v: float) -> None:
        """Mixed words-per-cycle fabric: like :func:`send_routed` but a slot
        only chains into the next-free list once its word count fills."""
        nonlocal token_hops, stall_cycles
        nf_get = nxt_free.get
        bk = book[nid]
        multi = len(bk) > 1
        booked: dict = {} if multi else None
        for eid, links in bk:
            t = cycles
            for key, capw in links:
                if multi:
                    bs = booked.get(key)
                    if bs is not None:
                        t = bs + 1
                        continue
                s = t
                ns = nf_get(key + s)
                if ns is not None:
                    chain = []
                    while ns is not None:
                        chain.append(s)
                        s = ns
                        ns = nf_get(key + s)
                    for cs in chain:
                        nxt_free[key + cs] = s
                stall_cycles += s - t
                ks = key + s
                c = used.get(ks, 0) + 1
                used[ks] = c
                if c >= capw:
                    nxt_free[ks] = s + 1
                if multi:
                    booked[key] = s
                token_hops += 1
                if telon:
                    tel.link_book(key >> SLOT_BITS, s, s - t)
                t = s + 1
            la = last_arr[eid]
            arr = t if t > la else la
            last_arr[eid] = arr
            _transit(eid, arr, v)

    def s_push(e: int, v) -> None:
        r = rings
        q = r.qlen_mv[e]
        if q >= r.phys_mv[e]:
            r._grow((e,))
            q = r.qlen_mv[e]
        pos = r.head_mv[e] + q
        ph = r.phys_mv[e]
        if pos >= ph:
            pos -= ph
        r.pool_mv[r.base_mv[e] + pos] = v
        q += 1
        r.qlen_mv[e] = q
        if q > maxocc_mv[e]:               # push-time sample, like Edge.push
            maxocc_mv[e] = q

    def s_popv(e: int):
        r = rings
        h = r.head_mv[e]
        v = r.pool_mv[r.base_mv[e] + h]
        h += 1
        r.head_mv[e] = 0 if h == r.phys_mv[e] else h
        r.qlen_mv[e] -= 1
        return v

    # sparse-path broadcast plan: local pushes + (net mode) routed booking
    if net is None:
        emit_loc = out_py
        has_routed = [False] * nN
    else:
        emit_loc = loc_py
        has_routed = [b is not None for b in book]
        if not wpc1:
            send_routed = send_routed_general

    if telon:
        prev_fires = np.zeros(nN, dtype=np.int64)
    in_ok = elig = None                    # bound per cycle; read by _classify

    def _classify(fired_mask: np.ndarray) -> np.ndarray:
        """One exclusive ``ST_*`` code per node for the cycle just executed,
        from this cycle's eligibility snapshot + the fire delta.  Mirrors the
        interpreter's scalar classification exactly (parity-gated)."""
        state = np.full(nN, ST_INACTIVE, dtype=np.int64)
        rest = active & ~fired_mask
        starv = rest & ~in_ok
        if net is not None:
            # starved, but tokens are riding the network toward an input
            intrans = tlen[in_mat].sum(axis=1) > 0
            if n_imux:
                intrans[imux_ids] = tlen[imux_sel] > 0
            state[starv & intrans] = ST_NET_WAIT
            starv &= ~intrans
        state[starv] = ST_INPUT_STARVED
        state[rest & in_ok & ~elig] = ST_OUTPUT_BLOCKED
        state[rest & elig] = ST_MEM_ARB    # lost memory-port arbitration
        state[fired_mask] = ST_FIRED
        return state

    def _final_cycle_summary() -> dict:
        names = [""] * nN
        ops = [""] * nN
        for nd in cp.nodes:
            names[nd.nid] = nd.name
            ops[nd.nid] = nd.op
        return summary_from_state(_classify(np.zeros(nN, dtype=bool)),
                                  names, ops)

    while not finished:
        if cycles >= max_cycles:
            if telon:
                tel.finish(cycles)
                summ = tel.stall_summary(window=64)
                raise SimDeadlock(f"exceeded max_cycles={max_cycles}"
                                  + format_stall_summary(summ),
                                  cycles=cycles, timed_out=True,
                                  stall_summary=summ)
            raise SimDeadlock(f"exceeded max_cycles={max_cycles}",
                              cycles=cycles, timed_out=True)
        cycles += 1
        credit = min(credit + elems_per_cycle, cap4)

        if net is not None:
            # slot searches always start at the current cycle; drop booking
            # entries for past slots periodically to keep memory flat.
            if cycles % 4096 == 0:
                mask = (1 << SLOT_BITS) - 1
                if used:
                    used = {k: v for k, v in used.items()
                            if (k & mask) >= cycles}
                if nxt_free:
                    nxt_free = {k: v for k, v in nxt_free.items()
                                if (k & mask) >= cycles}
            # deliver: arrivals land before the snapshot (buckets pop in
            # ascending arrival order; each bucket preserves send order)
            while arr_heap and arr_heap[0] <= cycles:
                for e, v in arrivals.pop(heapq.heappop(arr_heap)):
                    s_push(e, v)
                    if track_tlen:
                        tlen_mv[e] -= 1

        # phase 1: snapshot eligibility ------------------------------------
        in_ok = (qlen[in_mat] > 0).all(axis=1)
        if n_imux:
            in_ok[imux_ids] = qlen[imux_sel] > 0
        if all_unbounded:
            out_ok = true_arr
            elig = in_ok & active
        else:
            occ = qlen if net is None else qlen + tlen
            out_ok = (occ[out_mat] < capmat).all(axis=1)
            elig = in_ok & (out_ok | out_opt) & active

        cand = np.nonzero(elig)[0]
        ncand = len(cand)
        any_fired = False
        mem_waiting = False

        if not ncand:
            pass

        elif ncand <= _SPARSE_MAX:
            # ---- sparse path: scalar execute in interpreter order --------
            mems, others = [], []
            for n in cand.tolist():
                (mems if is_mem_l[n] else others).append(n)
            if mems:
                rot = cycles % n_mem
                mems.sort(key=lambda n: (bidx_l[n] - rot) % n_mem)
            for n in mems:
                if credit < 1.0:
                    mem_waiting = True
                    continue
                b = bidx_l[n]
                a = int(s_popv(mem_in0_l[b]))
                if kind_l[n] == K_LOAD:
                    v = flat_in_mv[midx_mv[midx_off_l[b] + a]]
                    loads += 1
                else:
                    val = s_popv(mem_in1_l[b])
                    flat_out_mv[midx_mv[midx_off_l[b] + a]] = val
                    stores += 1
                    v = 1.0
                credit -= 1.0
                fires_mv[n] += 1
                any_fired = True
                for e in emit_loc[n]:
                    s_push(e, v)
                if has_routed[n]:
                    send_routed(n, v)
            for n in others:
                k = kind_l[n]
                b = bidx_l[n]
                if k == K_LIN:
                    v = lin_a_l[b] * s_popv(lin_in0_l[b])
                    if lin_hasb_l[b]:
                        v = v + lin_b_l[b] * s_popv(lin_in1_l[b])
                    flops += lin_fw_l[b]
                elif k == K_FLT:
                    keep = flt_next_mv[b]
                    v = s_popv(flt_in0_l[b])
                    kk = flt_k_mv[b] + 1
                    flt_k_mv[b] = kk
                    if kk >= flt_klen_l[b]:
                        nxt = bool(cp.flt_nodes[b].params["keep"](kk))
                    else:
                        nxt = keep_l[flt_koff_l[b] + kk]
                    flt_next_mv[b] = nxt
                    out_opt_mv[n] = not nxt
                    fires_mv[n] += 1
                    any_fired = True
                    if keep:
                        for e in emit_loc[n]:
                            s_push(e, v)
                        if has_routed[n]:
                            send_routed(n, v)
                    continue
                elif k == K_ADDR:
                    kk = addr_k_mv[b]
                    v = float(kk)
                    addr_k_mv[b] = kk + 1
                    if kk + 1 >= addr_cnt_l[b]:
                        active_mv[n] = False
                elif k == K_SYNC:
                    s_popv(sync_in0_l[b])
                    c = sync_cnt_mv[b] + 1
                    sync_cnt_mv[b] = c
                    fires_mv[n] += 1
                    any_fired = True
                    if c == sync_exp_l[b] and out_ok[n]:
                        active_mv[n] = False
                        for e in emit_loc[n]:
                            s_push(e, 1.0)
                        if has_routed[n]:
                            send_routed(n, 1.0)
                    continue
                elif k == K_CMP:
                    for e in cmp_in_l[b]:
                        s_popv(e)
                    active_mv[n] = False
                    done_pending -= 1
                    if done_pending == 0:
                        finished = True
                    fires_mv[n] += 1
                    any_fired = True
                    continue
                else:                      # K_IMUX
                    v = s_popv(imux_sel_mv[b])
                    kk = imux_k_mv[b] + 1
                    imux_k_mv[b] = kk
                    pat = imux_pat_l[b]
                    imux_sel_mv[b] = imux_ports_l[b][pat[kk % len(pat)]]
                fires_mv[n] += 1
                any_fired = True
                for e in emit_loc[n]:
                    s_push(e, v)
                if has_routed[n]:
                    send_routed(n, v)

        else:
            # ---- dense path: one vectorized pass per op-kind -------------
            qstart = qlen.copy()
            pops = []
            fired = []
            push_mem_n = push_mem_v = None
            push_n, push_v = [], []

            # memory ops, rotating arbiter + fractional credit
            em = elig[mem_ids]
            em_any = em.any()
            mem_waiting = bool(em_any)
            if em_any and credit >= 1.0:
                rot = cycles % n_mem
                emr = np.concatenate((em[rot:], em[:rot])) if rot else em
                fire_r = emr & (np.cumsum(emr) <= int(credit))
                pos_r = np.nonzero(fire_r)[0]
                if rot:
                    pos_r = (pos_r + rot) % n_mem
                if len(pos_r):
                    ldm = is_load[pos_r]
                    v_mem = np.empty(len(pos_r), dtype=np.float64)
                    lp = pos_r[ldm]
                    if len(lp):
                        e0 = mem_in0[lp]
                        a = rings.front(e0).astype(np.int64)
                        v_mem[ldm] = flat_in[midx_flat[midx_off[lp] + a]]
                        pops.append(e0)
                        loads += len(lp)
                    sp = pos_r[~ldm]
                    if len(sp):
                        e0, e1 = mem_in0[sp], mem_in1[sp]
                        a = rings.front(e0).astype(np.int64)
                        flat_out[midx_flat[midx_off[sp] + a]] = rings.front(e1)
                        v_mem[~ldm] = 1.0
                        pops.append(e0)
                        pops.append(e1)
                        stores += len(sp)
                    for _ in range(len(pos_r)):   # match interp's float walk
                        credit -= 1.0
                    push_mem_n = mem_ids[pos_r]
                    push_mem_v = v_mem
                    fired.append(push_mem_n)

            # addr: index generators
            am = elig[addr_ids]
            if am.any():
                ai = np.nonzero(am)[0]
                nids = addr_ids[ai]
                push_n.append(nids)
                push_v.append(addr_k[ai].astype(np.float64))
                addr_k[ai] += 1
                done = addr_k[ai] >= addr_cnt[ai]
                if done.any():
                    active[nids[done]] = False
                fired.append(nids)

            # linear arithmetic: v = A*front0 [+ B*front1]
            lm = elig[lin_ids]
            if lm.any():
                li = np.nonzero(lm)[0]
                e0 = lin_in0[li]
                v = lin_a[li] * rings.front(e0)
                pops.append(e0)
                hb = lin_hasb[li]
                if hb.any():
                    bi = li[hb]
                    e1 = lin_in1[bi]
                    v[hb] += lin_b[bi] * rings.front(e1)
                    pops.append(e1)
                flops += int(lin_fw[li].sum())
                push_n.append(lin_ids[li])
                push_v.append(v)
                fired.append(lin_ids[li])

            # filters: pop always, forward only kept tokens
            fm = elig[flt_ids]
            if fm.any():
                fi = np.nonzero(fm)[0]
                e0 = flt_in0[fi]
                v = rings.front(e0)
                pops.append(e0)
                keep = flt_next[fi]
                if keep.any():
                    push_n.append(flt_ids[fi[keep]])
                    push_v.append(v[keep])
                flt_k[fi] += 1
                newk = flt_k[fi]
                klen = flt_klen[fi]
                over = newk >= klen
                nxt = keep_flat[flt_koff[fi] + np.minimum(newk, klen - 1)]
                if over.any():             # past the analytic horizon: ask
                    for j in np.nonzero(over)[0]:     # the original callable
                        nxt[j] = bool(cp.flt_nodes[int(fi[j])]
                                      .params["keep"](int(newk[j])))
                flt_next[fi] = nxt
                out_opt[flt_ids[fi]] = ~nxt
                fired.append(flt_ids[fi])

            # sync: count-ticks; emission rides the final tick
            sm = elig[sync_ids]
            if sm.any():
                si = np.nonzero(sm)[0]
                pops.append(sync_in0[si])
                sync_cnt[si] += 1
                emit = (sync_cnt[si] == sync_exp[si]) & out_ok[sync_ids[si]]
                if emit.any():
                    en = sync_ids[si[emit]]
                    active[en] = False
                    push_n.append(en)
                    push_v.append(np.ones(len(en), dtype=np.float64))
                fired.append(sync_ids[si])

            # cmp: completion combiners
            if done_pending:
                cm = elig[cmp_ids]
                if cm.any():
                    ci = np.nonzero(cm)[0]
                    for j in ci:
                        pops.append(cmp_in[int(j)])
                    active[cmp_ids[ci]] = False
                    done_pending -= len(ci)
                    if done_pending == 0:
                        finished = True
                    fired.append(cmp_ids[ci])

            # imux: pop the pattern-selected port
            if n_imux:
                im = elig[imux_ids]
                if im.any():
                    ii = np.nonzero(im)[0]
                    e0 = imux_sel[ii]
                    push_n.append(imux_ids[ii])
                    push_v.append(rings.front(e0))
                    pops.append(e0)
                    imux_k[ii] += 1
                    for j in ii:            # few imux nodes; ragged patterns
                        pat = cp.imux_pat[int(j)]
                        port = pat[int(imux_k[j]) % len(pat)]
                        imux_sel[j] = cp.imux_port_eids[int(j)][port]
                    fired.append(imux_ids[ii])

            # commit: pops, then pushes (snapshots were taken up front) ----
            if pops:
                pe = np.concatenate(pops)
                rings.pop(pe)
                popped_stamp[pe] = cycles
            any_fired = bool(fired)
            if any_fired:
                fires_arr[np.concatenate(fired)] += 1

            if push_mem_n is not None or push_n:
                if push_mem_n is not None:
                    nids = np.concatenate([push_mem_n] + push_n)
                    vals = np.concatenate([push_mem_v] + push_v)
                else:
                    nids = (np.concatenate(push_n) if len(push_n) > 1
                            else push_n[0])
                    vals = (np.concatenate(push_v) if len(push_v) > 1
                            else push_v[0])
                if net is None:
                    _expand_push(out_start, out_flat, nids, vals, rings,
                                 qstart, pop_first, popped_stamp, maxocc,
                                 cycles)
                else:
                    _expand_push(loc_start, loc_flat, nids, vals, rings,
                                 qstart, pop_first, popped_stamp, maxocc,
                                 cycles)
                    # booking order = interpreter execute order: memory ops
                    # in rotated order first, then the rest in graph order.
                    n_m = 0 if push_mem_n is None else len(push_mem_n)
                    if len(nids) > n_m:
                        oth = nids[n_m:]
                        order = np.argsort(pos_other[oth], kind="stable")
                        oth_n = oth[order]
                        oth_v = vals[n_m:][order]
                        if n_m:
                            nids = np.concatenate((nids[:n_m], oth_n))
                            vals = np.concatenate((vals[:n_m], oth_v))
                        else:
                            nids, vals = oth_n, oth_v
                    for nid, v in zip(nids.tolist(), vals.tolist()):
                        if book[nid] is not None:
                            send_routed(nid, v)

        if telon:
            fired_mask = fires_arr != prev_fires
            np.copyto(prev_fires, fires_arr)
            tel.observe(cycles, _classify(fired_mask))

        if not any_fired and not finished:
            if net is None or not arr_heap:
                if telon:
                    tel.finish(cycles)
                    summ = tel.stall_summary(window=64)
                else:
                    summ = _final_cycle_summary()
                raise SimDeadlock(_deadlock_msg(cp, rings, cycles)
                                  + format_stall_summary(summ),
                                  cycles=cycles, stall_summary=summ)
            # event skip: state is static until the next arrival (or the
            # memory credit crossing 1.0) — fast-forward to it.
            nxt = arr_heap[0]
            if mem_waiting and credit < 1.0 <= cap4:
                cc, n = credit, 0
                while cc < 1.0:
                    cc = min(cc + elems_per_cycle, cap4)
                    n += 1
                if cycles + n < nxt:
                    nxt = cycles + n
            k = nxt - 1 - cycles
            if k > 0:
                i = 0
                while i < k and credit < cap4:
                    credit = min(credit + elems_per_cycle, cap4)
                    i += 1
                cycles += k
                if telon:     # skipped cycles repeat the standing state
                    tel.observe_repeat(k)

    if telon:
        tel.finish(cycles)
    # write back per-node/per-edge telemetry so both backends expose the
    # same post-run state on the plan objects.
    fires: dict[str, int] = {}
    for nd in cp.nodes:
        f = int(fires_arr[nd.nid])
        if f:
            nd.fires += f
            fires[nd.op] = fires.get(nd.op, 0) + f
    for e in cp.edges:
        mo = int(maxocc[e.eid])
        if mo > e.max_occupancy:
            e.max_occupancy = mo
    return RawStats(
        cycles=cycles, flops=flops, loads=loads, stores=stores, fires=fires,
        max_queue_total=sum(e.max_occupancy for e in cp.g.edges()),
        token_hops=token_hops, stall_cycles=stall_cycles)
