"""Stencil → CGRA dataflow-graph mapping (paper §III).

Implements the paper's worker-pipeline decomposition:

* ``w`` **reader workers** load the input grid in an *interleaved* manner
  (reader k loads elements k, k+w, k+2w, … in row-major flat order).
* ``w`` **compute workers**: worker c computes interior outputs c, c+w, … (in
  row-major interior order) with a MUL→MAC→…→MAC chain, one arithmetic PE per
  coefficient tap.  Every tap has its own **data-filtering PE** that drops the
  values its MUL/MAC must not see — the paper's ``0^m 1^n 0^p`` patterns,
  generalized here per-dimension (lead/keep/drop along the row axis times a
  kept row-band along the column axis; §III-A, Fig. 6).
* ``w`` **writer workers** store outputs, fed by per-writer address generators
  (the paper's control units).
* ``w`` **synchronization workers** count stores against an analytically
  known expectation and combine into one "done" signal (§III-A).

2D (§III-B): each compute worker owns an x-dimension chain (taps fed by 2rx+1
*different* readers) and a y-dimension chain (all 2ry taps fed by the *same*
reader — the one that owns the output column), joined by a final ADD.  The
**mandatory buffering** requirement (≈ 2·ry rows resident in queues) falls out
of the per-tap filter row-bands and is returned in the plan as per-edge
minimum queue capacities so the simulator can verify both the bound and the
deadlock the paper warns about.

Requirement carried over from the paper's column-ownership argument: for 2D,
``nx % w == 0`` (each reader owns whole columns).  The planner pads/blocks
otherwise (strip-mining, §III-B "Blocking").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

from repro.core.dfg import DFG, Node
from repro.core.spec import StencilSpec


@dataclasses.dataclass
class MappingPlan:
    spec: StencilSpec
    workers: int
    dfg: DFG
    reader_loads: list[list[int]]         # flat indices per reader
    writer_stores: list[list[int]]        # flat indices per writer
    sync_expect: list[int]
    pe_counts: dict
    mac_pes: int
    min_capacities: dict[int, int]        # edge id -> analytic min queue depth
    notes: str = ""


# ---------------------------------------------------------------------------
# 1D mapping (paper §III-A, Figs. 3-7)
# ---------------------------------------------------------------------------
def map_1d(spec: StencilSpec, workers: int, queue_capacity: int | None = None,
           auto_capacity: bool = False) -> MappingPlan:
    """1D mapping.  ``spec.timesteps > 1`` stacks compute-worker *layers* —
    the paper's §IV temporal pipeline (left as future work there): layer t's
    taps consume directly from layer t-1's chain outputs, writers attach only
    to the final layer, and the interleave/filter arithmetic is *identical*
    at every layer because layer t's worker c tap j always sources worker
    ``(c+j) % w`` of the producing layer with lead ``(c+j) // w``.
    """
    assert spec.ndim == 1, "map_1d needs a 1D spec"
    (n,) = spec.grid_shape
    (r,) = spec.radii
    coeffs = spec.coeffs[0]
    w = workers
    T = spec.timesteps
    g = DFG(f"stencil1d_n{n}_r{r}_w{w}_t{T}")

    # reader workers -------------------------------------------------------
    reader_loads = [list(range(k, n, w)) for k in range(w)]
    readers: list[Node] = []
    for k in range(w):
        addr = g.add("addr", f"rd_addr{k}", stage="reader", worker=k,
                     count=len(reader_loads[k]))
        load = g.add("load", f"rd{k}", stage="reader", worker=k,
                     indices=reader_loads[k])
        g.connect(addr, load, capacity=queue_capacity)
        readers.append(load)

    # compute-worker layers (one per fused time-step) ------------------------
    min_caps: dict[int, int] = {}
    sources = readers          # layer 0 sources
    out_idx: list[list[int]] = []
    for layer in range(1, T + 1):
        out_idx = [list(range(layer * r + c, n - layer * r, w)) for c in range(w)]
        tails: list[Node] = []
        for c in range(w):
            n_c = len(out_idx[c])
            prev: Node | None = None
            for j in range(2 * r + 1):
                lead = (c + j) // w                  # 0^m: drop first m tokens
                keep = _make_keep_1d(lead, n_c)
                f = g.add("filter", f"flt_l{layer}_w{c}_t{j}", stage="compute",
                          worker=c, m=lead, n=n_c, layer=layer, keep=keep,
                          keep_count=n_c)
                g.connect(sources[(c + j) % w], f, capacity=queue_capacity)
                op = "mul" if prev is None else "mac"
                pe = g.add(op, f"{op}_l{layer}_w{c}_t{j}", stage="compute",
                           worker=c, coeff=float(coeffs[j]), layer=layer)
                if prev is not None:
                    g.connect(prev, pe, port=0, capacity=queue_capacity)
                e = g.connect(f, pe, port=(0 if prev is None else 1),
                              capacity=queue_capacity)
                # taps later in the chain see their value arrive earlier than
                # the partial sum; min depth ~ distance from chain head.
                min_caps[id(e)] = max(2, 2 * r - j + 2)
                prev = pe
            tails.append(prev)
        sources = tails

    # writer + sync workers --------------------------------------------------
    syncs = _attach_writers(g, sources, out_idx, queue_capacity)
    done = g.add("cmp", "done", stage="sync", worker=-1)
    for s in syncs:
        g.connect(s, done, capacity=queue_capacity)

    if auto_capacity:
        _apply_min_caps(g, min_caps)
    return MappingPlan(
        spec=spec, workers=w, dfg=g, reader_loads=reader_loads,
        writer_stores=out_idx, sync_expect=[len(o) for o in out_idx],
        pe_counts=g.pe_counts(), mac_pes=g.mac_pes(), min_capacities=min_caps,
        notes=(f"1D: {T} layer(s) x {w} workers x ({2*r} MAC + 1 MUL); "
               f"final interior [{T*r},{n-T*r})"))


# ---------------------------------------------------------------------------
# 2D mapping (paper §III-B, Figs. 9-11)
# ---------------------------------------------------------------------------
def map_2d(spec: StencilSpec, workers: int, queue_capacity: int | None = None,
           auto_capacity: bool = False) -> MappingPlan:
    assert spec.ndim == 2, "map_2d needs a 2D spec"
    ny, nx = spec.grid_shape
    ry, rx = spec.radii
    cy, cx = spec.coeffs
    w = workers
    if nx % w:
        raise ValueError(
            f"2D mapping needs nx % w == 0 (column ownership); got {nx} % {w}. "
            "Strip-mine with plan_blocks() first.")
    g = DFG(f"stencil2d_{ny}x{nx}_r{ry}x{rx}_w{w}")
    ncpr = nx // w                                   # columns per reader
    n_rows = ny - 2 * ry

    # readers: reader k owns columns ≡ k (mod w), streamed row-major ---------
    reader_loads = [[j * nx + i for j in range(ny) for i in range(k, nx, w)]
                    for k in range(w)]
    readers: list[Node] = []
    for k in range(w):
        addr = g.add("addr", f"rd_addr{k}", stage="reader", worker=k,
                     count=len(reader_loads[k]))
        load = g.add("load", f"rd{k}", stage="reader", worker=k,
                     indices=reader_loads[k])
        g.connect(addr, load, capacity=queue_capacity)
        readers.append(load)

    out_idx: list[list[int]] = []
    min_caps: dict[int, int] = {}
    tails: list[Node] = []
    for c in range(w):
        cols_c = list(range(rx + c, nx - rx, w))
        n_cols = len(cols_c)
        out_idx.append([j0 * nx + i for j0 in range(ry, ny - ry) for i in cols_c])

        # --- x-dimension chain: 2rx+1 taps from 2rx+1 different readers.
        # centre tap carries the full centre coefficient (cy centre + cx centre).
        prev: Node | None = None
        for j in range(2 * rx + 1):
            coeff = float(cx[j]) + (float(cy[ry]) if j == rx else 0.0)
            lead = (c + j) // w
            keep = _make_keep_2d(lead, n_cols, ncpr, row_lo=ry, n_rows=n_rows)
            f = g.add("filter", f"fx_w{c}_t{j}", stage="compute", worker=c,
                      m=lead, n=n_cols, row_lo=ry, keep=keep,
                      keep_count=n_cols * n_rows)
            g.connect(readers[(c + j) % w], f, capacity=queue_capacity)
            op = "mul" if prev is None else "mac"
            pe = g.add(op, f"{op}x_w{c}_t{j}", stage="compute", worker=c,
                       coeff=coeff)
            if prev is not None:
                g.connect(prev, pe, port=0, capacity=queue_capacity)
            e = g.connect(f, pe, port=(0 if prev is None else 1),
                          capacity=queue_capacity)
            # x values arrive ry rows ahead of the slowest y tap.
            min_caps[id(e)] = ry * n_cols + 2 * rx + 2
            prev = pe
        x_tail = prev

        # --- y-dimension chain: 2ry taps, all from the column-owning reader
        # (paper: "all MUL/MAC's input comes from only one particular reader").
        kc = (rx + c) % w
        lead = (rx + c) // w
        prev = None
        for j in [jj for jj in range(2 * ry + 1) if jj != ry]:
            keep = _make_keep_2d(lead, n_cols, ncpr, row_lo=j, n_rows=n_rows)
            f = g.add("filter", f"fy_w{c}_t{j}", stage="compute", worker=c,
                      m=lead, n=n_cols, row_lo=j, keep=keep,
                      keep_count=n_cols * n_rows)
            g.connect(readers[kc], f, capacity=queue_capacity)
            op = "mul" if prev is None else "mac"
            pe = g.add(op, f"{op}y_w{c}_t{j}", stage="compute", worker=c,
                       coeff=float(cy[j]))
            if prev is not None:
                g.connect(prev, pe, port=0, capacity=queue_capacity)
            e = g.connect(f, pe, port=(0 if prev is None else 1),
                          capacity=queue_capacity)
            # mandatory buffering (§III-B): tap at row_lo=j lags the reader by
            # (2ry - j) rows -> that many rows of this worker's columns queue up.
            min_caps[id(e)] = (2 * ry - j) * n_cols + 2
            prev = pe
        y_tail = prev

        addn = g.add("add", f"xy_add_w{c}", stage="compute", worker=c)
        ex = g.connect(x_tail, addn, port=0, capacity=queue_capacity)
        min_caps[id(ex)] = ry * n_cols + 2   # x outputs lead y by ry rows
        g.connect(y_tail, addn, port=1, capacity=queue_capacity)
        tails.append(addn)

    syncs = _attach_writers(g, tails, out_idx, queue_capacity)
    done = g.add("cmp", "done", stage="sync", worker=-1)
    for s in syncs:
        g.connect(s, done, capacity=queue_capacity)

    if auto_capacity:
        _apply_min_caps(g, min_caps)
    buf = 2 * ry * nx
    return MappingPlan(
        spec=spec, workers=w, dfg=g, reader_loads=reader_loads,
        writer_stores=out_idx, sync_expect=[len(o) for o in out_idx],
        pe_counts=g.pe_counts(), mac_pes=g.mac_pes(), min_capacities=min_caps,
        notes=(f"2D: {w} workers x ({4*max(ry,rx)} MAC + 1 MUL + ADD); mandatory "
               f"buffering ~= 2*ry*nx = {buf} elements across queues"))


# ---------------------------------------------------------------------------
def _attach_writers(g: DFG, tails: list[Node], out_idx: list[list[int]],
                    qc: int | None) -> list[Node]:
    syncs = []
    for c, tail in enumerate(tails):
        addr = g.add("addr", f"wr_addr{c}", stage="writer", worker=c,
                     count=len(out_idx[c]))
        st = g.add("store", f"wr{c}", stage="writer", worker=c,
                   indices=out_idx[c])
        g.connect(addr, st, port=0, capacity=qc)
        g.connect(tail, st, port=1, capacity=qc)
        sy = g.add("sync", f"sync{c}", stage="sync", worker=c,
                   expected=len(out_idx[c]))
        g.connect(st, sy, capacity=qc)
        syncs.append(sy)
    return syncs


def _make_keep_1d(lead: int, n: int) -> Callable[[int], bool]:
    return lambda k: lead <= k < lead + n


def _make_keep_2d(lead: int, n_cols: int, ncpr: int, row_lo: int,
                  n_rows: int) -> Callable[[int], bool]:
    def keep(k: int) -> bool:
        t, pos = divmod(k, ncpr)
        return (row_lo <= t < row_lo + n_rows) and (lead <= pos < lead + n_cols)
    return keep


def _apply_min_caps(g: DFG, min_caps: dict[int, int]) -> None:
    for e in g.edges():
        if id(e) in min_caps:
            e.capacity = min_caps[id(e)]
        elif e.capacity is None:
            e.capacity = 4


# ---------------------------------------------------------------------------
# Strip-mining / blocking planner (§III-B "Blocking") — also reused by the TPU
# kernels to pick BlockSpec tiles under a VMEM budget.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BlockPlan:
    block_shape: tuple[int, ...]
    halo: tuple[int, ...]
    grid: tuple[int, ...]               # number of blocks per axis
    working_set_bytes: int
    storage_budget_bytes: int

    @property
    def fits(self) -> bool:
        return self.working_set_bytes <= self.storage_budget_bytes


def plan_blocks(spec: StencilSpec, storage_budget_bytes: int,
                lane_multiple: int = 128) -> BlockPlan:
    """Choose per-axis block sizes so (block + 2*halo) working sets fit the
    on-fabric storage (CGRA scratchpad or TPU VMEM).

    Strategy (paper: vertical strips sized so ``2*ry*block_size`` fits):
    keep the innermost axis in lane_multiple chunks as large as possible,
    then grow outer axes.
    """
    halo = tuple(r * spec.timesteps for r in spec.radii)
    b = spec.bytes_per_elem
    shape = list(spec.grid_shape)
    block = [min(s, 8) for s in shape]
    block[-1] = min(shape[-1], lane_multiple)

    def ws(blk):  # in + out working set with halos
        inner = math.prod(bb + 2 * h for bb, h in zip(blk, halo))
        return (inner + math.prod(blk)) * b

    # grow innermost first, then outer axes round-robin
    order = list(range(spec.ndim - 1, -1, -1))
    progress = True
    while progress:
        progress = False
        for ax in order:
            step = lane_multiple if ax == spec.ndim - 1 else 8
            cand = list(block)
            cand[ax] = min(shape[ax], cand[ax] + step)
            if cand[ax] != block[ax] and ws(cand) <= storage_budget_bytes:
                block = cand
                progress = True
    grid = tuple(math.ceil(s / bb) for s, bb in zip(shape, block))
    return BlockPlan(tuple(block), halo, grid, ws(block), storage_budget_bytes)
