"""Stencil → CGRA dataflow-graph mapping (paper §III), dimension-generic.

The package decomposes the paper's worker pipeline into composable stages
(:mod:`~repro.core.mapping.stages`) over a single stream algebra
(:mod:`~repro.core.mapping.streams`) and builds every rank's mapping with one
entry point, :func:`map_nd` (:mod:`~repro.core.mapping.nd`):

* ``w`` **reader workers** load the grid interleaved in flat row-major order
  (reader ``k`` owns sites ``k, k+w, k+2w, ...``).
* ``w`` **compute workers** per temporal layer: per-axis filter + MUL/MAC
  tap chains (the ``0^m 1^n 0^p`` keep patterns of §III-A generalized to one
  digit window per axis) joined by an axis-combining ADD tree.
* ``w`` **writer** and **sync workers** store the final layer's outputs and
  count them against analytically known expectations (§III-A).

``map_1d``/``map_2d`` are thin wrappers that assert the structural contract
of the pre-refactor hand-rolled builders; ``map_3d`` (and any higher rank)
falls out of the same construction.  Mandatory buffering (§III-B) is derived
per axis — see :mod:`~repro.core.mapping.stages` — and ``plan_blocks``
(:mod:`~repro.core.mapping.blocks`) strip-mines grids whose innermost extent
does not divide by ``w``.
"""
from repro.core.mapping.blocks import (BlockPlan, minimal_working_set_bytes,
                                       plan_blocks)
from repro.core.mapping.nd import (apply_min_capacities, map_1d, map_2d,
                                   map_3d, map_nd)
from repro.core.mapping.plan import MappingPlan
from repro.core.mapping.stages import (AddTree, ReaderBank, SyncTree,
                                       TapChain, WorkerStream, WriterBank,
                                       compute_layer, layer_stream,
                                       owning_stream, reader_stream,
                                       row_tokens)
from repro.core.mapping.streams import KeepMask, StreamSpec, band_keep

__all__ = ["BlockPlan", "plan_blocks", "minimal_working_set_bytes",
           "apply_min_capacities", "map_1d",
           "map_2d", "map_3d", "map_nd", "MappingPlan", "AddTree",
           "ReaderBank", "SyncTree", "TapChain", "WorkerStream", "WriterBank",
           "compute_layer", "layer_stream", "owning_stream", "reader_stream",
           "row_tokens", "KeepMask", "StreamSpec", "band_keep"]
