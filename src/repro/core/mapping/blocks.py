"""Strip-mining / blocking planner (§III-B "Blocking") — also reused by the
TPU kernels to pick BlockSpec tiles under a VMEM budget."""
from __future__ import annotations

import dataclasses
import math

from repro.core.spec import StencilSpec


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    block_shape: tuple[int, ...]
    halo: tuple[int, ...]
    grid: tuple[int, ...]               # number of blocks per axis
    working_set_bytes: int
    storage_budget_bytes: int

    @property
    def fits(self) -> bool:
        return self.working_set_bytes <= self.storage_budget_bytes


def minimal_working_set_bytes(spec: StencilSpec) -> int:
    """Working set of the smallest possible block, ``(1, …, 1)`` — the hard
    floor any storage budget must clear for this spec."""
    halo = tuple(r * spec.timesteps for r in spec.radii)
    return (math.prod(1 + 2 * h for h in halo) + 1) * spec.bytes_per_elem


def plan_blocks(spec: StencilSpec, storage_budget_bytes: int,
                lane_multiple: int = 128) -> BlockPlan:
    """Choose per-axis block sizes so (block + 2*halo) working sets fit the
    on-fabric storage (CGRA scratchpad or TPU VMEM).

    Strategy (paper: vertical strips sized so ``2*ry*block_size`` fits):
    keep the innermost axis in lane_multiple chunks as large as possible,
    then grow outer axes.  If even the seed block overshoots a tight budget,
    the block *shrinks* toward ``(1, …, 1)`` — outer axes first, so the
    innermost axis keeps its lane alignment as long as possible — and a
    budget below the ``(1, …, 1)`` working set raises ``ValueError`` (the
    returned plan always has ``fits == True``).

    Raises:
      ValueError: when the halo-inclusive working set of a ``(1, …, 1)``
        block already exceeds ``storage_budget_bytes`` (the message carries
        the computed minimal working set).
    """
    halo = tuple(r * spec.timesteps for r in spec.radii)
    b = spec.bytes_per_elem
    shape = list(spec.grid_shape)
    block = [min(s, 8) for s in shape]
    block[-1] = min(shape[-1], lane_multiple)

    def ws(blk):  # in + out working set with halos
        inner = math.prod(bb + 2 * h for bb, h in zip(blk, halo))
        return (inner + math.prod(blk)) * b

    minimal = minimal_working_set_bytes(spec)
    if minimal > storage_budget_bytes:
        raise ValueError(
            f"storage budget {storage_budget_bytes} B cannot hold even a "
            f"(1, …, 1) block of {spec.grid_shape} (radii {spec.radii}, "
            f"timesteps {spec.timesteps}): minimal halo-inclusive working "
            f"set is {minimal} B")

    # shrink toward (1, …, 1) when the seed block overshoots: outer axes
    # halve first (innermost keeps its lane alignment while any outer axis
    # can still give ground — the seed never exceeds one lane chunk), then
    # the innermost halves too.
    while ws(block) > storage_budget_bytes:
        outer = [ax for ax in range(spec.ndim - 1) if block[ax] > 1]
        if outer:
            block[max(outer, key=lambda a: block[a])] //= 2
        else:   # block[-1] > 1 is guaranteed: the (1, …, 1) floor fits
            block[-1] //= 2

    # grow innermost first, then outer axes round-robin
    order = list(range(spec.ndim - 1, -1, -1))
    progress = True
    while progress:
        progress = False
        for ax in order:
            step = lane_multiple if ax == spec.ndim - 1 else 8
            cand = list(block)
            cand[ax] = min(shape[ax], cand[ax] + step)
            if cand[ax] != block[ax] and ws(cand) <= storage_budget_bytes:
                block = cand
                progress = True
    grid = tuple(math.ceil(s / bb) for s, bb in zip(shape, block))
    return BlockPlan(tuple(block), halo, grid, ws(block), storage_budget_bytes)
