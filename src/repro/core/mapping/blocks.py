"""Strip-mining / blocking planner (§III-B "Blocking") — also reused by the
TPU kernels to pick BlockSpec tiles under a VMEM budget."""
from __future__ import annotations

import dataclasses
import math

from repro.core.spec import StencilSpec


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    block_shape: tuple[int, ...]
    halo: tuple[int, ...]
    grid: tuple[int, ...]               # number of blocks per axis
    working_set_bytes: int
    storage_budget_bytes: int

    @property
    def fits(self) -> bool:
        return self.working_set_bytes <= self.storage_budget_bytes


def plan_blocks(spec: StencilSpec, storage_budget_bytes: int,
                lane_multiple: int = 128) -> BlockPlan:
    """Choose per-axis block sizes so (block + 2*halo) working sets fit the
    on-fabric storage (CGRA scratchpad or TPU VMEM).

    Strategy (paper: vertical strips sized so ``2*ry*block_size`` fits):
    keep the innermost axis in lane_multiple chunks as large as possible,
    then grow outer axes.
    """
    halo = tuple(r * spec.timesteps for r in spec.radii)
    b = spec.bytes_per_elem
    shape = list(spec.grid_shape)
    block = [min(s, 8) for s in shape]
    block[-1] = min(shape[-1], lane_multiple)

    def ws(blk):  # in + out working set with halos
        inner = math.prod(bb + 2 * h for bb, h in zip(blk, halo))
        return (inner + math.prod(blk)) * b

    # grow innermost first, then outer axes round-robin
    order = list(range(spec.ndim - 1, -1, -1))
    progress = True
    while progress:
        progress = False
        for ax in order:
            step = lane_multiple if ax == spec.ndim - 1 else 8
            cand = list(block)
            cand[ax] = min(shape[ax], cand[ax] + step)
            if cand[ax] != block[ax] and ws(cand) <= storage_budget_bytes:
                block = cand
                progress = True
    grid = tuple(math.ceil(s / bb) for s, bb in zip(shape, block))
    return BlockPlan(tuple(block), halo, grid, ws(block), storage_budget_bytes)
