"""``map_nd``: one dimension-generic worker-pipeline mapper (paper §III).

The paper's 1D (§III-A) and 2D (§III-B) mappings — and their 3D extension —
are instances of one construction, assembled from the stage library in
:mod:`repro.core.mapping.stages`:

* ``w`` readers load the grid interleaved in flat row-major order (reader
  ``k`` owns sites ``≡ k (mod w)``); for rank >= 2 this requires the
  innermost extent to divide by ``w`` (the paper's column ownership —
  strip-mine with :func:`repro.core.mapping.plan_blocks` otherwise).
* Each of ``w`` compute workers owns the interior outputs whose innermost
  coordinate is ``≡ r_inner + c (mod w)`` and evaluates them with one
  :class:`TapChain` per axis — ``2r+1`` taps from ``2r+1`` different streams
  on the innermost axis, ``2r`` taps from a single stream on every outer
  axis — joined by an :class:`AddTree`.
* ``timesteps > 1`` stacks compute layers uniformly at every rank (§IV):
  layer ``t`` consumes layer ``t-1``'s output streams directly, with the
  *same* interleave/filter algebra, because the class delta between adjacent
  layers is always ``r_inner + c (+ tap offset)``.
* Writers and sync workers attach to the final layer only; I/O happens at
  the pipeline ends and every element is loaded exactly once.

Mandatory buffering (§III-B) is computed per tap from the per-axis token-lag
formula in :mod:`repro.core.mapping.stages` and returned as
``MappingPlan.min_capacities``; ``auto_capacity=True`` applies it so the
simulator can verify both the bound and the deadlock below it.
"""
from __future__ import annotations

from repro.core.dfg import DFG
from repro.core.mapping.plan import MappingPlan
from repro.core.mapping.stages import (ReaderBank, SyncTree, WorkerStream,
                                       WriterBank, compute_layer,
                                       layer_stream, row_tokens)
from repro.core.spec import StencilSpec


def map_nd(spec: StencilSpec, workers: int, queue_capacity: int | None = None,
           auto_capacity: bool = False) -> MappingPlan:
    """Map a star stencil of any rank onto the CGRA worker pipeline."""
    d = spec.ndim
    w = workers
    T = spec.timesteps
    shape = spec.grid_shape
    radii = spec.radii
    if w < 1:
        raise ValueError("need at least one worker")
    if d >= 2 and shape[-1] % w:
        fit = max(k for k in range(1, min(w, shape[-1]) + 1)
                  if shape[-1] % k == 0)
        raise ValueError(
            f"rank-{d} spec (grid_shape={shape}) needs inner extent % workers"
            f" == 0 (column ownership); got {shape[-1]} % {w} == "
            f"{shape[-1] % w}. Strip-mine with plan_blocks() first, or use "
            f"workers={fit} — the largest count <= {w} that divides "
            f"{shape[-1]}.")
    interior_inner = shape[-1] - 2 * radii[-1] * T
    if w > interior_inner:
        raise ValueError(
            f"rank-{d} spec (grid_shape={shape}, radii={radii}, "
            f"timesteps={T}): {w} workers but only {interior_inner} interior "
            f"sites along the innermost axis, so some workers would own no "
            f"outputs (their sync would never trigger). Use workers <= "
            f"{interior_inner}.")

    g = DFG(f"stencil{d}d_{'x'.join(map(str, shape))}"
            f"_r{'x'.join(map(str, radii))}_w{w}_t{T}")
    min_caps: dict[int, int] = {}

    readers = ReaderBank(g, spec, w, queue_capacity)
    sources: list[WorkerStream] = readers.streams
    center_extra = sum(float(spec.coeffs[b][radii[b]]) for b in range(d - 1))

    out_streams = []
    for layer in range(1, T + 1):
        out_streams = [layer_stream(spec, layer, c, w) for c in range(w)]
        sources = compute_layer(
            g, radii=radii, coeffs=spec.coeffs, out_streams=out_streams,
            sources=sources, tag=f"l{layer}", queue_capacity=queue_capacity,
            min_caps=min_caps, center_extra=center_extra,
            params={"layer": layer})

    out_idx = [s.flat_indices(shape) for s in out_streams]
    writers = WriterBank(g, [ws.node for ws in sources], out_idx,
                         queue_capacity)
    SyncTree(g, writers.stores, [len(o) for o in out_idx], queue_capacity)

    if auto_capacity:
        apply_min_capacities(g, min_caps)
    chains_note = " + ".join(
        f"ax{b}:{2 * r + (1 if b == d - 1 else 0)}"
        for b, r in enumerate(radii) if r or b == d - 1)
    buf = sum(2 * r * rt for r, rt in
              zip(radii[:-1], row_tokens(shape)[:-1]))
    return MappingPlan(
        spec=spec, workers=w, dfg=g, reader_loads=readers.loads,
        writer_stores=out_idx, sync_expect=[len(o) for o in out_idx],
        pe_counts=g.pe_counts(), mac_pes=g.mac_pes(), min_capacities=min_caps,
        notes=(f"{d}D: {T} layer(s) x {w} workers x taps({chains_note}); "
               f"final interior {tuple(n - 2 * r * T for n, r in zip(shape, radii))}"
               + (f"; mandatory buffering ~= {buf} elements" if d > 1 else "")))


def apply_min_capacities(g: DFG, min_caps: dict[int, int]) -> None:
    """Set every queue to its analytic minimum (default 4 when no bound was
    derived) — the ``auto_capacity=True`` policy, shared with program-graph
    lowering (:mod:`repro.program.lower`).

    Bumps the graph's mutation counter so any compiled tables built *before*
    the recapacity (``repro.core.engine.compile``) invalidate instead of
    silently simulating with the stale capacities."""
    for e in g.edges():
        if id(e) in min_caps:
            e.capacity = min_caps[id(e)]
        elif e.capacity is None:
            e.capacity = 4
    g.mark_mutated()


# ---------------------------------------------------------------------------
# rank-specific wrappers.  map_1d/map_2d exist for source compatibility with
# the pre-refactor hand-rolled builders and *assert* the structural contract
# they used to guarantee (same PE inventory, same sync expectations).
# ---------------------------------------------------------------------------
def map_1d(spec: StencilSpec, workers: int, queue_capacity: int | None = None,
           auto_capacity: bool = False) -> MappingPlan:
    assert spec.ndim == 1, "map_1d needs a 1D spec"
    plan = map_nd(spec, workers, queue_capacity, auto_capacity)
    (n,), (r,), T, w = spec.grid_shape, spec.radii, spec.timesteps, workers
    if r:
        assert plan.pe_counts == {
            "addr": 2 * w, "load": w, "filter": T * w * (2 * r + 1),
            "mul": T * w, "mac": T * w * 2 * r, "store": w, "sync": w,
            "cmp": 1,
        }
    assert plan.sync_expect == [len(range(T * r + c, n - T * r, w))
                                for c in range(w)]
    return plan


def map_2d(spec: StencilSpec, workers: int, queue_capacity: int | None = None,
           auto_capacity: bool = False) -> MappingPlan:
    assert spec.ndim == 2, "map_2d needs a 2D spec"
    plan = map_nd(spec, workers, queue_capacity, auto_capacity)
    (ny, nx), (ry, rx), T, w = (spec.grid_shape, spec.radii, spec.timesteps,
                                workers)
    if T == 1 and ry and rx:      # the exact pre-refactor single-sweep shape
        assert plan.pe_counts == {
            "addr": 2 * w, "load": w, "filter": w * (2 * rx + 1 + 2 * ry),
            "mul": 2 * w, "mac": w * (2 * rx + 2 * ry - 1), "add": w,
            "store": w, "sync": w, "cmp": 1,
        }
        assert plan.sync_expect == [
            (ny - 2 * ry) * len(range(rx + c, nx - rx, w)) for c in range(w)]
    return plan


def map_3d(spec: StencilSpec, workers: int, queue_capacity: int | None = None,
           auto_capacity: bool = False) -> MappingPlan:
    assert spec.ndim == 3, "map_3d needs a 3D spec"
    return map_nd(spec, workers, queue_capacity, auto_capacity)
