"""The mapper's output contract: a logical DFG plus its analytic metadata."""
from __future__ import annotations

import dataclasses

from repro.core.dfg import DFG
from repro.core.spec import StencilSpec


@dataclasses.dataclass
class MappingPlan:
    spec: StencilSpec
    workers: int
    dfg: DFG
    reader_loads: list[list[int]]         # flat indices per reader
    writer_stores: list[list[int]]        # flat indices per writer
    sync_expect: list[int]
    pe_counts: dict
    mac_pes: int
    min_capacities: dict[int, int]        # edge id -> analytic min queue depth
    notes: str = ""
