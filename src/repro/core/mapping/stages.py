"""Composable worker-pipeline stages (paper §III, dimension-generic).

The paper builds every mapping out of the same five stage families; each is a
small builder over the DFG DSL here, parameterized by rank through the
:mod:`repro.core.mapping.streams` algebra:

* :class:`ReaderBank` — ``w`` interleaved load streams (reader ``k`` owns the
  flat row-major sites ``≡ k (mod w)``; for rank >= 2 this is the paper's
  column ownership, which requires ``n_inner % w == 0``).
* :class:`TapChain` — one axis of one compute worker: a data-filtering PE per
  tap (generalized ``0^m 1^n 0^p`` keep-mask) feeding a MUL -> MAC -> ... -> MAC
  chain.  The innermost axis has ``2r+1`` taps sourced from ``2r+1``
  *different* streams; every outer axis has ``2r`` taps (centre shared) all
  sourced from the *one* stream that owns the worker's innermost class.
* :class:`AddTree` — joins the per-axis chain tails of a worker (rank-1
  workers have a single chain and no ADDs; rank ``d`` needs ``d-1``).
* :class:`WriterBank` — per-worker address generator + store.
* :class:`SyncTree` — per-worker store counters combined into one ``done``.

Mandatory buffering (§III-B) is derived per tap, not per special case: with
``row_tokens[b]`` = filtered tokens per unit step along axis ``b`` and
``gate`` = the chain-wide worst-case token lag ``max_b r_b * row_tokens[b]``,
a tap at offset ``o`` on axis ``a`` must queue

    max(2, gate - o * row_tokens[a] + 2)

tokens: its values arrive that many outputs ahead of the slowest tap of the
worker.  At rank 1 this is the familiar ``2r - j + 2``; at rank 2 it is the
paper's ~``2*ry`` resident rows.
"""
from __future__ import annotations

import dataclasses

from repro.core.dfg import DFG, Node
from repro.core.mapping.streams import (StreamSpec, band_keep,
                                        row_major_strides)
from repro.core.spec import StencilSpec


@dataclasses.dataclass
class WorkerStream:
    """A producing node together with the site stream it emits."""
    node: Node
    spec: StreamSpec


# ---------------------------------------------------------------------------
# stream geometry (the worker-selection / band rules proved in streams.py)
# ---------------------------------------------------------------------------
def reader_stream(spec: StencilSpec, k: int, workers: int) -> StreamSpec:
    """Reader ``k``'s interleaved load stream."""
    if spec.ndim == 1:
        return StreamSpec(((k, spec.grid_shape[0], workers),))
    outer = tuple((0, n, 1) for n in spec.grid_shape[:-1])
    return StreamSpec(outer + ((k, spec.grid_shape[-1], workers),))


def layer_stream(spec: StencilSpec, layer: int, worker: int,
                 workers: int) -> StreamSpec:
    """Compute worker ``worker``'s output stream after ``layer`` fused sweeps:
    the interior shrunk by ``layer*r`` per face, innermost axis in the
    worker's congruence class."""
    axes = []
    for b, (n, r) in enumerate(zip(spec.grid_shape, spec.radii)):
        if b == spec.ndim - 1:
            axes.append((layer * r + worker, n - layer * r, workers))
        else:
            axes.append((layer * r, n - layer * r, 1))
    return StreamSpec(tuple(axes))


def tap_bands(spec: StencilSpec, layer: int, worker: int, axis: int,
              offset: int) -> tuple[tuple[int, int], ...]:
    """Coordinate bands ``[lo, hi)`` of the sites tap ``(axis, offset)`` of
    ``worker`` needs at ``layer`` — the worker's output box shifted by
    ``offset`` along ``axis``."""
    bands = []
    for b, (n, r) in enumerate(zip(spec.grid_shape, spec.radii)):
        ob = offset if b == axis else 0
        lo = layer * r + ob + (worker if b == spec.ndim - 1 else 0)
        bands.append((lo, n - layer * r + ob))
    return tuple(bands)


def source_worker(spec: StencilSpec, worker: int, axis: int, offset: int,
                  workers: int) -> int:
    """Index of the producing stream (reader or previous-layer worker) that
    owns the innermost congruence class tap ``(axis, offset)`` needs.  The
    same rule holds at every layer: readers sit at inner base 0 and layer
    ``t-1`` workers at inner base ``(t-1)*r``, so the class delta is always
    ``r_inner + worker (+ offset on the innermost axis)``."""
    o_inner = offset if axis == spec.ndim - 1 else 0
    return (spec.radii[-1] + worker + o_inner) % workers


def row_tokens(out_counts: tuple[int, ...]) -> tuple[int, ...]:
    """Filtered tokens per unit step along each axis, for one worker whose
    per-axis output counts are ``out_counts`` — the row-major strides of the
    output box."""
    return row_major_strides(out_counts)


# ---------------------------------------------------------------------------
# stages
# ---------------------------------------------------------------------------
class ReaderBank:
    """``w`` reader workers: per-reader address generator + load."""

    def __init__(self, g: DFG, spec: StencilSpec, workers: int,
                 queue_capacity: int | None):
        self.streams: list[WorkerStream] = []
        self.loads: list[list[int]] = []
        for k in range(workers):
            stream = reader_stream(spec, k, workers)
            idx = stream.flat_indices(spec.grid_shape)
            addr = g.add("addr", f"rd_addr{k}", stage="reader", worker=k,
                         count=len(idx))
            load = g.add("load", f"rd{k}", stage="reader", worker=k,
                         indices=idx)
            g.connect(addr, load, capacity=queue_capacity)
            self.streams.append(WorkerStream(load, stream))
            self.loads.append(idx)


class TapChain:
    """One axis of one compute worker in one layer: per-tap filter + MUL/MAC.

    ``center_extra`` is added to the centre-tap coefficient (the innermost
    chain carries every axis's centre contribution once, §III-B).
    """

    def __init__(self, g: DFG, spec: StencilSpec, *, layer: int, worker: int,
                 axis: int, sources: list[WorkerStream], workers: int,
                 queue_capacity: int | None, min_caps: dict[int, int],
                 rt: tuple[int, ...], gate: int, center_extra: float = 0.0):
        d = spec.ndim
        r = spec.radii[axis]
        coeffs = spec.coeffs[axis]
        inner = axis == d - 1
        taps = list(range(2 * r + 1)) if inner else \
            [j for j in range(2 * r + 1) if j != r]
        assert taps, "outer axis with radius 0 has no taps; skip the chain"
        prev: Node | None = None
        for j in taps:
            o = j - r
            src = sources[source_worker(spec, worker, axis, o, workers)]
            mask = band_keep(src.spec, tap_bands(spec, layer, worker, axis, o))
            f = g.add("filter", f"flt_l{layer}_a{axis}_w{worker}_t{j}",
                      stage="compute", worker=worker, layer=layer, axis=axis,
                      m=mask.lead, n=mask.kept, keep=mask.keep,
                      keep_count=mask.kept)
            g.connect(src.node, f, capacity=queue_capacity)
            coeff = float(coeffs[j]) + (center_extra if j == r else 0.0)
            op = "mul" if prev is None else "mac"
            pe = g.add(op, f"{op}_l{layer}_a{axis}_w{worker}_t{j}",
                       stage="compute", worker=worker, coeff=coeff,
                       layer=layer, axis=axis)
            if prev is not None:
                g.connect(prev, pe, port=0, capacity=queue_capacity)
            e = g.connect(f, pe, port=(0 if prev is None else 1),
                          capacity=queue_capacity)
            # mandatory buffering: this tap's values arrive up to
            # gate - o*rt[axis] outputs before the worker can consume them.
            min_caps[id(e)] = max(2, gate - o * rt[axis] + 2)
            prev = pe
        self.axis = axis
        self.radius = r
        self.tail: Node = prev


class AddTree:
    """Joins a worker's per-axis chain tails: innermost chain first, then one
    ADD per outer chain (rank-1 workers pass through untouched)."""

    def __init__(self, g: DFG, chains: list[TapChain], *, layer: int,
                 worker: int, queue_capacity: int | None,
                 min_caps: dict[int, int], rt: tuple[int, ...], gate: int):
        tail = chains[0].tail
        for i, ch in enumerate(chains[1:]):
            addn = g.add("add", f"axis_add_l{layer}_w{worker}_{i}",
                         stage="compute", worker=worker, layer=layer)
            e_part = g.connect(tail, addn, port=0, capacity=queue_capacity)
            # the partial side leads the remaining (slower) outer chains by
            # up to the full gate; the joining chain only by its own slack.
            min_caps[id(e_part)] = gate + 2
            e_chain = g.connect(ch.tail, addn, port=1,
                                capacity=queue_capacity)
            min_caps[id(e_chain)] = max(
                2, gate - ch.radius * rt[ch.axis] + 2)
            tail = addn
        self.tail: Node = tail


class WriterBank:
    """Per-worker address generator + store for the final layer's outputs."""

    def __init__(self, g: DFG, tails: list[Node], out_idx: list[list[int]],
                 queue_capacity: int | None):
        self.stores: list[Node] = []
        for c, tail in enumerate(tails):
            addr = g.add("addr", f"wr_addr{c}", stage="writer", worker=c,
                         count=len(out_idx[c]))
            st = g.add("store", f"wr{c}", stage="writer", worker=c,
                       indices=out_idx[c])
            g.connect(addr, st, port=0, capacity=queue_capacity)
            g.connect(tail, st, port=1, capacity=queue_capacity)
            self.stores.append(st)


class SyncTree:
    """Per-worker store counters combined into the single ``done`` trigger."""

    def __init__(self, g: DFG, stores: list[Node], expected: list[int],
                 queue_capacity: int | None):
        self.done = g.add("cmp", "done", stage="sync", worker=-1)
        self.syncs: list[Node] = []
        for c, (st, exp) in enumerate(zip(stores, expected)):
            sy = g.add("sync", f"sync{c}", stage="sync", worker=c,
                       expected=exp)
            g.connect(st, sy, capacity=queue_capacity)
            g.connect(sy, self.done, capacity=queue_capacity)
            self.syncs.append(sy)
