"""Composable worker-pipeline stages (paper §III, dimension-generic).

The paper builds every mapping out of the same five stage families; each is a
small builder over the DFG DSL here, parameterized by rank through the
:mod:`repro.core.mapping.streams` algebra:

* :class:`ReaderBank` — ``w`` interleaved load streams (reader ``k`` owns the
  flat row-major sites ``≡ k (mod w)``; for rank >= 2 this is the paper's
  column ownership, which requires ``n_inner % w == 0``).
* :class:`TapChain` — one axis of one compute worker: a data-filtering PE per
  tap (generalized ``0^m 1^n 0^p`` keep-mask) feeding a MUL -> MAC -> ... -> MAC
  chain.  The innermost axis has ``2r+1`` taps sourced from ``2r+1``
  *different* streams; every outer axis has ``2r`` taps (centre shared) all
  sourced from the *one* stream that owns the worker's innermost class.
  Geometry is explicit (``out_box`` + ``sources``), so the producing streams
  may be readers, a previous temporal layer, or — for program graphs
  (:mod:`repro.program`) — another operator's compute workers spliced in
  directly; :func:`owning_stream` resolves each tap's producer purely by
  innermost congruence class.
* :class:`AddTree` — joins the per-axis chain tails of a worker (rank-1
  workers have a single chain and no ADDs; rank ``d`` needs ``d-1``).
* :class:`WriterBank` — per-worker address generator + store.
* :class:`SyncTree` — per-worker store counters combined into one ``done``.

Mandatory buffering (§III-B) is derived per tap, not per special case: with
``row_tokens[b]`` = filtered tokens per unit step along axis ``b`` and
``gate`` = the chain-wide worst-case token lag ``max_b r_b * row_tokens[b]``,
a tap at offset ``o`` on axis ``a`` must queue

    max(2, gate - o * row_tokens[a] + 2)

tokens: its values arrive that many outputs ahead of the slowest tap of the
worker.  At rank 1 this is the familiar ``2r - j + 2``; at rank 2 it is the
paper's ~``2*ry`` resident rows.
"""
from __future__ import annotations

import dataclasses

from repro.core.dfg import DFG, Node
from repro.core.mapping.streams import (StreamSpec, band_keep,
                                        row_major_strides)
from repro.core.spec import StencilSpec


@dataclasses.dataclass
class WorkerStream:
    """A producing node together with the site stream it emits."""
    node: Node
    spec: StreamSpec


# ---------------------------------------------------------------------------
# stream geometry (the worker-selection / band rules proved in streams.py)
# ---------------------------------------------------------------------------
def reader_stream(spec: StencilSpec, k: int, workers: int) -> StreamSpec:
    """Reader ``k``'s interleaved load stream."""
    if spec.ndim == 1:
        return StreamSpec(((k, spec.grid_shape[0], workers),))
    outer = tuple((0, n, 1) for n in spec.grid_shape[:-1])
    return StreamSpec(outer + ((k, spec.grid_shape[-1], workers),))


def layer_stream(spec: StencilSpec, layer: int, worker: int,
                 workers: int) -> StreamSpec:
    """Compute worker ``worker``'s output stream after ``layer`` fused sweeps:
    the interior shrunk by ``layer*r`` per face, innermost axis in the
    worker's congruence class."""
    axes = []
    for b, (n, r) in enumerate(zip(spec.grid_shape, spec.radii)):
        if b == spec.ndim - 1:
            axes.append((layer * r + worker, n - layer * r, workers))
        else:
            axes.append((layer * r, n - layer * r, 1))
    return StreamSpec(tuple(axes))


def row_tokens(out_counts: tuple[int, ...]) -> tuple[int, ...]:
    """Filtered tokens per unit step along each axis, for one worker whose
    per-axis output counts are ``out_counts`` — the row-major strides of the
    output box."""
    return row_major_strides(out_counts)


def owning_stream(sources: list[WorkerStream], inner_lo: int) -> WorkerStream:
    """The source stream whose innermost congruence class contains coordinate
    ``inner_lo``.  One rule covers every producer kind: readers sit at inner
    base ``k``, layer-``t`` workers at ``t*r + c``, and program-graph
    producers at ``margin + c`` — all resolved uniformly by
    ``inner_lo ≡ start (mod step)``."""
    for ws in sources:
        start, _, step = ws.spec.axes[-1]
        if (inner_lo - start) % step == 0:
            return ws
    raise ValueError(
        f"no source stream owns innermost coordinate {inner_lo} "
        f"(classes available: {[ws.spec.axes[-1][:1] for ws in sources]})")


# ---------------------------------------------------------------------------
# stages
# ---------------------------------------------------------------------------
class ReaderBank:
    """``w`` reader workers: per-reader address generator + load.

    ``base`` offsets the flat load indices (program graphs pack several input
    fields into one flat memory image, one grid-sized slot per field).
    """

    def __init__(self, g: DFG, spec: StencilSpec, workers: int,
                 queue_capacity: int | None, *, base: int = 0, tag: str = "",
                 params: dict | None = None):
        extra = params or {}
        self.streams: list[WorkerStream] = []
        self.loads: list[list[int]] = []
        for k in range(workers):
            stream = reader_stream(spec, k, workers)
            idx = stream.flat_indices(spec.grid_shape)
            if base:
                idx = [base + i for i in idx]
            addr = g.add("addr", f"rd_addr{tag}{k}", stage="reader", worker=k,
                         count=len(idx), **extra)
            load = g.add("load", f"rd{tag}{k}", stage="reader", worker=k,
                         indices=idx, **extra)
            g.connect(addr, load, capacity=queue_capacity)
            self.streams.append(WorkerStream(load, stream))
            self.loads.append(idx)


class TapChain:
    """One axis of one compute worker: per-tap filter + MUL/MAC chain.

    The geometry is explicit so the chain can be spliced onto any producer:

    * ``out_box`` — the worker's output region, per-axis ``[lo, hi)`` with the
      innermost ``lo`` already in the worker's congruence class; tap ``(axis,
      o)`` needs that box shifted by ``o`` along ``axis``.
    * ``sources`` — streams that jointly cover every innermost class (readers,
      the previous temporal layer, or another operator's workers);
      :func:`owning_stream` picks each tap's producer by congruence.
    * ``src_min`` — optional analytic minimum capacity for the producer →
      filter queues (program graphs put the inter-operator skew buffer here).

    ``center_extra`` is added to the centre-tap coefficient (the innermost
    chain carries every axis's centre contribution once, §III-B).
    """

    def __init__(self, g: DFG, *, coeffs, radius: int, axis: int, inner: bool,
                 out_box: tuple[tuple[int, int], ...],
                 sources: list[WorkerStream], worker: int, tag: str,
                 queue_capacity: int | None, min_caps: dict[int, int],
                 rt: tuple[int, ...], gate: int, center_extra: float = 0.0,
                 src_min: int = 0, params: dict | None = None):
        r = radius
        taps = list(range(2 * r + 1)) if inner else \
            [j for j in range(2 * r + 1) if j != r]
        assert taps, "outer axis with radius 0 has no taps; skip the chain"
        extra = params or {}
        prev: Node | None = None
        for j in taps:
            o = j - r
            bands = tuple((lo + (o if b == axis else 0),
                           hi + (o if b == axis else 0))
                          for b, (lo, hi) in enumerate(out_box))
            src = owning_stream(sources, bands[-1][0])
            mask = band_keep(src.spec, bands)
            f = g.add("filter", f"flt_{tag}_a{axis}_w{worker}_t{j}",
                      stage="compute", worker=worker, axis=axis,
                      m=mask.lead, n=mask.kept, keep=mask.keep,
                      keep_count=mask.kept,
                      # compiled form of the same pattern: the vector engine
                      # evaluates digit windows over np.arange instead of
                      # calling ``keep`` once per token.
                      keep_vec={"windows": mask.windows,
                                "counts": src.spec.counts}, **extra)
            e_src = g.connect(src.node, f, capacity=queue_capacity)
            if src_min:
                min_caps[id(e_src)] = max(min_caps.get(id(e_src), 0), src_min)
            coeff = float(coeffs[j]) + (center_extra if j == r else 0.0)
            op = "mul" if prev is None else "mac"
            pe = g.add(op, f"{op}_{tag}_a{axis}_w{worker}_t{j}",
                       stage="compute", worker=worker, coeff=coeff, axis=axis,
                       **extra)
            if prev is not None:
                g.connect(prev, pe, port=0, capacity=queue_capacity)
            e = g.connect(f, pe, port=(0 if prev is None else 1),
                          capacity=queue_capacity)
            # mandatory buffering: this tap's values arrive up to
            # gate - o*rt[axis] outputs before the worker can consume them.
            min_caps[id(e)] = max(2, gate - o * rt[axis] + 2)
            prev = pe
        self.axis = axis
        self.radius = r
        self.tail: Node = prev


class AddTree:
    """Joins a worker's per-axis chain tails: innermost chain first, then one
    ADD per outer chain (rank-1 workers pass through untouched)."""

    def __init__(self, g: DFG, chains: list[TapChain], *, worker: int,
                 tag: str, queue_capacity: int | None,
                 min_caps: dict[int, int], rt: tuple[int, ...], gate: int,
                 params: dict | None = None):
        extra = params or {}
        tail = chains[0].tail
        for i, ch in enumerate(chains[1:]):
            addn = g.add("add", f"axis_add_{tag}_w{worker}_{i}",
                         stage="compute", worker=worker, **extra)
            e_part = g.connect(tail, addn, port=0, capacity=queue_capacity)
            # the partial side leads the remaining (slower) outer chains by
            # up to the full gate; the joining chain only by its own slack.
            min_caps[id(e_part)] = gate + 2
            e_chain = g.connect(ch.tail, addn, port=1,
                                capacity=queue_capacity)
            min_caps[id(e_chain)] = max(
                2, gate - ch.radius * rt[ch.axis] + 2)
            tail = addn
        self.tail: Node = tail


def compute_layer(g: DFG, *, radii: tuple[int, ...], coeffs,
                  out_streams: list[StreamSpec],
                  sources: list[WorkerStream], tag: str,
                  queue_capacity: int | None, min_caps: dict[int, int],
                  center_extra: float = 0.0, src_min: int = 0,
                  params: dict | None = None) -> list[WorkerStream]:
    """One full compute layer: per worker an innermost :class:`TapChain`,
    one outer chain per non-zero-radius axis, and the joining
    :class:`AddTree`.  Shared by :func:`map_nd` (temporal layers over one
    spec) and program-graph lowering (per-op layers spliced onto another
    op's streams) so the chain-assembly rules live in exactly one place."""
    d = len(radii)
    tails = []
    for c, stream in enumerate(out_streams):
        box = tuple((lo, hi) for lo, hi, _ in stream.axes)
        rt = row_tokens(stream.counts)
        gate = max(r * rt[b] for b, r in enumerate(radii))
        chains = [TapChain(g, coeffs=coeffs[-1], radius=radii[-1],
                           axis=d - 1, inner=True, out_box=box,
                           sources=sources, worker=c, tag=tag,
                           queue_capacity=queue_capacity, min_caps=min_caps,
                           rt=rt, gate=gate, center_extra=center_extra,
                           src_min=src_min, params=params)]
        for axis in range(d - 2, -1, -1):
            if radii[axis] == 0:
                continue
            chains.append(TapChain(g, coeffs=coeffs[axis],
                                   radius=radii[axis], axis=axis,
                                   inner=False, out_box=box, sources=sources,
                                   worker=c, tag=tag,
                                   queue_capacity=queue_capacity,
                                   min_caps=min_caps, rt=rt, gate=gate,
                                   src_min=src_min, params=params))
        tree = AddTree(g, chains, worker=c, tag=tag,
                       queue_capacity=queue_capacity, min_caps=min_caps,
                       rt=rt, gate=gate, params=params)
        tails.append(tree.tail)
    return [WorkerStream(t, s) for t, s in zip(tails, out_streams)]


class WriterBank:
    """Per-worker address generator + store for the final layer's outputs."""

    def __init__(self, g: DFG, tails: list[Node], out_idx: list[list[int]],
                 queue_capacity: int | None, *, tag: str = "",
                 params: dict | None = None):
        extra = params or {}
        self.stores: list[Node] = []
        for c, tail in enumerate(tails):
            addr = g.add("addr", f"wr_addr{tag}{c}", stage="writer", worker=c,
                         count=len(out_idx[c]), **extra)
            st = g.add("store", f"wr{tag}{c}", stage="writer", worker=c,
                       indices=out_idx[c], **extra)
            g.connect(addr, st, port=0, capacity=queue_capacity)
            g.connect(tail, st, port=1, capacity=queue_capacity)
            self.stores.append(st)


class SyncTree:
    """Per-worker store counters combined into one ``done`` trigger.  Program
    graphs build one tree per output field (``tag`` keeps names distinct); the
    simulator finishes when *every* ``cmp`` node has fired."""

    def __init__(self, g: DFG, stores: list[Node], expected: list[int],
                 queue_capacity: int | None, *, tag: str = "",
                 params: dict | None = None):
        extra = params or {}
        self.done = g.add("cmp", f"done{tag}", stage="sync", worker=-1,
                          **extra)
        self.syncs: list[Node] = []
        for c, (st, exp) in enumerate(zip(stores, expected)):
            sy = g.add("sync", f"sync{tag}{c}", stage="sync", worker=c,
                       expected=exp, **extra)
            g.connect(st, sy, capacity=queue_capacity)
            g.connect(sy, self.done, capacity=queue_capacity)
            self.syncs.append(sy)
