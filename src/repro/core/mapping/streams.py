"""Worker-stream algebra: the N-D generalization of the paper's interleave.

Every token stream in the worker pipeline — a reader's interleaved load
stream, a compute layer's per-worker output stream — enumerates a *strided
box* of grid sites in row-major order:

    axis b ranges over ``range(start_b, stop_b, step_b)``

with ``step_b == 1`` on every outer axis and ``step == workers`` on the
innermost axis (the interleave).  This single representation covers both
ranks of the paper's hand-built streams:

* 1D reader ``k``:   ``range(k, n, w)``                     (Fig. 4)
* 2D reader ``k``:   all rows x ``range(k, nx, w)``         (§III-B, column
  ownership — identical to the 1D interleave because ``nx % w == 0`` makes
  the flat row-major stream of reader ``k`` exactly ``{f : f mod w == k}``)
* layer-``t`` compute worker ``c``: the interior shrunk by ``t*r`` per face
  with the innermost axis in worker ``c``'s congruence class.

The data-filtering patterns (``0^m 1^n 0^p``, §III-A) generalize to one
*digit window* per axis: a filter keeps stream position ``s`` iff every
row-major digit of ``s`` falls inside its axis's kept window.  The innermost
check is a plain interval comparison (the paper's 1D pattern); each outer
axis adds one ``divmod``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """A row-major strided box of grid sites: per-axis ``(start, stop, step)``."""

    axes: tuple[tuple[int, int, int], ...]

    @property
    def ndim(self) -> int:
        return len(self.axes)

    @property
    def counts(self) -> tuple[int, ...]:
        return tuple(max(0, -((start - stop) // step))
                     for start, stop, step in self.axes)

    def __len__(self) -> int:
        return math.prod(self.counts)

    def coord(self, s: int) -> tuple[int, ...]:
        """Grid coordinate of stream position ``s`` (row-major digits)."""
        out = []
        for (start, _, step), cnt in zip(reversed(self.axes),
                                         reversed(self.counts)):
            s, d = divmod(s, cnt)
            out.append(start + d * step)
        return tuple(reversed(out))

    def flat_indices(self, grid_shape: tuple[int, ...]) -> list[int]:
        """All sites as flat row-major grid indices, in stream order."""
        strides = row_major_strides(grid_shape)
        base = [range(start, stop, step) for start, stop, step in self.axes]
        out = [0]
        for rng_, st in zip(base, strides):
            out = [f + v * st for f in out for v in rng_]
        return out


def row_major_strides(shape: tuple[int, ...]) -> tuple[int, ...]:
    strides = [1] * len(shape)
    for b in range(len(shape) - 2, -1, -1):
        strides[b] = strides[b + 1] * shape[b + 1]
    return tuple(strides)


@dataclasses.dataclass(frozen=True)
class KeepMask:
    """A compiled N-D ``0^m 1^n 0^p`` pattern over one stream.

    ``windows[b]`` is the kept digit interval ``[ilo, ihi)`` on axis ``b`` of
    the producing stream; ``keep`` evaluates position membership; ``lead`` is
    the stream position of the first kept token (the ``0^m`` prefix) and
    ``kept`` the total number of kept tokens (``sum of 1^n`` blocks).
    """

    windows: tuple[tuple[int, int], ...]
    keep: Callable[[int], bool]
    lead: int
    kept: int


def band_keep(stream: StreamSpec, bands: tuple[tuple[int, int], ...]) -> KeepMask:
    """Compile per-axis coordinate bands ``[lo, hi)`` into a keep-mask.

    Each band's ``lo`` must be congruent to the stream's axis start modulo
    the axis step (guaranteed by the mapper's worker-selection rule), so the
    kept positions form exact digit windows.
    """
    counts = stream.counts
    windows = []
    for (start, stop, step), cnt, (lo, hi) in zip(stream.axes, counts, bands):
        assert (lo - start) % step == 0, (
            f"band lo={lo} not in stream class (start={start}, step={step})")
        ilo = max(0, (lo - start) // step)
        ihi = min(cnt, -((start - hi) // step))
        windows.append((ilo, max(ilo, ihi)))
    kept = math.prod(ihi - ilo for ilo, ihi in windows)
    # stream position of the first kept token
    lead = 0
    for (ilo, _), cnt in zip(windows, counts):
        lead = lead * cnt + ilo
    lead = lead if kept else len(stream)

    if stream.ndim == 1:                      # the paper's 1D 0^m 1^n 0^p
        ilo0, ihi0 = windows[0]

        def keep1(s: int, _lo=ilo0, _hi=ihi0) -> bool:
            return _lo <= s < _hi

        return KeepMask(tuple(windows), keep1, lead, kept)

    # innermost window first; the outermost axis needs no divmod.
    inner = list(zip(counts, windows))[1:][::-1]
    olo, ohi = windows[0]

    def keep(s: int) -> bool:
        for cnt, (ilo, ihi) in inner:
            s, d = divmod(s, cnt)
            if not ilo <= d < ihi:
                return False
        return olo <= s < ohi

    return KeepMask(tuple(windows), keep, lead, kept)
