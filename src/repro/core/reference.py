"""Pure-jnp oracle for star stencils (any rank, any radius, fused timesteps).

This is the semantic ground truth every other implementation (CGRA simulator,
Pallas kernels, halo-exchanged distributed version) is tested against.

Boundary convention: outputs are computed only where the stencil has full
support; the ``radius``-wide rim of the output grid is zero.  This matches the
paper's data-filtering discipline (boundary values are *dropped*, §III-A) and
keeps single-device and halo-exchanged results bit-comparable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spec import StencilSpec


def _shift(x: jax.Array, offset: int, axis: int) -> jax.Array:
    """x shifted by ``offset`` along ``axis`` with zero fill (jnp.roll minus wrap)."""
    if offset == 0:
        return x
    n = x.shape[axis]
    pad = [(0, 0)] * x.ndim
    if offset > 0:  # tap at i+offset -> pull data left
        pad[axis] = (0, offset)
        sl = [slice(None)] * x.ndim
        sl[axis] = slice(offset, offset + n)
    else:
        pad[axis] = (-offset, 0)
        sl = [slice(None)] * x.ndim
        sl[axis] = slice(0, n)
    return jnp.pad(x, pad)[tuple(sl)]


def _interior_mask(shape: tuple[int, ...], radii: tuple[int, ...],
                   steps: int) -> np.ndarray:
    mask = np.ones(shape, dtype=bool)
    for ax, r in enumerate(radii):
        if r * steps == 0:
            continue
        idx = np.arange(shape[ax])
        ok = (idx >= r * steps) & (idx < shape[ax] - r * steps)
        mask &= np.expand_dims(ok, tuple(i for i in range(len(shape)) if i != ax))
    return mask


def stencil_sweep(x: jax.Array, spec: StencilSpec) -> jax.Array:
    """One star-stencil sweep; no boundary masking (callers mask)."""
    acc = jnp.zeros_like(x)
    for ax, (r, coeffs) in enumerate(zip(spec.radii, spec.coeffs)):
        for k, c in enumerate(coeffs):
            if c == 0.0:
                continue
            acc = acc + jnp.asarray(c, x.dtype) * _shift(x, k - r, ax)
    return acc


@functools.partial(jax.jit, static_argnums=(1,))
def stencil_reference(x: jax.Array, spec: StencilSpec) -> jax.Array:
    """``spec.timesteps`` fused sweeps with support-only outputs.

    After step t, only points with distance >= r*(t+1) from every face hold
    valid values; everything else is zeroed so that invalid values never
    propagate into the valid region's support.

    Returns an array of ``spec.grid_shape`` whose interior (shrunk by
    r*timesteps per face) is valid and whose rim is zero.
    """
    out = x
    for t in range(spec.timesteps):
        out = stencil_sweep(out, spec)
        mask = _interior_mask(spec.grid_shape, spec.radii, t + 1)
        out = jnp.where(jnp.asarray(mask), out, jnp.zeros_like(out))
    return out


def stencil_reference_np(x: np.ndarray, spec: StencilSpec) -> np.ndarray:
    """numpy twin of :func:`stencil_reference` (used by the CGRA simulator
    tests where we want no jax involvement at all)."""
    out = x.astype(np.float64 if spec.dtype == "float64" else np.float32)
    for t in range(spec.timesteps):
        acc = np.zeros_like(out)
        for ax, (r, coeffs) in enumerate(zip(spec.radii, spec.coeffs)):
            for k, c in enumerate(coeffs):
                if c == 0.0:
                    continue
                acc += c * np.asarray(_np_shift(out, k - r, ax))
        mask = _interior_mask(spec.grid_shape, spec.radii, t + 1)
        out = np.where(mask, acc, 0.0)
    return out


def _np_shift(x: np.ndarray, offset: int, axis: int) -> np.ndarray:
    if offset == 0:
        return x
    y = np.zeros_like(x)
    src = [slice(None)] * x.ndim
    dst = [slice(None)] * x.ndim
    if offset > 0:
        src[axis] = slice(offset, None)
        dst[axis] = slice(0, x.shape[axis] - offset)
    else:
        src[axis] = slice(0, x.shape[axis] + offset)
        dst[axis] = slice(-offset, None)
    y[tuple(dst)] = x[tuple(src)]
    return y
