"""Roofline model — paper §VI arithmetic, plus TPU-v5e constants for the port.

The paper's method: given a stencil's arithmetic intensity AI (flops/byte) and
a machine (peak bandwidth BW, #MAC PEs, clock f), choose the worker count

    w* = smallest w such that  w * flops_per_worker_per_cycle * f >= BW * AI

i.e. just enough compute workers to saturate the bandwidth-limited flop rate,
and the achievable peak is  min(BW * AI,  2 * #MAC * f).

Everything here is exact integer/float arithmetic reproduced from §VI so that
EXPERIMENTS.md §Paper-validation can assert the paper's own numbers:
  1D 17-pt N=194400:  AI = 2.06,  BW-peak = 206 GFLOPS, w*=6 demands 237.6
  2D 49-pt 960x449:   AI = 5.59,  BW-peak = 559 GFLOPS, 5 workers = 582
  CGRA compute peak:  2*256*1.2 = 614.4 GFLOPS
"""
from __future__ import annotations

import dataclasses
import math
import warnings

from repro.core.spec import StencilSpec


@dataclasses.dataclass(frozen=True)
class Machine:
    """A roofline machine model."""
    name: str
    clock_ghz: float          # PE clock (CGRA) or nominal (TPU: folded into peaks)
    num_macs: int             # MAC PEs (CGRA); for TPU use effective lanes
    bw_gbps: float            # HBM / memory bandwidth, GB/s
    peak_gflops: float        # 2 * num_macs * clock for the CGRA
    link_gbps: float = 0.0    # inter-chip link bandwidth (ICI / NVLink), GB/s
    tiles: int = 1            # CGRA tiles ganged together (paper uses 16)

    def scaled(self, tiles: int) -> "Machine":
        return dataclasses.replace(
            self, name=f"{self.name}x{tiles}", tiles=tiles,
            bw_gbps=self.bw_gbps * tiles, peak_gflops=self.peak_gflops * tiles,
            num_macs=self.num_macs * tiles)


# The paper's target CGRA (§VI): 1.2 GHz, 256 MACs, 100 GB/s.
CGRA = Machine("cgra", clock_ghz=1.2, num_macs=256, bw_gbps=100.0,
               peak_gflops=2 * 256 * 1.2)
# V100 as the paper models it (§VIII): 850 GB/s copy BW; DP peak 7.8 TFLOPS.
V100 = Machine("v100", clock_ghz=1.53, num_macs=2560, bw_gbps=850.0,
               peak_gflops=7800.0)
# TPU v5e — the port target (per assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s/link ICI.
TPU_V5E = Machine("tpu_v5e", clock_ghz=0.94, num_macs=0, bw_gbps=819.0,
                  peak_gflops=197_000.0, link_gbps=50.0)


@dataclasses.dataclass(frozen=True)
class RooflineReport:
    machine: str
    arithmetic_intensity: float
    bw_bound_gflops: float        # BW * AI
    compute_bound_gflops: float   # machine peak
    achievable_gflops: float      # min of the two
    bound: str                    # "memory" | "compute"
    workers: int                  # w* chosen
    worker_demand_gflops: float   # flops the chosen workers can execute
    macs_per_worker: int
    capped: bool = False          # w* silently hit the physical-fit ceiling
    workers_demanded: int = 0     # BW-limited demand before the fit cap

    @property
    def ridge_ai(self) -> float:
        return self.compute_bound_gflops / (self.bw_bound_gflops / self.arithmetic_intensity)


def worker_fit(spec: StencilSpec, machine: Machine) -> int:
    """How many workers physically fit: ``#MACs / MACs_per_worker``."""
    mpw = spec.macs_per_worker
    return max(1, machine.num_macs // mpw) if machine.num_macs else 1


def workers_demanded(spec: StencilSpec, machine: Machine) -> int:
    """The BW-limited worker demand *before* any physical-fit cap: the
    fewest workers whose flop rate covers ``BW * AI``."""
    mpw = spec.macs_per_worker
    ai = spec.arithmetic_intensity()
    bw_gflops = machine.bw_gbps * ai
    per_worker = (2 * (mpw - 1) + 1) * machine.clock_ghz  # 2r MACs + 1 MUL per cycle
    return max(1, math.ceil(bw_gflops / per_worker))


def select_workers(spec: StencilSpec, machine: Machine) -> int:
    """Paper §VI: fit Y/#MACs_per_worker workers; use the fewest that satisfy
    the BW-limited flop demand, capped by what physically fits.

    When the cap binds (the machine cannot host the demanded workers) a
    ``RuntimeWarning`` is emitted — callers wanting the cap programmatically
    should use :func:`analyze` and read ``RooflineReport.capped`` /
    ``RooflineReport.workers_demanded``.
    """
    need = workers_demanded(spec, machine)
    if not machine.num_macs:
        return need
    fit = worker_fit(spec, machine)
    if need > fit:
        warnings.warn(
            f"select_workers: bandwidth-limited demand of {need} workers "
            f"exceeds the {fit} that physically fit on {machine.name} "
            f"({machine.num_macs} MACs / {spec.macs_per_worker} per worker);"
            f" capping at {fit} leaves the memory system unsaturated",
            RuntimeWarning, stacklevel=2)
    return min(fit, need)


def worker_demand_gflops(spec: StencilSpec, machine: Machine, w: int) -> float:
    """GFLOPS demanded/suppliable by ``w`` workers (paper's 6*16*2*1.2 + 6*1.2 form)."""
    macs = spec.macs_per_worker - 1  # chain MACs
    return w * macs * 2 * machine.clock_ghz + w * machine.clock_ghz


def analyze(spec: StencilSpec, machine: Machine, workers: int | None = None) -> RooflineReport:
    ai = (spec.arithmetic_intensity_fused() if spec.timesteps > 1
          else spec.arithmetic_intensity())
    bw_bound = machine.bw_gbps * ai
    achievable = min(bw_bound, machine.peak_gflops)
    need = workers_demanded(spec, machine)
    fit = worker_fit(spec, machine)
    # same arithmetic as select_workers, without re-warning: the report
    # *records* the cap instead (capped only describes the selection path —
    # an explicitly-passed worker count was chosen, not capped)
    w = workers if workers is not None else (
        min(fit, need) if machine.num_macs else need)
    return RooflineReport(
        machine=machine.name,
        arithmetic_intensity=ai,
        bw_bound_gflops=bw_bound,
        compute_bound_gflops=machine.peak_gflops,
        achievable_gflops=achievable,
        bound="memory" if bw_bound < machine.peak_gflops else "compute",
        workers=w,
        worker_demand_gflops=worker_demand_gflops(spec, machine, w),
        macs_per_worker=spec.macs_per_worker,
        capped=workers is None and bool(machine.num_macs) and need > fit,
        workers_demanded=need,
    )


# ---------------------------------------------------------------------------
# Three-term roofline for compiled TPU programs (assignment §Roofline).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TpuRooflineTerms:
    """Seconds spent in each roofline term for one compiled step on a mesh."""
    flops: float                # total HLO flops (all chips)
    hbm_bytes: float            # total HLO bytes accessed (all chips)
    collective_bytes: float     # summed collective operand bytes (all chips)
    chips: int
    peak_flops_per_chip: float = 197e12   # bf16
    hbm_bw_per_chip: float = 819e9
    link_bw_per_chip: float = 50e9        # per ICI link

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * self.peak_flops_per_chip)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * self.hbm_bw_per_chip)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * self.link_bw_per_chip)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic (perfect-overlap) step time: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes, "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "step_time_s": self.step_time_s,
        }
