"""Cycle-level CGRA simulator (paper §VIII).

Models a triggered-instruction fabric: every node (= instruction mapped to a
PE) *fires* in a cycle iff all its input queues hold data and all its output
queues have space — exactly the TIA firing rule [Parashar et al., IEEE Micro
'14].  Loads/stores additionally arbitrate for a shared memory-bandwidth
budget (``bw_gbps / clock / bytes_per_elem`` element-ops per cycle, fractional
credit carried across cycles).

The simulator *executes the numerics*: it produces the output grid, so every
mapping is validated end-to-end against ``core.reference`` — not just timed.
Program-graph plans (``repro.program``) are simulated by the same loop: they
carry several ``cmp`` completion nodes (one per output field — the run ends
when *all* have fired), ``imux`` re-interleave nodes, and an ``out_shape``
that packs one grid-sized slot per output field.

Synchronous two-phase semantics: firing decisions for cycle t use queue state
at the start of t (push+pop on the same queue in one cycle is allowed, as in
real hardware FIFOs; a push into a queue that was full at cycle start is not).

**Network-aware mode** (``fabric=`` a placed-and-routed ``RoutedFabric`` from
``repro.fabric``): every producer→consumer queue is no longer a free one-hop
wire.  A pushed token enters the on-chip network, pays one cycle per hop of
its XY route, and contends with co-routed trees for each link's
words-per-cycle bandwidth (store-and-forward: a token blocked on a busy link
departs on the link's next free slot).  Fan-out is multicast — one producer's
token crosses each shared tree link once.  Values and firing rules are
untouched, so the output grid is bit-identical to ideal mode and routed
cycle counts are >= ideal ones.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import TYPE_CHECKING

import numpy as np

from repro.core.dfg import DFG, Edge, FLOPS_PER_OP, Node
from repro.core.mapping import MappingPlan
from repro.core.roofline import Machine, analyze

if TYPE_CHECKING:  # pragma: no cover - avoids core <-> fabric import cycle
    from repro.fabric.route import RoutedFabric


class SimDeadlock(RuntimeError):
    pass


@dataclasses.dataclass
class SimResult:
    cycles: int
    flops: int
    loads: int
    stores: int
    fires: dict[str, int]
    output: np.ndarray
    gflops: float
    pct_of_roofline: float
    pct_of_compute_peak: float
    max_queue_total: int
    mac_pes: int
    fabric: dict | None = None          # network-aware mode: routing stats

    def summary(self) -> str:
        s = (f"cycles={self.cycles} flops={self.flops} "
             f"GFLOPS={self.gflops:.1f} roofline%={self.pct_of_roofline:.1%} "
             f"loads={self.loads} stores={self.stores} macPEs={self.mac_pes}")
        if self.fabric is not None:
            s += (f" | fabric: pe_util={self.fabric['pe_utilization']:.0%} "
                  f"hops_mean={self.fabric['hops_mean']} "
                  f"max_chan={self.fabric['max_channel_load']} "
                  f"token_hops={self.fabric['token_hops']}")
        return s


class _Network:
    """Per-simulation on-chip network state (network-aware mode).

    Tokens pushed onto a routed edge ride through a transit pipeline:
    arrival = injection cycle + hops, plus any store-and-forward stalls when
    a link's words-per-cycle budget is already spoken for in a cycle.  A
    producer's fan-out is one multicast: shared tree links are crossed once
    per token (booked once per firing), not once per edge.
    """

    def __init__(self, fabric: "RoutedFabric", g: DFG):
        from repro.fabric.route import edge_key  # deferred: no import cycle
        self.wpc = {k: l.words_per_cycle for k, l in
                    fabric.topo.links.items()}
        self.routes: dict[int, tuple] = {}
        self.edge_by_id: dict[int, Edge] = {}
        for e in g.edges():
            self.routes[id(e)] = fabric.routes[edge_key(e)]
            self.edge_by_id[id(e)] = e
        self.transit: dict[int, deque] = {eid: deque() for eid in self.routes}
        self.used: dict[tuple, int] = {}     # (link, cycle) -> words in flight
        self.last_arrival: dict[int, int] = {}
        self.token_hops = 0
        self.stall_cycles = 0            # link-contention wait, summed

    def broadcast(self, nd: Node, v, cycle: int) -> None:
        booked: dict[tuple, int] = {}    # link -> slot of this token's copy
        for e in nd.out_edges:
            links = self.routes[id(e)]
            if not links:                # co-resident PEs: ideal local queue
                e.push(v)
                continue
            t = cycle
            for lk in links:
                if lk in booked:         # ride the multicast copy
                    t = booked[lk] + 1
                    continue
                cap = self.wpc[lk]
                slot = t
                while self.used.get((lk, slot), 0) >= cap:
                    slot += 1
                self.stall_cycles += slot - t
                self.used[(lk, slot)] = self.used.get((lk, slot), 0) + 1
                booked[lk] = slot
                self.token_hops += 1
                t = slot + 1
            arr = max(t, self.last_arrival.get(id(e), 0))  # FIFO per edge
            self.last_arrival[id(e)] = arr
            self.transit[id(e)].append((arr, v))

    def deliver(self, cycle: int) -> None:
        # slot searches always start at the current cycle, so bookings for
        # past cycles can never be read again — drop them periodically to
        # keep memory flat over long simulations.
        if cycle % 4096 == 0 and self.used:
            self.used = {k: v for k, v in self.used.items() if k[1] >= cycle}
        for eid, dq in self.transit.items():
            if dq and dq[0][0] <= cycle:
                e = self.edge_by_id[eid]
                while dq and dq[0][0] <= cycle:
                    e.push(dq.popleft()[1])

    def edge_full(self, e: Edge) -> bool:
        return e.capacity is not None and \
            len(e.q) + len(self.transit[id(e)]) >= e.capacity

    def in_flight(self) -> bool:
        return any(self.transit.values())


def simulate(plan: MappingPlan, x: np.ndarray, machine: Machine,
             max_cycles: int = 50_000_000,
             mem_efficiency: float = 1.0,
             fabric: "RoutedFabric | None" = None) -> SimResult:
    """``mem_efficiency`` derates the memory-port bandwidth to model cache
    conflict misses (the paper observed "more conflict misses in the cache
    for stencil 2D" — its cycle-accurate 2D result corresponds to ~0.80;
    our queue model is ideal at 1.0).  See EXPERIMENTS.md §Paper-validation.

    ``fabric``: a ``repro.fabric.route.RoutedFabric`` for this plan turns on
    network-aware mode (routed hop latency + link-bandwidth contention).
    """
    spec = plan.spec
    g = plan.dfg
    flat_in = np.asarray(x, dtype=np.float64).reshape(-1)
    # program plans (repro.program) pack several output fields into one image
    out_shape = tuple(getattr(plan, "out_shape", None) or spec.grid_shape)
    flat_out = np.zeros(int(np.prod(out_shape)), dtype=np.float64)

    # per-node runtime state ---------------------------------------------------
    state: dict[int, dict] = {}
    done_pending = 0
    for nd in g.nodes:
        st: dict = {"k": 0}
        if nd.op == "sync":
            st["count"] = 0
            st["emitted"] = False
        elif nd.op == "cmp":
            st["fired"] = False
            done_pending += 1
        state[nd.nid] = st
    assert done_pending, "graph has no completion (cmp) node"

    net = _Network(fabric, g) if fabric is not None else None

    elems_per_cycle = mem_efficiency * machine.bw_gbps / machine.clock_ghz / (
        8 if spec.dtype == "float64" else spec.bytes_per_elem)
    credit = 0.0
    cycles = 0
    fires: dict[str, int] = {}
    loads = stores = flops = 0
    finished = False

    # memory ops arbitrate for bandwidth with *rotating* priority (fair
    # round-robin, like the CGRA's memory-port arbiter); everything else is
    # order-independent because eligibility is snapshotted per cycle.
    mem_nodes = [nd for nd in g.nodes if nd.op in ("load", "store")]
    other_nodes = [nd for nd in g.nodes if nd.op not in ("load", "store")]
    n_mem = max(1, len(mem_nodes))

    nodes = g.nodes
    # hot-loop records: (node, nid, op, state, in_edges, out_edges) resolved
    # once — the edge lists are stable for the whole simulation, and skipping
    # the per-cycle attribute lookups is a measurable win on large graphs.
    # Eligibility snapshots are flat lists indexed by nid (nids are dense).
    rec = {nd.nid: (nd, nd.nid, nd.op, state[nd.nid], nd.in_edges,
                    nd.out_edges) for nd in nodes}
    # imux pops exactly one (pattern-selected) port per firing; snapshotting
    # all-ports-nonempty would both stall it and deadlock re-interleaves.
    snap_recs = [rec[nd.nid] for nd in nodes if nd.op != "imux"]
    imux_recs = [rec[nd.nid] for nd in nodes if nd.op == "imux"]
    mem_recs = [rec[nd.nid] for nd in mem_nodes]
    other_recs = [rec[nd.nid] for nd in other_nodes]
    n_ids = 1 + max(nd.nid for nd in nodes)
    in_avail = [False] * n_ids
    out_free = [False] * n_ids
    while not finished:
        if cycles >= max_cycles:
            raise SimDeadlock(f"exceeded max_cycles={max_cycles}")
        cycles += 1
        credit = min(credit + elems_per_cycle, 4 * elems_per_cycle)
        if net is not None:
            net.deliver(cycles)          # arrivals land before the snapshot
        # phase 1: snapshot eligibility -----------------------------------
        if net is None:
            for _, nid, _, _, ine, oute in snap_recs:
                in_avail[nid] = all(e.q for e in ine)
                out_free[nid] = all(not e.full() for e in oute)
        else:
            for _, nid, _, _, ine, oute in snap_recs:
                in_avail[nid] = all(e.q for e in ine)
                out_free[nid] = all(not net.edge_full(e) for e in oute)
        for nd_, nid, _, stx, ine, oute in imux_recs:
            pat = nd_.params["pattern"]
            in_avail[nid] = bool(ine[pat[stx["k"] % len(pat)]].q)
            out_free[nid] = (all(not e.full() for e in oute) if net is None
                             else all(not net.edge_full(e) for e in oute))
        any_fired = False
        # phase 2: execute. Memory nodes first in rotated order (fair
        # bandwidth arbitration), then the rest.
        rot = cycles % n_mem
        ordered = mem_recs[rot:] + mem_recs[:rot] + other_recs
        for nd, nid, op, st, in_edges, out_edges in ordered:
            if op == "addr":
                if st["k"] >= nd.params["count"] or not out_free[nid]:
                    continue
                v = st["k"]
                st["k"] += 1
            elif op == "load":
                if not (in_avail[nid] and out_free[nid] and credit >= 1.0):
                    continue
                a = in_edges[0].q.popleft()
                v = float(flat_in[nd.params["indices"][a]])
                credit -= 1.0
                loads += 1
            elif op == "store":
                if not (in_avail[nid] and out_free[nid] and credit >= 1.0):
                    continue
                a = in_edges[0].q.popleft()
                val = in_edges[1].q.popleft()
                flat_out[nd.params["indices"][a]] = val
                credit -= 1.0
                stores += 1
                v = 1  # done token to sync
            elif op == "filter":
                if not in_avail[nid]:
                    continue
                keep = nd.params["keep"](st["k"])
                if keep and not out_free[nid]:
                    continue  # must hold the token until downstream has space
                tok = in_edges[0].q.popleft()
                st["k"] += 1
                if not keep:
                    fires[op] = fires.get(op, 0) + 1
                    any_fired = True
                    continue
                v = tok
            elif op == "mul":
                if not (in_avail[nid] and out_free[nid]):
                    continue
                v = nd.params["coeff"] * in_edges[0].q.popleft()
                flops += 1
            elif op == "mac":
                if not (in_avail[nid] and out_free[nid]):
                    continue
                p = in_edges[0].q.popleft()
                v = p + nd.params["coeff"] * in_edges[1].q.popleft()
                flops += 2
            elif op == "add":
                if not (in_avail[nid] and out_free[nid]):
                    continue
                v = in_edges[0].q.popleft() + in_edges[1].q.popleft()
                flops += 1
            elif op == "sync":
                if st["emitted"] or not in_avail[nid]:
                    continue
                in_edges[0].q.popleft()
                st["count"] += 1
                fires[op] = fires.get(op, 0) + 1
                any_fired = True
                if st["count"] == nd.params["expected"] and out_free[nid]:
                    st["emitted"] = True
                    v = 1
                else:
                    continue
            elif op == "imux":  # re-interleave: pop the pattern-selected port
                if not (in_avail[nid] and out_free[nid]):
                    continue
                pat = nd.params["pattern"]
                v = in_edges[pat[st["k"] % len(pat)]].q.popleft()
                st["k"] += 1
            elif op == "cmp":  # a done-combiner (programs may carry several)
                if st["fired"] or not in_avail[nid]:
                    continue
                for e in in_edges:
                    e.q.popleft()
                st["fired"] = True
                done_pending -= 1
                if done_pending == 0:
                    finished = True
                fires[op] = fires.get(op, 0) + 1
                any_fired = True
                continue
            else:  # mux/demux/copy pass-through
                if not (in_avail[nid] and out_free[nid]):
                    continue
                v = in_edges[0].q.popleft()
            nd.fires += 1
            fires[op] = fires.get(op, 0) + 1
            any_fired = True
            if net is None:
                for e in out_edges:
                    e.push(v)
            else:
                net.broadcast(nd, v, cycles)
        if not any_fired and not finished:
            if net is not None and net.in_flight():
                continue                 # tokens still riding the network
            stuck = [f"{nd.name}({nd.op}) in={[len(e.q) for e in nd.in_edges]} "
                     f"outfull={[e.full() for e in nd.out_edges]}"
                     for nd in nodes if any(e.q for e in nd.in_edges)][:8]
            raise SimDeadlock(
                f"deadlock at cycle {cycles}; sample blocked nodes: {stuck}")

    gflops = (flops / cycles) * machine.clock_ghz
    roof = analyze(spec, machine, workers=plan.workers)
    max_q = sum(e.max_occupancy for e in g.edges())
    fabric_stats = None
    if fabric is not None:
        fabric_stats = {**fabric.stats(),
                        "token_hops": net.token_hops,
                        "stall_cycles": net.stall_cycles}
    return SimResult(
        cycles=cycles, flops=flops, loads=loads, stores=stores, fires=fires,
        output=flat_out.reshape(out_shape), gflops=gflops,
        pct_of_roofline=gflops / roof.achievable_gflops,
        pct_of_compute_peak=gflops / machine.peak_gflops,
        max_queue_total=max_q, mac_pes=plan.mac_pes, fabric=fabric_stats)
