"""Cycle-level CGRA simulator (paper §VIII) — backend-dispatching facade.

Models a triggered-instruction fabric: every node (= instruction mapped to a
PE) *fires* in a cycle iff all its input queues hold data and all its output
queues have space — exactly the TIA firing rule [Parashar et al., IEEE Micro
'14].  Loads/stores additionally arbitrate for a shared memory-bandwidth
budget (``bw_gbps / clock / bytes_per_elem`` element-ops per cycle, fractional
credit carried across cycles).

The simulator *executes the numerics*: it produces the output grid, so every
mapping is validated end-to-end against ``core.reference`` — not just timed.
Program-graph plans (``repro.program``) are simulated by the same machinery:
they carry several ``cmp`` completion nodes (one per output field — the run
ends when *all* have fired), ``imux`` re-interleave nodes, and an
``out_shape`` that packs one grid-sized slot per output field.

Two backends implement the identical semantics (see ``docs/simulator.md``):

* ``engine="interp"`` — :mod:`repro.core.engine.interp`, the reference
  per-node Python interpreter (the oracle).
* ``engine="vector"`` — :mod:`repro.core.engine.vector`, the compiled
  struct-of-arrays engine: the DFG is compiled once into dense numpy tables
  (op-kind buckets, CSR edge indices, one ring-buffer pool for all queues)
  and each cycle runs as a handful of vectorized passes per op-kind.  Cycle
  counts, fire counts, hop/stall stats and output grids are bit-identical to
  the interpreter; wall-clock is 5-20x faster on program-pipeline grids.

**Network-aware mode** (``fabric=`` a placed-and-routed ``RoutedFabric`` from
``repro.fabric``): every producer→consumer queue is no longer a free one-hop
wire.  A pushed token enters the on-chip network, pays one cycle per hop of
its XY route, and contends with co-routed trees for each link's
words-per-cycle bandwidth (store-and-forward: a token blocked on a busy link
departs on the link's next free slot).  Fan-out is multicast — one producer's
token crosses each shared tree link once.  Values and firing rules are
untouched, so the output grid is bit-identical to ideal mode and routed
cycle counts are >= ideal ones.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import numpy as np

from repro.core.engine import interp as _interp
from repro.core.engine import vector as _vector
from repro.core.engine.common import SimDeadlock, mem_elems_per_cycle
from repro.core.mapping import MappingPlan
from repro.core.roofline import Machine, analyze

if TYPE_CHECKING:  # pragma: no cover - avoids core <-> fabric import cycle
    from repro.fabric.route import RoutedFabric
    from repro.telemetry import Telemetry

__all__ = ["SimDeadlock", "SimResult", "simulate", "simulate_batch",
           "ENGINES"]

ENGINES = ("interp", "vector", "jax")


@dataclasses.dataclass
class SimResult:
    cycles: int
    flops: int
    loads: int
    stores: int
    fires: dict[str, int]
    output: np.ndarray
    gflops: float
    pct_of_roofline: float
    pct_of_compute_peak: float
    max_queue_total: int
    mac_pes: int
    fabric: dict | None = None          # network-aware mode: routing stats

    def summary(self) -> str:
        s = (f"cycles={self.cycles} flops={self.flops} "
             f"GFLOPS={self.gflops:.1f} roofline%={self.pct_of_roofline:.1%} "
             f"loads={self.loads} stores={self.stores} macPEs={self.mac_pes}")
        if self.fabric is not None:
            s += (f" | fabric: pe_util={self.fabric['pe_utilization']:.0%} "
                  f"hops_mean={self.fabric['hops_mean']} "
                  f"max_chan={self.fabric['max_channel_load']} "
                  f"token_hops={self.fabric['token_hops']}")
        return s


def _attach_hint(plan, exc: SimDeadlock) -> SimDeadlock:
    """Enrich an engine deadlock with the static verifier's capacity-repair
    hint (``suggested_capacities``) — *how to fix it*, next to the stall
    table's *where it stuck*.  Timeouts are left alone (the run may simply
    need more cycles) and diagnosis failures never mask the deadlock."""
    if not exc.timed_out and exc.suggested_capacities is None:
        from repro.analysis.static_verify import suggest_capacity_fix
        exc.suggested_capacities = suggest_capacity_fix(plan)
    return exc


def simulate(plan: MappingPlan, x: np.ndarray, machine: Machine,
             max_cycles: int = 50_000_000,
             mem_efficiency: float = 1.0,
             fabric: "RoutedFabric | None" = None,
             engine: str = "interp",
             telemetry: "Telemetry | None" = None,
             verify: str | None = None) -> SimResult:
    """``mem_efficiency`` derates the memory-port bandwidth to model cache
    conflict misses (the paper observed "more conflict misses in the cache
    for stencil 2D" — its cycle-accurate 2D result corresponds to ~0.80;
    our queue model is ideal at 1.0).  See EXPERIMENTS.md §Paper-validation.

    ``fabric``: a ``repro.fabric.route.RoutedFabric`` for this plan turns on
    network-aware mode (routed hop latency + link-bandwidth contention).

    ``engine``: ``"interp"`` (reference per-node interpreter), ``"vector"``
    (compiled struct-of-arrays engine, identical results, much faster), or
    ``"jax"`` (the compiled tables as a jitted ``lax.while_loop`` — identical
    results in ideal mode; raises ``NotImplementedError`` with ``fabric=`` or
    ``telemetry=``, see :mod:`repro.core.engine.jax_engine`).

    ``telemetry``: a ``repro.telemetry.Telemetry`` sink to record per-node
    fire/stall timelines, stall attribution and per-link occupancy into
    (``docs/telemetry.md``); ``None`` (the default) keeps the engines on
    their uninstrumented hot paths.

    ``verify="static"``: pre-flight the plan through the static verifier
    (``repro.analysis.static_verify``) and raise ``StaticDeadlock`` —
    naming the waits-for counterexample and carrying the capacity-repair
    hint — *before* burning any engine cycles on a plan that provably
    cannot complete.  See ``docs/analysis.md``.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; choose one of {ENGINES}")
    if verify is not None:
        if verify != "static":
            raise ValueError(f"unknown verify mode {verify!r}; "
                             f"only 'static' is supported")
        from repro.analysis.static_verify import check_static
        check_static(plan, fabric=fabric, machine=machine,
                     mem_efficiency=mem_efficiency)
    spec = plan.spec
    flat_in = np.asarray(x, dtype=np.float64).reshape(-1)
    # program plans (repro.program) pack several output fields into one image
    out_shape = tuple(getattr(plan, "out_shape", None) or spec.grid_shape)
    flat_out = np.zeros(int(np.prod(out_shape)), dtype=np.float64)

    epc = mem_elems_per_cycle(spec, machine, mem_efficiency)
    if engine == "jax":
        from repro.core.engine import jax_engine as _jax   # lazy: pulls jax
        backend = _jax.run
    else:
        backend = _interp.run if engine == "interp" else _vector.run
    if telemetry is not None:
        telemetry.attach(plan, fabric)
    try:
        stats = backend(plan, flat_in, flat_out, epc, max_cycles, fabric,
                        telemetry)
    except SimDeadlock as e:
        raise _attach_hint(plan, e)
    return _to_result(plan, machine, stats, flat_out, out_shape, fabric)


def _to_result(plan, machine: Machine, stats, flat_out, out_shape,
               fabric) -> SimResult:
    gflops = (stats.flops / stats.cycles) * machine.clock_ghz
    roof = analyze(plan.spec, machine, workers=plan.workers)
    fabric_stats = None
    if fabric is not None:
        fabric_stats = {**fabric.stats(),
                        "token_hops": stats.token_hops,
                        "stall_cycles": stats.stall_cycles}
    return SimResult(
        cycles=stats.cycles, flops=stats.flops, loads=stats.loads,
        stores=stats.stores, fires=stats.fires,
        output=flat_out.reshape(out_shape), gflops=gflops,
        pct_of_roofline=gflops / roof.achievable_gflops,
        pct_of_compute_peak=gflops / machine.peak_gflops,
        max_queue_total=stats.max_queue_total, mac_pes=plan.mac_pes,
        fabric=fabric_stats)


def simulate_batch(items, machine: Machine,
                   max_cycles: int = 50_000_000,
                   mem_efficiency: float = 1.0,
                   engine: str = "jax"):
    """Simulate B independent ``(plan, x)`` pairs and return a list of
    per-lane outcomes, aligned with ``items``: a :class:`SimResult` on
    success, or the failure **as a value** — ``SimDeadlock`` for
    deadlock/timeout, ``NotImplementedError`` (``JaxLoweringError``) for
    lanes the jax lowering rejects.  Nothing is raised for per-lane
    failures, so one bad lane never poisons its siblings.

    With ``engine="jax"`` (the default) the whole batch — plans padded to a
    common shape — runs as **one jitted+vmapped device call**
    (:mod:`repro.core.engine.jax_engine`); this is the auto-tuner's batched
    stage-1 evaluator.  Any other engine falls back to a sequential loop
    with the same returns-as-values contract (handy for benchmarking the
    batched path against the sequential one)."""
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; choose one of {ENGINES}")
    prepped = []
    for plan, x in items:
        spec = plan.spec
        flat_in = np.asarray(x, dtype=np.float64).reshape(-1)
        out_shape = tuple(getattr(plan, "out_shape", None) or spec.grid_shape)
        flat_out = np.zeros(int(np.prod(out_shape)), dtype=np.float64)
        epc = mem_elems_per_cycle(spec, machine, mem_efficiency)
        prepped.append((plan, flat_in, flat_out, out_shape, epc))

    if engine == "jax":
        from repro.core.engine import jax_engine as _jax   # lazy: pulls jax
        from repro.core.engine.compile import compiled_for
        batch, out = [], [None] * len(prepped)
        for i, (plan, flat_in, flat_out, _os, epc) in enumerate(prepped):
            try:
                batch.append((i, compiled_for(plan, None), flat_in,
                              flat_out, epc))
            except ValueError as e:        # uncompilable op vocabulary
                out[i] = _jax.JaxLoweringError(str(e))
        raw = _jax.run_compiled_batch(
            [(cp, fi, fo, epc) for _i, cp, fi, fo, epc in batch],
            max_cycles=max_cycles)
        for (i, _cp, _fi, _fo, _epc), stats in zip(batch, raw):
            plan, _flat_in, flat_out, out_shape, _e = prepped[i]
            if isinstance(stats, SimDeadlock):
                out[i] = _attach_hint(plan, stats)
            elif isinstance(stats, Exception):
                out[i] = stats
            else:
                out[i] = _to_result(plan, machine, stats, flat_out,
                                    out_shape, None)
        return out

    results = []
    for plan, flat_in, flat_out, out_shape, epc in prepped:
        backend = _interp.run if engine == "interp" else _vector.run
        try:
            stats = backend(plan, flat_in, flat_out, epc, max_cycles,
                            None, None)
        except SimDeadlock as e:
            results.append(_attach_hint(plan, e))
            continue
        results.append(_to_result(plan, machine, stats, flat_out, out_shape,
                                  None))
    return results
