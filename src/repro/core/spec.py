"""Stencil problem specification.

The paper (§II-B, §III) works with *star* stencils: an output point depends on
the input point at the same location plus ``radius`` neighbours in each
direction *along each axis* (no diagonal taps).  A (2r+1)-point 1D stencil has
taps ``in[i-r] .. in[i+r]``; the 5-point 2D Jacobian has taps along x and y.

``StencilSpec`` is the single source of truth consumed by:
  * the pure-jnp oracle           (core/reference.py)
  * the CGRA mapper + simulator   (core/mapping/, core/simulator.py)
  * the roofline model            (core/roofline.py)
  * the TPU kernels               (kernels/stencil1d, kernels/stencil2d)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

_ITEMSIZE = {"float32": 4, "float64": 8, "bfloat16": 2}


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """A star stencil over an N-D grid.

    Attributes:
      grid_shape: input grid extents, e.g. ``(194400,)`` or ``(449, 960)``.
        Axis order is row-major (y before x for 2D, matching the paper's
        ``in[j][i]`` indexing: axis 0 = j/y, axis 1 = i/x).
      radii: per-axis radius ``r``; taps span ``[-r, +r]`` on each axis.
      coeffs: per-axis tap coefficients, each of length ``2*r+1``.  The centre
        tap of every axis multiplies the centre point; following the paper's
        separable formulation the centre contribution is counted **once** (the
        first axis keeps its centre coefficient; subsequent axes have their
        centre coefficient forced to zero at construction if ``share_center``).
      dtype: numpy dtype string for the data ("float32"/"float64"/"bfloat16").
      timesteps: number of fused time-steps (§IV); 1 = single sweep.
    """

    grid_shape: tuple[int, ...]
    radii: tuple[int, ...]
    coeffs: tuple[tuple[float, ...], ...]
    dtype: str = "float32"
    timesteps: int = 1

    def __post_init__(self):
        if len(self.grid_shape) != len(self.radii):
            raise ValueError("grid_shape and radii rank mismatch")
        if len(self.coeffs) != len(self.radii):
            raise ValueError("coeffs and radii rank mismatch")
        for r, c in zip(self.radii, self.coeffs):
            if len(c) != 2 * r + 1:
                raise ValueError(f"axis with radius {r} needs {2*r+1} coeffs, got {len(c)}")
        if self.timesteps < 1:
            raise ValueError("timesteps must be >= 1")
        for n, r in zip(self.grid_shape, self.radii):
            if n <= 2 * r * self.timesteps:
                raise ValueError(
                    f"grid extent {n} too small for radius {r} x {self.timesteps} steps")

    # ----- derived quantities -------------------------------------------------
    @property
    def ndim(self) -> int:
        return len(self.grid_shape)

    @property
    def points(self) -> int:
        """Number of taps: (2*r0+1) + sum_axis>0 (2*r+1 - 1) for star stencils."""
        n = 2 * self.radii[0] + 1
        for r in self.radii[1:]:
            n += 2 * r  # centre tap shared with axis 0
        return n

    @property
    def interior_shape(self) -> tuple[int, ...]:
        """Output region with full support (one time-step)."""
        return tuple(n - 2 * r for n, r in zip(self.grid_shape, self.radii))

    @property
    def interior_shape_fused(self) -> tuple[int, ...]:
        """Output region with full support after ``timesteps`` fused sweeps."""
        t = self.timesteps
        return tuple(n - 2 * r * t for n, r in zip(self.grid_shape, self.radii))

    @property
    def bytes_per_elem(self) -> int:
        return _ITEMSIZE.get(self.dtype) or np.dtype(self.dtype).itemsize

    @property
    def flops_per_output(self) -> int:
        """MULs+MACs per output point, counted the paper's way (§VI).

        A (2r+1)-pt 1D stencil = 1 MUL + 2r MAC = (2*(2r)+1) flops.
        A 2D star with rx=ry=r = 1 MUL + 4r MAC = (2*(4r)+1) flops
        (paper: 49-pt, r=12 -> 48 MAC + 1 MUL -> 97 flops).
        """
        macs = sum(2 * r for r in self.radii)
        return 2 * macs + 1

    @property
    def macs_per_worker(self) -> int:
        """MAC-chain length of one compute worker (MUL counted as a MAC PE slot)."""
        return sum(2 * r for r in self.radii) + 1

    def total_flops(self, timesteps: int | None = None) -> int:
        """Flops of ``timesteps`` fused sweeps: each sweep computes only the
        outputs with full support, so sweep ``k`` covers the interior shrunk
        by ``r*(k+1)`` per face (matches ``arithmetic_intensity_fused``)."""
        t = self.timesteps if timesteps is None else timesteps
        if t < 1:
            raise ValueError(f"timesteps must be >= 1, got {t}")
        return self.flops_per_output * sum(
            math.prod(tuple(max(0, n - 2 * r * (k + 1))
                            for n, r in zip(self.grid_shape, self.radii)))
            for k in range(t))

    def arithmetic_intensity(self) -> float:
        """Flops/byte exactly as §VI computes it: interior flops over one full
        read + one full write of the grid (single sweep)."""
        bytes_moved = 2 * math.prod(self.grid_shape) * self.bytes_per_elem
        return self.total_flops(1) / bytes_moved

    def arithmetic_intensity_fused(self) -> float:
        """AI of the ``timesteps``-fused sweep (§IV beyond-paper): T sweeps of
        flops (:meth:`total_flops`) for one read + one write."""
        bytes_moved = 2 * math.prod(self.grid_shape) * self.bytes_per_elem
        return self.total_flops() / bytes_moved


# --- the paper's two benchmark stencils (§VI) --------------------------------
def paper_stencil_1d(n: int = 194400, rx: int = 8, dtype: str = "float64") -> StencilSpec:
    """17-pt 1D stencil, grid 194400, rx=8 (paper §VI 'Stencil 1D')."""
    rng = np.random.default_rng(0)
    coeffs = tuple(float(c) for c in rng.normal(size=2 * rx + 1) / (2 * rx + 1))
    return StencilSpec((n,), (rx,), (coeffs,), dtype=dtype)


def paper_stencil_2d(ny: int = 449, nx: int = 960, r: int = 12,
                     dtype: str = "float64") -> StencilSpec:
    """49-pt 2D star stencil, grid 960x449, rx=ry=12 (oil/gas seismic, §VI)."""
    rng = np.random.default_rng(1)
    cy = rng.normal(size=2 * r + 1) / (4 * r + 1)
    cx = rng.normal(size=2 * r + 1) / (4 * r + 1)
    cx[r] = 0.0  # centre tap counted once, on axis 0
    return StencilSpec((ny, nx), (r, r),
                       (tuple(map(float, cy)), tuple(map(float, cx))), dtype=dtype)


def heat_2d(ny: int, nx: int, alpha: float = 0.1, dtype: str = "float32") -> StencilSpec:
    """5-pt Jacobi heat step: u += alpha * laplacian(u)."""
    cy = (alpha, 1.0 - 4.0 * alpha, alpha)
    cx = (alpha, 0.0, alpha)
    return StencilSpec((ny, nx), (1, 1), (cy, cx), dtype=dtype)


def heat_3d(nz: int, ny: int, nx: int, alpha: float = 0.1,
            dtype: str = "float32") -> StencilSpec:
    """7-pt Jacobi heat step: u += alpha * laplacian(u) over (z, y, x)."""
    cz = (alpha, 1.0 - 6.0 * alpha, alpha)
    cyx = (alpha, 0.0, alpha)
    return StencilSpec((nz, ny, nx), (1, 1, 1), (cz, cyx, cyx), dtype=dtype)


def star_3d(nz: int, ny: int, nx: int, r: int = 2, seed: int = 2,
            dtype: str = "float64") -> StencilSpec:
    """(6r+1)-pt 3D star with random coefficients (centre counted on axis 0)."""
    rng = np.random.default_rng(seed)
    cz, cy, cx = (rng.normal(size=2 * r + 1) / (6 * r + 1) for _ in range(3))
    cy[r] = 0.0
    cx[r] = 0.0
    return StencilSpec((nz, ny, nx), (r, r, r),
                       (tuple(map(float, cz)), tuple(map(float, cy)),
                        tuple(map(float, cx))), dtype=dtype)
