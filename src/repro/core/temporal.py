"""Temporal-locality planner (paper §IV, implemented beyond the paper).

Fusing T time-steps multiplies arithmetic intensity ~T× (one grid read + one
write amortized over T sweeps) at the cost of:
  * T x the arithmetic PEs (CGRA) / T x the per-block compute (TPU),
  * halo growth: a block of interior size B needs B + 2*T*r input points,
  * redundant flops at block seams ~ proportional to T^2 * r / B
    (the classic overlapped-trapezoid overhead).

``fusion_report`` finds the smallest T at which the stencil crosses from
memory- to compute-bound on a machine, and the PE/VMEM budget it costs.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.roofline import Machine, analyze
from repro.core.spec import StencilSpec


@dataclasses.dataclass(frozen=True)
class FusionPoint:
    timesteps: int
    arithmetic_intensity: float
    achievable_gflops: float
    bound: str
    mac_pes_needed: int          # CGRA: T * w * macs_per_worker
    fits_fabric: bool
    halo: int                    # per-face input halo, elements
    seam_overhead: float         # redundant flops fraction for a given block


def fusion_report(spec: StencilSpec, machine: Machine, workers: int,
                  block: int = 1024, max_t: int = 16) -> list[FusionPoint]:
    out = []
    for t in range(1, max_t + 1):
        s = dataclasses.replace(spec, timesteps=t)
        rep = analyze(s, machine, workers=workers)
        mac_needed = t * workers * spec.macs_per_worker
        fits = machine.num_macs == 0 or mac_needed <= machine.num_macs
        halo = t * max(spec.radii)
        # redundant work at seams: each block recomputes a trapezoid skirt of
        # width r*(t-k) at step k -> sum_k 2*r*(t-k) = r*t*(t-1) extra points
        # per block per axis pair, vs block*t useful points.
        seam = (max(spec.radii) * t * (t - 1)) / max(1, block * t)
        out.append(FusionPoint(
            timesteps=t, arithmetic_intensity=rep.arithmetic_intensity,
            achievable_gflops=rep.achievable_gflops, bound=rep.bound,
            mac_pes_needed=mac_needed, fits_fabric=fits, halo=halo,
            seam_overhead=seam))
    return out


def crossover_timesteps(spec: StencilSpec, machine: Machine, workers: int,
                        max_t: int = 64) -> int | None:
    """Smallest T at which the fused stencil becomes compute-bound."""
    for t in range(1, max_t + 1):
        s = dataclasses.replace(spec, timesteps=t)
        if analyze(s, machine, workers=workers).bound == "compute":
            return t
    return None


def vmem_working_set(spec: StencilSpec, block_shape: tuple[int, ...],
                     timesteps: int | None = None) -> int:
    """Bytes resident in VMEM for a fused block: input block + halos, the
    rolling intermediate, and the output block."""
    t = timesteps or spec.timesteps
    b = spec.bytes_per_elem
    ext = math.prod(bb + 2 * r * t for bb, r in zip(block_shape, spec.radii))
    return (2 * ext + math.prod(block_shape)) * b
