"""Data pipeline: deterministic synthetic LM stream + host-sharded, resumable
iterator with background prefetch.

Synthetic stream: token[b, s] at global step t is a splitmix-style integer
hash of (t, global_example_index, s) — fully deterministic, seekable to any
step (that's the checkpoint/restart property: resuming at step k reproduces
exactly the batches a never-restarted run would have seen), and shardable by
host without coordination.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    pattern: str = "uniform"      # "uniform" | "markov" (learnable stream)
    markov_noise: float = 0.05    # fraction of random transitions


class SyntheticLM:
    """Deterministic, seekable synthetic token stream."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.host_count == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.host_count
        self.step = 0

    def seek(self, step: int) -> None:
        self.step = step

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])

    def next_batch(self) -> dict:
        c = self.cfg
        t = self.step
        ex0 = c.host_index * self.local_batch
        b_idx = (np.arange(self.local_batch, dtype=np.uint64) + ex0)[:, None]
        s_idx = np.arange(c.seq_len, dtype=np.uint64)[None, :]
        key = (np.uint64(c.seed) * np.uint64(0x100000001B3)
               + np.uint64(t) * np.uint64(0x1000193)
               + b_idx * np.uint64(1_000_003) + s_idx)
        toks = (_splitmix64(key) % np.uint64(c.vocab_size)).astype(np.int32)
        if c.pattern == "markov":
            # learnable stream: deterministic affine walk with sparse noise —
            # a model that learns t_{s+1} = (a*t_s + 1) mod V reaches ~
            # -log(1 - noise) loss instead of the uniform ln(V) floor.
            a = 5
            start = toks[:, 0].astype(np.int64)
            walk = np.empty_like(toks, dtype=np.int64)
            walk[:, 0] = start
            for s_ in range(1, c.seq_len):
                walk[:, s_] = (a * walk[:, s_ - 1] + 1) % c.vocab_size
            noise_mask = (_splitmix64(key + np.uint64(0xABCDEF))
                          % np.uint64(10_000)).astype(np.float64) / 10_000.0
            toks = np.where(noise_mask < c.markov_noise, toks,
                            walk.astype(np.int32)).astype(np.int32)
        self.step += 1
        return {"tokens": toks, "labels": toks}


class Prefetcher:
    """Background-thread prefetch (depth-N) around any ``next_batch`` source;
    overlap host-side batch synthesis with device compute."""

    def __init__(self, source, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._worker, daemon=True)
        self.t.start()

    def _worker(self):
        while not self._stop.is_set():
            batch = self.source.next_batch()
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next_batch(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.t.join(timeout=2)
