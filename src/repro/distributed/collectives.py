"""Distributed-optimization collectives: gradient compression with error
feedback, and a quantized all-reduce.

Two integration points (DESIGN.md §5.4):

1. **Gradient transformation** (works under plain pjit where the all-reduce is
   implicit): ``compress_decompress`` applies quantize→dequantize with an
   error-feedback accumulator, so the *effective* gradient the optimizer sees
   is exactly what a compressed all-reduce would deliver.  EF guarantees the
   quantization error is re-injected next step (Karimireddy et al., 2019).

2. **Explicit compressed all-reduce** (shard_map paths, e.g. the DP axis of
   the halo-exchange trainer): ``int8_psum`` quantizes per-leaf to int8 with a
   shared fp32 scale, psums the int8 payload (4x less ICI traffic), and
   dequantizes.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    error: jax.Array        # same shape as the gradient leaf


def init_ef(params) -> dict:
    return jax.tree.map(lambda p: EFState(jnp.zeros_like(p)), params)


def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _topk_mask(x: jax.Array, frac: float) -> jax.Array:
    flat = jnp.abs(x.reshape(-1))
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def compress_decompress(grads, ef_state, *, method: str = "int8",
                        topk_frac: float = 0.01):
    """Apply lossy compression with error feedback.

    Returns (effective_grads, new_ef_state).  ``method``:
      * "int8": per-leaf int8 quantization (what int8_psum transmits);
      * "topk": keep the top ``topk_frac`` magnitudes (sparsified all-reduce);
      * "none": identity.
    """
    if method == "none":
        return grads, ef_state

    def leaf(g, ef: EFState):
        corrected = g.astype(jnp.float32) + ef.error.astype(jnp.float32)
        if method == "int8":
            q, s = _quantize_int8(corrected)
            sent = _dequantize_int8(q, s)
        elif method == "topk":
            sent = corrected * _topk_mask(corrected, topk_frac)
        else:
            raise ValueError(method)
        return sent.astype(g.dtype), EFState((corrected - sent).astype(g.dtype))

    flat = jax.tree.map(leaf, grads, ef_state,
                        is_leaf=lambda x: isinstance(x, EFState))
    effective = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda x: isinstance(x, tuple) and
                             len(x) == 2 and isinstance(x[1], EFState))
    new_ef = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple) and
                          len(x) == 2 and isinstance(x[1], EFState))
    return effective, new_ef


def int8_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Quantized all-reduce (inside shard_map): transmit int8 + one fp32
    scale instead of fp32 payloads — 4x less ICI traffic.

    Uses a *shared* scale (max over the axis) so the int8 sum cannot
    overflow int32 for axis sizes < 2^24/127.
    """
    scale = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(x)), 1e-12), axis_name) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def compressed_psum_tree(grads, axis_name: str):
    return jax.tree.map(lambda g: int8_psum(g, axis_name), grads)
