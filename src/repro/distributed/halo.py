"""Multi-chip halo exchange for distributed stencils (DESIGN.md §3).

The paper's PE→PE producer-consumer links, lifted to ICI scale: when a stencil
grid is sharded into strips across mesh devices, each sweep only needs
``r * timesteps`` boundary elements from the two neighbour shards — a
``jax.lax.ppermute`` pair, not an all-gather.  Devices at the global edges
receive zeros from ppermute (no source), which *is* the oracle's boundary
convention — no special-casing.

Fusing T time-steps per exchange divides the number of neighbour messages by
T at the cost of wider halos and overlapped recompute: the
communication-avoiding trade the paper's §IV pipeline makes on-fabric.

All functions run *inside* ``jax.shard_map``.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.spec import StencilSpec
from repro.distributed.sharding import shard_map_compat


# --------------------------------------------------------------------------
# shard_map interior: exchange + local sweeps
# --------------------------------------------------------------------------
def halo_exchange(x: jax.Array, halo: int, axis_name: str,
                  array_axis: int) -> tuple[jax.Array, jax.Array]:
    """Return (left_halo, right_halo) received from neighbours along
    ``axis_name``; zeros at the global edges."""
    n = jax.lax.psum(1, axis_name)
    fwd = [(i, i + 1) for i in range(n - 1)]      # my right edge -> right nbr
    bwd = [(i, i - 1) for i in range(1, n)]       # my left edge -> left nbr
    sl = [slice(None)] * x.ndim

    sl[array_axis] = slice(x.shape[array_axis] - halo, None)
    from_left = jax.lax.ppermute(x[tuple(sl)], axis_name, fwd)

    sl[array_axis] = slice(0, halo)
    from_right = jax.lax.ppermute(x[tuple(sl)], axis_name, bwd)
    return from_left, from_right


def _sweep_ext_1d(ext: jax.Array, coeffs: tuple[float, ...],
                  out_w: int) -> jax.Array:
    acc = jnp.zeros(ext.shape[:-1] + (out_w,), ext.dtype)
    for k, c in enumerate(coeffs):
        if c != 0.0:
            acc = acc + c * ext[..., k:k + out_w]
    return acc


def _local_stencil1d(x: jax.Array, spec: StencilSpec, axis_name: str):
    """Local shard of the fused 1D stencil with one halo exchange."""
    (r,) = spec.radii
    t = spec.timesteps
    halo = r * t
    nl = x.shape[-1]
    left, right = halo_exchange(x, halo, axis_name, array_axis=x.ndim - 1)
    ext = jnp.concatenate([left, x, right], axis=-1)
    w = nl + 2 * halo
    for _ in range(t):
        w -= 2 * r
        ext = _sweep_ext_1d(ext, spec.coeffs[0], w)
    # global boundary mask (matches reference: rim of r*t is zeroed)
    idx = jax.lax.axis_index(axis_name)
    gpos = idx * nl + jnp.arange(nl)
    n_total = jax.lax.psum(1, axis_name) * nl
    valid = (gpos >= halo) & (gpos < n_total - halo)
    return jnp.where(valid, ext, 0).astype(x.dtype)


def _local_stencil2d(x: jax.Array, spec: StencilSpec, ax_names: tuple[str, str]):
    """Local shard of the fused 2D star stencil; exchanges along both axes.

    Fused star sweeps have diamond composite support, so after exchanging
    rows we also exchange the *corner-extended* columns: exchange along y
    first, then exchange the y-extended array along x (corners ride along).
    """
    ry, rx = spec.radii
    t = spec.timesteps
    hy, hx = ry * t, rx * t
    ny_l, nx_l = x.shape[-2], x.shape[-1]
    yname, xname = ax_names

    up, down = halo_exchange(x, hy, yname, array_axis=x.ndim - 2)
    xt = jnp.concatenate([up, x, down], axis=-2)
    left, right = halo_exchange(xt, hx, xname, array_axis=x.ndim - 1)
    ext = jnp.concatenate([left, xt, right], axis=-1)

    h, w = ny_l + 2 * hy, nx_l + 2 * hx
    cy, cx = spec.coeffs
    for _ in range(t):
        h -= 2 * ry
        w -= 2 * rx
        acc = jnp.zeros(ext.shape[:-2] + (h, w), ext.dtype)
        for a, c in enumerate(cy):
            if c != 0.0:
                acc = acc + c * ext[..., a:a + h, rx:rx + w]
        for b_, c in enumerate(cx):
            if c != 0.0:
                acc = acc + c * ext[..., ry:ry + h, b_:b_ + w]
        ext = acc

    iy = jax.lax.axis_index(yname)
    ix = jax.lax.axis_index(xname)
    gy = iy * ny_l + jnp.arange(ny_l)[:, None]
    gx = ix * nx_l + jnp.arange(nx_l)[None, :]
    tot_y = jax.lax.psum(1, yname) * ny_l
    tot_x = jax.lax.psum(1, xname) * nx_l
    valid = (gy >= hy) & (gy < tot_y - hy) & (gx >= hx) & (gx < tot_x - hx)
    return jnp.where(valid, ext, 0).astype(x.dtype)


def _local_stencil3d(x: jax.Array, spec: StencilSpec,
                     ax_names: tuple[str, str]):
    """Local shard of a 3D star stencil; z over ax_names[0], y over
    ax_names[1], x unsharded (the innermost axis keeps lane locality)."""
    rz, ry, rx = spec.radii
    t = spec.timesteps
    hz, hy = rz * t, ry * t
    nz_l, ny_l = x.shape[-3], x.shape[-2]
    zname, yname = ax_names

    up, down = halo_exchange(x, hz, zname, array_axis=x.ndim - 3)
    zt = jnp.concatenate([up, x, down], axis=-3)
    left, right = halo_exchange(zt, hy, yname, array_axis=x.ndim - 2)
    ext = jnp.concatenate([left, zt, right], axis=-2)

    d, h = nz_l + 2 * hz, ny_l + 2 * hy
    w = x.shape[-1]
    cz, cy, cx = spec.coeffs
    for _ in range(t):
        d -= 2 * rz
        h -= 2 * ry
        w2 = w - 2 * rx
        acc = jnp.zeros(ext.shape[:-3] + (d, h, w2), ext.dtype)
        for a, c in enumerate(cz):
            if c != 0.0:
                acc = acc + c * ext[..., a:a + d, ry:ry + h, rx:rx + w2]
        for b_, c in enumerate(cy):
            if c != 0.0:
                acc = acc + c * ext[..., rz:rz + d, b_:b_ + h, rx:rx + w2]
        for c_, c in enumerate(cx):
            if c != 0.0:
                acc = acc + c * ext[..., rz:rz + d, ry:ry + h, c_:c_ + w2]
        # x axis is unsharded: re-pad with zeros to keep extents aligned
        acc = jnp.pad(acc, [(0, 0)] * (acc.ndim - 1) + [(rx, rx)])
        ext = acc
        w = acc.shape[-1]

    iz = jax.lax.axis_index(zname)
    iy = jax.lax.axis_index(yname)
    gz = iz * nz_l + jnp.arange(nz_l)[:, None, None]
    gy = iy * ny_l + jnp.arange(ny_l)[None, :, None]
    gx = jnp.arange(x.shape[-1])[None, None, :]
    tz = jax.lax.psum(1, zname) * nz_l
    ty = jax.lax.psum(1, yname) * ny_l
    valid = ((gz >= hz) & (gz < tz - hz) & (gy >= hy) & (gy < ty - hy) &
             (gx >= rx * t) & (gx < x.shape[-1] - rx * t))
    return jnp.where(valid, ext, 0).astype(x.dtype)


# --------------------------------------------------------------------------
# public API: mesh-level distributed stencils
# --------------------------------------------------------------------------
def distributed_stencil1d(spec: StencilSpec, mesh: Mesh, axis: str = "data"):
    """Build a jitted f(x) running the fused 1D stencil sharded into strips
    along ``axis``.  x: (N,) with N % mesh.shape[axis] == 0."""
    (n,) = spec.grid_shape
    shards = mesh.shape[axis]
    assert n % shards == 0, (n, shards)
    assert n // shards >= spec.radii[0] * spec.timesteps, \
        "shard smaller than halo; reduce timesteps or shards"
    pspec = P(axis)

    fn = shard_map_compat(
        functools.partial(_local_stencil1d, spec=spec, axis_name=axis),
        mesh=mesh, in_specs=pspec, out_specs=pspec)
    return jax.jit(fn, in_shardings=NamedSharding(mesh, pspec),
                   out_shardings=NamedSharding(mesh, pspec))


def distributed_stencil2d(spec: StencilSpec, mesh: Mesh,
                          axes: tuple[str, str] = ("pod", "data")):
    """Fused 2D stencil sharded (y over axes[0], x over axes[1])."""
    ny, nx = spec.grid_shape
    sy, sx = mesh.shape[axes[0]], mesh.shape[axes[1]]
    assert ny % sy == 0 and nx % sx == 0
    assert ny // sy >= spec.radii[0] * spec.timesteps
    assert nx // sx >= spec.radii[1] * spec.timesteps
    pspec = P(axes[0], axes[1])

    fn = shard_map_compat(
        functools.partial(_local_stencil2d, spec=spec, ax_names=axes),
        mesh=mesh, in_specs=pspec, out_specs=pspec)
    return jax.jit(fn, in_shardings=NamedSharding(mesh, pspec),
                   out_shardings=NamedSharding(mesh, pspec))


def distributed_stencil3d(spec: StencilSpec, mesh: Mesh,
                          axes: tuple[str, str] = ("pod", "data")):
    """Fused 3D star stencil sharded (z over axes[0], y over axes[1])."""
    nz, ny, nx = spec.grid_shape
    sz, sy = mesh.shape[axes[0]], mesh.shape[axes[1]]
    assert nz % sz == 0 and ny % sy == 0
    assert nz // sz >= spec.radii[0] * spec.timesteps
    assert ny // sy >= spec.radii[1] * spec.timesteps
    pspec = P(axes[0], axes[1], None)

    fn = shard_map_compat(
        functools.partial(_local_stencil3d, spec=spec, ax_names=axes),
        mesh=mesh, in_specs=pspec, out_specs=pspec)
    return jax.jit(fn, in_shardings=NamedSharding(mesh, pspec),
                   out_shardings=NamedSharding(mesh, pspec))


def halo_bytes_per_step(spec: StencilSpec, shards: Sequence[int]) -> int:
    """Collective traffic of one fused exchange (for §Roofline accounting)."""
    b = spec.bytes_per_elem
    total = 0
    for ax, (n, r, s) in enumerate(zip(spec.grid_shape, spec.radii, shards)):
        if s <= 1:
            continue
        other = 1
        for a2, n2 in enumerate(spec.grid_shape):
            if a2 != ax:
                other *= n2
        total += 2 * (s - 1) * r * spec.timesteps * other * b
    return total
