"""Logical-axis sharding rules with divisibility fallback (MaxText-style).

Every tensor dim is annotated with a *logical* name ("batch", "heads",
"mlp", …).  Rules map logical names to an ordered list of mesh-axis
candidates; the first candidate whose size divides the dim is chosen, else the
dim is replicated.  This is what lets all 10 assigned architectures lower on
the same (data=16, model=16) / (pod=2, data=16, model=16) meshes even when
e.g. kv_heads=8 cannot split 16 ways (DESIGN.md §7).
"""
from __future__ import annotations

import math
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# candidates are tuples-of-mesh-axes (a tuple shards a dim over several axes)
Rules = Mapping[str, Sequence[tuple[str, ...]]]

DEFAULT_RULES: Rules = {
    # activations
    "batch":      [("pod", "data"), ("data",)],
    "seq":        [()],                       # replicated (SP via halo path)
    "seq_shard":  [("data",)],                # sequence parallelism opt-in
    "embed":      [()],
    # params
    "vocab":      [("model",)],
    "heads":      [("model",)],
    "kv_heads":   [("model",)],
    "head_dim":   [()],
    "mlp":        [("model",)],
    "experts":    [("model",)],
    "expert_cap": [("model",)],   # MoE fallback: shard capacity when E can't
    "cache_seq":  [("model",)],   # KV-cache positions: kv_heads never divide
                                  # 16 on the assigned archs, so decode shards
                                  # the cache *sequence* instead (the dry-run
                                  # caught 74 GiB/dev unsharded caches)
    "fsdp":       [("data",)],                # param leading-dim FSDP
    "conv_k":     [()],
    "stencil_x":  [("data",)],                # distributed stencil strips
    "stencil_y":  [("pod",)],
}


# Serving layout: identical to DEFAULT_RULES except params are NOT
# FSDP-sharded — decode would otherwise re-all-gather every weight on every
# step (EXPERIMENTS.md §Perf cell B).
INFERENCE_RULES: Rules = {**DEFAULT_RULES, "fsdp": [()]}


def make_mesh_compat(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """``jax.make_mesh`` with explicit Auto axis_types where the installed
    jax supports them (``jax.sharding.AxisType`` is newer than 0.4.x); older
    jax treats every axis as Auto already, so plain make_mesh is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` under its pre-promotion spelling when needed."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def mesh_context(mesh: Mesh):
    """Context manager installing ``mesh`` as the ambient mesh:
    ``jax.sharding.set_mesh`` where it exists, else the Mesh object itself
    (which has been a context manager since the pjit days)."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def resolve_spec(shape: tuple[int, ...], logical: tuple[str | None, ...],
                 mesh: Mesh, rules: Rules | None = None) -> P:
    """Pick a PartitionSpec for ``shape`` given per-dim logical names.

    Falls back to replication when no candidate divides the dim or the mesh
    lacks the axis.  A mesh axis is used at most once per tensor (pjit
    requirement); earlier dims win.
    """
    rules = rules or DEFAULT_RULES
    assert len(shape) == len(logical), (shape, logical)
    used: set[str] = set()
    parts = []
    for dim, name in zip(shape, logical):
        chosen: tuple[str, ...] | None = None
        if name:
            for cand in rules.get(name, [()]):
                cand = tuple(a for a in cand if a in mesh.shape)
                if not cand or any(a in used for a in cand):
                    continue
                if dim % _axes_size(mesh, cand) == 0:
                    chosen = cand
                    break
        if chosen:
            used.update(chosen)
            parts.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def named_sharding(shape: tuple[int, ...], logical: tuple[str | None, ...],
                   mesh: Mesh, rules: Rules | None = None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(shape, logical, mesh, rules))


def constrain(x, logical: tuple, rules: Rules | None = None):
    """Activation sharding constraint by logical names, resolved against the
    ambient mesh (``jax.sharding.set_mesh``).  No-op when no mesh is set
    (single-device tests) — models stay mesh-agnostic.

    Without these anchors the SPMD partitioner loses the batch sharding at
    gathers (token embedding) and silently replicates the whole network —
    caught by the dry-run flop accounting (EXPERIMENTS.md §Perf, iteration 0).
    """
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if am is None or not getattr(am, "shape", None):
        return x
    spec = resolve_spec(tuple(x.shape), logical, am, rules)
    if not spec:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def tree_shardings(tree_of_shapes, tree_of_logical, mesh: Mesh,
                   rules: Rules | None = None):
    """Map (shape-tree, logical-tree) -> NamedSharding tree (same structure)."""
    return jax.tree.map(
        lambda sh, lg: named_sharding(tuple(sh), tuple(lg), mesh, rules),
        tree_of_shapes, tree_of_logical,
        is_leaf=lambda x: isinstance(x, (tuple, list)) and
        (not x or not isinstance(x[0], (tuple, list))))
