"""Mapping auto-tuner: measured design-space exploration (docs/explore.md).

The paper picks worker counts analytically (§VI roofline) — this package
closes the loop with *measured* search over the whole mapping lattice
(workers x temporal layers x queue-capacity policy x ``plan_blocks`` tiling
x fabric grid/topology x placement seed), pruned by the same roofline
arithmetic and evaluated with the compiled vector engine:

    from repro.core import CGRA
    from repro.core.spec import heat_2d
    from repro.explore import explore, SpaceOptions, Budget

    res = explore(heat_2d(48, 96, dtype="float64"), CGRA,
                  options=SpaceOptions(fabrics=((16, 16, "mesh"),)),
                  budget=Budget(routed_finalists=3),
                  cache=".explore_cache.json")
    res.best()        # lexicographic (cycles, PEs, channel load) winner
    res.front         # the measured Pareto front
    res.analytic      # the paper's §VI baseline, measured the same way

Works for single-op specs (``map_nd``) and program DAGs
(``repro.program.lower``) alike.
"""
from repro.explore.cache import EvalCache
from repro.explore.pareto import (assert_non_dominated, best_point,
                                  dominates, pareto_front)
from repro.explore.prune import (PruneLog, fits_fabric, prune_reason,
                                 prune_space)
from repro.explore.search import Budget, EvalPoint, ExploreResult, explore
from repro.explore.space import (MappingConfig, ProgramTarget, SpaceOptions,
                                 SpecTarget, analytic_config, as_target,
                                 enumerate_space, tile_candidates)

__all__ = ["EvalCache", "assert_non_dominated", "best_point", "dominates",
           "pareto_front", "PruneLog", "fits_fabric", "prune_reason",
           "prune_space", "Budget", "EvalPoint", "ExploreResult", "explore",
           "MappingConfig", "ProgramTarget", "SpaceOptions", "SpecTarget",
           "analytic_config", "as_target", "enumerate_space",
           "tile_candidates"]
