"""Persistent evaluation cache for the mapping auto-tuner.

Every measured evaluation is stored under the canonical config hash
(:meth:`repro.explore.space.MappingConfig.key` scoped by target + machine +
mode), so re-running the same search — the ``ci.sh`` smoke refresh, an
interrupted sweep, a second target sharing configs — pays only for configs
it has never simulated.  Failures (deadlocks, placement overflows) are
cached too: a config known to deadlock is not re-simulated.

The store is a single JSON file, loaded eagerly and written atomically
(tmp + rename), so a crashed search never corrupts it.  A schema bump
invalidates old files wholesale — entries are measurements, never worth a
migration.
"""
from __future__ import annotations

import json
import os
import tempfile

SCHEMA = "explore-cache/v1"


class EvalCache:
    """Dict-like JSON-backed store: canonical config hash -> eval record."""

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = os.fspath(path) if path is not None else None
        self.data: dict[str, dict] = {}
        self.hits = 0
        self.misses = 0
        self.failure_hits = 0           # replayed known-bad configs (free)
        if self.path and os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    raw = json.load(f)
                if raw.get("schema") == SCHEMA:
                    self.data = dict(raw.get("entries", {}))
            except (OSError, ValueError):
                self.data = {}          # unreadable cache = empty cache

    def get(self, key: str) -> dict | None:
        ent = self.data.get(key)
        if ent is None:
            self.misses += 1
        else:
            self.hits += 1
            if "failed" in ent:
                self.failure_hits += 1
        return ent

    def put(self, key: str, value: dict) -> None:
        self.data[key] = value

    def stats(self) -> dict:
        """Hit/miss accounting for this process (the persistent store only
        grows; ``entries`` is its current size)."""
        return {"hits": self.hits, "misses": self.misses,
                "failures_replayed": self.failure_hits,
                "entries": len(self.data)}

    def save(self) -> None:
        if not self.path:
            return
        payload = {"schema": SCHEMA, "entries": self.data}
        d = os.path.dirname(os.path.abspath(self.path)) or "."
        fd, tmp = tempfile.mkstemp(prefix=".explore_cache.", dir=d)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __len__(self) -> int:
        return len(self.data)
