"""Pareto machinery for the mapping auto-tuner.

The tuner judges a mapping by the objective vector

    (workload cycles, PEs used, max channel load)

— lower is better on every axis.  A config *dominates* another when it is no
worse everywhere and strictly better somewhere; the *front* is the set of
measured points no other measured point dominates.  ``best()`` breaks the
front's ties lexicographically (cycles first — the paper's figure of merit —
then PE footprint, then link pressure).
"""
from __future__ import annotations

from typing import Callable, Iterable, Sequence, TypeVar

T = TypeVar("T")


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True iff objective vector ``a`` dominates ``b`` (minimization)."""
    if len(a) != len(b):
        raise ValueError(f"objective ranks differ: {len(a)} vs {len(b)}")
    return all(x <= y for x, y in zip(a, b)) and any(
        x < y for x, y in zip(a, b))


def pareto_front(points: Iterable[T],
                 key: Callable[[T], Sequence[float]] = lambda p: p  # type: ignore[assignment,return-value]
                 ) -> list[T]:
    """The non-dominated subset of ``points``, in first-seen order.

    Points with *equal* objective vectors neither dominate each other, so
    ties all survive — callers that want one representative per vector can
    dedupe on ``key``.
    """
    pts = list(points)
    objs = [tuple(key(p)) for p in pts]
    front = []
    for i, p in enumerate(pts):
        if not any(dominates(objs[j], objs[i])
                   for j in range(len(pts)) if j != i):
            front.append(p)
    return front


def assert_non_dominated(points: Iterable[T],
                         key: Callable[[T], Sequence[float]] = lambda p: p  # type: ignore[assignment,return-value]
                         ) -> None:
    """Raise ``AssertionError`` naming the offending pair if any point in
    ``points`` dominates another — the artifact-verification gate."""
    pts = list(points)
    objs = [tuple(key(p)) for p in pts]
    for i in range(len(pts)):
        for j in range(len(pts)):
            if i != j and dominates(objs[i], objs[j]):
                raise AssertionError(
                    f"front is internally dominated: {objs[i]} (point {i}) "
                    f"dominates {objs[j]} (point {j})")


def best_point(points: Iterable[T],
               key: Callable[[T], Sequence[float]] = lambda p: p  # type: ignore[assignment,return-value]
               ) -> T:
    """Lexicographic minimum of the objective vectors (cycles, PEs, load)."""
    pts = list(points)
    if not pts:
        raise ValueError("no points to choose from")
    return min(pts, key=lambda p: tuple(key(p)))
