"""Analytical pruning of the mapping lattice (the cheap half of the search).

Before any cycle is simulated the tuner discards configs that provably
cannot map or cannot win, using only the §VI roofline arithmetic and the
``map_nd`` structural constraints:

* ``indivisible``      — rank >= 2 column ownership needs the innermost
                         extent (of the tile, when tiling) to divide by the
                         worker count.
* ``no-interior``      — more workers than interior sites along the
                         innermost axis: some workers would own no outputs.
* ``temporal``         — the fused depth must divide the workload's sweep
                         count (and stay 1 for program targets — fusion is
                         per-op in the program IR).
* ``tile-degenerate``  — the fused halo leaves a tile no interior.
* ``mac-overflow``     — the plan's MAC chains (w x temporal x chain length,
                         summed over program ops) exceed the machine's MACs.
* ``roofline-excess``  — workers beyond the bandwidth-limited demand
                         (+ ``worker_slack``): §VI says extra workers only
                         burn PEs once the memory system is saturated, so
                         they cannot beat a front that already contains the
                         saturating count.

``prune_space`` returns the surviving configs plus a :class:`PruneLog`
(reason -> count, and the dropped configs for the artifact/stats).  A second
exact gate, :func:`fits_fabric`, runs post-build on survivors headed to the
routed stage (instruction count vs PE slots per capability class).
"""
from __future__ import annotations

import dataclasses

from repro.core.roofline import Machine, workers_demanded
from repro.explore.space import MappingConfig, SpaceOptions, feasible_workers
from repro.fabric.topology import FabricTopology, op_class


@dataclasses.dataclass
class PruneLog:
    reasons: dict[str, int] = dataclasses.field(default_factory=dict)
    dropped: list[tuple[MappingConfig, str]] = dataclasses.field(
        default_factory=list)

    def drop(self, cfg: MappingConfig, reason: str) -> None:
        self.reasons[reason] = self.reasons.get(reason, 0) + 1
        self.dropped.append((cfg, reason))

    def as_dict(self) -> dict:
        return dict(sorted(self.reasons.items()))


def prune_reason(target, machine: Machine, cfg: MappingConfig,
                 options: SpaceOptions) -> str | None:
    """The first rule ``cfg`` violates, or None if it survives."""
    if cfg.temporal < 1 or target.workload_timesteps % cfg.temporal:
        return "temporal"
    if target.kind != "spec" and (cfg.temporal != 1 or cfg.tile is not None):
        return "temporal" if cfg.temporal != 1 else "tile-degenerate"
    if cfg.tile is not None:
        spec = target.spec
        if len(cfg.tile) != spec.ndim:
            return "tile-degenerate"
        for n, t, r in zip(spec.grid_shape, cfg.tile, spec.radii):
            if t > n or t - 2 * r * cfg.temporal < 1:
                return "tile-degenerate"
    if not feasible_workers(target, cfg):
        inner = target.inner_extent(cfg)
        if target.ndim() >= 2 and inner % max(1, cfg.workers):
            return "indivisible"
        return "no-interior"
    if machine.num_macs and target.mac_demand(cfg) > machine.num_macs:
        return "mac-overflow"
    need = workers_demanded(target.roofline_spec(), machine)
    if cfg.workers > need + options.worker_slack:
        return "roofline-excess"
    return None


def prune_space(target, machine: Machine, configs, options: SpaceOptions,
                *, keep: MappingConfig | None = None
                ) -> tuple[list[MappingConfig], PruneLog]:
    """Split ``configs`` into survivors and a reason log.  ``keep`` (the
    analytical seed) is exempt from the *roofline* rule only — it must still
    be mappable, but we never prune the baseline we compare against."""
    log = PruneLog()
    kept = []
    for cfg in configs:
        reason = prune_reason(target, machine, cfg, options)
        if reason == "roofline-excess" and keep is not None and cfg == keep:
            reason = None
        if reason is None:
            kept.append(cfg)
        else:
            log.drop(cfg, reason)
    return kept, log


def static_prune_reason(plan, fabric=None) -> tuple[str, dict | None] | None:
    """Post-build static-verifier gate (``repro.analysis.static_verify``):
    a config whose plan provably deadlocks is pruned *before* any engine
    burns up to ``max_cycles`` on it.  Returns ``(reason,
    suggested_capacities)`` — reason ``"static-capacity: ..."`` when a
    capacity bump (the returned hint) provably fixes it, ``"static-deadlock:
    ..."`` when the deadlock is structural — or ``None`` for plans the
    verifier proves safe (or cannot decide: never prune on "unknown")."""
    from repro.analysis.static_verify import verify_plan
    report = verify_plan(plan, fabric=fabric)
    if report.verdict != "deadlock":
        return None
    detail = (report.counterexample.describe() if report.counterexample
              else "; ".join(str(f) for f in report.errors()) or "unfixable")
    return f"{report.reason}: {detail}", report.suggested_capacities


def fits_fabric(plan, topo: FabricTopology) -> str | None:
    """Exact post-build fabric gate: instruction count vs total slots and
    per-capability-class slot budgets (mirrors ``place``'s own precheck
    without paying for placement).  Returns a reason string or None."""
    nodes = plan.dfg.nodes
    if len(nodes) > topo.total_slots():
        return (f"fabric-slots: {len(nodes)} instructions > "
                f"{topo.total_slots()} slots")
    demand: dict[str, int] = {}
    for n in nodes:
        cls = op_class(n.op)
        demand[cls] = demand.get(cls, 0) + 1
    for cls, need in demand.items():
        have = topo.total_slots(cls)
        if need > have:
            return f"fabric-slots: {need} {cls!r} ops > {have} {cls} slots"
    return None
