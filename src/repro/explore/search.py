"""Budgeted design-space search: enumerate → prune → measure → Pareto.

The measured half of the tuner.  Stage 1 simulates every pruned-in config in
*ideal* mode (no network) with the compiled vector engine — fast enough that
a whole worker/temporal/capacity/tiling lattice costs less than one routed
interp run used to.  With ``Budget.batch_size`` set, stage 1 instead chunks
the pending configs and runs each chunk as **one** jitted+vmapped device
call on the jax engine (:func:`repro.core.simulator.simulate_batch`);
lanes the jax lowering can't express fall back to the sequential engine.  Stage 2 takes the stage-1 Pareto finalists (plus,
always, the paper's analytical baseline) and pays for physics: seeded
placement (optionally restarted), XY routing, and network-aware simulation
per candidate fabric, producing the final objective vectors

    (workload cycles, PEs used, max channel load).

Every simulate() call is budgeted (``Budget.max_evals`` /
``Budget.max_sim_cycles``) and cached by canonical config hash
(:mod:`repro.explore.cache`), failures included — a config known to
deadlock is never paid for twice.  The analytical config is evaluated
first, so even a one-eval budget yields the baseline, and the best()
pick can only match or beat it.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.analysis.static_verify import STATIC_SEMANTICS
from repro.core.engine import ENGINE_SEMANTICS
from repro.core.engine.common import SimDeadlock
from repro.core.roofline import Machine
from repro.core.simulator import simulate
from repro.explore.cache import EvalCache
from repro.explore.pareto import best_point, pareto_front
from repro.explore.prune import (PruneLog, fits_fabric, prune_space,
                                 static_prune_reason)
from repro.explore.space import (MappingConfig, SpaceOptions, as_target,
                                 enumerate_space)


@dataclasses.dataclass(frozen=True)
class Budget:
    """What the measured stage may spend.  ``None`` = unlimited.

    ``batch_size`` switches the stage-1 ideal sweep to the batched jax
    engine: pending configs are chunked into groups of ``batch_size`` and
    each group simulates as one jitted+vmapped device call
    (``simulate_batch``), instead of one sequential ``vector.run`` per
    config.  Lanes the jax lowering rejects fall back to the sequential
    evaluator; stage-2 routed finalists always use the sequential engine
    (the jax path is ideal-mode only).  ``None`` keeps the sequential
    stage 1."""
    max_evals: int | None = None          # simulate() calls (cache hits free)
    max_sim_cycles: int | None = None     # summed simulated cycles
    routed_finalists: int = 4             # stage-1 survivors that get routed
    sim_max_cycles: int = 5_000_000       # per-simulation runaway guard
    batch_size: int | None = None         # stage-1 lanes per batched jax call


@dataclasses.dataclass
class EvalPoint:
    """One measured mapping: config + objective vector + provenance."""
    config: MappingConfig
    cycles: int                           # workload cycles (sim x repeats)
    pes: int                              # instructions (ideal) / PEs (routed)
    max_channel_load: int                 # 0 in ideal mode
    gflops: float
    routed: bool
    cached: bool = False
    sim_cycles: int = 0                   # raw cycles of the simulate() call
    bottleneck: str = ""                  # attribution label ("" = unknown)

    def objectives(self) -> tuple[int, int, int]:
        return (self.cycles, self.pes, self.max_channel_load)

    def as_dict(self) -> dict:
        return {"config": self.config.canonical(),
                "cycles": self.cycles, "pes": self.pes,
                "max_channel_load": self.max_channel_load,
                "gflops": round(self.gflops, 3), "routed": self.routed,
                "cached": self.cached, "bottleneck": self.bottleneck}


@dataclasses.dataclass
class ExploreResult:
    target: str
    machine: str
    points: list[EvalPoint]               # final-mode measurements
    ideal_points: list[EvalPoint]
    front: list[EvalPoint]
    analytic: EvalPoint | None            # the paper's §VI baseline, measured
    analytic_config: MappingConfig
    failures: list[dict]
    prune: PruneLog
    stats: dict

    def best(self) -> EvalPoint:
        return best_point(self.front, key=EvalPoint.objectives)

    def to_json(self) -> dict:
        best = self.best() if self.front else None
        return {
            "target": self.target, "machine": self.machine,
            "analytic": self.analytic.as_dict() if self.analytic else None,
            "best": best.as_dict() if best else None,
            "front": [p.as_dict() for p in self.front],
            "n_points": len(self.points),
            "failures": self.failures,
            "pruned": self.prune.as_dict(),
            "stats": self.stats,
        }


class _BudgetState:
    def __init__(self, budget: Budget):
        self.budget = budget
        self.evals = 0
        self.sim_cycles = 0

    def exhausted(self) -> bool:
        b = self.budget
        return ((b.max_evals is not None and self.evals >= b.max_evals)
                or (b.max_sim_cycles is not None
                    and self.sim_cycles >= b.max_sim_cycles))

    def charge(self, cycles: int) -> None:
        self.evals += 1
        self.sim_cycles += cycles


def _machine_sig(machine: Machine) -> dict:
    return {"name": machine.name, "clock_ghz": machine.clock_ghz,
            "num_macs": machine.num_macs, "bw_gbps": machine.bw_gbps,
            "peak_gflops": machine.peak_gflops}


def _mk_topo(fabric: tuple[int, int, str]):
    from repro.fabric import FabricTopology
    rows, cols, kind = fabric
    if kind == "torus":
        return FabricTopology.torus_grid(rows, cols)
    return FabricTopology.mesh(rows, cols)


def _point_from_cache(cfg: MappingConfig, ent: dict,
                      routed: bool) -> EvalPoint:
    return EvalPoint(config=cfg, cycles=ent["cycles"], pes=ent["pes"],
                     max_channel_load=ent["chan"], gflops=ent["gflops"],
                     routed=routed, cached=True,
                     sim_cycles=ent["sim_cycles"],
                     bottleneck=ent.get("bottleneck", ""))


def _hint_json(suggested: dict | None) -> dict | None:
    """``suggested_capacities`` as a JSON-stable ``{str(eid): cap}`` map —
    the form failure records and cache entries carry (eids are deterministic
    per config, so a rebuilt plan accepts the replayed hint as-is)."""
    if not suggested:
        return None
    return {str(k): int(v) for k, v in sorted(suggested.items())}


def _paranoia_check(target, cfg: MappingConfig, plan, machine: Machine,
                    state: _BudgetState, rf) -> None:
    """``static_paranoia``: prove the verifier right the expensive way — a
    statically-rejected config must really deadlock when simulated.  Used
    by the fuzz gate; raises AssertionError on any unsound verdict."""
    x = target.make_input(plan)
    try:
        simulate(plan, x, machine, engine="vector", fabric=rf,
                 max_cycles=state.budget.sim_max_cycles)
    except SimDeadlock as e:
        if not e.timed_out:
            return
        raise AssertionError(
            f"static verifier rejected {cfg.canonical()} but the "
            f"simulation timed out instead of deadlocking") from e
    raise AssertionError(
        f"static verifier rejected {cfg.canonical()} but the simulation "
        f"completed — unsound static verdict")


def _evaluate(target, cfg: MappingConfig, machine: Machine, *, scope: dict,
              cache: EvalCache, state: _BudgetState, engine: str,
              failures: list, skipped: list, verify: bool,
              routed: bool, tel=None, static_gate: bool = False,
              paranoia: bool = False) -> EvalPoint | None:
    """One (possibly cached) measurement; None on failure/budget-skip."""
    key = cfg.key(scope, ideal=not routed)
    t0 = time.perf_counter()
    mode = "routed" if routed else "ideal"

    def span(outcome: str, *, cached: bool = False,
             cycles: int | None = None, bottleneck: str = "") -> None:
        """One structured span per evaluation into the telemetry sink —
        exported as a search-timeline trace (docs/telemetry.md)."""
        if tel is None:
            return
        b = state.budget
        el = time.perf_counter() - t0
        tel.span(f"{mode} {key[:10]}", cat="tuner", track=f"search/{mode}",
                 t0=tel.now() - el, dur=el, key=key, phase=mode,
                 config=cfg.canonical(), outcome=outcome, cached=cached,
                 cycles=cycles, bottleneck=bottleneck,
                 evals_remaining=(None if b.max_evals is None
                                  else b.max_evals - state.evals),
                 sim_cycles_remaining=(None if b.max_sim_cycles is None
                                       else b.max_sim_cycles
                                       - state.sim_cycles))

    ent = cache.get(key)
    if ent is not None:
        if "failed" in ent:
            rec = {"config": cfg.canonical(), "reason": ent["failed"],
                   "cached": True}
            if ent.get("suggested_capacities"):
                # cached failures replay the capacity-repair hint too
                rec["suggested_capacities"] = ent["suggested_capacities"]
            failures.append(rec)
            span(f"cached-failure: {ent['failed']}", cached=True)
            return None
        span("cached", cached=True, cycles=ent["sim_cycles"])
        return _point_from_cache(cfg, ent, routed)
    if state.exhausted():
        skipped.append(cfg)
        span("budget-skipped")
        return None

    def fail(reason: str, suggested: dict | None = None) -> None:
        rec = {"config": cfg.canonical(), "reason": reason, "cached": False}
        ent = {"failed": reason}
        hint = _hint_json(suggested)
        if hint:
            rec["suggested_capacities"] = hint
            ent["suggested_capacities"] = hint
        failures.append(rec)
        cache.put(key, ent)
        span(f"failed: {reason}")

    try:
        plan = target.build(cfg)
    except ValueError as e:
        fail(f"build: {e}")
        return None

    rf = placement = None
    if routed:
        topo = _mk_topo(cfg.fabric)
        reason = fits_fabric(plan, topo)
        if reason is not None:
            fail(reason)
            return None
        from repro.fabric import (PlacementError, RouteError,
                                  apply_routed_capacities, place, route)
        try:
            placement = place(plan, topo, seed=cfg.place_seed,
                              restarts=cfg.place_restarts)
            rf = route(placement)
        except (PlacementError, RouteError) as e:
            fail(f"place/route: {e}")
            return None
        if cfg.capacity == "auto":
            # routed auto-capacity: grow the analytic minima by each edge's
            # routed hop depth — ideal minima back-pressure on long routes
            apply_routed_capacities(rf)

    if static_gate:
        # after apply_routed_capacities so the gate judges the capacities
        # the engine would actually run with
        sr = static_prune_reason(plan, fabric=rf)
        if sr is not None:
            reason, suggested = sr
            if paranoia:
                _paranoia_check(target, cfg, plan, machine, state, rf)
            fail(reason, suggested)
            return None

    from repro.telemetry import Telemetry, attribute
    mtel = Telemetry(timeline=False)      # counters only: cheap attribution
    x = target.make_input(plan)
    try:
        res = simulate(plan, x, machine, engine=engine, fabric=rf,
                       max_cycles=state.budget.sim_max_cycles,
                       telemetry=mtel)
    except SimDeadlock as e:
        state.charge(e.cycles)            # the cycles burnt before giving up
        fail(f"{'timeout' if e.timed_out else 'deadlock'}: {e}",
             getattr(e, "suggested_capacities", None))
        return None
    state.charge(res.cycles)
    if verify:
        target.verify(plan, cfg, x, res)
    bottleneck = attribute(mtel, res).bottleneck

    pt = EvalPoint(
        config=cfg,
        cycles=res.cycles * target.repeats(cfg),
        pes=placement.pes_used() if placement is not None
        else len(plan.dfg.nodes),
        max_channel_load=(rf.stats()["max_channel_load"]
                          if rf is not None else 0),
        gflops=res.gflops, routed=routed, sim_cycles=res.cycles,
        bottleneck=bottleneck)
    cache.put(key, {"cycles": pt.cycles, "pes": pt.pes,
                    "chan": pt.max_channel_load, "gflops": pt.gflops,
                    "sim_cycles": pt.sim_cycles, "bottleneck": pt.bottleneck})
    span("measured", cycles=res.cycles, bottleneck=bottleneck)
    return pt


def _stage1_batched(target, kept, machine, *, base_scope: dict,
                    seq_scope: dict, cache: EvalCache, state: _BudgetState,
                    engine: str, failures: list, skipped: list,
                    verify: bool, tel=None, static_gate: bool = False,
                    paranoia: bool = False) -> list[EvalPoint]:
    """Stage-1 ideal sweep as chunked one-device-call jax batches.

    Pending (uncached, in-budget) configs are built, chunked into groups of
    ``Budget.batch_size`` and dispatched through ``simulate_batch`` — each
    chunk is one jitted+vmapped device call over plans padded to a common
    shape.  Measurements are keyed under the jax engine's own scope
    (``engine`` + ``engine_semantics``), so batched results and sequential
    ``engine`` results can never replay each other.  Per-lane failures come
    back *as values*: deadlocks/timeouts are cached as failures exactly like
    the sequential path; lanes the jax lowering rejects
    (:class:`~repro.core.engine.jax_engine.JaxLoweringError`) fall back to
    the sequential evaluator under its own scope."""
    from repro.core.simulator import simulate_batch

    scope = {**base_scope, "engine": "jax",
             "engine_semantics": ENGINE_SEMANTICS["jax"], "mode": "ideal"}
    points: list[EvalPoint] = []
    pending: list[tuple[MappingConfig, str]] = []

    def span(key: str, outcome: str, t0: float, *, cached: bool = False,
             cycles: int | None = None) -> None:
        if tel is None:
            return
        el = time.perf_counter() - t0
        b = state.budget
        tel.span(f"ideal {key[:10]}", cat="tuner", track="search/ideal",
                 t0=tel.now() - el, dur=el, key=key, phase="ideal",
                 outcome=outcome, cached=cached, cycles=cycles,
                 batched=True,
                 evals_remaining=(None if b.max_evals is None
                                  else b.max_evals - state.evals),
                 sim_cycles_remaining=(None if b.max_sim_cycles is None
                                       else b.max_sim_cycles
                                       - state.sim_cycles))

    for cfg in kept:
        key = cfg.key(scope, ideal=True)
        t0 = time.perf_counter()
        ent = cache.get(key)
        if ent is not None:
            if "failed" in ent:
                rec = {"config": cfg.canonical(),
                       "reason": ent["failed"], "cached": True}
                if ent.get("suggested_capacities"):
                    rec["suggested_capacities"] = ent["suggested_capacities"]
                failures.append(rec)
                span(key, f"cached-failure: {ent['failed']}", t0, cached=True)
            else:
                span(key, "cached", t0, cached=True,
                     cycles=ent["sim_cycles"])
                points.append(_point_from_cache(cfg, ent, False))
            continue
        pending.append((cfg, key))

    bsz = max(1, int(state.budget.batch_size))
    i = 0
    while i < len(pending):
        if state.exhausted():
            for cfg, key in pending[i:]:
                skipped.append(cfg)
                span(key, "budget-skipped", time.perf_counter())
            break
        take = bsz
        if state.budget.max_evals is not None:
            # never dispatch more lanes than the eval budget has left
            take = min(take, state.budget.max_evals - state.evals)
        chunk = pending[i:i + take]
        i += len(chunk)
        lanes = []                        # (cfg, key, plan, x, t0)
        for cfg, key in chunk:
            t0 = time.perf_counter()
            try:
                plan = target.build(cfg)
            except ValueError as e:
                failures.append({"config": cfg.canonical(),
                                 "reason": f"build: {e}", "cached": False})
                cache.put(key, {"failed": f"build: {e}"})
                span(key, f"failed: build: {e}", t0)
                continue
            if static_gate:
                sr = static_prune_reason(plan)
                if sr is not None:
                    reason, suggested = sr
                    if paranoia:
                        _paranoia_check(target, cfg, plan, machine, state,
                                        None)
                    rec = {"config": cfg.canonical(), "reason": reason,
                           "cached": False}
                    ent = {"failed": reason}
                    hint = _hint_json(suggested)
                    if hint:
                        rec["suggested_capacities"] = hint
                        ent["suggested_capacities"] = hint
                    failures.append(rec)
                    cache.put(key, ent)
                    span(key, f"failed: {reason}", t0)
                    continue
            lanes.append((cfg, key, plan, target.make_input(plan), t0))
        if not lanes:
            continue
        raw = simulate_batch([(p, x) for _c, _k, p, x, _t in lanes],
                             machine, max_cycles=state.budget.sim_max_cycles,
                             engine="jax")
        for (cfg, key, plan, x, t0), res in zip(lanes, raw):
            if isinstance(res, NotImplementedError):
                # lowering rejected this lane: sequential fallback, measured
                # and cached under the sequential engine's own scope
                pt = _evaluate(target, cfg, machine, scope=seq_scope,
                               cache=cache, state=state, engine=engine,
                               failures=failures, skipped=skipped,
                               verify=verify, routed=False, tel=tel)
                if pt is not None:
                    points.append(pt)
                continue
            if isinstance(res, SimDeadlock):
                state.charge(res.cycles)  # the cycles burnt before giving up
                reason = (f"{'timeout' if res.timed_out else 'deadlock'}: "
                          f"{res}")
                rec = {"config": cfg.canonical(), "reason": reason,
                       "cached": False}
                ent = {"failed": reason}
                hint = _hint_json(getattr(res, "suggested_capacities", None))
                if hint:
                    rec["suggested_capacities"] = hint
                    ent["suggested_capacities"] = hint
                failures.append(rec)
                cache.put(key, ent)
                span(key, f"failed: {reason}", t0)
                continue
            state.charge(res.cycles)
            if verify:
                target.verify(plan, cfg, x, res)
            pt = EvalPoint(
                config=cfg, cycles=res.cycles * target.repeats(cfg),
                pes=len(plan.dfg.nodes), max_channel_load=0,
                gflops=res.gflops, routed=False, sim_cycles=res.cycles,
                bottleneck="")
            cache.put(key, {"cycles": pt.cycles, "pes": pt.pes, "chan": 0,
                            "gflops": pt.gflops, "sim_cycles": pt.sim_cycles,
                            "bottleneck": ""})
            span(key, "measured", t0, cycles=res.cycles)
            points.append(pt)
    return points


def explore(target, machine: Machine, *,
            options: SpaceOptions | None = None,
            budget: Budget | None = None,
            cache: EvalCache | str | None = None,
            engine: str = "vector",
            workload_timesteps: int = 1,
            verify: bool = False,
            telemetry=None,
            static_verify: bool = True,
            static_paranoia: bool = False) -> ExploreResult:
    """Search mapping configs for ``target`` (a ``StencilSpec``, a
    ``StencilProgram``, or a ready-made target) on ``machine`` and return
    the measured Pareto front.  See the module docstring for the staging;
    ``docs/explore.md`` for the full semantics.

    ``telemetry``: a ``repro.telemetry.Telemetry`` sink — the search records
    one structured span per evaluation into it (config hash, outcome or
    prune reason, cache hit/miss, wall time, budget remaining), exportable
    as a search-timeline trace via ``repro.telemetry.write_trace``.

    ``static_verify`` (default on) runs every freshly-built plan through the
    static verifier (``repro.analysis.static_verify``) before paying for any
    simulation: provable deadlocks are recorded as ``static-capacity`` /
    ``static-deadlock`` failures — with the verifier's
    ``suggested_capacities`` repair hint on the failure record and in the
    cache entry — and never reach an engine.  ``static_paranoia``
    additionally simulates every statically-rejected config and asserts it
    really deadlocks (the fuzz-suite soundness gate; expensive)."""
    t0 = time.perf_counter()
    target = as_target(target, workload_timesteps=workload_timesteps)
    options = options or SpaceOptions()
    budget = budget or Budget()
    if not isinstance(cache, EvalCache):
        cache = EvalCache(cache)

    configs, analytic_cfg = enumerate_space(target, machine, options)
    kept, plog = prune_space(target, machine, configs, options,
                             keep=analytic_cfg)
    if telemetry is not None:       # pruned configs get a (zero-cost) span
        for cfg, reason in plog.dropped:
            telemetry.span(f"pruned {reason}", cat="tuner",
                           track="search/prune", config=cfg.canonical(),
                           outcome=f"pruned: {reason}")
    # analytical baseline first: even a one-eval budget measures it
    kept.sort(key=lambda c: c != analytic_cfg)

    state = _BudgetState(budget)
    failures: list[dict] = []
    skipped: list[MappingConfig] = []
    # sim_max_cycles is part of the scope: a timeout under a small budget
    # must not be replayed from cache as a failure under a bigger one
    # capacity_model names the queue-sizing policy measured evals ran under
    # (hop/v1 = routed auto-capacity grows minima by hop depth); bumping it
    # invalidates cached evals taken under the older sizing.
    # engine + engine_semantics scope a measurement to the backend (and its
    # semantics version) that took it: batched-jax evals can never be
    # replayed as vector evals or vice versa.
    # static_semantics scopes entries to the static-verifier version that
    # gated them: a verifier semantics bump (or turning the gate off) must
    # re-measure, not replay verdict-dependent failures from cache.
    base_scope = {"target": target.signature(),
                  "machine": _machine_sig(machine), "engine": engine,
                  "engine_semantics": ENGINE_SEMANTICS[engine],
                  "sim_max_cycles": budget.sim_max_cycles,
                  "capacity_model": "hop/v1",
                  "static_semantics":
                      STATIC_SEMANTICS if static_verify else None}

    # ----- stage 1: ideal-mode sweep ----------------------------------------
    scope = {**base_scope, "mode": "ideal"}
    if budget.batch_size:
        ideal_points = _stage1_batched(
            target, kept, machine, base_scope=base_scope, seq_scope=scope,
            cache=cache, state=state, engine=engine, failures=failures,
            skipped=skipped, verify=verify, tel=telemetry,
            static_gate=static_verify, paranoia=static_paranoia)
    else:
        ideal_points = []
        for cfg in kept:
            pt = _evaluate(target, cfg, machine, scope=scope, cache=cache,
                           state=state, engine=engine, failures=failures,
                           skipped=skipped, verify=verify, routed=False,
                           tel=telemetry, static_gate=static_verify,
                           paranoia=static_paranoia)
            if pt is not None:
                ideal_points.append(pt)

    analytic_pt = next((p for p in ideal_points
                        if p.config == analytic_cfg), None)

    # ----- stage 2: route the finalists -------------------------------------
    points = ideal_points
    if options.fabrics and ideal_points:
        finalists = pareto_front(ideal_points, key=EvalPoint.objectives)
        finalists = sorted(finalists, key=EvalPoint.objectives)
        finalists = finalists[:max(1, budget.routed_finalists)]
        if analytic_pt is not None and analytic_pt not in finalists:
            finalists.append(analytic_pt)
        scope = {**base_scope, "mode": "routed"}
        routed_points = []
        for pt in finalists:
            for fab in options.fabrics:
                for seed in options.place_seeds:
                    cfg = pt.config.with_fabric(fab, seed,
                                                options.place_restarts)
                    rpt = _evaluate(target, cfg, machine, scope=scope,
                                    cache=cache, state=state, engine=engine,
                                    failures=failures, skipped=skipped,
                                    verify=False, routed=True, tel=telemetry,
                                    static_gate=static_verify,
                                    paranoia=static_paranoia)
                    if rpt is not None:
                        routed_points.append(rpt)
        points = routed_points
        # the baseline must be measured in the SAME mode as the points it
        # anchors: if its routed eval failed there is no baseline (None),
        # never the ideal-mode stand-in (routed >= ideal would skew margins)
        analytic_pt = next(
            (p for p in routed_points
             if p.config.fabric == options.fabrics[0]
             and p.config.place_seed == options.place_seeds[0]
             and dataclasses.replace(p.config, fabric=None, place_seed=0,
                                     place_restarts=1) == analytic_cfg),
            None)

    front = pareto_front(points, key=EvalPoint.objectives)
    cache.save()
    # fold static-gate rejections into the prune log (reason prefix only:
    # "static-capacity"/"static-deadlock") so artifacts report them next to
    # the analytical prune rules; they stay in `failures` with full detail.
    for f in failures:
        if f["reason"].startswith("static-"):
            pfx = f["reason"].split(":", 1)[0]
            plog.reasons[pfx] = plog.reasons.get(pfx, 0) + 1
    stats = {
        "n_configs": len(configs), "n_pruned": len(plog.dropped),
        "n_kept": len(kept), "n_measured": state.evals,
        "n_cached": cache.hits, "n_failures": len(failures),
        "n_budget_skipped": len(skipped),
        "static_pruned": sum(1 for f in failures
                             if f["reason"].startswith("static-")),
        "sim_cycles_total": state.sim_cycles,
        "wall_s": round(time.perf_counter() - t0, 3),
        "cache": cache.stats(),
    }
    return ExploreResult(
        target=target.name, machine=machine.name, points=points,
        ideal_points=ideal_points, front=front, analytic=analytic_pt,
        analytic_config=analytic_cfg, failures=failures, prune=plog,
        stats=stats)
