"""The mapping design space: configs, targets, enumeration, canonical hashes.

A :class:`MappingConfig` names one point of the lattice the tuner searches:

* ``workers``        — worker-pipeline width (the paper's §VI knob)
* ``temporal``       — fused time-steps per sweep (§IV temporal layers);
                       must divide the target's ``workload_timesteps``
* ``capacity``       — queue-capacity policy: ``"auto"`` (the §III-B
                       mandatory-buffering minima via ``auto_capacity``),
                       ``"unbounded"`` (idealized infinite queues), or a
                       fixed uniform int (which may deadlock — the tuner
                       records that as a measured failure)
* ``tile``           — optional ``plan_blocks`` block shape: the sweep is
                       strip-mined and one representative block is simulated,
                       workload cycles = per-block cycles x #blocks
* ``fabric``         — optional physical grid ``(rows, cols, kind)`` for the
                       routed stage, with ``place_seed``/``place_restarts``

Targets adapt the two plan kinds to one interface: :class:`SpecTarget` wraps
a single-op :class:`~repro.core.spec.StencilSpec` (mapped with ``map_nd``),
:class:`ProgramTarget` wraps a :class:`~repro.program.ir.StencilProgram`
(lowered with ``repro.program.lower``).  Everything hashes canonically
(:meth:`MappingConfig.key`) so evaluations cache across runs.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import math

import numpy as np

from repro.core.mapping import map_nd, plan_blocks
from repro.core.roofline import Machine, worker_fit, workers_demanded
from repro.core.spec import StencilSpec


@dataclasses.dataclass(frozen=True)
class MappingConfig:
    workers: int
    temporal: int = 1
    capacity: str | int = "auto"
    tile: tuple[int, ...] | None = None
    fabric: tuple[int, int, str] | None = None     # (rows, cols, mesh|torus)
    place_seed: int = 0
    place_restarts: int = 1

    def __post_init__(self):
        if isinstance(self.capacity, str) and self.capacity not in (
                "auto", "unbounded"):
            raise ValueError(
                f"capacity policy must be 'auto', 'unbounded' or an int; "
                f"got {self.capacity!r}")
        if isinstance(self.capacity, int) and self.capacity < 1:
            raise ValueError("fixed queue capacity must be >= 1")

    # ----- canonical identity ------------------------------------------------
    def canonical(self, *, ideal: bool = False) -> dict:
        """JSON-stable description; ``ideal=True`` drops the physical knobs
        (fabric, placement seed) that cannot change an ideal-mode result, so
        routed variants share one cached ideal evaluation."""
        d = {"workers": self.workers, "temporal": self.temporal,
             "capacity": self.capacity,
             "tile": list(self.tile) if self.tile else None}
        if not ideal:
            d["fabric"] = list(self.fabric) if self.fabric else None
            d["place_seed"] = self.place_seed
            d["place_restarts"] = self.place_restarts
        return d

    def key(self, scope: dict, *, ideal: bool = False) -> str:
        """Canonical hash of (scope, config) — the eval-cache key.  ``scope``
        carries the target + machine signature."""
        blob = json.dumps({"scope": scope,
                           "config": self.canonical(ideal=ideal)},
                          sort_keys=True, separators=(",", ":"))
        return hashlib.sha1(blob.encode()).hexdigest()

    def with_fabric(self, fabric: tuple[int, int, str], seed: int,
                    restarts: int = 1) -> "MappingConfig":
        return dataclasses.replace(self, fabric=fabric, place_seed=seed,
                                   place_restarts=restarts)


@dataclasses.dataclass(frozen=True)
class SpaceOptions:
    """What the lattice enumerates.  ``workers=None`` derives candidates from
    the machine (1 .. min(physical fit, roofline demand + slack))."""
    workers: tuple[int, ...] | None = None
    temporal: tuple[int, ...] = (1,)
    capacities: tuple = ("auto",)
    tiles: tuple = (None,)                 # None = full grid, or block shapes
    fabrics: tuple[tuple[int, int, str], ...] = ()
    place_seeds: tuple[int, ...] = (0,)
    place_restarts: int = 1
    worker_slack: int = 2                  # workers kept above the BW demand
    max_workers: int = 16


# ---------------------------------------------------------------------------
# targets
# ---------------------------------------------------------------------------
def _digest(obj) -> str:
    return hashlib.sha1(
        json.dumps(obj, sort_keys=True, separators=(",", ":"),
                   default=str).encode()).hexdigest()[:16]


class SpecTarget:
    """A single-op stencil workload: advance ``workload_timesteps`` sweeps of
    ``spec`` (configs trade how many of them fuse into one pass)."""

    kind = "spec"

    def __init__(self, spec: StencilSpec, *, workload_timesteps: int = 1,
                 name: str | None = None):
        if spec.timesteps != 1:
            raise ValueError(
                "pass the single-sweep spec; fusion is the tuner's 'temporal'"
                " knob (workload_timesteps carries the sweep count)")
        if workload_timesteps < 1:
            raise ValueError("workload_timesteps must be >= 1")
        self.spec = spec
        self.workload_timesteps = workload_timesteps
        self.name = name or (f"stencil{spec.ndim}d_"
                             f"{'x'.join(map(str, spec.grid_shape))}")

    def signature(self) -> dict:
        return {"kind": self.kind, "grid": list(self.spec.grid_shape),
                "radii": list(self.spec.radii), "dtype": self.spec.dtype,
                "coeffs": _digest(self.spec.coeffs),
                "workload_timesteps": self.workload_timesteps}

    def sim_spec(self, cfg: MappingConfig) -> StencilSpec:
        """The spec one simulate() call maps: fused ``temporal`` steps over
        the tile (or full) grid."""
        spec = self.spec
        if cfg.temporal != spec.timesteps:
            spec = dataclasses.replace(spec, timesteps=cfg.temporal)
        if cfg.tile is not None:
            spec = dataclasses.replace(spec, grid_shape=tuple(cfg.tile))
        return spec

    def repeats(self, cfg: MappingConfig) -> int:
        """How many simulate() results one workload costs: #sweep passes
        (``workload_timesteps / temporal``) x #blocks (tiled sweeps run the
        blocks back to back; the estimate ignores inter-block pipeline
        overlap, so it is conservative)."""
        passes = self.workload_timesteps // cfg.temporal
        if cfg.tile is None:
            return passes
        shrink = tuple(2 * r * cfg.temporal for r in self.spec.radii)
        out_tile = tuple(t - s for t, s in zip(cfg.tile, shrink))
        full_out = tuple(n - s for n, s in zip(self.spec.grid_shape, shrink))
        blocks = math.prod(-(-f // o) for f, o in zip(full_out, out_tile))
        return passes * blocks

    def build(self, cfg: MappingConfig):
        spec = self.sim_spec(cfg)
        qcap = cfg.capacity if isinstance(cfg.capacity, int) else None
        return map_nd(spec, cfg.workers, queue_capacity=qcap,
                      auto_capacity=cfg.capacity == "auto")

    def make_input(self, plan) -> np.ndarray:
        return np.random.default_rng(0).normal(size=plan.spec.grid_shape)

    def verify(self, plan, cfg: MappingConfig, x: np.ndarray, res) -> None:
        """Cross-check the simulated numerics against the jnp-free oracle
        (the tile/temporal geometry is baked into ``sim_spec``, so the
        reference applies verbatim)."""
        from repro.core.reference import stencil_reference_np
        ref = stencil_reference_np(np.asarray(x), self.sim_spec(cfg))
        np.testing.assert_allclose(res.output, ref, atol=1e-9)

    def inner_extent(self, cfg: MappingConfig) -> int:
        grid = cfg.tile if cfg.tile is not None else self.spec.grid_shape
        return grid[-1]

    def ndim(self) -> int:
        return self.spec.ndim

    def mac_demand(self, cfg: MappingConfig) -> int:
        """MAC-class PEs the mapped plan will occupy (w chains per layer)."""
        return cfg.workers * cfg.temporal * self.spec.macs_per_worker

    def roofline_spec(self) -> StencilSpec:
        return self.spec


class ProgramTarget:
    """A multi-operator stencil program DAG, lowered into one fused pipeline
    (``repro.program.lower``).  Temporal layering and tiling are per-op
    properties of the program itself, so those knobs stay at 1/None."""

    kind = "program"

    def __init__(self, program, *, name: str | None = None):
        self.program = program
        self.workload_timesteps = 1
        self.name = name or program.name

    def signature(self) -> dict:
        ops = []
        for op in self.program.schedule():
            spec = getattr(op, "spec", None)
            ops.append({
                "name": op.name, "out": op.output,
                "in": list(op.inputs),
                "spec": None if spec is None else {
                    "radii": list(spec.radii), "timesteps": spec.timesteps,
                    "coeffs": _digest(spec.coeffs)},
            })
        return {"kind": self.kind, "name": self.program.name,
                "grid": list(self.program.grid_shape),
                "dtype": self.program.dtype, "ops": ops}

    def repeats(self, cfg: MappingConfig) -> int:
        return 1

    def build(self, cfg: MappingConfig):
        from repro.program import lower
        qcap = cfg.capacity if isinstance(cfg.capacity, int) else None
        return lower(self.program, workers=cfg.workers, queue_capacity=qcap,
                     auto_capacity=cfg.capacity == "auto")

    def make_input(self, plan) -> np.ndarray:
        rng = np.random.default_rng(0)
        return plan.pack_inputs({f: rng.normal(size=self.program.grid_shape)
                                 for f in plan.in_fields})

    def verify(self, plan, cfg: MappingConfig, x: np.ndarray, res) -> None:
        from repro.program import program_reference_np
        rng = np.random.default_rng(0)
        inputs = {f: rng.normal(size=self.program.grid_shape)
                  for f in plan.in_fields}
        ref = program_reference_np(self.program, inputs)
        fields = plan.unpack_outputs(res.output)
        for f in plan.out_fields:
            np.testing.assert_allclose(fields[f], ref[f], atol=1e-9)

    def inner_extent(self, cfg: MappingConfig) -> int:
        return self.program.grid_shape[-1]

    def ndim(self) -> int:
        return len(self.program.grid_shape)

    def mac_demand(self, cfg: MappingConfig) -> int:
        total = 0
        for op in self.program.schedule():
            spec = getattr(op, "spec", None)
            mpw = spec.macs_per_worker * spec.timesteps if spec else 1
            total += cfg.workers * mpw
        return total

    def roofline_spec(self) -> StencilSpec:
        """Representative spec for worker selection: the op with the deepest
        MAC chain dominates the physical-fit cap."""
        specs = [op.spec for op in self.program.schedule()
                 if getattr(op, "spec", None) is not None]
        if not specs:
            raise ValueError(f"program {self.program.name!r} has no "
                             f"stencil ops to size workers from")
        return max(specs, key=lambda s: s.macs_per_worker)


def as_target(target, *, workload_timesteps: int = 1):
    """Coerce a StencilSpec / StencilProgram / ready-made target."""
    if isinstance(target, StencilSpec):
        return SpecTarget(target, workload_timesteps=workload_timesteps)
    if hasattr(target, "schedule") and hasattr(target, "grid_shape"):
        return ProgramTarget(target)
    if hasattr(target, "build") and hasattr(target, "signature"):
        return target
    raise TypeError(f"cannot make an exploration target from {target!r}")


# ---------------------------------------------------------------------------
# enumeration
# ---------------------------------------------------------------------------
def analytic_config(target, machine: Machine) -> MappingConfig:
    """The paper's analytical §VI choice, made feasible: ``select_workers``'
    count clamped to the largest worker count that divides the innermost
    extent (rank >= 2 column ownership) and leaves every worker an output.
    This config is always seeded into the search space, so the measured
    best can only match or beat it."""
    spec = target.roofline_spec()
    need = workers_demanded(spec, machine)
    fit = worker_fit(spec, machine)
    cfg = MappingConfig(workers=max(1, min(need, fit)))
    while cfg.workers > 1 and not feasible_workers(target, cfg):
        cfg = dataclasses.replace(cfg, workers=cfg.workers - 1)
    return cfg


def feasible_workers(target, cfg: MappingConfig) -> bool:
    """Static mapper feasibility: divisibility + at least one output per
    worker (mirrors the ``map_nd`` constructor checks without building)."""
    w = cfg.workers
    if w < 1:
        return False
    inner = target.inner_extent(cfg)
    if target.ndim() >= 2 and inner % w:
        return False
    if target.kind == "spec":
        spec = target.spec
        interior = inner - 2 * spec.radii[-1] * cfg.temporal
        if w > interior:
            return False
    else:
        # programs accumulate margins op by op; the lowering itself checks
        # exactly — here only the cheap global bound
        if w > inner:
            return False
    return True


def derive_worker_candidates(target, machine: Machine,
                             options: SpaceOptions) -> tuple[int, ...]:
    """1..min(fit, demand+slack, max_workers), the roofline-informed ladder."""
    spec = target.roofline_spec()
    hi = min(worker_fit(spec, machine) if machine.num_macs else
             options.max_workers,
             workers_demanded(spec, machine) + options.worker_slack,
             options.max_workers)
    return tuple(range(1, max(1, hi) + 1))


def enumerate_space(target, machine: Machine, options: SpaceOptions
                    ) -> tuple[list[MappingConfig], MappingConfig]:
    """The ideal-mode lattice (fabric applied later, to finalists only) plus
    the always-included analytical seed config."""
    workers = (options.workers if options.workers is not None
               else derive_worker_candidates(target, machine, options))
    temporal = options.temporal
    if target.kind != "spec":
        temporal = (1,)
    tiles = options.tiles if target.kind == "spec" else (None,)
    configs = []
    seen = set()
    for w, t, cap, tile in itertools.product(
            workers, temporal, options.capacities, tiles):
        cfg = MappingConfig(workers=w, temporal=t, capacity=cap,
                            tile=tuple(tile) if tile else None)
        k = (w, t, cap, cfg.tile)
        if k not in seen:
            seen.add(k)
            configs.append(cfg)
    analytic = analytic_config(target, machine)
    if not any(c.workers == analytic.workers and c.temporal == 1
               and c.capacity == analytic.capacity and c.tile is None
               for c in configs):
        configs.insert(0, analytic)
    return configs, analytic


def tile_candidates(spec: StencilSpec, storage_budgets_bytes,
                    lane_multiple: int = 128) -> tuple:
    """Distinct ``plan_blocks`` block shapes for a ladder of storage budgets
    (the tiling axis of the lattice); budgets below the minimal working set
    are skipped, full-grid blocks collapse to ``None``."""
    out, seen = [], set()
    for b in storage_budgets_bytes:
        try:
            bp = plan_blocks(spec, b, lane_multiple=lane_multiple)
        except ValueError:
            continue
        tile = None if bp.block_shape == spec.grid_shape else bp.block_shape
        if tile not in seen:
            seen.add(tile)
            out.append(tile)
    return tuple(out) or (None,)
