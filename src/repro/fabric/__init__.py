"""Physical fabric subsystem: PE-grid topology, placement, routing, export.

Pipeline (docs/fabric.md):

    plan  = map_1d(spec, workers=w)                  # logical DFG (core)
    topo  = FabricTopology.mesh(16, 16)              # physical PE grid
    pl    = place(plan, topo, seed=0)                # DFG node -> PE
    rf    = route(pl)                                # edge -> XY circuit
    res   = simulate(plan, x, CGRA, fabric=rf)       # network-aware timing
"""
from repro.fabric.topology import (Coord, FabricTopology, Link, LinkKey, PE,
                                   op_class)
from repro.fabric.place import Placement, PlacementError, edge_traffic, place
from repro.fabric.route import (EdgeKey, RoutedFabric, RouteError,
                                apply_routed_capacities, edge_key, route,
                                xy_route)
from repro.fabric.config import placed_assembly, placed_dot, route_string

__all__ = ["Coord", "FabricTopology", "Link", "LinkKey", "PE", "op_class",
           "Placement", "PlacementError", "edge_traffic", "place",
           "EdgeKey", "RoutedFabric", "RouteError", "apply_routed_capacities",
           "edge_key", "route", "xy_route", "placed_assembly", "placed_dot",
           "route_string"]
