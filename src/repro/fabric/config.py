"""Per-PE configuration export for a placed-and-routed mapping.

Physical twins of the logical emitters in ``core.dfg`` (paper §V):

* :func:`placed_assembly` — ``DFG.to_assembly()`` extended with each
  instruction's physical PE coordinate and each queue's route, written as a
  compass-direction string (``E E N``), i.e. the switch settings a bitstream
  generator would consume.
* :func:`placed_dot` — ``DFG.to_dot()`` with nodes pinned at their grid
  coordinates (``pos="col,row!"``, neato-compatible) and colored by stage,
  so the physical layout renders as the fabric floorplan.
"""
from __future__ import annotations

from repro.core.dfg import _DOT_COLORS
from repro.fabric.route import RoutedFabric
from repro.fabric.topology import FabricTopology, LinkKey


def _direction(lk: LinkKey, topo: FabricTopology) -> str:
    (r1, c1), (r2, c2) = lk
    dr, dc = r2 - r1, c2 - c1
    # wrap-form deltas (e.g. dc == 1-cols for an eastward wrap) only exist on
    # a torus; on a mesh they would collide with the opposite direction when
    # cols == 2 or rows == 2.
    if dc == 1 or (topo.torus and dc == 1 - topo.cols):
        return "E"
    if dc == -1 or (topo.torus and dc == topo.cols - 1):
        return "W"
    if dr == 1 or (topo.torus and dr == 1 - topo.rows):
        return "S"
    return "N"


def route_string(rf: RoutedFabric, links: tuple[LinkKey, ...]) -> str:
    return " ".join(_direction(lk, rf.topo) for lk in links) or "local"


def placed_assembly(rf: RoutedFabric) -> str:
    """One line per instruction with its PE coordinate and routed queues."""
    pl = rf.placement
    g = pl.plan.dfg
    out = [f"; {g.name} on {pl.topo!r}",
           f"; placement seed={pl.seed} weighted_hops={pl.weighted_hops()}"]
    for n in g.nodes:
        r, c = pl.coords[n.nid]
        srcs = ",".join(f"n{e.src.nid}.out" for e in n.in_edges) or "-"
        for line in [f"PE({r:>2},{c:>2}) n{n.nid:<4} {n.op:<7} "
                     f"stage={n.stage}/{n.worker} src=[{srcs}]"]:
            out.append(line)
        for e in n.out_edges:
            links = rf.route_for(e)
            dst_r, dst_c = pl.coords[e.dst.nid]
            out.append(f"    -> n{e.dst.nid}.p{e.dst_port} @({dst_r},{dst_c}) "
                       f"hops={len(links)} route=[{route_string(rf, links)}]")
    return "\n".join(out)


def placed_dot(rf: RoutedFabric) -> str:
    """Graphviz dot with physical positions (render with ``neato -n``)."""
    pl = rf.placement
    g = pl.plan.dfg
    scale = 1.2
    lines = [f'digraph "{g.name}_placed" {{',
             "  layout=neato;", "  node [style=filled, shape=box];"]
    # offset co-resident instructions slightly so they stay visible
    seen: dict[tuple[int, int], int] = {}
    for n in g.nodes:
        r, c = pl.coords[n.nid]
        k = seen.get((r, c), 0)
        seen[(r, c)] = k + 1
        x = c * scale + 0.25 * (k % 2)
        y = -r * scale - 0.25 * (k // 2)
        color = _DOT_COLORS.get(n.op, "white")
        lines.append(
            f'  n{n.nid} [label="{n.name}\\n({r},{c})", '
            f'fillcolor="{color}", pos="{x:.2f},{y:.2f}!"];')
    for e in g.edges():
        hops = rf.hops(e)
        attr = "" if hops == 0 else f' [label="{hops}h"]'
        lines.append(f"  n{e.src.nid} -> n{e.dst.nid}{attr};")
    lines.append("}")
    return "\n".join(lines)
