"""Placement: logical DFG nodes -> physical PEs (stage/worker-aware).

Two phases, both deterministic under a fixed seed:

1. **Greedy seed** — nodes are laid out worker-pipeline by worker-pipeline
   (reader → compute → writer → sync per worker) along a snake scan of the
   grid, so each worker's MUL→MAC chain starts out physically contiguous.
   Memory ops (load/store) are snapped to the nearest mem-capable PE (the
   fabric boundary, where the memory ports are).

2. **Simulated annealing** — random single-node moves and pair swaps,
   accepted by Metropolis on the *weighted hop count*
   ``sum_e traffic(e) * hops(e)``, where ``traffic`` is the analytic number
   of tokens each queue carries (reader streams, filter keep-counts, writer
   stores — all known statically from the MappingPlan).

The weighted hop count is exactly the quantity the network-aware simulator
pays for, so annealing directly minimizes routed latency and link pressure.
"""
from __future__ import annotations

import dataclasses
import math
import random

from repro.core.dfg import DFG, Edge, Node
from repro.core.mapping import MappingPlan
from repro.fabric.topology import Coord, FabricTopology, op_class


class PlacementError(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# analytic per-edge traffic (tokens pushed over the edge during one run)
# ---------------------------------------------------------------------------
def _node_tokens(n: Node, memo: dict[int, int]) -> int:
    if n.nid in memo:
        return memo[n.nid]
    memo[n.nid] = 1  # cycle guard (DFGs are acyclic; belt and braces)
    op = n.op
    if op == "addr":
        t = n.params["count"]
    elif op == "load":
        t = _node_tokens(n.in_edges[0].src, memo) if n.in_edges else 1
    elif op == "filter":
        t = n.params.get("keep_count", n.params.get("n", 1))
    elif op == "store":
        t = len(n.params.get("indices", ())) or 1
    elif op == "sync":
        t = 1
    elif op == "cmp":
        t = 0
    elif op == "imux":  # re-interleave: forwards every popped input token
        t = (sum(_node_tokens(e.src, memo) for e in n.in_edges)
             if n.in_edges else 1)
    else:  # mul/mac/add/mux/demux/copy: fire once per complete input set
        t = (min(_node_tokens(e.src, memo) for e in n.in_edges)
             if n.in_edges else 1)
    memo[n.nid] = t
    return t


def edge_traffic(g: DFG) -> dict[int, int]:
    """edge id -> analytic token count (the annealing weight)."""
    memo: dict[int, int] = {}
    return {id(e): _node_tokens(e.src, memo) for e in g.edges()}


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Placement:
    topo: FabricTopology
    plan: MappingPlan
    coords: dict[int, Coord]            # nid -> PE coordinate
    seed: int
    traffic: dict[int, int]             # edge id -> tokens

    def hops(self, e: Edge) -> int:
        return self.topo.distance(self.coords[e.src.nid],
                                  self.coords[e.dst.nid])

    def weighted_hops(self) -> int:
        return sum(self.traffic[id(e)] * self.hops(e)
                   for e in self.plan.dfg.edges())

    def pes_used(self) -> int:
        return len(set(self.coords.values()))

    def utilization(self) -> float:
        """Fraction of physical PEs holding at least one instruction."""
        return self.pes_used() / len(self.topo.pes)


def _stage_rank(n: Node) -> int:
    return {"reader": 0, "compute": 1, "writer": 2, "sync": 3}.get(n.stage, 4)


def _seed_key(n: Node) -> tuple:
    """Greedy-seed order: subgraph by subgraph (program graphs tag each
    operator's nodes with ``subgraph=<topo index>`` so every op's chains stay
    physically contiguous instead of interleaving by worker id), then worker
    pipeline by worker pipeline, and *within* a compute worker one axis
    tap-chain at a time (rank-3 workers carry three chains plus an ADD tree;
    interleaving them would scatter each MUL→MAC string across the fabric
    before annealing starts).  Temporal layers are kept together the same
    way.  Single-op plans carry no ``subgraph`` tag — their order is
    unchanged."""
    return (n.params.get("subgraph", 0), n.worker, _stage_rank(n),
            n.params.get("layer", 0), -n.params.get("axis", -1), n.nid)


def _snake(topo: FabricTopology) -> list[Coord]:
    out = []
    for r in range(topo.rows):
        cols = range(topo.cols) if r % 2 == 0 else range(topo.cols - 1, -1, -1)
        out.extend((r, c) for c in cols)
    return out


def place(plan: MappingPlan, topo: FabricTopology, *, seed: int = 0,
          anneal_iters: int | None = None, restarts: int = 1) -> Placement:
    """Place every DFG node on a capability-compatible PE slot.

    ``restarts > 1`` runs the whole greedy-seed + annealing pipeline under
    seeds ``seed, seed+1, …`` and keeps the placement with the lowest
    weighted hop count — the restartable form the mapping auto-tuner
    (``repro.explore``) uses to spend extra placement budget on finalists.
    Deterministic for a fixed ``(seed, restarts)``; ``restarts=1`` is
    bit-identical to the previous single-shot behaviour."""
    if restarts < 1:
        raise ValueError("restarts must be >= 1")
    if restarts > 1:
        best = None
        for s in range(seed, seed + restarts):
            cand = place(plan, topo, seed=s, anneal_iters=anneal_iters)
            if best is None or cand.weighted_hops() < best.weighted_hops():
                best = cand
        return best
    g = plan.dfg
    nodes = sorted(g.nodes, key=_seed_key)
    if len(nodes) > topo.total_slots():
        raise PlacementError(
            f"{len(nodes)} instructions exceed {topo.total_slots()} PE slots "
            f"on {topo!r}")
    # per-capability-class budgets: deep multi-chain workers (3D, fused
    # layers) are alu/util-heavy, so check every class, not just mem.
    demand: dict[str, int] = {}
    for n in nodes:
        cls = op_class(n.op)
        demand[cls] = demand.get(cls, 0) + 1
    for cls, need in demand.items():
        have = topo.total_slots(cls)
        if need > have:
            where = " (fabric boundary)" if cls == "mem" else ""
            raise PlacementError(
                f"{need} {cls!r} ops exceed {have} {cls}-capable slots"
                f"{where}")

    # --- phase 1: greedy snake-order seed -----------------------------------
    order = _snake(topo)
    free = {c: topo.pes[c].slots for c in order}
    coords: dict[int, Coord] = {}
    cursor = 0
    for n in nodes:
        if op_class(n.op) == "mem":
            # snap to nearest mem-capable PE with a free slot
            anchor = order[cursor % len(order)]
            best = min(
                (c for c in order if free[c] > 0 and topo.capable(c, n.op)),
                key=lambda c: (topo.distance(anchor, c), c))
            coords[n.nid] = best
            free[best] -= 1
            continue
        while free[order[cursor % len(order)]] <= 0:
            cursor += 1
        c = order[cursor % len(order)]
        coords[n.nid] = c
        free[c] -= 1

    traffic = edge_traffic(g)
    pl = Placement(topo, plan, coords, seed, traffic)

    # --- phase 2: simulated annealing on weighted hop count -----------------
    rng = random.Random(seed)
    iters = (anneal_iters if anneal_iters is not None
             else min(30_000, 60 * len(nodes)))
    if iters <= 0:
        return pl

    # incident edge lists for O(degree) delta evaluation
    incident: dict[int, list[Edge]] = {n.nid: [] for n in g.nodes}
    for e in g.edges():
        incident[e.src.nid].append(e)
        if e.dst.nid != e.src.nid:
            incident[e.dst.nid].append(e)

    def node_cost(nid: int) -> int:
        return sum(traffic[id(e)] * topo.distance(coords[e.src.nid],
                                                  coords[e.dst.nid])
                   for e in incident[nid])

    all_coords = list(order)
    by_nid = {n.nid: n for n in g.nodes}
    residents: dict[Coord, list[int]] = {c: [] for c in order}
    for nid, c in coords.items():
        residents[c].append(nid)
    movable = [n.nid for n in nodes if incident[n.nid]]
    mean_w = (sum(traffic.values()) / max(1, len(traffic)))
    t0, t1 = 4.0 * mean_w, 0.02 * mean_w + 1e-9
    cooling = (t1 / t0) ** (1.0 / iters)
    temp = t0
    for _ in range(iters):
        temp *= cooling
        nid = movable[rng.randrange(len(movable))]
        tgt = all_coords[rng.randrange(len(all_coords))]
        src_c = coords[nid]
        if tgt == src_c or not topo.capable(tgt, by_nid[nid].op):
            continue
        if free[tgt] > 0:                      # move into a free slot
            before = node_cost(nid)
            coords[nid] = tgt
            delta = node_cost(nid) - before
            if delta <= 0 or rng.random() < math.exp(-delta / temp):
                free[tgt] -= 1
                free[src_c] += 1
                residents[src_c].remove(nid)
                residents[tgt].append(nid)
            else:
                coords[nid] = src_c
        else:                                  # swap with a resident node
            here = [m for m in residents[tgt]
                    if topo.capable(src_c, by_nid[m].op)]
            if not here:
                continue
            mid = here[rng.randrange(len(here))]
            before = node_cost(nid) + node_cost(mid)
            coords[nid], coords[mid] = tgt, src_c
            delta = node_cost(nid) + node_cost(mid) - before
            if delta <= 0 or rng.random() < math.exp(-delta / temp):
                residents[src_c].remove(nid)
                residents[tgt].append(nid)
                residents[tgt].remove(mid)
                residents[src_c].append(mid)
            else:
                coords[nid], coords[mid] = src_c, tgt

    # invariant check: capabilities + slot budgets survived annealing
    occ: dict[Coord, int] = {}
    for n in g.nodes:
        c = coords[n.nid]
        occ[c] = occ.get(c, 0) + 1
        if not topo.capable(c, n.op):
            raise PlacementError(f"node {n.name} ({n.op}) on incapable PE {c}")
    for c, k in occ.items():
        if k > topo.pes[c].slots:
            raise PlacementError(f"PE {c} over capacity: {k} instructions")
    return pl
