"""Dimension-ordered XY routing with per-link channel accounting.

Every DFG edge whose endpoints sit on different PEs becomes a static route:
column-first (X), then row (Y) — deadlock-free dimension-ordered routing; on
a torus each axis takes the shorter wrap direction.

Fan-out is **multicast**: the XY routes from one producer to its consumers
always share link prefixes, and their union is a tree, so all edges of one
producer occupy a single channel (routing track) on every shared link and a
broadcast token crosses each tree link once — exactly the paper's
load-once/forward-neighbor-to-neighbor claim, and the BandMap model of
circuit-switched CGRA interconnect allocation.  When any link's tree count
exceeds its channel budget, :func:`route` fails loudly with the hot-spot
list — a mapping that does not route is not a mapping.
"""
from __future__ import annotations

import dataclasses

from repro.core.dfg import Edge
from repro.fabric.place import Placement
from repro.fabric.topology import Coord, FabricTopology, LinkKey

EdgeKey = tuple[int, int, int]          # (src nid, dst nid, dst port)


def edge_key(e: Edge) -> EdgeKey:
    return (e.src.nid, e.dst.nid, e.dst_port)


class RouteError(RuntimeError):
    pass


def _axis_steps(a: int, b: int, n: int, torus: bool) -> list[int]:
    """Positions visited walking one axis from a to b (excluding a)."""
    if a == b:
        return []
    fwd = (b - a) % n
    bwd = (a - b) % n
    if torus and bwd < fwd:
        step, dist = -1, bwd
    elif torus:
        step, dist = 1, fwd
    else:
        step, dist = (1 if b > a else -1), abs(b - a)
    out, cur = [], a
    for _ in range(dist):
        cur = (cur + step) % n if torus else cur + step
        out.append(cur)
    return out


def xy_route(topo: FabricTopology, src: Coord, dst: Coord) -> list[LinkKey]:
    """Directed link sequence of the X-then-Y dimension-ordered route."""
    links: list[LinkKey] = []
    cur = src
    for c in _axis_steps(src[1], dst[1], topo.cols, topo.torus):   # X first
        nxt = (cur[0], c)
        links.append((cur, nxt))
        cur = nxt
    for r in _axis_steps(src[0], dst[0], topo.rows, topo.torus):   # then Y
        nxt = (r, cur[1])
        links.append((cur, nxt))
        cur = nxt
    assert cur == dst
    return links


@dataclasses.dataclass
class RoutedFabric:
    """A fully placed-and-routed configuration, ready to simulate."""
    placement: Placement
    routes: dict[EdgeKey, tuple[LinkKey, ...]]
    channel_load: dict[LinkKey, int]       # multicast trees per link
    traffic_load: dict[LinkKey, int]       # token-traffic per link

    @property
    def topo(self) -> FabricTopology:
        return self.placement.topo

    def route_for(self, e: Edge) -> tuple[LinkKey, ...]:
        return self.routes[edge_key(e)]

    def hops(self, e: Edge) -> int:
        return len(self.routes[edge_key(e)])

    def link_index(self) -> dict[LinkKey, int]:
        """Dense link ids (topology iteration order) for engines that keep
        per-link bandwidth state in flat arrays instead of dict probes
        (``repro.core.engine.compile.compile_network``)."""
        return {lk: i for i, lk in enumerate(self.topo.links)}

    def words_per_cycle(self) -> list[int]:
        """Per-link dynamic bandwidth, aligned with :meth:`link_index`."""
        return [l.words_per_cycle for l in self.topo.links.values()]

    def link_names(self) -> list[str]:
        """Human-readable ``(r,c)->(r,c)`` labels aligned with
        :meth:`link_index` — the one naming scheme shared by :meth:`stats`
        hotspots and the telemetry link tracks (``repro.telemetry``), so a
        link in a Perfetto trace is findable in the routing report."""
        return [f"{a}->{b}" for a, b in self.topo.links]

    # ----- congestion / utilization reporting -------------------------------
    def hotspots(self, k: int = 5) -> list[tuple[LinkKey, int, int]]:
        """Top-k links by channel load: (link, trees, token traffic)."""
        ranked = sorted(self.channel_load,
                        key=lambda l: (-self.channel_load[l],
                                       -self.traffic_load.get(l, 0), l))
        return [(l, self.channel_load[l], self.traffic_load.get(l, 0))
                for l in ranked[:k]]

    def stats(self) -> dict:
        hops = [len(r) for r in self.routes.values()]
        routed = [h for h in hops if h > 0]
        topo = self.topo
        max_load = max(self.channel_load.values(), default=0)
        return {
            "pes_used": self.placement.pes_used(),
            "pe_utilization": round(self.placement.utilization(), 4),
            "edges": len(self.routes),
            "edges_routed": len(routed),
            "edges_local": len(hops) - len(routed),
            "hops_mean": round(sum(hops) / max(1, len(hops)), 3),
            "hops_max": max(hops, default=0),
            "weighted_hops": self.placement.weighted_hops(),
            "links_used": len(self.channel_load),
            "link_utilization": round(
                len(self.channel_load) / max(1, len(topo.links)), 4),
            "max_channel_load": max_load,
            "channel_capacity": (min(l.channels for l in topo.links.values())
                                 if topo.links else 0),
            "hotspots": [
                {"link": f"{a}->{b}", "trees": c, "traffic": t}
                for (a, b), c, t in self.hotspots()],
        }


def route(placement: Placement, *, strict: bool = True) -> RoutedFabric:
    """Route every DFG edge; ``strict`` fails when channel demand exceeds any
    link's budget (set False to get the overloaded result for inspection)."""
    topo = placement.topo
    routes: dict[EdgeKey, tuple[LinkKey, ...]] = {}
    channel_load: dict[LinkKey, int] = {}
    traffic_load: dict[LinkKey, int] = {}
    for n in placement.plan.dfg.nodes:
        if not n.out_edges:
            continue
        src = placement.coords[n.nid]
        tree: set[LinkKey] = set()         # union of this producer's routes
        for e in n.out_edges:
            dst = placement.coords[e.dst.nid]
            links = tuple(xy_route(topo, src, dst))
            routes[edge_key(e)] = links
            tree.update(links)
        # one channel + one token-copy per tree link (multicast)
        w = max((placement.traffic.get(id(e), 1) for e in n.out_edges),
                default=1)
        for lk in tree:
            assert lk in topo.links, f"route uses non-existent link {lk}"
            channel_load[lk] = channel_load.get(lk, 0) + 1
            traffic_load[lk] = traffic_load.get(lk, 0) + w
    rf = RoutedFabric(placement, routes, channel_load, traffic_load)
    if strict:
        over = [(lk, n) for lk, n in channel_load.items()
                if n > topo.links[lk].channels]
        if over:
            over.sort(key=lambda x: -x[1])
            msg = ", ".join(f"{a}->{b}: {n}/{topo.links[(a, b)].channels}"
                            for (a, b), n in over[:5])
            raise RouteError(
                f"{len(over)} link(s) over channel capacity (demand/budget): "
                f"{msg}. Use a larger fabric, more channels/link, or a "
                f"different placement seed.")
    return rf


def apply_routed_capacities(rf: RoutedFabric, *, slack: int = 1) -> int:
    """Grow every bounded edge's queue capacity by its routed hop depth.

    The ideal-mode minima (``MappingPlan.min_capacities``) assume a token is
    consumable the cycle after it is produced.  On the routed fabric a token
    spends ``hops`` extra cycles in per-link transit buffers, and the routed
    engines count in-flight transit words against the edge's capacity — so an
    edge sized to the ideal minimum back-pressures (or deadlocks a mux cycle)
    purely because its route is long.  This rewrites each bounded edge to::

        capacity += hops(edge) + slack

    leaving unbounded edges (``capacity=None``) alone, and returns the number
    of edges grown.  The mutation is recorded (``DFG.mark_mutated``) so the
    compiled-engine plan cache re-specializes instead of reusing a stale
    ring presize.  The tuner applies this automatically for routed
    evaluations when ``SearchConfig.capacity == "auto"``.
    """
    g = rf.placement.plan.dfg
    grown = 0
    for e in g.edges():
        if e.capacity is None:
            continue
        hops = len(rf.routes.get(edge_key(e), ()))
        if hops:
            e.capacity += hops + slack
            grown += 1
    if grown:
        g.mark_mutated()
    return grown
