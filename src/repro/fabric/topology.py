"""Physical PE-grid fabric model (paper §II hardware).

The paper's CGRA is a 2D array of processing elements joined by an on-chip
network; loaded values travel PE-to-PE instead of through shared memory.
``FabricTopology`` is the parametric description of that hardware the rest of
the ``fabric`` subsystem maps onto:

* an R×C grid of PEs with per-PE *op-class* capabilities and a small number
  of instruction ``slots`` (real CGRAs time-multiplex a few static
  instructions per PE);
* 4-neighbour directed links, either **mesh** (no wraparound) or **torus**
  (wraparound), each with a static routing-track budget (``channels`` —
  BandMap-style circuit-switched allocation) and a dynamic bandwidth
  (``words_per_cycle`` — contended during network-aware simulation).

Memory ports live on the fabric boundary by default: only boundary PEs carry
the ``mem`` capability, so loads/stores must be placed where the memory
controllers are — the physical constraint that makes placement non-trivial.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

Coord = tuple[int, int]                 # (row, col)
LinkKey = tuple[Coord, Coord]           # directed (src PE, dst PE)

# op -> op-class; placement only matches classes, not individual ops.
OP_CLASS = {
    "load": "mem", "store": "mem",
    "mul": "alu", "mac": "alu", "add": "alu",
    # everything else (filter/addr/sync/mux/demux/copy/cmp) is light-weight
    # control/routing logic any PE implements.
}


def op_class(op: str) -> str:
    return OP_CLASS.get(op, "util")


@dataclasses.dataclass(frozen=True)
class PE:
    row: int
    col: int
    capabilities: frozenset[str]        # subset of {"mem", "alu", "util"}
    slots: int                          # static instructions this PE can hold

    @property
    def coord(self) -> Coord:
        return (self.row, self.col)


@dataclasses.dataclass(frozen=True)
class Link:
    src: Coord
    dst: Coord
    channels: int                       # static routing tracks (route-time)
    words_per_cycle: int                # dynamic bandwidth (sim-time)

    @property
    def key(self) -> LinkKey:
        return (self.src, self.dst)


class FabricTopology:
    """R×C PE grid with 4-neighbour links (mesh or torus)."""

    def __init__(self, rows: int, cols: int, *, torus: bool = False,
                 slots: int = 4, channels: int = 32, words_per_cycle: int = 1,
                 mem_boundary_only: bool = True):
        if rows < 2 or cols < 2:
            raise ValueError("fabric needs at least a 2x2 grid")
        self.rows = rows
        self.cols = cols
        self.torus = torus
        self.pes: dict[Coord, PE] = {}
        for r in range(rows):
            for c in range(cols):
                caps = {"alu", "util"}
                boundary = r in (0, rows - 1) or c in (0, cols - 1)
                if boundary or not mem_boundary_only:
                    caps.add("mem")
                self.pes[(r, c)] = PE(r, c, frozenset(caps), slots)
        self.links: dict[LinkKey, Link] = {}
        for (r, c) in self.pes:
            for dr, dc in ((0, 1), (0, -1), (1, 0), (-1, 0)):
                nr, nc = r + dr, c + dc
                if torus:
                    nr, nc = nr % rows, nc % cols
                elif not (0 <= nr < rows and 0 <= nc < cols):
                    continue
                self.links[((r, c), (nr, nc))] = Link(
                    (r, c), (nr, nc), channels, words_per_cycle)

    # ----- constructors ------------------------------------------------------
    @classmethod
    def mesh(cls, rows: int, cols: int, **kw) -> "FabricTopology":
        return cls(rows, cols, torus=False, **kw)

    @classmethod
    def torus_grid(cls, rows: int, cols: int, **kw) -> "FabricTopology":
        return cls(rows, cols, torus=True, **kw)

    # ----- geometry ----------------------------------------------------------
    def coords(self) -> Iterator[Coord]:
        return iter(self.pes)

    def capable(self, coord: Coord, op: str) -> bool:
        return op_class(op) in self.pes[coord].capabilities

    def _axis_dist(self, a: int, b: int, n: int) -> int:
        d = abs(a - b)
        return min(d, n - d) if self.torus else d

    def distance(self, a: Coord, b: Coord) -> int:
        """Hop count of the minimal (XY) route between two PEs."""
        return (self._axis_dist(a[0], b[0], self.rows)
                + self._axis_dist(a[1], b[1], self.cols))

    def total_slots(self, cls_name: str | None = None) -> int:
        if cls_name is None:
            return sum(p.slots for p in self.pes.values())
        return sum(p.slots for p in self.pes.values()
                   if cls_name in p.capabilities)

    def __repr__(self) -> str:
        kind = "torus" if self.torus else "mesh"
        return (f"FabricTopology({self.rows}x{self.cols} {kind}, "
                f"{len(self.links)} links, {self.total_slots()} slots)")
