"""TPU Pallas kernels for the paper's compute hot-spots.

Each subpackage: ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(public jit'd wrapper with padding/planning/backend dispatch), ``ref.py``
(pure-jnp oracle used by the tests' allclose sweeps).
"""
from repro.kernels.conv1d.ops import causal_conv1d
from repro.kernels.stencil1d.ops import stencil1d, stencil1d_from_spec
from repro.kernels.stencil2d.ops import stencil2d, stencil2d_from_spec
from repro.kernels.stencil3d.ops import stencil3d
from repro.kernels.swa.ops import sliding_window_attention

__all__ = ["causal_conv1d", "stencil1d", "stencil1d_from_spec", "stencil2d",
           "stencil2d_from_spec", "stencil3d", "sliding_window_attention"]
