"""JAX version-compat shims shared by the Pallas kernels.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and back
again across release lines); every ``kernel.py`` builds its compiler params
through :func:`tpu_compiler_params` so the rename never breaks a kernel.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CP_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def tpu_compiler_params(**kwargs):
    """Construct TPU compiler params under either pltpu spelling."""
    return _CP_CLS(**kwargs)
