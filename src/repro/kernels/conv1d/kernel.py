"""Pallas TPU kernel: depthwise causal conv1d (one-sided sequence stencil).

Same halo-view mapping as kernels/stencil1d, specialized:
  * taps are *learned per-channel* weights — passed as an operand (the paper's
    "constant input" to each MAC PE becomes a VMEM-resident (K, C) tile);
  * one-sided (causal) halo: only the previous sequence block is viewed;
  * channel axis rides the 128-lane dimension, sequence the sublane dimension
    — each loaded (bs, bc) tile is reused by all K taps from VMEM.

Grid: (B, num_seq_blocks, num_channel_blocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import tpu_compiler_params


def _body(prev, cur, wref, o, *, kk, block_s, out_dtype):
    si = pl.program_id(1)
    halo = kk - 1
    acc_dtype = jnp.float32
    ext = jnp.concatenate([prev[0, -halo:, :], cur[0, :, :]], 0).astype(acc_dtype)
    # causal zero-fill: positions before the sequence start
    pos = si * block_s - halo + jax.lax.broadcasted_iota(
        jnp.int32, (block_s + halo, 1), 0)
    ext = jnp.where(pos >= 0, ext, 0)
    acc = jnp.zeros((block_s, ext.shape[1]), acc_dtype)
    for k in range(kk):
        acc = acc + ext[k:k + block_s, :] * wref[k, :][None, :].astype(acc_dtype)
    o[0, :, :] = acc.astype(out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_s", "block_c", "interpret"))
def conv1d_pallas(x: jax.Array, w: jax.Array, *, block_s: int = 256,
                  block_c: int = 128, interpret: bool = False) -> jax.Array:
    """x: (B, S, C); w: (K, C). S % block_s == 0, C % block_c == 0,
    K - 1 <= block_s (ops.py pads)."""
    b, s, c = x.shape
    kk = w.shape[0]
    assert s % block_s == 0 and c % block_c == 0 and kk - 1 <= block_s
    ns, nc = s // block_s, c // block_c

    xspec_prev = pl.BlockSpec(
        (1, block_s, block_c),
        lambda i, si, ci: (i, jnp.maximum(si - 1, 0), ci))
    xspec_cur = pl.BlockSpec((1, block_s, block_c),
                             lambda i, si, ci: (i, si, ci))
    wspec = pl.BlockSpec((kk, block_c), lambda i, si, ci: (0, ci))
    body = functools.partial(_body, kk=kk, block_s=block_s, out_dtype=x.dtype)
    return pl.pallas_call(
        body, grid=(b, ns, nc),
        in_specs=[xspec_prev, xspec_cur, wspec],
        out_specs=pl.BlockSpec((1, block_s, block_c),
                               lambda i, si, ci: (i, si, ci)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret)(x, x, w)
