"""Public entry point for depthwise causal conv1d."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.conv1d.kernel import conv1d_pallas
from repro.kernels.conv1d.ref import conv1d_ref


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array | None = None, *,
                  backend: str = "auto", block_s: int = 256,
                  block_c: int = 128) -> jax.Array:
    """x: (B, S, C); w: (K, C); optional bias (C,)."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend == "xla":
        return conv1d_ref(x, w, b)

    interpret = jax.default_backend() != "tpu"
    bs, s, c = x.shape
    kk = w.shape[0]
    block_s = max(block_s, kk - 1)
    ps = (-s) % block_s
    pc = (-c) % block_c
    xp = jnp.pad(x, ((0, 0), (0, ps), (0, pc)))
    wp = jnp.pad(w, ((0, 0), (0, pc)))
    y = conv1d_pallas(xp, wp, block_s=block_s, block_c=block_c,
                      interpret=interpret)[:, :s, :c]
    if b is not None:
        y = (y.astype(jnp.float32) + b[None, None, :].astype(jnp.float32)
             ).astype(x.dtype)
    return y
