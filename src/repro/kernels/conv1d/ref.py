"""Pure-jnp oracle for depthwise *causal* 1D convolution.

``y[b, s, c] = sum_k w[k, c] * x[b, s - K + 1 + k, c]``  (left zero padding),
optionally + bias.  This is a radius-(K-1) one-sided sequence stencil with
learned per-channel taps — the temporal-conv block of Griffin/RG-LRU and the
Whisper conv stem use exactly this shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def conv1d_ref(x: jax.Array, w: jax.Array,
               b: jax.Array | None = None) -> jax.Array:
    """x: (B, S, C); w: (K, C); b: (C,) or None."""
    kk = w.shape[0]
    acc_dtype = jnp.float32
    out = jnp.zeros(x.shape, acc_dtype)
    for k in range(kk):
        shift = kk - 1 - k          # tap k reads x[s - shift]
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1], :]
        out = out + xs.astype(acc_dtype) * w[k][None, None, :].astype(acc_dtype)
    if b is not None:
        out = out + b[None, None, :].astype(acc_dtype)
    return out.astype(x.dtype)
