"""Pallas TPU kernel for the batched 1D star stencil (paper §III-A on TPU).

CGRA→TPU mapping (DESIGN.md §3):
  * a Pallas *program instance* (one grid cell) = one worker team: it owns an
    output tile of ``(block_b, block_n)`` points;
  * the reader workers' load-once/reuse-2r-times discipline = the halo-view
    trick: the input row is DMA'd into VMEM once per tile (plus two
    neighbour-tile views) and every one of the 2r+1 taps reads it from VMEM;
  * the MUL→MAC chain = an unrolled shift–FMA ladder on the VPU;
  * the data-filtering PEs (0^m 1^n 0^p) = position masks from
    ``broadcasted_iota`` — same predicate, vectorized;
  * §IV temporal pipelining = ``timesteps`` fused sweeps in VMEM with the halo
    widened to ``r * timesteps`` (trapezoid tiling).

Two compute formulations:
  * ``_stencil_vpu_body``  — shift-FMA ladder (tap-parallel on lanes); flops =
    2*(2r+1) per point; VPU-bound.
  * ``_stencil_mxu_body``  — beyond-paper: out = ext @ W_band, a banded-matrix
    matmul that trades ~(block_n+2rT)/(2r+1)x redundant flops for MXU
    throughput; wins once the fused stencil turns compute-bound (see
    EXPERIMENTS.md §Perf).

Grid requirements (enforced by ops.py): N % block_n == 0, B % block_b == 0,
r * timesteps <= block_n.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.compat import tpu_compiler_params


def _ext_positions(j, block_n: int, halo: int):
    return j * block_n - halo + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_n + 2 * halo), 1)


def _masked_ext(prev, cur, nxt, j, *, block_n: int, halo: int, n: int,
                acc_dtype):
    """Assemble the haloed VMEM workspace; zero positions outside [0, n)
    (this also erases the garbage the clamped edge views bring in)."""
    ext = jnp.concatenate(
        [prev[:, -halo:], cur[:, :], nxt[:, :halo]], axis=1).astype(acc_dtype)
    pos = _ext_positions(j, block_n, halo)
    return jnp.where((pos >= 0) & (pos < n), ext, 0)


def _sweep_ladder(ext, coeffs: tuple[float, ...], out_w: int, acc_dtype):
    """One stencil sweep: shift-FMA ladder over the taps (the MAC chain)."""
    r = (len(coeffs) - 1) // 2
    acc = jnp.zeros((ext.shape[0], out_w), acc_dtype)
    for k, c in enumerate(coeffs):
        if c == 0.0:
            continue
        acc = acc + jnp.asarray(c, acc_dtype) * ext[:, k:k + out_w]
    return acc


def _vpu_body(prev, cur, nxt, o, *, coeffs, timesteps, block_n, n, out_dtype):
    j = pl.program_id(1)
    r = (len(coeffs) - 1) // 2
    halo = r * timesteps
    acc_dtype = jnp.float32
    ext = _masked_ext(prev, cur, nxt, j, block_n=block_n, halo=halo, n=n,
                      acc_dtype=acc_dtype)
    w = block_n + 2 * halo
    for _ in range(timesteps):
        w -= 2 * r
        ext = _sweep_ladder(ext, coeffs, w, acc_dtype)
    opos = j * block_n + jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
    valid = (opos >= halo) & (opos < n - halo)
    o[:, :] = jnp.where(valid, ext, 0).astype(out_dtype)


def _mxu_body(prev, cur, nxt, band, o, *, timesteps, radius, block_n, n,
              out_dtype):
    """out = ext @ W_band (one banded matmul per fused sweep)."""
    j = pl.program_id(1)
    halo = radius * timesteps
    ext = _masked_ext(prev, cur, nxt, j, block_n=block_n, halo=halo, n=n,
                      acc_dtype=jnp.float32)
    w = block_n + 2 * halo
    off = 0
    for _ in range(timesteps):
        w -= 2 * radius
        # band operand holds the largest needed banded matrix; slice per sweep.
        ext = jax.lax.dot_general(
            ext, band[off:off + w + 2 * radius, :w],
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        off = 0  # band rows always indexed from 0: widths only shrink
    opos = j * block_n + jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
    valid = (opos >= halo) & (opos < n - halo)
    o[:, :] = jnp.where(valid, ext, 0).astype(out_dtype)


def make_band(coeffs: tuple[float, ...], in_w: int, out_w: int) -> np.ndarray:
    """Banded matrix W with W[i + k, i] = coeffs[k]: ext(in_w) @ W -> (out_w)."""
    r = (len(coeffs) - 1) // 2
    assert in_w >= out_w + 2 * r
    band = np.zeros((in_w, out_w), np.float32)
    for k, c in enumerate(coeffs):
        for i in range(out_w):
            band[i + k, i] = c
    return band


@functools.partial(
    jax.jit,
    static_argnames=("coeffs", "timesteps", "block_b", "block_n", "variant",
                     "interpret"))
def stencil1d_pallas(x: jax.Array, coeffs: tuple[float, ...], *,
                     timesteps: int = 1, block_b: int = 8,
                     block_n: int = 512, variant: str = "vpu",
                     interpret: bool = False) -> jax.Array:
    """x: (B, N) -> (B, N). Requires B % block_b == 0, N % block_n == 0,
    radius * timesteps <= block_n (ops.py pads to satisfy these)."""
    b, n = x.shape
    r = (len(coeffs) - 1) // 2
    halo = r * timesteps
    if b % block_b or n % block_n:
        raise ValueError(f"shape {x.shape} not divisible by block "
                         f"({block_b},{block_n}); pad in ops.py")
    if halo > block_n:
        raise ValueError(f"halo {halo} exceeds block_n {block_n}")
    nb, nn = b // block_b, n // block_n

    views = [
        pl.BlockSpec((block_b, block_n), lambda i, j: (i, jnp.maximum(j - 1, 0))),
        pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        pl.BlockSpec((block_b, block_n),
                     lambda i, j, _nn=nn: (i, jnp.minimum(j + 1, _nn - 1))),
    ]
    out_spec = pl.BlockSpec((block_b, block_n), lambda i, j: (i, j))
    out_shape = jax.ShapeDtypeStruct((b, n), x.dtype)
    params = tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))

    if variant == "vpu":
        body = functools.partial(
            _vpu_body, coeffs=coeffs, timesteps=timesteps, block_n=block_n,
            n=n, out_dtype=x.dtype)
        return pl.pallas_call(
            body, grid=(nb, nn), in_specs=views, out_specs=out_spec,
            out_shape=out_shape, compiler_params=params,
            interpret=interpret)(x, x, x)
    elif variant == "mxu":
        band = jnp.asarray(make_band(coeffs, block_n + 2 * halo,
                                     block_n + 2 * halo - 2 * r))
        band_spec = pl.BlockSpec(band.shape, lambda i, j: (0, 0))
        body = functools.partial(
            _mxu_body, timesteps=timesteps, radius=r, block_n=block_n, n=n,
            out_dtype=x.dtype)
        return pl.pallas_call(
            body, grid=(nb, nn), in_specs=views + [band_spec],
            out_specs=out_spec, out_shape=out_shape, compiler_params=params,
            interpret=interpret)(x, x, x, band)
    raise ValueError(f"unknown variant {variant!r}")
