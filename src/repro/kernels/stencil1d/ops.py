"""Public entry point for the 1D stencil: planning, padding, backend dispatch.

``stencil1d(x, coeffs)`` accepts any (..., N) array:
  * flattens leading dims to a batch,
  * pads batch/length to the planned block multiples (zero padding is
    harmless: the kernel's position masks ignore out-of-range columns, and
    padded batch rows are sliced away),
  * dispatches to the Pallas kernel (TPU, or ``interpret=True`` elsewhere) or
    the pure-jnp reference (``backend="xla"``), which is also what the LM
    models use under jit on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.spec import StencilSpec
from repro.kernels.stencil1d.kernel import stencil1d_pallas
from repro.kernels.stencil1d.ref import stencil1d_ref

VMEM_BUDGET_BYTES = 8 * 1024 * 1024  # conservative half of v5e VMEM


def plan_1d_blocks(n: int, batch: int, radius: int, timesteps: int,
                   bytes_per_elem: int = 4,
                   vmem_budget: int = VMEM_BUDGET_BYTES) -> tuple[int, int]:
    """Pick (block_b, block_n): lane-aligned block_n as large as fits."""
    halo = radius * timesteps
    block_b = 8 if batch >= 8 else max(1, batch)
    block_n = 128
    while block_n < min(n, 4096):
        cand = block_n * 2
        ws = block_b * (3 * cand + 2 * (cand + 2 * halo)) * bytes_per_elem
        if ws > vmem_budget:
            break
        block_n = cand
    block_n = max(block_n, _next_multiple(halo, 128))
    return block_b, block_n


def _next_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def stencil1d(x: jax.Array, coeffs: tuple[float, ...], *,
              timesteps: int = 1, backend: str = "auto",
              variant: str = "vpu",
              block: tuple[int, int] | None = None) -> jax.Array:
    """Batched 1D star stencil along the last axis. See ref.py for semantics."""
    coeffs = tuple(float(c) for c in coeffs)
    r = (len(coeffs) - 1) // 2
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend == "xla":
        return stencil1d_ref(x, coeffs, timesteps=timesteps)

    interpret = jax.default_backend() != "tpu"
    lead = x.shape[:-1]
    n = x.shape[-1]
    xb = x.reshape((-1, n))
    batch = xb.shape[0]
    if block is None:
        block = plan_1d_blocks(n, batch, r, timesteps)
    bb, bn = block
    pb = _next_multiple(batch, bb) - batch
    pn = _next_multiple(n, bn) - n
    xp = jnp.pad(xb, ((0, pb), (0, pn)))
    # padded tail columns are masked via the n-argument = true length
    out = _dispatch(xp, coeffs, timesteps, bb, bn, variant, interpret, n)
    return out[:batch, :n].reshape(*lead, n)


@functools.partial(jax.jit,
                   static_argnames=("coeffs", "timesteps", "bb", "bn",
                                    "variant", "interpret", "true_n"))
def _dispatch(xp, coeffs, timesteps, bb, bn, variant, interpret, true_n):
    # The kernel masks by padded length; re-mask by the true length so padded
    # columns cannot contribute (they're zero anyway) and outputs beyond
    # true_n - halo are dropped.
    y = stencil1d_pallas(xp, coeffs, timesteps=timesteps, block_b=bb,
                         block_n=bn, variant=variant, interpret=interpret)
    r = (len(coeffs) - 1) // 2
    halo = r * timesteps
    idx = jnp.arange(xp.shape[-1])
    valid = (idx >= halo) & (idx < true_n - halo)
    return jnp.where(valid, y, 0).astype(y.dtype)


def stencil1d_from_spec(x: jax.Array, spec: StencilSpec, **kw) -> jax.Array:
    assert spec.ndim == 1
    return stencil1d(x, spec.coeffs[0], timesteps=spec.timesteps, **kw)
