"""Pure-jnp oracle for the batched 1D star stencil.

Semantics: ``out[b, i] = sum_k coeffs[k] * x[b, i - r + k]`` for positions with
full support after ``timesteps`` fused sweeps; everything else is zero (the
paper's boundary-drop discipline).  Matches ``repro.core.reference`` for
batch=1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("coeffs", "timesteps"))
def stencil1d_ref(x: jax.Array, coeffs: tuple[float, ...],
                  timesteps: int = 1) -> jax.Array:
    """x: (..., N) -> (..., N); stencil along the last axis."""
    r = (len(coeffs) - 1) // 2
    n = x.shape[-1]
    out = x
    acc_dtype = jnp.float32 if x.dtype in (jnp.bfloat16, jnp.float16) else x.dtype
    for t in range(1, timesteps + 1):
        o = jnp.zeros(out.shape, acc_dtype)
        for k, c in enumerate(coeffs):
            off = k - r
            if c == 0.0:
                continue
            shifted = _shift_last(out.astype(acc_dtype), off)
            o = o + jnp.asarray(c, acc_dtype) * shifted
        idx = jnp.arange(n)
        valid = (idx >= r * t) & (idx < n - r * t)
        out = jnp.where(valid, o, 0.0).astype(x.dtype)
    return out


def _shift_last(x: jax.Array, off: int) -> jax.Array:
    if off == 0:
        return x
    n = x.shape[-1]
    pad = [(0, 0)] * (x.ndim - 1)
    if off > 0:
        return jnp.pad(x, pad + [(0, off)])[..., off:off + n]
    return jnp.pad(x, pad + [(-off, 0)])[..., :n]
