"""Pallas TPU kernel for the batched 2D star stencil (paper §III-B on TPU).

CGRA→TPU mapping:
  * the paper's **mandatory buffering** (2·ry rows live on-fabric while the x
    sweep streams) = the row-halo views held in VMEM for the life of a tile;
  * **strip-mining/blocking** (§III-B "Blocking") = the (block_y, block_x)
    BlockSpec tiling chosen by ops.plan_2d_blocks under the VMEM budget;
  * x-chains and y-chains = two unrolled shift-FMA ladders sharing one VMEM
    workspace (each input element is read from HBM once per tile and feeds up
    to 2rx+2ry+1 taps — the paper's reuse bound);
  * §IV temporal fusion: T sweeps in VMEM, halo = r·T per face.  Fused star
    sweeps have diamond-shaped composite support, so the workspace is
    assembled from all 9 neighbour tiles (corners included); for T=1 the
    corner contribution is masked-zero dead weight (see §Perf for the 5-view
    variant trade-off).

Grid: (batch, nby, nbx); batch blocks are size 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import tpu_compiler_params


def _sweep2d(ext, cy, cx, out_h, out_w, acc_dtype):
    ry = (len(cy) - 1) // 2
    rx = (len(cx) - 1) // 2
    acc = jnp.zeros((ext.shape[0], out_h, out_w), acc_dtype)
    for a, c in enumerate(cy):
        if c != 0.0:
            acc = acc + jnp.asarray(c, acc_dtype) * ext[:, a:a + out_h, rx:rx + out_w]
    for b, c in enumerate(cx):
        if c != 0.0:
            acc = acc + jnp.asarray(c, acc_dtype) * ext[:, ry:ry + out_h, b:b + out_w]
    return acc


def _body(tl, tc, tr, ml, mc, mr, bl, bc, br, o, *, cy, cx, timesteps,
          block_y, block_x, ny, nx, out_dtype):
    jy = pl.program_id(1)
    jx = pl.program_id(2)
    ry = (len(cy) - 1) // 2
    rx = (len(cx) - 1) // 2
    hy, hx = ry * timesteps, rx * timesteps
    acc_dtype = jnp.float32

    top = jnp.concatenate([tl[:, -hy:, -hx:], tc[:, -hy:, :], tr[:, -hy:, :hx]], 2)
    mid = jnp.concatenate([ml[:, :, -hx:], mc[:, :, :], mr[:, :, :hx]], 2)
    bot = jnp.concatenate([bl[:, :hy, -hx:], bc[:, :hy, :], br[:, :hy, :hx]], 2)
    ext = jnp.concatenate([top, mid, bot], 1).astype(acc_dtype)

    rr = (jy * block_y - hy
          + jax.lax.broadcasted_iota(jnp.int32, (1, block_y + 2 * hy, 1), 1))
    cc = (jx * block_x - hx
          + jax.lax.broadcasted_iota(jnp.int32, (1, 1, block_x + 2 * hx), 2))
    ext = jnp.where((rr >= 0) & (rr < ny) & (cc >= 0) & (cc < nx), ext, 0)

    h, w = block_y + 2 * hy, block_x + 2 * hx
    for _ in range(timesteps):
        h -= 2 * ry
        w -= 2 * rx
        ext = _sweep2d(ext, cy, cx, h, w, acc_dtype)

    orr = jy * block_y + jax.lax.broadcasted_iota(jnp.int32, (1, block_y, 1), 1)
    occ = jx * block_x + jax.lax.broadcasted_iota(jnp.int32, (1, 1, block_x), 2)
    valid = ((orr >= hy) & (orr < ny - hy) & (occ >= hx) & (occ < nx - hx))
    o[:, :, :] = jnp.where(valid, ext, 0).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("cy", "cx", "timesteps", "block_y", "block_x",
                     "interpret"))
def stencil2d_pallas(x: jax.Array, cy: tuple[float, ...],
                     cx: tuple[float, ...], *, timesteps: int = 1,
                     block_y: int = 128, block_x: int = 256,
                     interpret: bool = False) -> jax.Array:
    """x: (B, ny, nx) -> (B, ny, nx). ny % block_y == 0, nx % block_x == 0,
    ry*T <= block_y, rx*T <= block_x (ops.py pads)."""
    b, ny, nx = x.shape
    ry = (len(cy) - 1) // 2
    rx = (len(cx) - 1) // 2
    if ny % block_y or nx % block_x:
        raise ValueError(f"grid {(ny, nx)} not divisible by block "
                         f"({block_y},{block_x})")
    if ry * timesteps > block_y or rx * timesteps > block_x:
        raise ValueError("halo exceeds block")
    nby, nbx = ny // block_y, nx // block_x

    def vspec(dy, dx):
        def imap(i, jy, jx):
            return (i, jnp.clip(jy + dy, 0, nby - 1), jnp.clip(jx + dx, 0, nbx - 1))
        return pl.BlockSpec((1, block_y, block_x), imap)

    views = [vspec(dy, dx) for dy in (-1, 0, 1) for dx in (-1, 0, 1)]
    body = functools.partial(
        _body, cy=cy, cx=cx, timesteps=timesteps, block_y=block_y,
        block_x=block_x, ny=ny, nx=nx, out_dtype=x.dtype)
    return pl.pallas_call(
        body, grid=(b, nby, nbx), in_specs=views,
        out_specs=pl.BlockSpec((1, block_y, block_x), lambda i, jy, jx: (i, jy, jx)),
        out_shape=jax.ShapeDtypeStruct((b, ny, nx), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret)(*([x] * 9))
