"""Public entry point for the 2D stencil: planning, padding, backend dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.spec import StencilSpec
from repro.kernels.stencil2d.kernel import stencil2d_pallas
from repro.kernels.stencil2d.ref import stencil2d_ref

VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def plan_2d_blocks(ny: int, nx: int, ry: int, rx: int, timesteps: int,
                   bytes_per_elem: int = 4,
                   vmem_budget: int = VMEM_BUDGET_BYTES) -> tuple[int, int]:
    """(block_y, block_x): x stays lane-aligned (128), y in sublane units (8).
    Working set = 9 input tiles + ext workspace + out tile."""
    hy, hx = ry * timesteps, rx * timesteps
    by = max(8, _next_multiple(hy, 8))
    bx = max(128, _next_multiple(hx, 128))

    def ws(by_, bx_):
        ext = (by_ + 2 * hy) * (bx_ + 2 * hx)
        return (9 * by_ * bx_ + 2 * ext + by_ * bx_) * bytes_per_elem

    progress = True
    while progress:
        progress = False
        if by < min(ny, 512) and ws(by * 2, bx) <= vmem_budget:
            by *= 2
            progress = True
        if bx < min(nx, 1024) and ws(by, bx * 2) <= vmem_budget:
            bx *= 2
            progress = True
    return by, bx


def _next_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def stencil2d(x: jax.Array, cy: tuple[float, ...], cx: tuple[float, ...], *,
              timesteps: int = 1, backend: str = "auto",
              block: tuple[int, int] | None = None) -> jax.Array:
    """Batched 2D star stencil over the last two axes (y=-2, x=-1)."""
    cy = tuple(float(c) for c in cy)
    cx = tuple(float(c) for c in cx)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend == "xla":
        return stencil2d_ref(x, cy, cx, timesteps=timesteps)

    interpret = jax.default_backend() != "tpu"
    ry, rx = (len(cy) - 1) // 2, (len(cx) - 1) // 2
    lead = x.shape[:-2]
    ny, nx = x.shape[-2:]
    xb = x.reshape((-1, ny, nx))
    if block is None:
        block = plan_2d_blocks(ny, nx, ry, rx, timesteps)
    by, bx = block
    py = _next_multiple(ny, by) - ny
    px = _next_multiple(nx, bx) - nx
    xp = jnp.pad(xb, ((0, 0), (0, py), (0, px)))
    out = _dispatch(xp, cy, cx, timesteps, by, bx, interpret, ny, nx)
    return out[:, :ny, :nx].reshape(*lead, ny, nx)


@functools.partial(jax.jit,
                   static_argnames=("cy", "cx", "timesteps", "by", "bx",
                                    "interpret", "tny", "tnx"))
def _dispatch(xp, cy, cx, timesteps, by, bx, interpret, tny, tnx):
    y = stencil2d_pallas(xp, cy, cx, timesteps=timesteps, block_y=by,
                         block_x=bx, interpret=interpret)
    ry, rx = (len(cy) - 1) // 2, (len(cx) - 1) // 2
    hy, hx = ry * timesteps, rx * timesteps
    jj = jnp.arange(xp.shape[-2])[:, None]
    ii = jnp.arange(xp.shape[-1])[None, :]
    valid = (jj >= hy) & (jj < tny - hy) & (ii >= hx) & (ii < tnx - hx)
    return jnp.where(valid, y, 0).astype(y.dtype)


def stencil2d_from_spec(x: jax.Array, spec: StencilSpec, **kw) -> jax.Array:
    assert spec.ndim == 2
    return stencil2d(x, spec.coeffs[0], spec.coeffs[1],
                     timesteps=spec.timesteps, **kw)
