"""Pure-jnp oracle for the batched 2D star stencil.

``out[..., j, i] = sum_a cy[a] * x[..., j-ry+a, i] + sum_b cx[b] * x[..., j, i-rx+b]``
on fully-supported positions after ``timesteps`` fused sweeps; zero elsewhere.
Axis convention follows the paper: axis -2 = y (rows, ``j``), axis -1 = x
(cols, ``i``).  cy carries the (single) centre coefficient; cx's centre entry
is normally zero (see core.spec).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("cy", "cx", "timesteps"))
def stencil2d_ref(x: jax.Array, cy: tuple[float, ...], cx: tuple[float, ...],
                  timesteps: int = 1) -> jax.Array:
    ry = (len(cy) - 1) // 2
    rx = (len(cx) - 1) // 2
    ny, nx = x.shape[-2], x.shape[-1]
    acc_dtype = jnp.float32 if x.dtype in (jnp.bfloat16, jnp.float16) else x.dtype
    out = x
    for t in range(1, timesteps + 1):
        xo = out.astype(acc_dtype)
        o = jnp.zeros(out.shape, acc_dtype)
        for a, c in enumerate(cy):
            if c != 0.0:
                o = o + jnp.asarray(c, acc_dtype) * _shift(xo, a - ry, -2)
        for b, c in enumerate(cx):
            if c != 0.0:
                o = o + jnp.asarray(c, acc_dtype) * _shift(xo, b - rx, -1)
        jj = jnp.arange(ny)[:, None]
        ii = jnp.arange(nx)[None, :]
        valid = ((jj >= ry * t) & (jj < ny - ry * t) &
                 (ii >= rx * t) & (ii < nx - rx * t))
        out = jnp.where(valid, o, 0.0).astype(x.dtype)
    return out


def _shift(x: jax.Array, off: int, axis: int) -> jax.Array:
    if off == 0:
        return x
    n = x.shape[axis]
    axis = axis % x.ndim
    pad = [(0, 0)] * x.ndim
    sl = [slice(None)] * x.ndim
    if off > 0:
        pad[axis] = (0, off)
        sl[axis] = slice(off, off + n)
    else:
        pad[axis] = (-off, 0)
        sl[axis] = slice(0, n)
    return jnp.pad(x, pad)[tuple(sl)]
