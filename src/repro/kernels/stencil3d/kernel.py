"""Pallas TPU kernel: 3D star stencil, single sweep per call.

Star stencils at T=1 only need *face* neighbours, so the VMEM workspace is
assembled from 7 views (centre ± one block per axis) instead of the 27-view
full halo — the 3D generalization of the paper's line-buffer discipline:
a (bz + 2rz, by + 2ry, bx + 2rx) *cross-shaped* region is resident per tile
and every input element loaded from HBM feeds up to 2(rz+ry+rx)+1 taps.

Fused T>1 needs corner halos (diamond composite support); ops.py runs T
separate sweeps instead and documents the HBM-roundtrip trade (the §IV
fusion analysis in core/temporal still applies to the CGRA/1D/2D paths).

Grid: (batch, nbz, nby, nbx) with batch blocks of 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import tpu_compiler_params


def _body(c, zm, zp, ym, yp, xm, xp, o, *, cz, cy, cx, bz, by, bx,
          nz, ny, nx, out_dtype):
    jz, jy, jx = pl.program_id(1), pl.program_id(2), pl.program_id(3)
    rz, ry, rx = ((len(cc) - 1) // 2 for cc in (cz, cy, cx))
    f32 = jnp.float32
    ctr = c[0].astype(f32)                           # (bz, by, bx)

    def gpos(j, b, n, axis, extent, halo):
        base = j * b - halo
        io = jax.lax.broadcasted_iota(jnp.int32, extent, axis)
        return base + io

    acc = jnp.zeros((bz, by, bx), f32)
    # z-axis taps: band (bz + 2rz, by, bx) from zm/c/zp
    zext = jnp.concatenate([zm[0, -rz:].astype(f32), ctr,
                            zp[0, :rz].astype(f32)], 0)
    zpos = gpos(jz, bz, nz, 0, (bz + 2 * rz, 1, 1), rz)
    zext = jnp.where((zpos >= 0) & (zpos < nz), zext, 0)
    for k, cc in enumerate(cz):
        if cc != 0.0:
            acc = acc + cc * zext[k:k + bz]
    # y-axis taps
    yext = jnp.concatenate([ym[0, :, -ry:].astype(f32), ctr,
                            yp[0, :, :ry].astype(f32)], 1)
    ypos = gpos(jy, by, ny, 1, (1, by + 2 * ry, 1), ry)
    yext = jnp.where((ypos >= 0) & (ypos < ny), yext, 0)
    for k, cc in enumerate(cy):
        if cc != 0.0:
            acc = acc + cc * yext[:, k:k + by]
    # x-axis taps
    xext = jnp.concatenate([xm[0, :, :, -rx:].astype(f32), ctr,
                            xp[0, :, :, :rx].astype(f32)], 2)
    xpos = gpos(jx, bx, nx, 2, (1, 1, bx + 2 * rx), rx)
    xext = jnp.where((xpos >= 0) & (xpos < nx), xext, 0)
    for k, cc in enumerate(cx):
        if cc != 0.0:
            acc = acc + cc * xext[:, :, k:k + bx]

    oz = gpos(jz, bz, nz, 0, (bz, 1, 1), 0)
    oy = gpos(jy, by, ny, 1, (1, by, 1), 0)
    ox = gpos(jx, bx, nx, 2, (1, 1, bx), 0)
    valid = ((oz >= rz) & (oz < nz - rz) & (oy >= ry) & (oy < ny - ry) &
             (ox >= rx) & (ox < nx - rx))
    o[0] = jnp.where(valid, acc, 0).astype(out_dtype)


@functools.partial(
    jax.jit, static_argnames=("cz", "cy", "cx", "block", "interpret"))
def stencil3d_pallas(x: jax.Array, cz: tuple[float, ...],
                     cy: tuple[float, ...], cx: tuple[float, ...], *,
                     block: tuple[int, int, int] = (8, 16, 128),
                     interpret: bool = False) -> jax.Array:
    """x: (B, nz, ny, nx) -> same shape; one star sweep."""
    b, nz, ny, nx = x.shape
    bz, by, bx = block
    assert nz % bz == 0 and ny % by == 0 and nx % bx == 0
    rz, ry, rx = ((len(c) - 1) // 2 for c in (cz, cy, cx))
    assert rz <= bz and ry <= by and rx <= bx
    nbz, nby, nbx = nz // bz, ny // by, nx // bx

    def vspec(dz, dy, dx):
        def imap(i, jz, jy, jx):
            return (i, jnp.clip(jz + dz, 0, nbz - 1),
                    jnp.clip(jy + dy, 0, nby - 1),
                    jnp.clip(jx + dx, 0, nbx - 1))
        return pl.BlockSpec((1, bz, by, bx), imap)

    views = [vspec(0, 0, 0), vspec(-1, 0, 0), vspec(1, 0, 0),
             vspec(0, -1, 0), vspec(0, 1, 0), vspec(0, 0, -1),
             vspec(0, 0, 1)]
    body = functools.partial(_body, cz=cz, cy=cy, cx=cx, bz=bz, by=by, bx=bx,
                             nz=nz, ny=ny, nx=nx, out_dtype=x.dtype)
    return pl.pallas_call(
        body, grid=(b, nbz, nby, nbx), in_specs=views,
        out_specs=pl.BlockSpec((1, bz, by, bx),
                               lambda i, jz, jy, jx: (i, jz, jy, jx)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary",
                                 "arbitrary")),
        interpret=interpret)(*([x] * 7))
