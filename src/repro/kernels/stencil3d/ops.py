"""Public entry point for the 3D stencil.

``timesteps > 1`` runs T separate sweeps (each a Pallas call): fused-T 3D
star sweeps have diamond composite support and would need all 26 corner
views; the HBM round trip between sweeps is the documented trade (the CGRA/
1D/2D paths fuse in-fabric per §IV).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.stencil3d.kernel import stencil3d_pallas
from repro.kernels.stencil3d.ref import stencil3d_ref

# default per-core VMEM budget for auto-blocking (v5e has 128 MiB; leave
# headroom for the 7 halo views + double buffering the kernel allocates).
_VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _next_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _auto_block(shape: tuple[int, int, int], cz, cy, cx,
                dtype: str, budget: int) -> tuple[int, int, int]:
    """Pick (bz, by, bx) with the CGRA strip-mining planner (§III-B): the
    same ``plan_blocks`` that sizes scratchpad strips sizes VMEM tiles."""
    from repro.core.mapping import plan_blocks
    from repro.core.spec import StencilSpec
    spec = StencilSpec(shape, tuple((len(c) - 1) // 2 for c in (cz, cy, cx)),
                       (tuple(cz), tuple(cy), tuple(cx)), dtype=dtype)
    bz, by, bx = plan_blocks(spec, budget, lane_multiple=128).block_shape
    # TPU sublane tiling holds by construction: plan_blocks seeds the y axis
    # at min(ny, 8) and only grows it in +8 steps.
    assert by == shape[1] or by % 8 == 0
    return (bz, by, bx)


def stencil3d(x: jax.Array, cz, cy, cx, *, timesteps: int = 1,
              backend: str = "auto",
              block: tuple[int, int, int] | None = (8, 16, 128),
              vmem_budget_bytes: int = _VMEM_BUDGET_BYTES) -> jax.Array:
    """Batched 3D star stencil over the last three axes (z, y, x).

    ``block=None`` derives the tile from :func:`repro.core.mapping.plan_blocks`
    under ``vmem_budget_bytes`` instead of using a fixed shape.
    """
    cz = tuple(float(c) for c in cz)
    cy = tuple(float(c) for c in cy)
    cx = tuple(float(c) for c in cx)
    if block is None:
        block = _auto_block(x.shape[-3:], cz, cy, cx, str(x.dtype),
                            vmem_budget_bytes)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend == "xla":
        return stencil3d_ref(x, cz, cy, cx, timesteps=timesteps)

    interpret = jax.default_backend() != "tpu"
    lead = x.shape[:-3]
    nz, ny, nx = x.shape[-3:]
    xb = x.reshape((-1, nz, ny, nx))
    bz, by, bx = block
    pz, py, px = (_next_multiple(nz, bz) - nz, _next_multiple(ny, by) - ny,
                  _next_multiple(nx, bx) - nx)
    xp = jnp.pad(xb, ((0, 0), (0, pz), (0, py), (0, px)))
    rz, ry, rx = ((len(c) - 1) // 2 for c in (cz, cy, cx))
    zz = jnp.arange(xp.shape[-3])[:, None, None]
    yy = jnp.arange(xp.shape[-2])[None, :, None]
    xx = jnp.arange(xp.shape[-1])[None, None, :]
    out = xp
    for t in range(1, timesteps + 1):
        out = stencil3d_pallas(out, cz, cy, cx, block=block,
                               interpret=interpret)
        valid = ((zz >= rz * t) & (zz < nz - rz * t) &
                 (yy >= ry * t) & (yy < ny - ry * t) &
                 (xx >= rx * t) & (xx < nx - rx * t))
        out = jnp.where(valid, out, 0).astype(out.dtype)
    return out[:, :nz, :ny, :nx].reshape(*lead, nz, ny, nx)
