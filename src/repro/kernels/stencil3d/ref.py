"""Pure-jnp oracle for the batched 3D star stencil (paper §VII's comparison
workload; §III-B: "This design can be extended to 3D as well").

``out[..., z, y, x] = sum_a cz[a]·in[z-rz+a, y, x] + sum_b cy[b]·in[z, y-ry+b, x]
                      + sum_c cx[c]·in[z, y, x-rx+c]``
on fully-supported positions after ``timesteps`` fused sweeps; zero rim.
cz carries the centre coefficient; cy/cx centres are normally zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("cz", "cy", "cx", "timesteps"))
def stencil3d_ref(x: jax.Array, cz: tuple[float, ...], cy: tuple[float, ...],
                  cx: tuple[float, ...], timesteps: int = 1) -> jax.Array:
    rz, ry, rx = ((len(c) - 1) // 2 for c in (cz, cy, cx))
    nz, ny, nx = x.shape[-3], x.shape[-2], x.shape[-1]
    acc_dtype = jnp.float32 if x.dtype in (jnp.bfloat16, jnp.float16) else x.dtype
    out = x
    for t in range(1, timesteps + 1):
        xo = out.astype(acc_dtype)
        o = jnp.zeros(out.shape, acc_dtype)
        for axis, (r, coeffs) in zip((-3, -2, -1),
                                     ((rz, cz), (ry, cy), (rx, cx))):
            for k, c in enumerate(coeffs):
                if c != 0.0:
                    o = o + jnp.asarray(c, acc_dtype) * _shift(xo, k - r, axis)
        zz = jnp.arange(nz)[:, None, None]
        yy = jnp.arange(ny)[None, :, None]
        xx = jnp.arange(nx)[None, None, :]
        valid = ((zz >= rz * t) & (zz < nz - rz * t) &
                 (yy >= ry * t) & (yy < ny - ry * t) &
                 (xx >= rx * t) & (xx < nx - rx * t))
        out = jnp.where(valid, o, 0.0).astype(x.dtype)
    return out


def _shift(x: jax.Array, off: int, axis: int) -> jax.Array:
    if off == 0:
        return x
    n = x.shape[axis]
    axis = axis % x.ndim
    pad = [(0, 0)] * x.ndim
    sl = [slice(None)] * x.ndim
    if off > 0:
        pad[axis] = (0, off)
        sl[axis] = slice(off, off + n)
    else:
        pad[axis] = (-off, 0)
        sl[axis] = slice(0, n)
    return jnp.pad(x, pad)[tuple(sl)]
