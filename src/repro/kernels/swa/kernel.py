"""Pallas TPU kernel: causal sliding-window attention (flash-style online
softmax), with the paper's stencil reuse discipline on the MXU.

Mapping rationale (DESIGN.md §4): local attention is a sequence stencil —
every query block's support is a fixed-width band of KV blocks behind it.
As in the stencil kernels, each KV block is DMA'd into VMEM once per query
band and reused by the whole (bq x bk) tile on the MXU; boundary handling is
the same position-predicate filtering the paper implements with filter PEs.

Grid: (B*Hq, num_q_blocks, num_window_blocks); the window dimension is the
innermost (sequential) axis carrying the online-softmax recurrence in VMEM
scratch.  KV block index = q_block - (nw-1) + wi, clamped; contributions from
negative (non-existent) desired blocks are skipped with ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _body(qref, kref, vref, oref, mref, lref, accref, *, bq, bk, nw, window,
          seq, scale, out_dtype):
    qi = pl.program_id(1)
    wi = pl.program_id(2)

    @pl.when(wi == 0)
    def _init():
        mref[:, :] = jnp.full_like(mref[:, :], NEG_INF)
        lref[:, :] = jnp.zeros_like(lref[:, :])
        accref[:, :] = jnp.zeros_like(accref[:, :])

    desired = qi - (nw - 1) + wi

    @pl.when(desired >= 0)
    def _compute():
        q = qref[0, 0, :, :].astype(jnp.float32) * scale      # (bq, D)
        k = kref[0, 0, :, :].astype(jnp.float32)              # (bk, D)
        v = vref[0, 0, :, :].astype(jnp.float32)              # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = desired * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = (kpos <= qpos) & (kpos > qpos - window) & (kpos < seq)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = mref[:, :]                                   # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)          # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                       # (bq, 1)
        lref[:, :] = lref[:, :] * alpha + jnp.sum(p, axis=1, keepdims=True)
        accref[:, :] = accref[:, :] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        mref[:, :] = m_new

    @pl.when(wi == nw - 1)
    def _finish():
        l = jnp.maximum(lref[:, :], 1e-30)
        oref[0, 0, :, :] = (accref[:, :] / l).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "block_q", "block_k", "interpret"))
def swa_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *, window: int,
               block_q: int = 128, block_k: int = 128,
               interpret: bool = False) -> jax.Array:
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D). S % block_q == 0 required
    (ops.py pads); block_q == block_k for static index math."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, "GQA needs Hq % Hkv == 0"
    group = hq // hkv
    assert block_q == block_k, "kv-block walk assumes block_q == block_k"
    assert s % block_q == 0
    nq = s // block_q
    nw = (window - 1 + block_q - 1) // block_q + 1   # kv blocks per window
    nw = min(nw, nq)
    scale = 1.0 / (d ** 0.5)

    def qmap(bh, qi, wi):
        return (bh // hq, bh % hq, qi, 0)

    def kvmap(bh, qi, wi):
        blk = jnp.clip(qi - (nw - 1) + wi, 0, nq - 1)
        return (bh // hq, (bh % hq) // group, blk, 0)

    body = functools.partial(
        _body, bq=block_q, bk=block_k, nw=nw, window=window, seq=s,
        scale=scale, out_dtype=q.dtype)
    return pl.pallas_call(
        body,
        grid=(b * hq, nq, nw),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), qmap),
            pl.BlockSpec((1, 1, block_k, d), kvmap),
            pl.BlockSpec((1, 1, block_k, d), kvmap),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), qmap),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret)(q, k, v)
