"""Public entry point for sliding-window attention: padding + dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.swa.kernel import swa_pallas
from repro.kernels.swa.ref import swa_ref, swa_ref_chunked

# beyond this many positions the dense (S x S) mask path is replaced by the
# strip-mined chunked path (linear memory in S).
CHUNKED_THRESHOLD = 4096


def sliding_window_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                             window: int, backend: str = "auto",
                             block: int = 128) -> jax.Array:
    """Causal local attention. q: (B, Hq, S, D); k/v: (B, Hkv, S, D).

    Padding note: S is right-padded to a block multiple; padded *queries*
    produce garbage rows that are sliced off, and padded *keys* are excluded
    by the kernel's ``kpos < seq`` filter (with seq = true length) — the same
    boundary-drop discipline as the stencil kernels.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend == "xla":
        s = q.shape[2]
        if s > CHUNKED_THRESHOLD or (s > 2 * window and s > 1024):
            return swa_ref_chunked(q, k, v, window=window)
        return swa_ref(q, k, v, window=window)

    interpret = jax.default_backend() != "tpu"
    s = q.shape[2]
    pad = (-s) % block
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    # true-length filtering happens inside the kernel via seq=s… but the
    # kernel reads seq from the padded shape; pass the padded arrays and mask
    # keys by true length with an explicit kpos bound baked into `window`
    # logic: we simply zero-pad K/V — padded keys can only be attended by
    # padded queries (causality), which are sliced away below.
    out = swa_pallas(qp, kp, vp, window=window, block_q=block, block_k=block,
                     interpret=interpret)
    return out[:, :, :s, :]
