"""Pure-jnp oracle for causal sliding-window (local) attention with GQA.

``out[b,h,i] = softmax_j(q_i . k_j / sqrt(D)) @ v``  over keys
``j in (i - window, i]`` (causal, window includes the current token).
This is attention-as-a-sequence-stencil: a fixed-shape local dependency
pattern of radius ``window-1`` behind each query (DESIGN.md §4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("window",))
def swa_ref_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int) -> jax.Array:
    """Linear-memory XLA formulation: queries in window-sized chunks, each
    attending to its (chunk + trailing-window) KV band — the strip-mined
    stencil schedule (§III-B Blocking) applied to attention.  Identical
    semantics to :func:`swa_ref`; used for long sequences where the dense
    (S x S) mask would be quadratic."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    w = window
    c = w                                     # chunk size = window
    pad = (-s) % c
    sp = s + pad
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kp = jnp.pad(jnp.repeat(k, group, axis=1),
                 ((0, 0), (0, 0), (w, pad), (0, 0)))
    vp = jnp.pad(jnp.repeat(v, group, axis=1),
                 ((0, 0), (0, 0), (w, pad), (0, 0)))
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    outs = []
    for i in range(sp // c):
        qi = qp[:, :, i * c:(i + 1) * c].astype(jnp.float32) * scale
        kwin = kp[:, :, i * c:i * c + c + w].astype(jnp.float32)
        vwin = vp[:, :, i * c:i * c + c + w].astype(jnp.float32)
        logits = jnp.einsum("bhid,bhjd->bhij", qi, kwin)
        qpos = i * c + jnp.arange(c)[:, None]
        kpos = i * c - w + jnp.arange(c + w)[None, :]
        mask = (kpos <= qpos) & (kpos > qpos - w) & (kpos >= 0) & (kpos < s)
        logits = jnp.where(mask, logits, -jnp.inf)
        p = jax.nn.softmax(logits, axis=-1)
        p = jnp.where(jnp.any(mask, -1, keepdims=True), p, 0.0)
        # probs and PV run in the input dtype (bf16 in production configs):
        # halves the dominant byte traffic of the window band (§Perf cell C).
        outs.append(jnp.einsum("bhij,bhjd->bhid", p.astype(q.dtype),
                               vwin.astype(q.dtype)))
    out = jnp.concatenate(outs, axis=2)[:, :, :s]
    return out.astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("window",))
def swa_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
            window: int) -> jax.Array:
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D); Hq % Hkv == 0."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bhid,bhjd->bhij", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = (j <= i) & (j > i - window)
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhij,bhjd->bhid", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
