import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import (jax locks the device count at first
# init); smoke tests / benches import repro without this module and see 1.

DOC = """Multi-pod dry-run (assignment deliverable (e)).

For every (architecture x input-shape) cell and both production meshes
(single-pod 16x16=256 chips, multi-pod 2x16x16=512 chips), lower + compile
the cell's step function against ShapeDtypeStruct stand-ins (no allocation),
then record:
  * memory_analysis()        (fits-per-device proof)
  * cost_analysis()          (flops / bytes for §Roofline)
  * collective bytes         (parsed from the optimized HLO; analysis/hlo.py)
  * the three roofline terms (core/roofline.TpuRooflineTerms)

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
Results land in results/dryrun/<arch>__<shape>__<mesh>.json.
"""


import argparse
import json
import math
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_bytes, remat_duplication
from repro.configs import SHAPES, ArchConfig, ShapeSpec, cells, get_config
from repro.core.roofline import TpuRooflineTerms
from repro.distributed.sharding import (INFERENCE_RULES, mesh_context,
                                        resolve_spec)
from repro.launch.mesh import make_production_mesh
from repro.models import params as pr
from repro.models.registry import build_model, input_specs
from repro.serving.serve_step import make_decode_step
from repro.train.optim import AdamWState, OptConfig
from repro.train.train_step import make_loss_fn, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

BATCH_LOGICAL = {
    "tokens": ("batch", None),
    "labels": ("batch", None),
    "frames": ("batch", None, None),
    "patches": ("batch", None, None),
    "positions": (None, "batch", None),
}


def _shard(mesh, shape, logical, rules=None):
    return NamedSharding(mesh, resolve_spec(tuple(shape), logical, mesh,
                                            rules))


def batch_shardings(mesh, structs: dict) -> dict:
    return {k: _shard(mesh, v.shape, BATCH_LOGICAL[k])
            for k, v in structs.items()}


def cache_logical_for(name: str, ndim: int, stacked: bool) -> tuple:
    lead = ("layers",) if stacked else ()
    n = name.split(".")[-1].strip("'] ").lower()
    base_nd = ndim - len(lead)
    if n in ("k", "v") and base_nd == 4:          # KV cache (B, KV, C, hd)
        return lead + ("batch", "kv_heads", "cache_seq", None)
    if n in ("cross_k", "cross_v"):               # (L, B, T, KV, hd)
        return ("layers", "batch", "cache_seq", "kv_heads", None)
    if n == "pos":
        return ("layers",) * ndim          # scalar, or (L,) when stacked
    if n == "h" and base_nd == 2:                 # RG-LRU state (B, W)
        return lead + ("batch", "mlp")
    if n == "conv" and base_nd == 3:              # (B, K-1, W)
        return lead + ("batch", None, "mlp")
    if n == "s" and base_nd == 4:                 # RWKV state (B, H, n, n)
        return lead + ("batch", "heads", None, None)
    if n in ("shift_tm", "shift_cm") and base_nd == 2:
        return lead + ("batch", None)
    return (None,) * ndim


def cache_shardings(mesh, cache_structs,
                    stacked_names=("scan", "self")) -> Any:
    named, treedef = jax.tree_util.tree_flatten_with_path(cache_structs)
    out = []
    for path, leaf in named:
        pstr = jax.tree_util.keystr(path)
        stacked = any(f"'{s}'" in pstr for s in stacked_names)
        logical = cache_logical_for(pstr, leaf.ndim, stacked)
        out.append(_shard(mesh, leaf.shape, logical))
    return jax.tree_util.tree_unflatten(treedef, out)


def param_bytes_per_device(structs, shardings, mesh) -> int:
    total = 0
    for sd, sh in zip(jax.tree.leaves(structs), jax.tree.leaves(shardings)):
        spec = sh.spec
        n = 1
        for i, dim in enumerate(sd.shape):
            axes = spec[i] if i < len(spec) else None
            div = 1
            if axes:
                axes = (axes,) if isinstance(axes, str) else axes
                div = math.prod(mesh.shape[a] for a in axes)
            n *= dim // div
        total += n * sd.dtype.itemsize
    return total


def _clone_cfg(cfg: ArchConfig, periods: int) -> ArchConfig:
    """Depth-reduced clone for the scan-cost extrapolation (§scan-correction):
    ``periods`` full pattern periods; lowered force-unrolled."""
    import dataclasses
    p = len(cfg.block_pattern)
    if cfg.family == "audio":
        return dataclasses.replace(cfg, num_layers=periods,
                                   encoder_layers=periods)
    return dataclasses.replace(cfg, num_layers=p * periods)


def _lower_and_compile(cfg: ArchConfig, shape: ShapeSpec, mesh, chips,
                       remat: str, force_unroll: bool,
                       infer_layout: bool = False):
    """Shared lowering path; returns (compiled, lower_s, compile_s,
    model_flops, specs, p_structs, p_shard).

    Lowering runs inside ``jax.sharding.set_mesh(mesh)`` so the models'
    activation sharding constraints (distributed.sharding.constrain) resolve
    against the production mesh."""
    with mesh_context(mesh):
        return _lower_and_compile_inner(cfg, shape, mesh, chips, remat,
                                        force_unroll, infer_layout)


def _lower_and_compile_inner(cfg, shape, mesh, chips, remat, force_unroll,
                             infer_layout=False):
    model = build_model(cfg)
    model.force_unroll = force_unroll
    specs = model.specs()
    rules = INFERENCE_RULES if infer_layout else None
    p_structs = pr.shape_tree(specs, cfg.param_dtype)
    p_logical = pr.logical_tree(specs)
    p_shard = jax.tree.map(
        lambda sd, lg: _shard(mesh, sd.shape, lg, rules), p_structs,
        p_logical)
    in_structs = input_specs(cfg, shape)
    b_shard = batch_shardings(mesh, in_structs)

    t0 = time.time()
    if shape.kind == "train":
        opt_structs = AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                           p_structs),
            v=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                           p_structs),
            ef=None)
        opt_shard = AdamWState(step=NamedSharding(mesh, P()),
                               m=p_shard, v=p_shard, ef=None)
        fn = make_train_step(model, cfg, OptConfig(), remat=remat)
        jf = jax.jit(fn, in_shardings=(p_shard, opt_shard, b_shard))
        lowered = jf.lower(p_structs, opt_structs, in_structs)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * cfg.params_billion_estimate() * 1e9 * tokens
    elif shape.kind == "prefill":
        loss_free = make_loss_fn  # unused; prefill = forward logits

        def prefill(params, batch):
            if cfg.family == "audio":
                return model.forward(params, batch["tokens"],
                                     batch["frames"])[0]
            return model.forward(params, batch["tokens"],
                                 positions=batch.get("positions"),
                                 patches=batch.get("patches"))[0]

        jf = jax.jit(prefill, in_shardings=(p_shard, b_shard))
        lowered = jf.lower(p_structs, in_structs)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * cfg.params_billion_estimate() * 1e9 * tokens
    else:  # decode
        cache_structs = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        c_shard = cache_shardings(mesh, cache_structs)
        step_fn = make_decode_step(model, cfg)
        tok_struct = in_structs["tokens"]
        step_struct = jax.ShapeDtypeStruct((), jnp.int32)
        jf = jax.jit(step_fn, in_shardings=(
            p_shard, c_shard, _shard(mesh, tok_struct.shape, ("batch", None)),
            NamedSharding(mesh, P())))
        lowered = jf.lower(p_structs, cache_structs, tok_struct, step_struct)
        tokens = shape.global_batch
        model_flops = 2 * cfg.params_billion_estimate() * 1e9 * tokens
    lower_s = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    return compiled, lower_s, compile_s, model_flops, specs, p_structs, p_shard


def _analyze(compiled, chips) -> dict:
    try:
        mem = compiled.memory_analysis()
        mem_d = {k: int(getattr(mem, k)) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "peak_memory_in_bytes",
            "generated_code_size_in_bytes") if hasattr(mem, k)}
    except Exception as e:                       # CPU backend may lack it
        mem_d = {"unavailable": str(e)}
    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        flops_dev = float(cost.get("flops", 0.0))
        bytes_dev = float(cost.get("bytes accessed", 0.0))
    except Exception:
        flops_dev, bytes_dev = 0.0, 0.0
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return {"mem": mem_d, "flops_dev": flops_dev, "bytes_dev": bytes_dev,
            "coll": coll, "dup": remat_duplication(hlo),
            "hlo_lines": hlo.count("\n")}


def _wkv_analytic_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """RWKV's WKV recurrence is a time-scan (cost-counted once); add the
    analytic (S-1)-step remainder: ~7*n^2 flops /step /head /batch /layer,
    x3 for the train backward."""
    if "rwkv" not in cfg.block_pattern or shape.kind == "decode":
        return 0.0
    n = cfg.resolved_head_dim
    steps = shape.seq_len - 1
    mult = 3.0 if shape.kind == "train" else 1.0
    return (cfg.num_layers * shape.global_batch * steps * cfg.num_heads *
            7 * n * n * mult)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             remat: str = "dots", extra_tag: str = "",
             correction: bool = True, infer_layout: bool = False,
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = math.prod(mesh.shape.values())

    compiled, lower_s, compile_s, model_flops, specs, p_structs, p_shard = \
        _lower_and_compile(cfg, shape, mesh, chips, remat, False,
                           infer_layout)
    a = _analyze(compiled, chips)

    # ---- scan-cost correction (two-point extrapolation over clone depth) ---
    model = build_model(cfg)
    del specs  # keep the full-model spec tree only via p_structs below
    specs = model.specs()
    n_scan = getattr(model, "n_full", 0)
    if cfg.family == "audio":
        n_scan = cfg.num_layers          # enc+dec scans, equal depths
    corr = {"applied": False}
    if correction and n_scan > 1:
        c1 = _analyze(_lower_and_compile(
            _clone_cfg(cfg, 1), shape, mesh, chips, remat, True,
            infer_layout)[0], chips)
        c2 = _analyze(_lower_and_compile(
            _clone_cfg(cfg, 2), shape, mesh, chips, remat, True,
            infer_layout)[0], chips)
        body_flops = max(0.0, c2["flops_dev"] - c1["flops_dev"])
        body_bytes = max(0.0, c2["bytes_dev"] - c1["bytes_dev"])
        body_coll = max(0, c2["coll"]["total_bytes"] - c1["coll"]["total_bytes"])
        corr = {"applied": True, "n_scan": n_scan,
                "body_flops_dev": body_flops, "body_bytes_dev": body_bytes,
                "body_collective_dev": body_coll}
        a["flops_dev"] += (n_scan - 1) * body_flops
        a["bytes_dev"] += (n_scan - 1) * body_bytes
        a["coll"]["total_bytes"] += (n_scan - 1) * body_coll

    wkv_extra = _wkv_analytic_flops(cfg, shape)   # global flops
    flops_global = a["flops_dev"] * chips + wkv_extra

    terms = TpuRooflineTerms(
        flops=flops_global, hbm_bytes=a["bytes_dev"] * chips,
        collective_bytes=a["coll"]["total_bytes"] * chips, chips=chips)
    pbytes = param_bytes_per_device(p_structs, p_shard, mesh)

    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": extra_tag,
        "kind": shape.kind, "chips": chips, "ok": True,
        "lower_s": round(lower_s, 2), "compile_s": round(compile_s, 2),
        "flops_per_device": a["flops_dev"], "bytes_per_device": a["bytes_dev"],
        "collective_bytes_per_device": a["coll"]["total_bytes"],
        "collective_by_op": a["coll"]["by_op"],
        "collective_counts": a["coll"]["counts"],
        "remat_duplication": round(a["dup"], 3),
        "memory_analysis": a["mem"],
        "scan_correction": corr,
        "wkv_analytic_flops": wkv_extra,
        "param_count": pr.param_count(specs),
        "param_bytes_per_device": pbytes,
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / flops_global
                               if flops_global else None),
        "roofline": terms.as_dict(),
        "hlo_lines": a["hlo_lines"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--tag", default="")
    ap.add_argument("--infer-layout", action="store_true",
                    help="serving param layout: TP-resident, no FSDP gathers")
    ap.add_argument("--cfg-override", action="append", default=[],
                    help="e.g. --cfg-override num_heads=16")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    todo = cells() if args.all else [(args.arch, args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for arch, shape in todo:
        for mk in meshes:
            tag = f"__{args.tag}" if args.tag else ""
            path = os.path.join(args.out, f"{arch}__{shape}__{mk}{tag}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"skip {path}")
                continue
            print(f"=== {arch} x {shape} x {mk} ===", flush=True)
            try:
                ov = {}
                for o in args.cfg_override:
                    k, v = o.split("=", 1)
                    ov[k] = int(v) if v.lstrip("-").isdigit() else v
                rec = run_cell(arch, shape, mk, remat=args.remat,
                               extra_tag=args.tag,
                               infer_layout=args.infer_layout,
                               overrides=ov or None)
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "mesh": mk, "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = "OK" if rec.get("ok") else "FAIL " + rec.get("error", "")
            print(f"    -> {status} "
                  f"(lower {rec.get('lower_s', '?')}s, "
                  f"compile {rec.get('compile_s', '?')}s)", flush=True)


if __name__ == "__main__":
    main()
