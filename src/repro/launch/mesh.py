"""Production mesh factories.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init and then
calls this.
"""
from __future__ import annotations

from repro.distributed.sharding import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests/examples)."""
    return make_mesh_compat((data, model), ("data", "model"))


# XLA flags recommended for the real-TPU launch scripts (latency-hiding
# scheduler = the compute/comm overlap knob; async collectives).
TPU_PERF_FLAGS = " ".join([
    "--xla_tpu_enable_latency_hiding_scheduler=true",
    "--xla_enable_async_all_gather=true",
    "--xla_enable_async_collective_permute=true",
    "--xla_tpu_enable_async_collective_fusion=true",
    "--xla_tpu_overlap_compute_collective_tc=true",
])
