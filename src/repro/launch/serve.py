"""Serving driver: batched requests through the BatchEngine (deliverable (b)).

CPU-scale usage:
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
      --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.models.registry import build_model
from repro.serving.engine import BatchEngine, Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    if cfg.family in ("audio",):
        print("serve driver targets decoder-only archs; use examples for "
              "enc-dec")
        return 1
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    engine = BatchEngine(model, cfg, params, batch_slots=args.slots,
                         cache_len=args.cache_len)
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    tok = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)}/{len(reqs)} requests, {tok} tokens in "
          f"{dt:.1f}s ({tok/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  rid={r.rid} out[:8]={r.out[:8]}")
    return 0 if len(done) == len(reqs) else 1


if __name__ == "__main__":
    sys.exit(main())
