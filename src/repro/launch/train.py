"""Training driver with the fault-tolerance loop (deliverable (b) + DESIGN §6).

Features exercised end-to-end here:
  * resume-from-latest checkpoint (atomic manager; data-pipeline position
    rides in the manifest, so batch order is restart-invariant);
  * async checkpointing every --ckpt-every steps (I/O overlaps compute);
  * step-time EMA watchdog (straggler mitigation: a stalled step beyond
    k-sigma is logged and, with --watchdog-abort, exits non-zero so the
    cluster supervisor restarts the job from the last checkpoint);
  * microbatch gradient accumulation, remat, optional gradient compression;
  * elastic resume: checkpoints are mesh-agnostic host arrays, so
    --data-par/--model-par may differ across restarts.

CPU-scale usage (examples/train_lm.py wraps this):
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, get_reduced_config
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.distributed.sharding import mesh_context, resolve_spec
from repro.launch.mesh import make_local_mesh
from repro.models import params as pr
from repro.models.registry import build_model, input_arrays
from repro.train.optim import OptConfig, init_opt_state
from repro.train.train_step import make_train_step
from jax.sharding import NamedSharding


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the arch's reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--watchdog-sigma", type=float, default=6.0)
    ap.add_argument("--watchdog-abort", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-pattern", default="markov",
                    choices=["uniform", "markov"])
    ap.add_argument("--override", action="append", default=[],
                    help="config overrides, e.g. --override num_layers=8 "
                         "--override d_model=512")
    args = ap.parse_args(argv)

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    if args.override:
        import dataclasses
        kv = {}
        for ov in args.override:
            k, v = ov.split("=", 1)
            cur = getattr(cfg, k)
            kv[k] = type(cur)(v) if not isinstance(cur, bool) else v == "True"
        cfg = dataclasses.replace(cfg, **kv)
    model = build_model(cfg)
    mesh = make_local_mesh(args.data_par, args.model_par)

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=args.warmup,
                        total_steps=args.steps,
                        compression=args.compression)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed,
                                  pattern=args.data_pattern))

    # --- init or resume ------------------------------------------------------
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = init_opt_state(params, opt_cfg)
    start_step = 0
    if mgr and args.resume and mgr.latest_step() is not None:
        step = mgr.latest_step()
        (state, extra) = mgr.restore(step, {"params": params,
                                            "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        data.restore(extra["data"])
        start_step = extra["train_step"]
        print(f"[resume] from checkpoint step {step} "
              f"(train step {start_step})", flush=True)

    with mesh_context(mesh):
        step_fn = jax.jit(make_train_step(
            model, cfg, opt_cfg, remat=args.remat,
            microbatches=args.microbatches))

        pf = Prefetcher(data, depth=2)
        ema, emvar = None, 0.0
        t_train0 = time.time()
        losses = []
        try:
            for step in range(start_step, args.steps):
                t0 = time.time()
                batch = {k: jnp.asarray(v) for k, v in pf.next_batch().items()}
                if cfg.family == "audio":
                    rngf = np.random.default_rng(step)
                    batch["frames"] = jnp.asarray(
                        rngf.normal(size=(args.batch, cfg.encoder_seq,
                                          cfg.d_model)) * 0.02, cfg.dtype)
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
                losses.append(loss)
                dt = time.time() - t0

                # --- straggler watchdog (EMA + k-sigma) --------------------
                if ema is None:
                    ema = dt
                else:
                    dev = dt - ema
                    thresh = ema + args.watchdog_sigma * max(emvar ** 0.5,
                                                             0.1 * ema)
                    if step > start_step + 5 and dt > thresh:
                        print(f"[watchdog] step {step} took {dt:.2f}s "
                              f"(ema {ema:.2f}s, thresh {thresh:.2f}s)",
                              flush=True)
                        if args.watchdog_abort:
                            if mgr:
                                mgr.save(step, {"params": params,
                                                "opt": opt_state},
                                         extra={"data": data.state(),
                                                "train_step": step + 1})
                            return 42          # supervisor restarts us
                    ema = 0.9 * ema + 0.1 * dt
                    emvar = 0.9 * emvar + 0.1 * dev * dev

                if step % args.log_every == 0 or step == args.steps - 1:
                    print(f"step {step:5d} loss {loss:.4f} "
                          f"aux {float(metrics['aux_loss']):.4f} "
                          f"{dt:.2f}s/step", flush=True)
                if mgr and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                    mgr.save(step + 1, {"params": params, "opt": opt_state},
                             extra={"data": data.state(),
                                    "train_step": step + 1},
                             blocking=False)     # async writer
        finally:
            pf.close()

        if mgr:
            mgr.save(args.steps, {"params": params, "opt": opt_state},
                     extra={"data": data.state(), "train_step": args.steps})
            mgr.wait()
        n = pr.param_count(model.specs())
        dt_all = time.time() - t_train0
        print(f"[done] {args.steps - start_step} steps, {n/1e6:.1f}M params, "
              f"{dt_all:.1f}s total; loss {losses[0]:.4f} -> {losses[-1]:.4f}",
              flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
