"""Attention: GQA/MQA, qk-norm, RoPE/M-RoPE, full-causal or sliding-window,
bidirectional (encoder) and cross (decoder) variants, with KV caches.

Sliding-window layers are the LM-side home of the paper's stencil technique:
on TPU the local-attention prefill dispatches to ``kernels/swa`` (stencil
reuse on the MXU); under jit on CPU and in the dry-run it uses the same-math
XLA path.  Decode uses a ring-buffer KV cache bounded by the window — the
"mandatory buffering" of §III-B applied to sequence state.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.sharding import constrain
from repro.kernels.swa.ops import sliding_window_attention
from repro.models.common import apply_rope, mrope_angles, rmsnorm, rmsnorm_spec, rope_angles
from repro.models.params import Spec

NEG_INF = -1e30


def attention_specs(cfg: ArchConfig, *, kv_heads: int | None = None) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    kv = kv_heads if kv_heads is not None else cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    specs = {
        "wq": Spec((d, h, hd), ("fsdp", "heads", "head_dim")),
        "wk": Spec((d, kv, hd), ("fsdp", "kv_heads", "head_dim")),
        "wv": Spec((d, kv, hd), ("fsdp", "kv_heads", "head_dim")),
        "wo": Spec((h, hd, d), ("heads", "head_dim", "fsdp")),
    }
    if cfg.qkv_bias:
        specs |= {"bq": Spec((h, hd), ("heads", "head_dim"), init="zeros"),
                  "bk": Spec((kv, hd), ("kv_heads", "head_dim"), init="zeros"),
                  "bv": Spec((kv, hd), ("kv_heads", "head_dim"), init="zeros")}
    if cfg.qk_norm:
        specs |= {"q_norm": rmsnorm_spec(hd), "k_norm": rmsnorm_spec(hd)}
    return specs


class KVCache(NamedTuple):
    """k/v: (B, Hkv, C, hd); C = full seq for global layers, window for local.
    ``pos``: next absolute write position (scalar int32)."""
    k: jax.Array
    v: jax.Array
    pos: jax.Array

    @staticmethod
    def init(batch: int, kv_heads: int, capacity: int, head_dim: int, dtype):
        z = jnp.zeros((batch, kv_heads, capacity, head_dim), dtype)
        return KVCache(z, z, jnp.zeros((), jnp.int32))


def _project(p: dict, x: jax.Array, cfg: ArchConfig):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,KV,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    return q, k, v


def _rope_qk(q, k, cfg: ArchConfig, positions):
    if cfg.rope_theta <= 0 or positions is None:
        return q, k
    hd = q.shape[-1]
    if cfg.mrope_sections is not None:
        cos, sin = mrope_angles(positions, hd, cfg.rope_theta,
                                cfg.mrope_sections)
    else:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin)


def _sdpa(q, k, v, mask, group: int) -> jax.Array:
    """q: (B,S,H,hd); k/v: (B,T,KV,hd); mask: (B,1,S,T) or None (full)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qg = qf.reshape(b, s, kv, group, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    if mask is not None:
        logits = jnp.where(mask[:, None, :, :, :] if mask.ndim == 4 else mask,
                           logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    out = out.reshape(b, s, h, hd).astype(q.dtype)
    return constrain(out, ("batch", None, "heads", None))


def attend_full(p: dict, x: jax.Array, cfg: ArchConfig, *, positions,
                causal: bool = True,
                cross_kv: Optional[tuple[jax.Array, jax.Array]] = None):
    """Training/prefill attention without cache. cross_kv supplies encoder
    K/V for cross-attention (positions then only rotate q... whisper uses no
    rope; cross_kv path skips rope entirely)."""
    b, s, _ = x.shape
    if cross_kv is None:
        q, k, v = _project(p, x, cfg)
        q, k = _rope_qk(q, k, cfg, positions)
        if causal:
            i = jnp.arange(s)[:, None]
            j = jnp.arange(s)[None, :]
            mask = (j <= i)[None, None, :, :]
        else:
            mask = None
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        k, v = cross_kv
        mask = None
    out = _sdpa(q, k, v, mask, cfg.q_per_kv if cross_kv is None else
                q.shape[2] // k.shape[2])
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def attend_local(p: dict, x: jax.Array, cfg: ArchConfig, *, positions):
    """Sliding-window attention (stencil path). Uses kernels/swa."""
    q, k, v = _project(p, x, cfg)
    q, k = _rope_qk(q, k, cfg, positions)
    out = sliding_window_attention(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        window=cfg.window)
    out = jnp.moveaxis(out, 1, 2)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


# ----------------------------------------------------------------------------
# single-token decode with caches
# ----------------------------------------------------------------------------
def decode_step(p: dict, x: jax.Array, cache: KVCache, cfg: ArchConfig, *,
                window: int = 0, positions=None
                ) -> tuple[jax.Array, KVCache]:
    """x: (B, 1, D); returns (out (B,1,D), new cache).

    Global layers write at ``pos``; local layers write at ``pos % window``
    (ring buffer) and mask by recency — the §III-B line buffer in time.
    """
    b, s1, _ = x.shape
    assert s1 == 1
    q, k_new, v_new = _project(p, x, cfg)
    pos = cache.pos
    if positions is None:
        pos_arr = jnp.full((b, 1), pos, jnp.int32)
    else:
        pos_arr = positions
    q, k_new = _rope_qk(q, k_new, cfg, pos_arr)

    cap = cache.k.shape[2]
    slot = (pos % window) if window else pos
    k = jax.lax.dynamic_update_slice(cache.k, k_new.swapaxes(1, 2),
                                     (0, 0, slot, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.swapaxes(1, 2),
                                     (0, 0, slot, 0))

    idx = jnp.arange(cap)
    if window:
        # absolute position held by ring slot i = the latest write time t
        # with t <= pos and t % window == i; negative -> never written.
        abs_pos = pos - ((pos % window) - idx) % window
        visible = abs_pos >= 0          # ring holds only the last `window`
    else:
        visible = idx <= pos
    bias = jnp.where(visible, 0.0, NEG_INF)                 # (C,)

    kv = k.shape[1]
    group = q.shape[2] // kv
    qf = (q.astype(jnp.float32) /
          jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32)))
    qg = qf.reshape(b, 1, kv, group, -1)
    logits = jnp.einsum("bskgd,bktd->bkgst", qg, k.astype(jnp.float32))
    logits = logits + bias[None, None, None, None, :]
    pr = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,bktd->bskgd", pr, v.astype(jnp.float32))
    out = out.reshape(b, 1, q.shape[2], q.shape[3]).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, KVCache(k, v, pos + 1)
