"""Shared model components: norms, activations, rotary embeddings (RoPE and
M-RoPE), token embedding.  All pure functions over param dicts (see
models/params.py for the spec system).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import Spec


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------
def rmsnorm_spec(dim: int) -> Spec:
    return Spec((dim,), (None,), init="ones", dtype="float32")


def rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def layernorm_specs(dim: int) -> dict:
    return {"scale": Spec((dim,), (None,), init="ones", dtype="float32"),
            "bias": Spec((dim,), (None,), init="zeros", dtype="float32")}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"]
            + p["bias"]).astype(x.dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
            "relu2": lambda x: jnp.square(jax.nn.relu(x))}[name]


# ----------------------------------------------------------------------------
# rotary position embeddings
# ----------------------------------------------------------------------------
def rope_angles(positions: jax.Array, head_dim: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    """positions: (..., S) int -> cos/sin of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions: jax.Array, head_dim: int, theta: float,
                 sections: tuple[int, int, int]) -> tuple[jax.Array, jax.Array]:
    """Qwen2-VL multimodal RoPE. positions: (3, B, S) (t/h/w components);
    returns cos/sin (B, S, head_dim//2) where frequency slot f takes its
    position component from the section it falls in (t|h|w interleaved
    across the frequency axis per the M-RoPE layout)."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=half)          # (half,)
    # for each frequency slot, pick the position component of its section
    pos = positions.astype(jnp.float32)                     # (3, B, S)
    chosen = pos[sec_id, ...]                               # (half, B, S)
    ang = jnp.moveaxis(chosen, 0, -1) * freqs               # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D//2) (broadcast over heads).
    Rotates the two halves (llama convention)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(jnp.float32)
    s = sin[:, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int, offset=0) -> jax.Array:
    """Whisper-style fixed sinusoidal table: (seq, dim)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None] + offset
    half = dim // 2
    inv = 10_000.0 ** (-jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------------
# embedding
# ----------------------------------------------------------------------------
def embed_spec(vocab: int, dim: int) -> Spec:
    return Spec((vocab, dim), ("vocab", "fsdp"), init="embed", scale=0.02)


def embed(table: jax.Array, tokens: jax.Array, compute_dtype) -> jax.Array:
    return table.astype(compute_dtype)[tokens]


def unembed(table_or_w: jax.Array, x: jax.Array, *, tied: bool) -> jax.Array:
    """Logits in fp32 (stable softmax/xent).

    bf16 weights are consumed natively with fp32 accumulation — converting a
    (V, D) table to fp32 every step costs 6 B/elem and dominated serving
    byte traffic (§Perf cell B iteration 3).  fp32 master weights keep the
    fp32 path (activations are the smaller operand there).
    """
    w = table_or_w
    if w.dtype == jnp.bfloat16:
        eq = "bsd,vd->bsv" if tied else "bsd,dv->bsv"
        return jnp.einsum(eq, x.astype(jnp.bfloat16), w,
                          preferred_element_type=jnp.float32)
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    if tied:
        return jnp.einsum("bsd,vd->bsv", xf, wf)
    return jnp.einsum("bsd,dv->bsv", xf, wf)
