"""Whisper-style encoder-decoder backbone (audio family).

Per the assignment the conv/mel frontend is a STUB: the encoder consumes
precomputed frame embeddings (B, encoder_seq, d_model) from input_specs().
Encoder: bidirectional attention blocks with sinusoidal positions.
Decoder: causal self-attention + cross-attention + MLP, sinusoidal positions
(the real model's learned 448-position table is replaced so the assigned
32k-decode shapes are expressible; noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import params as pr
from repro.models.attention import (KVCache, attention_specs, attend_full,
                                    decode_step as attn_decode)
from repro.models.common import (embed, embed_spec, rmsnorm, rmsnorm_spec,
                                 sinusoidal_positions, unembed)
from repro.models.mlp import mlp, mlp_specs
from repro.models.params import Spec
from repro.models.transformer import maybe_scan, stack_specs


def _enc_block_specs(cfg: ArchConfig) -> dict:
    return {"ln1": rmsnorm_spec(cfg.d_model), "attn": attention_specs(cfg),
            "ln2": rmsnorm_spec(cfg.d_model), "mlp": mlp_specs(cfg)}


def _dec_block_specs(cfg: ArchConfig) -> dict:
    return {"ln1": rmsnorm_spec(cfg.d_model), "self": attention_specs(cfg),
            "lnx": rmsnorm_spec(cfg.d_model), "cross": attention_specs(cfg),
            "ln2": rmsnorm_spec(cfg.d_model), "mlp": mlp_specs(cfg)}


class EncDecLM:
    """Whisper-tiny-style backbone."""

    def __init__(self, cfg: ArchConfig, force_unroll: bool = False):
        self.cfg = cfg
        self.force_unroll = force_unroll

    def specs(self) -> dict:
        cfg = self.cfg
        d, v = cfg.d_model, cfg.vocab_size
        return {
            "embed": embed_spec(v, d),
            "enc": stack_specs(_enc_block_specs(cfg), cfg.encoder_layers),
            "enc_norm": rmsnorm_spec(d),
            "dec": stack_specs(_dec_block_specs(cfg), cfg.num_layers),
            "final_norm": rmsnorm_spec(d),
        }

    def init(self, key: jax.Array):
        return pr.init_params(self.specs(), key, self.cfg.param_dtype)

    # ---- encoder -----------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames: (B, T, D) stub embeddings -> encoder output (B, T, D)."""
        cfg = self.cfg
        pos = sinusoidal_positions(frames.shape[1], cfg.d_model)
        h = frames.astype(jnp.dtype(cfg.dtype)) + pos.astype(cfg.dtype)[None]
        h = constrain(h, ("batch", None, None))

        def body(h, bp):
            hn = rmsnorm(bp["ln1"], h, cfg.norm_eps)
            h = h + attend_full(bp["attn"], hn, cfg, positions=None,
                                causal=False)
            hn = rmsnorm(bp["ln2"], h, cfg.norm_eps)
            h = h + mlp(bp["mlp"], hn, cfg)
            return h, None

        h, _ = maybe_scan(body, h, params["enc"],
                          force_unroll=self.force_unroll)
        return rmsnorm(params["enc_norm"], h, cfg.norm_eps)

    def _cross_kv(self, bp, enc_out):
        k = jnp.einsum("btd,dhk->bthk", enc_out,
                       bp["cross"]["wk"].astype(enc_out.dtype))
        v = jnp.einsum("btd,dhk->bthk", enc_out,
                       bp["cross"]["wv"].astype(enc_out.dtype))
        return k, v

    # ---- decoder (teacher-forced / prefill logits) --------------------------
    def forward(self, params, tokens: jax.Array, frames: jax.Array,
                remat: str = "none") -> tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        pos = sinusoidal_positions(tokens.shape[1], cfg.d_model)
        h = embed(params["embed"], tokens, jnp.dtype(cfg.dtype)) + \
            pos.astype(cfg.dtype)[None]
        h = constrain(h, ("batch", None, None))

        def body(h, bp):
            hn = rmsnorm(bp["ln1"], h, cfg.norm_eps)
            h = h + attend_full(bp["self"], hn, cfg, positions=None,
                                causal=True)
            hn = rmsnorm(bp["lnx"], h, cfg.norm_eps)
            h = h + attend_full(bp["cross"], hn, cfg, positions=None,
                                cross_kv=self._cross_kv(bp, enc_out))
            hn = rmsnorm(bp["ln2"], h, cfg.norm_eps)
            h = h + mlp(bp["mlp"], hn, cfg)
            return h, None

        fn = body
        if remat in ("full", "dots"):
            fn = jax.checkpoint(body, prevent_cse=False)
        h, _ = maybe_scan(fn, h, params["dec"],
                          force_unroll=self.force_unroll)
        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = unembed(params["embed"], h, tied=True)
        return logits, jnp.zeros((), jnp.float32)

    # ---- decode ------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        hd = cfg.resolved_head_dim
        one = KVCache.init(batch, cfg.num_kv_heads, cache_len, hd, dtype)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None],
                                       (cfg.num_layers,) + x.shape).copy(), one)
        # cross K/V computed once per request at prefill; stored stacked.
        xk = jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                        cfg.num_kv_heads, hd), dtype)
        return {"self": stacked, "cross_k": xk, "cross_v": xk}

    def decode(self, params, cache, tokens: jax.Array,
               *, positions=None) -> tuple[jax.Array, Any]:
        cfg = self.cfg
        pos_scalar = jax.tree.leaves(cache["self"])[-1][0]   # pos of layer 0
        h = embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
        ptab = sinusoidal_positions(1, cfg.d_model, offset=pos_scalar)
        h = h + ptab.astype(cfg.dtype)[None]

        def body(h, xs):
            bp, kv_cache, xk, xv = xs
            hn = rmsnorm(bp["ln1"], h, cfg.norm_eps)
            y, kv_cache = attn_decode(bp["self"], hn, kv_cache, cfg,
                                      positions=None)
            h = h + y
            hn = rmsnorm(bp["lnx"], h, cfg.norm_eps)
            h = h + attend_full(bp["cross"], hn, cfg, positions=None,
                                cross_kv=(xk, xv))
            hn = rmsnorm(bp["ln2"], h, cfg.norm_eps)
            h = h + mlp(bp["mlp"], hn, cfg)
            return h, kv_cache

        h, new_self = maybe_scan(
            body, h, (params["dec"], cache["self"], cache["cross_k"],
                      cache["cross_v"]), force_unroll=self.force_unroll)
        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = unembed(params["embed"], h, tied=True)
        return logits, {"self": new_self, "cross_k": cache["cross_k"],
                        "cross_v": cache["cross_v"]}
