"""Feed-forward blocks: gated (SwiGLU/GeGLU) dense MLP and GShard-style
top-k MoE with capacity-factor dispatch (EP-shardable on the expert axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.sharding import constrain
from repro.models.common import activation
from repro.models.params import Spec


def mlp_specs(cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wi_gate": Spec((d, f), ("fsdp", "mlp")),
        "wi_up": Spec((d, f), ("fsdp", "mlp")),
        "wo": Spec((f, d), ("mlp", "fsdp")),
    }


def mlp(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    act = activation(cfg.act)
    g = constrain(jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(x.dtype)),
                  ("batch", None, "mlp"))
    u = constrain(jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(x.dtype)),
                  ("batch", None, "mlp"))
    out = jnp.einsum("bsf,fd->bsd", act(g) * u, p["wo"].astype(x.dtype))
    return constrain(out, ("batch", None, None))


# ----------------------------------------------------------------------------
# MoE (GShard/Switch-style top-k with capacity; dropless-ish via capacity
# factor; aux load-balance loss returned for the trainer)
# ----------------------------------------------------------------------------
def moe_specs(cfg: ArchConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": Spec((d, e), ("fsdp", "experts"), scale=0.1),
        "wi_gate": Spec((e, d, f), ("experts", "fsdp", "mlp")),
        "wi_up": Spec((e, d, f), ("experts", "fsdp", "mlp")),
        "wo": Spec((e, f, d), ("experts", "mlp", "fsdp")),
    }


def moe(p: dict, x: jax.Array, cfg: ArchConfig,
        group_size: int | None = None) -> tuple[jax.Array, jax.Array]:
    """Returns (out, aux_loss).  x: (B, S, D).

    *Grouped* GShard dispatch: tokens are split into groups of ``group_size``
    and each group routes independently with per-expert capacity
    C = ceil(group * k / E * capacity_factor).  The (g, E, C) dispatch tensor
    scales quadratically in the group size, so grouping bounds the dispatch
    working set regardless of global batch (1M-token train_4k steps would
    need a ~TB-scale flat dispatch otherwise).  Tokens over capacity are
    dropped (standard GShard).  Group axis shards on (pod, data); experts on
    model (EP).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    xt = x.reshape(t, d)
    act = activation(cfg.act)

    g = min(group_size or cfg.moe_group_size, t)
    pad = (-t) % g
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    ng = xt.shape[0] // g
    xg = xt.reshape(ng, g, d)

    xg = constrain(xg, ("batch", None, None))
    logits = constrain(
        jnp.einsum("Ggd,de->Gge", xg.astype(jnp.float32),
                   p["router"].astype(jnp.float32)),
        ("batch", None, "experts"))
    probs = jax.nn.softmax(logits, axis=-1)                  # (G, g, E)
    gate_vals, choices = jax.lax.top_k(probs, k)             # (G, g, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)    # renormalize

    # small groups (decode steps, smoke tests) run dropless — capacity only
    # binds where it pays, at prefill/train group sizes.
    cap = g if g <= 64 else max(1, min(g, int(g * k / e *
                                              cfg.moe_capacity_factor)))
    onehot = jax.nn.one_hot(choices, e, dtype=jnp.float32)   # (G, g, k, E)
    flat = onehot.reshape(ng, g * k, e)
    pos_flat = jnp.cumsum(flat, axis=1) - flat               # queue position
    pos = jnp.einsum("Ggke,Ggke->Ggk",
                     pos_flat.reshape(ng, g, k, e), onehot)  # (G, g, k)
    keep = (pos < cap).astype(jnp.float32)
    gate_vals = gate_vals * keep

    # the big (G,g,E,C)-shaped dispatch/combine tensors carry exact 0/1 (and
    # bf16-rounded gate) values — ride the activation dtype, not fp32
    # (§Perf cell A iteration 2).
    dd = x.dtype
    pos_oh = (jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
              * keep[..., None])
    # the (G,g,E,C) dispatch/combine intermediates MUST be group-sharded —
    # without the constraint the partitioner replicates them per device
    # (~20 GB/layer at train_4k; §Perf cell A iteration 3).
    dispatch = constrain(
        jnp.einsum("Ggke,Ggkc->Ggec", onehot.astype(dd), pos_oh.astype(dd)),
        ("batch", None, "experts", "expert_cap"))             # (G, g, E, C)
    combine = constrain(
        jnp.einsum("Ggec,Ggk,Ggke->Ggec", dispatch,
                   gate_vals.astype(dd), onehot.astype(dd)),
        ("batch", None, "experts", "expert_cap"))

    # when E divides the model axis this shards experts (EP); otherwise the
    # divisibility fallback lands on the *capacity* dim so MoE compute still
    # splits across the model axis instead of replicating (granite-3b's 40
    # experts; EXPERIMENTS.md §Perf cell A).
    xe = constrain(jnp.einsum("Ggec,Ggd->Gecd", dispatch, xg),
                   ("batch", "experts", "expert_cap", None))
    gg = jnp.einsum("Gecd,edf->Gecf", xe, p["wi_gate"].astype(x.dtype))
    uu = jnp.einsum("Gecd,edf->Gecf", xe, p["wi_up"].astype(x.dtype))
    ye = constrain(jnp.einsum("Gecf,efd->Gecd", act(gg) * uu,
                              p["wo"].astype(x.dtype)),
                   ("batch", "experts", "expert_cap", None))
    out = constrain(jnp.einsum("Ggec,Gecd->Ggd", combine, ye),
                    ("batch", None, None))
    out = out.reshape(-1, d)[:t].reshape(b, s, d)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    f_e = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))     # fraction routed
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e) / k
    return out, aux.astype(jnp.float32)
