"""Parameter-spec trees: shapes + logical sharding axes, materialization-free.

Models declare their parameters as trees of :class:`Spec` (shape, per-dim
logical axis names, init recipe).  Three consumers:
  * ``init_params``     — materialize real arrays (training, smoke tests);
  * ``shape_tree``      — ``jax.ShapeDtypeStruct`` stand-ins (the dry-run
                          lowers against these; nothing is allocated);
  * ``logical_tree``    — feeds ``distributed.sharding.resolve_spec`` to build
                          the in/out shardings for pjit.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "fan_in"        # fan_in | normal | zeros | ones | embed
    scale: float = 1.0
    dtype: str | None = None    # override (norm scales stay fp32)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _fan_in(shape: tuple[int, ...]) -> int:
    # convention: last dim is the output features; everything else is fan-in
    return max(1, math.prod(shape[:-1]))


def init_leaf(spec: Spec, key: jax.Array, default_dtype: str) -> jax.Array:
    dtype = jnp.dtype(spec.dtype or default_dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "normal":
        return (spec.scale * jax.random.normal(key, spec.shape)).astype(dtype)
    if spec.init == "embed":
        return (spec.scale * jax.random.normal(key, spec.shape)).astype(dtype)
    if spec.init == "fan_in":
        std = spec.scale / np.sqrt(_fan_in(spec.shape))
        return (std * jax.random.normal(key, spec.shape)).astype(dtype)
    raise ValueError(spec.init)


def init_params(spec_tree, key: jax.Array, default_dtype: str = "float32"):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [init_leaf(s, k, default_dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def shape_tree(spec_tree, default_dtype: str = "float32"):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or default_dtype)),
        spec_tree, is_leaf=is_spec)


def logical_tree(spec_tree):
    return jax.tree.map(lambda s: s.logical, spec_tree, is_leaf=is_spec)


def param_count(spec_tree) -> int:
    return sum(math.prod(s.shape)
               for s in jax.tree.leaves(spec_tree, is_leaf=is_spec))


def param_bytes(spec_tree, default_dtype: str = "float32") -> int:
    return sum(math.prod(s.shape) * jnp.dtype(s.dtype or default_dtype).itemsize
               for s in jax.tree.leaves(spec_tree, is_leaf=is_spec))
