"""Model registry: ArchConfig -> model object + input builders.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of the (arch x shape) cell — the dry-run lowers against these
with no allocation.  ``input_arrays`` materializes small real inputs for
smoke tests / examples with the same structure.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig, ShapeSpec
from repro.models.encdec import EncDecLM
from repro.models.transformer import LM


def build_model(cfg: ArchConfig):
    return EncDecLM(cfg) if cfg.family == "audio" else LM(cfg)


def _mrope_positions_struct(b: int, s: int):
    return jax.ShapeDtypeStruct((3, b, s), jnp.int32)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStruct tree for one dry-run cell.

    train:   tokens + labels (+ stub patches / frames / mrope positions)
    prefill: tokens (+ stubs)
    decode:  one new token + the cache is supplied separately (see
             launch/dryrun.py — caches come from model.init_cache shapes).
    """
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    one = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    dt = jnp.dtype(cfg.dtype)

    if cfg.family == "audio":
        frames = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), dt)
        if shape.kind == "train":
            return {"tokens": tok, "labels": tok, "frames": frames}
        if shape.kind == "prefill":
            return {"tokens": tok, "frames": frames}
        return {"tokens": one}

    out: dict[str, Any] = {}
    if shape.kind == "train":
        out = {"tokens": tok, "labels": tok}
    elif shape.kind == "prefill":
        out = {"tokens": tok}
    else:
        out = {"tokens": one}

    if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
        out["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.d_model), dt)
        out["positions"] = _mrope_positions_struct(b, s)
    elif cfg.family == "vlm":
        out["positions"] = _mrope_positions_struct(b, 1)
    return out


def input_arrays(cfg: ArchConfig, shape: ShapeSpec, seed: int = 0
                 ) -> dict[str, Any]:
    """Small real inputs with the cell's structure (for smoke tests the
    caller passes a reduced cfg + reduced ShapeSpec)."""
    rng = np.random.default_rng(seed)
    structs = input_specs(cfg, shape)
    out = {}
    for name, sd in structs.items():
        if sd.dtype == jnp.int32 and name in ("tokens", "labels"):
            out[name] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=sd.shape), jnp.int32)
        elif name == "positions":
            s = sd.shape[-1]
            pos = np.broadcast_to(np.arange(s), sd.shape).copy()
            out[name] = jnp.asarray(pos, jnp.int32)
        else:
            out[name] = jnp.asarray(rng.normal(size=sd.shape) * 0.02, sd.dtype)
    return out
