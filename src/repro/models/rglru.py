"""Griffin RG-LRU recurrent block (recurrentgemma) [arXiv:2402.19427].

Block structure (the "recurrent block" of Griffin):
    x ->  linear (D -> lru) -> causal conv1d (width 4) -> RG-LRU  \
    x ->  linear (D -> lru) -> GeLU                                ⊙ -> out proj

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)                 (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                 (input gate)
    log a_t = -c * softplus(Λ) * r_t             (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t ⊙ x_t)

The recurrence is a linear first-order scan → computed with
``jax.lax.associative_scan`` (parallel prefix), the wavefront-parallel
formulation of the paper's pipeline parallelism.  The temporal conv is the
paper's 1D stencil (kernels/conv1d on TPU).

The prefill/train path scans the whole sequence; the decode path carries
(conv_state (K-1 tokens), h) per layer.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.sharding import constrain
from repro.kernels.conv1d.ops import causal_conv1d
from repro.models.params import Spec

_C = 8.0


def rglru_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    k = cfg.conv_width
    return {
        "w_in": Spec((d, w), ("fsdp", "mlp")),
        "w_gate_branch": Spec((d, w), ("fsdp", "mlp")),
        "conv_w": Spec((k, w), ("conv_k", "mlp"), scale=1.0),
        "conv_b": Spec((w,), ("mlp",), init="zeros"),
        "wa": Spec((w, w), ("mlp", None), scale=0.5),
        "ba": Spec((w,), (None,), init="zeros"),
        "wx": Spec((w, w), ("mlp", None), scale=0.5),
        "bx": Spec((w,), (None,), init="zeros"),
        "lam": Spec((w,), (None,), init="normal", scale=1.0),
        "w_out": Spec((w, d), ("mlp", "fsdp")),
    }


class RGLRUState(NamedTuple):
    h: jax.Array           # (B, W) recurrent state
    conv: jax.Array        # (B, K-1, W) trailing inputs for the conv stencil


def _gates(p, xc):
    """xc: (..., W) post-conv branch -> (log_a, bx_scaled) both (..., W)."""
    r = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", xc.astype(jnp.float32),
                   p["wa"].astype(jnp.float32)) + p["ba"])
    i = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", xc.astype(jnp.float32),
                   p["wx"].astype(jnp.float32)) + p["bx"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xc.astype(jnp.float32))
    return log_a, b


def rglru_scan(p: dict, xc: jax.Array) -> jax.Array:
    """xc: (B, S, W) -> h: (B, S, W) via associative scan over
    h_t = a_t h_{t-1} + b_t  (composition: (a1,b1)∘(a2,b2) = (a1a2, a2 b1 + b2)).

    The log-decay carry stays fp32 (long products need it); the additive
    carry ``b`` rides in the activation dtype — at bf16 this cuts the
    log2(S)-level scan traffic ~25% (§Perf cell C)."""
    log_a, b = _gates(p, xc)
    b = b.astype(xc.dtype)

    def combine(l, r):
        la_l, b_l = l
        la_r, b_r = r
        return (la_l + la_r,
                (jnp.exp(la_r).astype(b_r.dtype) * b_l + b_r))

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return h.astype(xc.dtype)


def rglru_block(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Full recurrent block, training/prefill path. x: (B, S, D)."""
    branch = constrain(jnp.einsum("bsd,dw->bsw", x, p["w_in"].astype(x.dtype)),
                       ("batch", None, "mlp"))
    gate = constrain(
        jnp.einsum("bsd,dw->bsw", x, p["w_gate_branch"].astype(x.dtype)),
        ("batch", None, "mlp"))
    xc = causal_conv1d(branch, p["conv_w"].astype(x.dtype),
                       p["conv_b"].astype(x.dtype))
    h = rglru_scan(p, xc)
    y = h * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsw,wd->bsd", y, p["w_out"].astype(x.dtype))


def rglru_decode(p: dict, x: jax.Array, state: RGLRUState,
                 cfg: ArchConfig) -> tuple[jax.Array, RGLRUState]:
    """Single-token decode. x: (B, 1, D)."""
    branch = jnp.einsum("bsd,dw->bsw", x, p["w_in"].astype(x.dtype))[:, 0]
    gate = jnp.einsum("bsd,dw->bsw", x, p["w_gate_branch"].astype(x.dtype))[:, 0]
    # conv over (state ++ current): (B, K, W)
    win = jnp.concatenate([state.conv, branch[:, None, :]], axis=1)
    wts = p["conv_w"].astype(x.dtype)
    xc = jnp.einsum("bkw,kw->bw", win, wts) + p["conv_b"].astype(x.dtype)
    log_a, b = _gates(p, xc)
    h = jnp.exp(log_a) * state.h.astype(jnp.float32) + b
    y = h.astype(x.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bw,wd->bd", y, p["w_out"].astype(x.dtype))[:, None, :]
    new_state = RGLRUState(h=h.astype(state.h.dtype), conv=win[:, 1:, :])
    return out, new_state


def rglru_init_state(batch: int, cfg: ArchConfig, dtype) -> RGLRUState:
    w = cfg.lru_width or cfg.d_model
    return RGLRUState(
        h=jnp.zeros((batch, w), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, w), dtype))
