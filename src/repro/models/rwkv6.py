"""RWKV-6 "Finch" block [arXiv:2404.05892] — attention-free, data-dependent
decay.

Time-mix (per head h, head_dim n):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (S: (n, n) per head)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with data-dependent per-channel decay  w_t = exp(-exp(w0 + lora_w(x_mix))) and
the token-shift data-dependent interpolation (ddlerp) of RWKV-6.  GroupNorm
per head on the output, sigmoid(gate) multiplicative gate.

Channel-mix: out = sigmoid(x_r W_r) ⊙ (relu(x_k W_k)^2 W_v).

The token shift is a radius-1 one-sided sequence stencil (the paper's
technique at its smallest); the WKV recurrence itself is a wavefront scan
(``jax.lax.scan`` over time with (B, H, n, n) state) — chunked variants are a
§Perf iteration.  Decode carries (shift_tm, shift_cm, S).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.sharding import constrain
from repro.models.params import Spec

_LORA_TM = 32      # ddlerp lora rank (5 projections)
_LORA_W = 64       # decay lora rank


def rwkv_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    f = cfg.d_ff
    h = cfg.num_heads
    n = cfg.resolved_head_dim
    assert h * n == d, "rwkv heads*head_dim must equal d_model"
    return {
        # time-mix ddlerp
        "mu_x": Spec((d,), (None,), init="zeros"),
        "mu": Spec((5, d), (None, None), init="zeros"),        # w,k,v,r,g
        "tm_w1": Spec((d, 5 * _LORA_TM), ("fsdp", None), scale=0.1),
        "tm_w2": Spec((5, _LORA_TM, d), (None, None, "fsdp"), scale=0.1),
        # decay
        "w0": Spec((d,), (None,), init="normal", scale=1.0),
        "w_lora1": Spec((d, _LORA_W), ("fsdp", None), scale=0.1),
        "w_lora2": Spec((_LORA_W, d), (None, "fsdp"), scale=0.1),
        "u": Spec((h, n), ("heads", "head_dim"), init="normal", scale=0.5),
        # projections
        "wr": Spec((d, d), ("fsdp", "mlp")),
        "wk": Spec((d, d), ("fsdp", "mlp")),
        "wv": Spec((d, d), ("fsdp", "mlp")),
        "wg": Spec((d, d), ("fsdp", "mlp")),
        "wo": Spec((d, d), ("mlp", "fsdp")),
        "ln_x_scale": Spec((d,), (None,), init="ones", dtype="float32"),
        # channel-mix
        "cm_mu_k": Spec((d,), (None,), init="zeros"),
        "cm_mu_r": Spec((d,), (None,), init="zeros"),
        "cm_wk": Spec((d, f), ("fsdp", "mlp")),
        "cm_wv": Spec((f, d), ("mlp", "fsdp")),
        "cm_wr": Spec((d, d), ("fsdp", "mlp")),
    }


class RWKVState(NamedTuple):
    shift_tm: jax.Array    # (B, D) previous token (time-mix)
    shift_cm: jax.Array    # (B, D) previous token (channel-mix)
    s: jax.Array           # (B, H, n, n) WKV state (fp32)


def _ddlerp(p, x, xx):
    """RWKV6 data-dependent token-shift interpolation.
    x, xx: (B, S, D); returns 5 mixed streams (w, k, v, r, g)."""
    xf = x.astype(jnp.float32)
    dxf = xx.astype(jnp.float32) - xf
    base = xf + dxf * p["mu_x"]
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", base, p["tm_w1"].astype(jnp.float32)))
    lora = lora.reshape(*lora.shape[:-1], 5, _LORA_TM)
    adj = jnp.einsum("bsir,ird->bsid", lora, p["tm_w2"].astype(jnp.float32))
    mixed = xf[:, :, None, :] + dxf[:, :, None, :] * (p["mu"] + adj)
    return [mixed[:, :, i, :] for i in range(5)]             # each (B, S, D)


def _decay(p, xw):
    """w_t in (0,1): exp(-exp(w0 + lora));  xw: (B, S, D) fp32."""
    lora = jnp.einsum("bsd,dr->bsr", jnp.tanh(xw), p["w_lora1"].astype(jnp.float32))
    ww = p["w0"] + jnp.einsum("bsr,rd->bsd", lora, p["w_lora2"].astype(jnp.float32))
    return jnp.exp(-jnp.exp(ww.clip(-30.0, 20.0)))


def _wkv_scan(r, k, v, w, u, s0):
    """r/k/v/w: (B, S, H, n) fp32; u: (H, n); s0: (B, H, n, n).
    Returns o: (B, S, H, n), s_final."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                              # (B, H, n)
        kv = jnp.einsum("bhi,bhj->bhij", k_t, v_t)            # (B, H, n, n)
        o = jnp.einsum("bhi,bhij->bhj", r_t, s + u[None, :, :, None] * kv)
        s_new = w_t[..., None] * s + kv
        return s_new, o

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))   # (S, B, H, n)
    s_fin, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 1), s_fin


def rwkv_time_mix(p: dict, x: jax.Array, cfg: ArchConfig, *,
                  shift: jax.Array | None = None,
                  s0: jax.Array | None = None):
    """x: (B, S, D) -> (out, final RWKV substate pieces)."""
    b, s, d = x.shape
    h, n = cfg.num_heads, cfg.resolved_head_dim
    prev = jnp.zeros((b, 1, d), x.dtype) if shift is None else shift[:, None, :]
    xx = jnp.concatenate([prev, x[:, :-1, :]], axis=1)        # token shift
    xw, xk, xv, xr, xg = _ddlerp(p, x, xx)

    w = _decay(p, xw)                                          # (B,S,D)

    # projections read weights in their native dtype (bf16 at serving) with
    # fp32 accumulation — casting bf16->f32 per step costs 6 extra B/elem
    # and made B2 *slower* (§Perf cell B iteration 2, refuted -> 2').
    def proj(a, wname):
        wt = p[wname]
        return jnp.einsum("bsd,de->bse", a.astype(wt.dtype), wt,
                          preferred_element_type=jnp.float32)

    r = proj(xr, "wr")
    k = proj(xk, "wk")
    v = proj(xv, "wv")
    g = proj(xg, "wg")

    rh = constrain(r.reshape(b, s, h, n), ("batch", None, "heads", None))
    kh = constrain(k.reshape(b, s, h, n), ("batch", None, "heads", None))
    vh = constrain(v.reshape(b, s, h, n), ("batch", None, "heads", None))
    wh = constrain(w.reshape(b, s, h, n), ("batch", None, "heads", None))
    s_init = (jnp.zeros((b, h, n, n), jnp.float32) if s0 is None else s0)
    o, s_fin = _wkv_scan(rh, kh, vh, wh, p["u"].astype(jnp.float32), s_init)

    o = o.reshape(b, s, d)
    # per-head groupnorm
    og = o.reshape(b, s, h, n)
    mu = jnp.mean(og, axis=-1, keepdims=True)
    var = jnp.var(og, axis=-1, keepdims=True)
    og = (og - mu) * jax.lax.rsqrt(var + 64e-5)
    o = og.reshape(b, s, d) * p["ln_x_scale"]
    out = (o * jax.nn.silu(g)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", out, p["wo"].astype(x.dtype))
    return out, (x[:, -1, :], s_fin)


def rwkv_channel_mix(p: dict, x: jax.Array, *, shift: jax.Array | None = None):
    b, s, d = x.shape
    prev = jnp.zeros((b, 1, d), x.dtype) if shift is None else shift[:, None, :]
    xx = jnp.concatenate([prev, x[:, :-1, :]], axis=1)
    xf, dxf = x.astype(jnp.float32), (xx - x).astype(jnp.float32)
    xk = xf + dxf * p["cm_mu_k"]
    xr = xf + dxf * p["cm_mu_r"]
    kk = constrain(jnp.square(jax.nn.relu(
        jnp.einsum("bsd,df->bsf", xk.astype(p["cm_wk"].dtype), p["cm_wk"],
                   preferred_element_type=jnp.float32))),
        ("batch", None, "mlp"))
    vv = jnp.einsum("bsf,fd->bsd", kk.astype(p["cm_wv"].dtype), p["cm_wv"],
                    preferred_element_type=jnp.float32)
    rr = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr.astype(p["cm_wr"].dtype), p["cm_wr"],
                   preferred_element_type=jnp.float32))
    return (rr * vv).astype(x.dtype), x[:, -1, :]


def rwkv_init_state(batch: int, cfg: ArchConfig, dtype) -> RWKVState:
    d, h, n = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    return RWKVState(
        shift_tm=jnp.zeros((batch, d), dtype),
        shift_cm=jnp.zeros((batch, d), dtype),
        s=jnp.zeros((batch, h, n, n), jnp.float32))
