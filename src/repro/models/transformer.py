"""Decoder-only LM stack covering the dense / moe / hybrid / ssm / vlm
families.

Layer-scan structure: the block pattern (e.g. ``("rglru","rglru","local")``
for recurrentgemma) is cycled over ``num_layers``; full pattern periods are
stacked and driven by one ``jax.lax.scan`` whose body applies one period
(keeps HLO size ~O(period), independent of depth — essential for the 64-layer
dry-runs), and the ``num_layers % period`` remainder is applied unrolled.
Remat (``jax.checkpoint``) wraps the scan body.

Caches: each layer kind carries its own state type — KVCache (full), ring-buffer
KVCache (local window), RGLRUState, RWKVState — stacked along the scan axis.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import params as pr
from repro.models.attention import (KVCache, attention_specs, attend_full,
                                    attend_local, decode_step as attn_decode)
from repro.models.common import embed, embed_spec, rmsnorm, rmsnorm_spec, unembed
from repro.models.mlp import mlp, mlp_specs, moe, moe_specs
from repro.models.params import Spec
from repro.models.rglru import (RGLRUState, rglru_block, rglru_decode,
                                rglru_init_state, rglru_specs)
from repro.models.rwkv6 import (RWKVState, rwkv_channel_mix, rwkv_init_state,
                                rwkv_specs, rwkv_time_mix)


def maybe_scan(body, carry, xs, *, force_unroll: bool = False):
    """lax.scan, except a leading dim of 1 (or ``force_unroll``) is applied
    as an unrolled python loop — no while op.  Besides being cheaper for
    n==1, this is what lets the dry-run's 1-period / 2-period clone compiles
    produce *unrolled* HLO so scan-body costs can be extrapolated (XLA's
    cost_analysis counts a while body exactly once, ignoring trip count —
    see launch/dryrun.py §scan-correction)."""
    n = jax.tree.leaves(xs)[0].shape[0]
    if n == 1 or force_unroll:
        ys = []
        for i in range(n):
            carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
            ys.append(y)
        if ys and ys[0] is not None:
            y = jax.tree.map(lambda *a: jnp.stack(a), *ys)
        else:
            y = None
        return carry, y
    return jax.lax.scan(body, carry, xs)


def stack_specs(tree, n: int):
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, ("layers",) + s.logical, init=s.init,
                       scale=s.scale, dtype=s.dtype),
        tree, is_leaf=pr.is_spec)


def block_specs(cfg: ArchConfig, kind: str) -> dict:
    d = cfg.d_model
    out: dict[str, Any] = {"ln1": rmsnorm_spec(d), "ln2": rmsnorm_spec(d)}
    if kind in ("attn", "local"):
        out["attn"] = attention_specs(cfg)
        out["mlp"] = moe_specs(cfg) if cfg.num_experts else mlp_specs(cfg)
    elif kind == "rglru":
        out["rec"] = rglru_specs(cfg)
        out["mlp"] = moe_specs(cfg) if cfg.num_experts else mlp_specs(cfg)
    elif kind == "rwkv":
        out["rwkv"] = rwkv_specs(cfg)
    else:
        raise ValueError(kind)
    return out


class LM:
    """Decoder-only language model built from an ArchConfig."""

    def __init__(self, cfg: ArchConfig, force_unroll: bool = False):
        self.cfg = cfg
        self.period = len(cfg.block_pattern)
        self.n_full = cfg.num_layers // self.period
        self.n_tail = cfg.num_layers % self.period
        self.force_unroll = force_unroll   # dry-run scan-cost clones

    # ----- parameters -------------------------------------------------------
    def specs(self) -> dict:
        cfg = self.cfg
        d, v = cfg.d_model, cfg.vocab_size
        specs: dict[str, Any] = {
            "embed": embed_spec(v, d),
            "final_norm": rmsnorm_spec(d),
        }
        if self.n_full:
            specs["scan"] = {
                f"p{p}": stack_specs(block_specs(cfg, cfg.block_pattern[p]),
                                     self.n_full)
                for p in range(self.period)
            }
        if self.n_tail:
            specs["tail"] = {
                f"t{i}": block_specs(cfg, cfg.block_pattern[i])
                for i in range(self.n_tail)
            }
        if not cfg.tie_embeddings:
            specs["unembed"] = Spec((d, v), ("fsdp", "vocab"))
        return specs

    def init(self, key: jax.Array):
        return pr.init_params(self.specs(), key, self.cfg.param_dtype)

    # ----- forward (train / prefill logits) ---------------------------------
    def _apply_block(self, kind: str, bp: dict, h: jax.Array, aux: jax.Array,
                     positions) -> tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        if kind in ("attn", "local"):
            hn = rmsnorm(bp["ln1"], h, cfg.norm_eps)
            if kind == "attn":
                h = h + attend_full(bp["attn"], hn, cfg, positions=positions)
            else:
                h = h + attend_local(bp["attn"], hn, cfg, positions=positions)
            hn = rmsnorm(bp["ln2"], h, cfg.norm_eps)
            if cfg.num_experts:
                y, a = moe(bp["mlp"], hn, cfg)
                h, aux = h + y, aux + a
            else:
                h = h + mlp(bp["mlp"], hn, cfg)
        elif kind == "rglru":
            hn = rmsnorm(bp["ln1"], h, cfg.norm_eps)
            h = h + rglru_block(bp["rec"], hn, cfg)
            hn = rmsnorm(bp["ln2"], h, cfg.norm_eps)
            h = h + mlp(bp["mlp"], hn, cfg)
        elif kind == "rwkv":
            hn = rmsnorm(bp["ln1"], h, cfg.norm_eps)
            y, _ = rwkv_time_mix(bp["rwkv"], hn, cfg)
            h = h + y
            hn = rmsnorm(bp["ln2"], h, cfg.norm_eps)
            y, _ = rwkv_channel_mix(bp["rwkv"], hn)
            h = h + y
        return h, aux

    def embed_inputs(self, params, tokens, patches=None) -> jax.Array:
        cfg = self.cfg
        h = embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
        if cfg.family == "hybrid":                      # gemma lineage scales
            h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
        if patches is not None:
            tv = patches.shape[1]
            h = jnp.concatenate([patches.astype(h.dtype), h[:, tv:, :]], axis=1)
        return constrain(h, ("batch", None, None))

    def forward(self, params, tokens, *, positions=None, patches=None,
                remat: str = "none") -> tuple[jax.Array, jax.Array]:
        """tokens: (B, S) -> (logits (B,S,V) fp32, aux loss scalar)."""
        cfg = self.cfg
        h = self.embed_inputs(params, tokens, patches)
        if positions is None:
            positions = jnp.arange(tokens.shape[1])[None, :]
        aux = jnp.zeros((), jnp.float32)

        def period_body(carry, layer_ps):
            h, aux = carry
            for p in range(self.period):
                h, aux = self._apply_block(cfg.block_pattern[p],
                                           layer_ps[f"p{p}"], h, aux,
                                           positions)
                h = constrain(h, ("batch", None, None))
            return (h, aux), None

        body = period_body
        if remat == "full":
            body = jax.checkpoint(period_body, prevent_cse=False)
        elif remat == "dots":
            body = jax.checkpoint(
                period_body, prevent_cse=False,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        if self.n_full:
            (h, aux), _ = maybe_scan(body, (h, aux), params["scan"],
                                     force_unroll=self.force_unroll)
        for i in range(self.n_tail):
            h, aux = self._apply_block(cfg.block_pattern[i],
                                       params["tail"][f"t{i}"], h, aux,
                                       positions)

        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = unembed(params.get("unembed", params["embed"]), h,
                         tied=cfg.tie_embeddings)
        return constrain(logits, ("batch", None, "vocab")), aux

    # ----- serving ----------------------------------------------------------
    def _cache_for(self, kind: str, batch: int, cache_len: int, dtype):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        if kind == "attn":
            return KVCache.init(batch, cfg.num_kv_heads, cache_len, hd, dtype)
        if kind == "local":
            return KVCache.init(batch, cfg.num_kv_heads,
                                min(cache_len, cfg.window), hd, dtype)
        if kind == "rglru":
            return rglru_init_state(batch, cfg, dtype)
        if kind == "rwkv":
            return rwkv_init_state(batch, cfg, dtype)
        raise ValueError(kind)

    def init_cache(self, batch: int, cache_len: int):
        """Cache pytree matching the parameter layout (scan-stacked)."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        cache: dict[str, Any] = {}
        if self.n_full:
            cache["scan"] = {
                f"p{p}": jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (self.n_full,) + x.shape).copy(),
                    self._cache_for(cfg.block_pattern[p], batch, cache_len,
                                    dtype))
                for p in range(self.period)
            }
        if self.n_tail:
            cache["tail"] = {
                f"t{i}": self._cache_for(cfg.block_pattern[i], batch,
                                         cache_len, dtype)
                for i in range(self.n_tail)
            }
        return cache

    def _decode_block(self, kind: str, bp: dict, h: jax.Array, cache,
                      positions):
        cfg = self.cfg
        if kind in ("attn", "local"):
            hn = rmsnorm(bp["ln1"], h, cfg.norm_eps)
            y, cache = attn_decode(bp["attn"], hn, cache, cfg,
                                   window=cfg.window if kind == "local" else 0,
                                   positions=positions)
            h = h + y
            hn = rmsnorm(bp["ln2"], h, cfg.norm_eps)
            if cfg.num_experts:
                y, _ = moe(bp["mlp"], hn, cfg)
                h = h + y
            else:
                h = h + mlp(bp["mlp"], hn, cfg)
        elif kind == "rglru":
            hn = rmsnorm(bp["ln1"], h, cfg.norm_eps)
            y, new_state = rglru_decode(bp["rec"], hn, cache, cfg)
            h, cache = h + y, new_state
            hn = rmsnorm(bp["ln2"], h, cfg.norm_eps)
            h = h + mlp(bp["mlp"], hn, cfg)
        elif kind == "rwkv":
            hn = rmsnorm(bp["ln1"], h, cfg.norm_eps)
            y, (tm_shift, s_fin) = rwkv_time_mix(
                bp["rwkv"], hn, cfg, shift=cache.shift_tm, s0=cache.s)
            h = h + y
            hn = rmsnorm(bp["ln2"], h, cfg.norm_eps)
            y, cm_shift = rwkv_channel_mix(bp["rwkv"], hn,
                                           shift=cache.shift_cm)
            h = h + y
            cache = RWKVState(shift_tm=tm_shift, shift_cm=cm_shift, s=s_fin)
        return h, cache

    def decode(self, params, cache, tokens, *, positions=None
               ) -> tuple[jax.Array, Any]:
        """One-token decode. tokens: (B, 1). Returns (logits (B,1,V), cache)."""
        cfg = self.cfg
        h = self.embed_inputs(params, tokens)

        def body(h, xs):
            layer_ps, layer_cache = xs
            new_caches = {}
            for p in range(self.period):
                h, nc = self._decode_block(cfg.block_pattern[p],
                                           layer_ps[f"p{p}"], h,
                                           layer_cache[f"p{p}"], positions)
                new_caches[f"p{p}"] = nc
            return h, new_caches

        new_cache: dict[str, Any] = {}
        if self.n_full:
            h, new_cache["scan"] = maybe_scan(
                body, h, (params["scan"], cache["scan"]),
                force_unroll=self.force_unroll)
        if self.n_tail:
            new_cache["tail"] = {}
            for i in range(self.n_tail):
                h, nc = self._decode_block(cfg.block_pattern[i],
                                           params["tail"][f"t{i}"], h,
                                           cache["tail"][f"t{i}"], positions)
                new_cache["tail"][f"t{i}"] = nc

        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = unembed(params.get("unembed", params["embed"]), h,
                         tied=cfg.tie_embeddings)
        return logits, new_cache


def xent_loss(logits: jax.Array, labels: jax.Array,
              z_loss: float = 1e-4) -> jax.Array:
    """Mean token cross-entropy (fp32) + z-loss regularizer."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss
