"""Stencil program graphs: compose multi-operator DAGs into one fused
spatial pipeline (docs/program.md).

    prog = hdiff_program(48, 64)                     # IR: fields + op DAG
    plan = lower(prog, workers=4, auto_capacity=True)  # ONE combined DFG
    rf   = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
    res, fields = simulate_program(plan, {"inp": x}, CGRA, fabric=rf)
    # fields bit-match program_reference_np(prog, {"inp": x})
"""
from repro.program.ir import CombineOp, StencilOp, StencilProgram
from repro.program.library import (hdiff_program, laplacian_2d,
                                   two_stage_heat)
from repro.program.lower import (ProgramPlan, field_leads, lower,
                                 simulate_program)
from repro.program.oracle import program_reference, program_reference_np

__all__ = ["CombineOp", "StencilOp", "StencilProgram", "hdiff_program",
           "laplacian_2d", "two_stage_heat", "ProgramPlan", "field_leads",
           "lower", "simulate_program", "program_reference",
           "program_reference_np"]
