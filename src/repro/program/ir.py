"""Stencil-program IR: multi-operator DAGs over named fields.

The paper maps a *single* star stencil; real workloads (the paper's
seismic/oil-and-gas motivation, weather kernels like horizontal diffusion)
are **programs** of several dependent stencil operators.  Following
StencilFlow, a :class:`StencilProgram` is a DAG whose nodes are

* :class:`StencilOp` — apply a star stencil (a full :class:`StencilSpec`,
  including fused ``timesteps``) to one named field, producing another;
* :class:`CombineOp` — an elementwise linear combine
  ``out = sum_i coeffs[i] * inputs[i]`` (``a + b``, ``a - k*b``, ...);

and whose edges are the fields.  One field may fan out into any number of
consumers.  Fields that no op produces are the program's external inputs;
fields that no op consumes (or an explicit ``outputs=`` list) are its
results.

Shape/halo inference: every field lives on the one program grid and carries a
per-axis **margin** — the rim of sites that hold no valid value.  External
inputs have margin 0; a stencil op adds ``radius * timesteps`` per axis; a
combine's margin is the per-axis max of its inputs' margins (the intersection
of their valid boxes).  Margins are exactly the information the lowering
(:mod:`repro.program.lower`) needs to splice producer worker streams straight
into consumer tap chains, and the oracle (:mod:`repro.program.oracle`) needs
to mask each intermediate.
"""
from __future__ import annotations

import dataclasses

from repro.core.spec import StencilSpec


@dataclasses.dataclass(frozen=True)
class StencilOp:
    """Apply ``spec`` (incl. fused ``spec.timesteps`` sweeps) to ``input``."""

    name: str
    spec: StencilSpec
    input: str
    output: str

    @property
    def inputs(self) -> tuple[str, ...]:
        return (self.input,)


@dataclasses.dataclass(frozen=True)
class CombineOp:
    """Elementwise linear combine: ``out = sum_i coeffs[i] * inputs[i]``."""

    name: str
    inputs: tuple[str, ...]
    coeffs: tuple[float, ...]
    output: str

    def __post_init__(self):
        if not self.inputs:
            raise ValueError(f"combine op {self.name!r} needs >= 1 input")
        if len(self.coeffs) != len(self.inputs):
            raise ValueError(
                f"combine op {self.name!r}: {len(self.inputs)} inputs but "
                f"{len(self.coeffs)} coefficients")


class StencilProgram:
    """A validated, scheduled stencil-operator DAG.

    Construction performs all static analysis: single assignment per field,
    one shared grid/dtype, cycle detection (Kahn), topological scheduling,
    and per-field margin inference with non-empty valid boxes.
    """

    def __init__(self, name: str, ops, outputs=None,
                 grid_shape: tuple[int, ...] | None = None,
                 dtype: str | None = None):
        self.name = name
        self.ops: tuple = tuple(ops)
        if not self.ops:
            raise ValueError("a StencilProgram needs at least one op")
        names = [op.name for op in self.ops]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate op names: {sorted(names)}")

        # one grid, one dtype, shared by every stencil op -------------------
        specs = [op.spec for op in self.ops if isinstance(op, StencilOp)]
        shapes = {s.grid_shape for s in specs} | (
            {tuple(grid_shape)} if grid_shape else set())
        if len(shapes) != 1:
            got = sorted(shapes) or "none (pass grid_shape= for " \
                                    "combine-only programs)"
            raise ValueError(
                f"program {name!r} needs exactly one grid shape; got {got}")
        dtypes = {s.dtype for s in specs} | ({dtype} if dtype else set())
        if len(dtypes) != 1:
            got = sorted(dtypes) or "none (pass dtype= for combine-only " \
                                    "programs)"
            raise ValueError(
                f"program {name!r} needs exactly one dtype; got {got}")
        self.grid_shape: tuple[int, ...] = next(iter(shapes))
        self.dtype: str = next(iter(dtypes))

        # single assignment + external inputs -------------------------------
        producer: dict[str, object] = {}
        for op in self.ops:
            if op.output in producer:
                raise ValueError(
                    f"field {op.output!r} produced by both "
                    f"{producer[op.output].name!r} and {op.name!r} "
                    "(fields are single-assignment)")
            producer[op.output] = op
        self._producer = producer
        in_fields: list[str] = []
        for op in self.ops:
            for f in op.inputs:
                if f not in producer and f not in in_fields:
                    in_fields.append(f)
        self.in_fields: tuple[str, ...] = tuple(in_fields)

        # cycle detection + topological schedule (Kahn) ---------------------
        indeg = {op.name: sum(1 for f in op.inputs if f in producer)
                 for op in self.ops}
        consumers: dict[str, list] = {}
        for op in self.ops:
            for f in op.inputs:
                consumers.setdefault(f, []).append(op)
        ready = [op for op in self.ops if indeg[op.name] == 0]
        order: list = []
        while ready:
            op = ready.pop(0)
            order.append(op)
            for nxt in consumers.get(op.output, []):
                indeg[nxt.name] -= 1
                if indeg[nxt.name] == 0:
                    ready.append(nxt)
        if len(order) != len(self.ops):
            stuck = sorted(n for n, k in indeg.items() if k > 0)
            raise ValueError(f"program {name!r} has a cycle through ops "
                             f"{stuck}")
        self._schedule: tuple = tuple(order)

        # outputs: explicit, or every field nothing consumes ----------------
        consumed = {f for op in self.ops for f in op.inputs}
        if outputs is None:
            outputs = [op.output for op in self._schedule
                       if op.output not in consumed]
        for f in outputs:
            if f not in producer:
                raise ValueError(f"output field {f!r} is not produced by any "
                                 "op")
        if not outputs:
            raise ValueError(f"program {name!r} has no output fields")
        self.out_fields: tuple[str, ...] = tuple(outputs)

        # margin inference (per-field halo accounting across the DAG) -------
        d = len(self.grid_shape)
        m: dict[str, tuple[int, ...]] = {f: (0,) * d for f in self.in_fields}
        for op in self._schedule:
            if isinstance(op, StencilOp):
                m[op.output] = tuple(
                    mi + r * op.spec.timesteps
                    for mi, r in zip(m[op.input], op.spec.radii))
            else:
                m[op.output] = tuple(
                    max(m[f][b] for f in op.inputs) for b in range(d))
            for n, mb in zip(self.grid_shape, m[op.output]):
                if n - 2 * mb < 1:
                    raise ValueError(
                        f"field {op.output!r} (op {op.name!r}) has an empty "
                        f"valid box: margin {m[op.output]} on grid "
                        f"{self.grid_shape}")
        self._margins = m

    # ----- queries -----------------------------------------------------------
    def schedule(self) -> tuple:
        """Ops in dependency (topological) order."""
        return self._schedule

    def producer_of(self, field: str):
        return self._producer.get(field)

    def margins(self) -> dict[str, tuple[int, ...]]:
        """Per-field, per-axis invalid rim width (external inputs: 0)."""
        return dict(self._margins)

    def field_interior(self, field: str) -> tuple[int, ...]:
        """Valid-box extents of ``field``: ``n - 2*margin`` per axis."""
        return tuple(n - 2 * mb
                     for n, mb in zip(self.grid_shape, self._margins[field]))

    @property
    def rep_spec(self) -> StencilSpec:
        """A representative spec (grid/dtype carrier) for machine models and
        reader-stream construction."""
        for op in self.ops:
            if isinstance(op, StencilOp):
                return op.spec
        d = len(self.grid_shape)
        return StencilSpec(self.grid_shape, (0,) * d, ((1.0,),) * d,
                           dtype=self.dtype)

    def __repr__(self) -> str:
        return (f"StencilProgram({self.name!r}, {len(self.ops)} ops, "
                f"grid={self.grid_shape}, in={list(self.in_fields)}, "
                f"out={list(self.out_fields)})")
