"""Canonical stencil programs (shared by tests, benchmarks, and examples)."""
from __future__ import annotations

from repro.core.spec import StencilSpec, heat_2d
from repro.program.ir import CombineOp, StencilOp, StencilProgram


def two_stage_heat(ny: int, nx: int, alpha: float = 0.1,
                   dtype: str = "float64") -> StencilProgram:
    """``heat_2d ∘ heat_2d``: two dependent 5-pt Jacobi sweeps, fused into
    one spatial pipeline (no store/reload of the intermediate field)."""
    spec = heat_2d(ny, nx, alpha=alpha, dtype=dtype)
    return StencilProgram("two_stage_heat", [
        StencilOp("heat1", spec, input="u", output="u1"),
        StencilOp("heat2", spec, input="u1", output="u2"),
    ])


def laplacian_2d(ny: int, nx: int, dtype: str = "float64") -> StencilSpec:
    """Plain 5-pt laplacian (the hdiff first stage)."""
    return StencilSpec((ny, nx), (1, 1),
                       ((1.0, -4.0, 1.0), (1.0, 0.0, 1.0)), dtype=dtype)


def hdiff_program(ny: int, nx: int, coeff: float = 0.025,
                  dtype: str = "float64") -> StencilProgram:
    """StencilFlow-style horizontal diffusion: laplacian → flux → output.

    ``lap = ∇²(inp)``; ``flx`` is a symmetric flux smoother of ``lap``; the
    output combines the *original* field with the flux — the branch that
    makes ``inp`` fan out into both the deep (2-op) pipeline and the final
    combine, exercising the computed inter-operator skew buffers.
    """
    flux = StencilSpec((ny, nx), (1, 1),
                       ((0.25, 0.0, 0.25), (0.25, 0.0, 0.25)), dtype=dtype)
    return StencilProgram("hdiff", [
        StencilOp("lap", laplacian_2d(ny, nx, dtype), input="inp",
                  output="lap"),
        StencilOp("flx", flux, input="lap", output="flx"),
        CombineOp("out", inputs=("inp", "flx"), coeffs=(1.0, -coeff),
                  output="out"),
    ])
