"""Lower a :class:`StencilProgram` into ONE combined worker-pipeline DFG.

The StencilFlow insight: chaining stencil operators through the on-chip
network — producer worker streams spliced *directly* into consumer tap
chains — removes the store-to-memory/reload-from-memory round trip between
operators, which is where spatial architectures beat GPUs hardest.  This
module is that splice for the paper's CGRA worker pipeline:

* Each op is lowered with the PR 2 stage library (:mod:`repro.core.mapping`):
  per-worker :class:`TapChain`/:class:`AddTree` stacks whose *sources* are the
  producing op's worker output streams (or reader streams for external
  fields).  :func:`~repro.core.mapping.stages.owning_stream` resolves every
  tap's producer by innermost congruence class, so the same rule that stacks
  temporal layers inside one op splices *between* ops.
* **Inter-operator skew buffers** generalize the PR 2 per-axis mandatory
  buffering.  Each field carries a site-lead ``D(f)`` — the deepest
  pipeline distance from the external inputs, in grid sites, where a stencil
  op contributes ``timesteps * max_b(r_b * stride_b)``.  When an op joins
  fields of different depth (a combine after a fan-out), the shallow field's
  producer→filter queue must absorb ``(max_i D(f_i) - D(f)) / step`` tokens
  or the shared producer deadlocks behind the deep branch; ``auto_capacity``
  sizes exactly that.
* **Interleave fallback**: when producer and consumer worker counts differ,
  the streams cannot be spliced class-for-class; an explicit re-interleave
  buffer is inserted — per consumer class one ``imux`` node fed by strided
  filters on every producer stream, merging tokens in a per-row periodic
  pattern back into row-major order at the consumer's interleave.
* Output fields get :class:`WriterBank`/:class:`SyncTree` pairs (one ``cmp``
  per field; the simulator finishes when all have fired); several outputs
  pack into one flat image, one grid-sized slot per field, and likewise for
  external inputs.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.dfg import DFG
from repro.core.mapping.nd import apply_min_capacities
from repro.core.mapping.stages import (ReaderBank, SyncTree, WorkerStream,
                                       WriterBank, band_keep, compute_layer,
                                       owning_stream)
from repro.core.mapping.streams import StreamSpec, row_major_strides
from repro.core.spec import StencilSpec
from repro.program.ir import StencilOp, StencilProgram


@dataclasses.dataclass
class ProgramPlan:
    """The program lowering's output contract (the multi-op ``MappingPlan``).

    Duck-types what :func:`repro.core.simulator.simulate` consumes: ``spec``
    (machine-model carrier), ``dfg``, ``workers``, ``mac_pes`` — plus
    ``out_shape`` so several output fields pack into one output image.
    """

    program: StencilProgram
    dfg: DFG
    op_workers: dict[str, int]
    spec: StencilSpec                     # representative: grid + dtype
    in_fields: tuple[str, ...]
    out_fields: tuple[str, ...]
    out_shape: tuple[int, ...]
    reader_loads: dict[str, list[list[int]]]
    writer_stores: dict[str, list[list[int]]]
    sync_expect: dict[str, list[int]]
    pe_counts: dict
    mac_pes: int
    min_capacities: dict[int, int]
    notes: str = ""

    @property
    def workers(self) -> int:
        return max(self.op_workers.values())

    def pack_inputs(self, inputs: dict[str, np.ndarray]) -> np.ndarray:
        """Stack the named input fields into the flat memory image the
        readers index (one grid-sized slot per field, program order)."""
        missing = [f for f in self.in_fields if f not in inputs]
        if missing:
            raise ValueError(f"missing input fields: {missing}")
        return np.stack([np.asarray(inputs[f], dtype=np.float64)
                         for f in self.in_fields])

    def unpack_outputs(self, output: np.ndarray) -> dict[str, np.ndarray]:
        """Split a simulated output image back into named fields."""
        grid = self.program.grid_shape
        out = np.asarray(output).reshape((len(self.out_fields),) + grid)
        return {f: out[i] for i, f in enumerate(self.out_fields)}


def _site_gate(op) -> int:
    """An op's pipeline lead in grid sites: how far ahead of its output site
    its furthest tap reaches (0 for elementwise combines).  The per-axis
    reaches are *summed* — a deliberate overestimate of the ``max`` that the
    op truly needs, so skew buffers sized from accumulated leads stay
    sufficient down arbitrarily deep chains."""
    if not isinstance(op, StencilOp):
        return 0
    strides = row_major_strides(op.spec.grid_shape)
    return op.spec.timesteps * sum(
        r * s for r, s in zip(op.spec.radii, strides))


def field_leads(program: StencilProgram) -> dict[str, int]:
    """Site-lead ``D(f)`` per field: the deepest pipeline distance from the
    external inputs, in grid sites (the generalized skew/delay-buffer
    quantity)."""
    lead = {f: 0 for f in program.in_fields}
    for op in program.schedule():
        lead[op.output] = (max(lead[f] for f in op.inputs) + _site_gate(op))
    return lead


def _box_streams(grid: tuple[int, ...], margin: tuple[int, ...],
                 w: int) -> list[StreamSpec]:
    """The ``w`` interleaved worker streams over a valid box with ``margin``:
    outer axes full-box, innermost axis class ``margin + c (mod w)``."""
    d = len(grid)
    out = []
    for c in range(w):
        axes = tuple(
            (margin[b] + (c if b == d - 1 else 0), grid[b] - margin[b],
             w if b == d - 1 else 1) for b in range(d))
        out.append(StreamSpec(axes))
    return out


def _remux(g: DFG, field: str, sources: list[WorkerStream], w_src: int,
           w_dst: int, grid: tuple[int, ...], margin: tuple[int, ...],
           queue_capacity: int | None, min_caps: dict[int, int],
           subgraph: int) -> list[WorkerStream]:
    """Explicit re-interleave buffer: ``w_src`` producer streams -> ``w_dst``
    consumer-class streams over the same valid box.

    Per consumer class ``c`` one ``imux`` merges strided filters on every
    producer stream that owns sites of that class, popping ports in the
    per-row periodic pattern that restores row-major order.
    """
    out: list[WorkerStream] = []
    sg = {"subgraph": subgraph}
    for c, stream in enumerate(_box_streams(grid, margin, w_dst)):
        cnt_inner = stream.counts[-1]
        assert cnt_inner > 0, "empty re-interleave class (validated upstream)"
        pattern_src = [(c + i * w_dst) % w_src for i in range(cnt_inner)]
        classes = sorted(set(pattern_src))
        port_of = {p: k for k, p in enumerate(classes)}
        imux = g.add("imux", f"imux_{field}w{w_dst}_c{c}", stage="compute",
                     worker=c, pattern=[port_of[p] for p in pattern_src],
                     **sg)
        for p in classes:
            src = owning_stream(sources, margin[-1] + p)
            cnt_p = src.spec.counts[-1]
            start_p = src.spec.axes[-1][0]
            target = margin[-1] + c

            def keep(s: int, _cnt=cnt_p, _st=start_p, _w=w_src, _t=target,
                     _wd=w_dst) -> bool:
                return (_st + (s % _cnt) * _w - _t) % _wd == 0

            kept_row = sum(1 for j in range(cnt_p)
                           if (start_p + j * w_src - target) % w_dst == 0)
            kept = kept_row * math.prod(src.spec.counts[:-1])
            f = g.add("filter", f"rflt_{field}w{w_dst}_c{c}_p{p}",
                      stage="compute", worker=c, m=0, n=kept, keep=keep,
                      keep_count=kept,
                      # compiled form for the vector engine: keep(s) iff
                      # (off + (s % cnt) * step) % mod == 0.
                      keep_mod={"cnt": cnt_p, "step": w_src,
                                "off": start_p - target, "mod": w_dst}, **sg)
            g.connect(src.node, f, capacity=queue_capacity)
            e = g.connect(f, imux, port=port_of[p], capacity=queue_capacity)
            # the imux drains a port only at its pattern slots; a full row of
            # this port's tokens may queue while the other ports drain.
            min_caps[id(e)] = kept_row + 4
        out.append(WorkerStream(imux, stream))
    return out


def lower(program: StencilProgram, workers, queue_capacity: int | None = None,
          auto_capacity: bool = False) -> ProgramPlan:
    """Lower every op of ``program`` into one combined DFG.

    ``workers`` is a single int (every op) or a ``{op name: int}`` dict;
    differing counts trigger the explicit re-interleave fallback between the
    mismatched ops.
    """
    grid = program.grid_shape
    d = len(grid)
    ngrid = math.prod(grid)
    ops = program.schedule()
    margins = program.margins()
    leads = field_leads(program)
    if isinstance(workers, int):
        opw = {op.name: workers for op in ops}
    else:
        opw = dict(workers)
        missing = [op.name for op in ops if op.name not in opw]
        if missing:
            raise ValueError(f"no worker count for ops {missing}")

    # per-op legality (the map_nd preconditions, with the op named) ---------
    for op in ops:
        w = opw[op.name]
        if w < 1:
            raise ValueError(f"op {op.name!r}: need at least one worker")
        if d >= 2 and grid[-1] % w:
            raise ValueError(
                f"op {op.name!r} (grid_shape={grid}): inner extent "
                f"{grid[-1]} % workers {w} != 0; choose a divisor")
        interior_inner = grid[-1] - 2 * margins[op.output][-1]
        if w > interior_inner:
            raise ValueError(
                f"op {op.name!r} (grid_shape={grid}): {w} workers but only "
                f"{interior_inner} valid sites along the innermost axis of "
                f"{op.output!r}; some workers would own no outputs. Use "
                f"workers <= {interior_inner}.")

    g = DFG(f"program_{program.name}")
    min_caps: dict[int, int] = {}
    streams: dict[str, list[WorkerStream]] = {}
    stream_w: dict[str, int] = {}
    remux_cache: dict[tuple[str, int], list[WorkerStream]] = {}
    reader_loads: dict[str, list[list[int]]] = {}

    # external inputs: one ReaderBank per field, interleaved at the first
    # consumer's worker count (other counts re-interleave on demand).
    first_w: dict[str, int] = {}
    for op in ops:
        for f in op.inputs:
            if f in program.in_fields and f not in first_w:
                first_w[f] = opw[op.name]
    for slot, f in enumerate(program.in_fields):
        bank = ReaderBank(g, program.rep_spec, first_w[f], queue_capacity,
                          base=slot * ngrid, tag=f"_{f}_",
                          params={"subgraph": 0})
        streams[f] = bank.streams
        stream_w[f] = first_w[f]
        reader_loads[f] = bank.loads

    def streams_for(f: str, w: int, subgraph: int) -> list[WorkerStream]:
        if stream_w[f] == w:
            return streams[f]
        key = (f, w)
        if key not in remux_cache:
            remux_cache[key] = _remux(
                g, f, streams[f], stream_w[f], w, grid, margins[f],
                queue_capacity, min_caps, subgraph)
        return remux_cache[key]

    def src_cap(op, fname: str, step: int) -> int:
        """Producer→filter queue bound: intra-op slack + inter-op skew.  A
        field joined with deeper siblings (combine after a fan-out) must
        queue the depth difference or the shared producer deadlocks behind
        the deep branch."""
        skew = max(leads[f] for f in op.inputs) - leads[fname]
        return 6 + -(-skew // step)

    for i, op in enumerate(ops, start=1):
        w = opw[op.name]
        sg = {"subgraph": i}
        if isinstance(op, StencilOp):
            radii, coeffs, T = op.spec.radii, op.spec.coeffs, op.spec.timesteps
            center_extra = sum(float(coeffs[b][radii[b]])
                               for b in range(d - 1))
            cur = streams_for(op.input, w, i)
            m_in = margins[op.input]
            for t in range(1, T + 1):
                m_t = tuple(mb + t * rb for mb, rb in zip(m_in, radii))
                smin = src_cap(op, op.input, cur[0].spec.axes[-1][2]) \
                    if t == 1 else 0
                cur = compute_layer(
                    g, radii=radii, coeffs=coeffs,
                    out_streams=_box_streams(grid, m_t, w), sources=cur,
                    tag=f"{op.name}_l{t}", queue_capacity=queue_capacity,
                    min_caps=min_caps, center_extra=center_extra,
                    src_min=smin, params={**sg, "layer": t})
        else:                                     # elementwise CombineOp
            m_out = margins[op.output]
            out_streams = _box_streams(grid, m_out, w)
            tails = []
            for c in range(w):
                box = tuple((lo, hi) for lo, hi, _ in out_streams[c].axes)
                prev = None
                for k, (fname, coeff) in enumerate(
                        zip(op.inputs, op.coeffs)):
                    srcs = streams_for(fname, w, i)
                    src = owning_stream(srcs, box[-1][0])
                    mask = band_keep(src.spec, box)
                    f = g.add("filter", f"flt_{op.name}_w{c}_i{k}",
                              stage="compute", worker=c, m=mask.lead,
                              n=mask.kept, keep=mask.keep,
                              keep_count=mask.kept,
                              keep_vec={"windows": mask.windows,
                                        "counts": src.spec.counts}, **sg)
                    e_src = g.connect(src.node, f, capacity=queue_capacity)
                    smin = src_cap(op, fname, src.spec.axes[-1][2])
                    min_caps[id(e_src)] = max(min_caps.get(id(e_src), 0),
                                              smin)
                    opn = "mul" if prev is None else "mac"
                    pe = g.add(opn, f"{opn}_{op.name}_w{c}_i{k}",
                               stage="compute", worker=c, coeff=float(coeff),
                               **sg)
                    if prev is not None:
                        g.connect(prev, pe, port=0, capacity=queue_capacity)
                    e = g.connect(f, pe, port=(0 if prev is None else 1),
                                  capacity=queue_capacity)
                    min_caps[id(e)] = 4
                    prev = pe
                tails.append(prev)
            cur = [WorkerStream(tl, s) for tl, s in zip(tails, out_streams)]
        streams[op.output] = cur
        stream_w[op.output] = w

    # writers + one sync tree (one cmp) per output field --------------------
    writer_stores: dict[str, list[list[int]]] = {}
    sync_expect: dict[str, list[int]] = {}
    multi_out = len(program.out_fields) > 1
    wsg = {"subgraph": len(ops) + 1}
    for slot, fname in enumerate(program.out_fields):
        ws = streams[fname]
        base = slot * ngrid if multi_out else 0
        idx = [[base + i for i in s.spec.flat_indices(grid)] if base
               else s.spec.flat_indices(grid) for s in ws]
        wb = WriterBank(g, [s.node for s in ws], idx, queue_capacity,
                        tag=f"_{fname}", params=wsg)
        SyncTree(g, wb.stores, [len(o) for o in idx], queue_capacity,
                 tag=f"_{fname}", params=wsg)
        writer_stores[fname] = idx
        sync_expect[fname] = [len(o) for o in idx]

    if auto_capacity:
        apply_min_capacities(g, min_caps)

    out_shape = ((len(program.out_fields),) + grid if multi_out else grid)
    return ProgramPlan(
        program=program, dfg=g, op_workers=opw, spec=program.rep_spec,
        in_fields=program.in_fields, out_fields=program.out_fields,
        out_shape=out_shape, reader_loads=reader_loads,
        writer_stores=writer_stores, sync_expect=sync_expect,
        pe_counts=g.pe_counts(), mac_pes=g.mac_pes(),
        min_capacities=min_caps,
        notes=(f"program {program.name}: {len(ops)} ops "
               f"{[op.name for op in ops]}, "
               f"workers {sorted(set(opw.values()))}, "
               f"{len(remux_cache)} re-interleave(s), "
               f"inputs {list(program.in_fields)} -> "
               f"outputs {list(program.out_fields)}"))


def simulate_program(plan: ProgramPlan, inputs: dict[str, np.ndarray],
                     machine, **kw):
    """Convenience wrapper: pack inputs, run the core simulator, split the
    output image back into named fields.  Returns ``(SimResult, fields)``."""
    from repro.core.simulator import simulate
    res = simulate(plan, plan.pack_inputs(inputs), machine, **kw)
    return res, plan.unpack_outputs(res.output)
