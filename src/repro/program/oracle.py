"""Composed pure-jnp reference for stencil programs (+ a numpy twin).

Executes the DAG op-by-op — each stencil op as ``spec.timesteps`` masked
sweeps, each combine as a masked elementwise linear combination — with the
same margin discipline the lowering implements in hardware: after every op,
everything outside the output field's valid box is zeroed, so invalid rim
values never propagate (the program-level generalization of
``core.reference``'s support-only convention).

``program_reference_np`` is the simulator tests' ground truth (no jax
involvement, float64 end to end, like ``stencil_reference_np``).
"""
from __future__ import annotations

import numpy as np

from repro.core.reference import _interior_mask, _np_shift, stencil_sweep
from repro.program.ir import StencilOp, StencilProgram


def _mask(shape, margin) -> np.ndarray:
    return _interior_mask(shape, margin, 1)


def program_reference_np(program: StencilProgram,
                         inputs: dict[str, np.ndarray]
                         ) -> dict[str, np.ndarray]:
    """Execute the DAG with numpy; returns the named output fields."""
    dt = np.float64 if program.dtype == "float64" else np.float32
    missing = [f for f in program.in_fields if f not in inputs]
    if missing:
        raise ValueError(f"missing input fields: {missing}")
    vals = {f: np.asarray(inputs[f], dtype=dt) for f in program.in_fields}
    margins = program.margins()
    shape = program.grid_shape
    for op in program.schedule():
        if isinstance(op, StencilOp):
            out = vals[op.input]
            m_in = margins[op.input]
            for t in range(1, op.spec.timesteps + 1):
                acc = np.zeros_like(out)
                for ax, (r, coeffs) in enumerate(zip(op.spec.radii,
                                                     op.spec.coeffs)):
                    for k, c in enumerate(coeffs):
                        if c == 0.0:
                            continue
                        acc += c * _np_shift(out, k - r, ax)
                m_t = tuple(mb + t * rb
                            for mb, rb in zip(m_in, op.spec.radii))
                out = np.where(_mask(shape, m_t), acc, 0.0)
        else:
            acc = np.zeros(shape, dtype=dt)
            for f, c in zip(op.inputs, op.coeffs):
                acc = acc + c * vals[f]
            out = np.where(_mask(shape, margins[op.output]), acc, 0.0)
        vals[op.output] = out
    return {f: vals[f] for f in program.out_fields}


def program_reference(program: StencilProgram, inputs: dict) -> dict:
    """jax twin of :func:`program_reference_np` (jit-friendly per-op sweeps;
    dtype follows the inputs, as in :func:`core.reference.stencil_sweep`)."""
    import jax.numpy as jnp

    vals = dict(inputs)
    margins = program.margins()
    shape = program.grid_shape
    for op in program.schedule():
        if isinstance(op, StencilOp):
            out = vals[op.input]
            m_in = margins[op.input]
            for t in range(1, op.spec.timesteps + 1):
                out = stencil_sweep(out, op.spec)
                m_t = tuple(mb + t * rb
                            for mb, rb in zip(m_in, op.spec.radii))
                out = jnp.where(jnp.asarray(_mask(shape, m_t)), out,
                                jnp.zeros_like(out))
        else:
            acc = jnp.zeros_like(vals[op.inputs[0]])
            for f, c in zip(op.inputs, op.coeffs):
                acc = acc + jnp.asarray(c, acc.dtype) * vals[f]
            out = jnp.where(jnp.asarray(_mask(shape, margins[op.output])),
                            acc, jnp.zeros_like(acc))
        vals[op.output] = out
    return {f: vals[f] for f in program.out_fields}
