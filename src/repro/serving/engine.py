"""Minimal batched serving engine: request queue -> fixed-batch decode loop
with slot recycling (continuous batching in its simplest honest form).

Designed for the examples and integration tests; the production-scale decode
path itself is the jitted ``make_decode_step`` product.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchEngine:
    """Fixed B decode slots; prompts are fed token-by-token through the same
    decode step (prefill-as-decode keeps one compiled program), then free-run
    until EOS/max_new.  Finished slots immediately take the next request."""

    def __init__(self, model, cfg, params, *, batch_slots: int,
                 cache_len: int, eos_id: int = -1):
        self.model, self.cfg, self.params = model, cfg, params
        self.b = batch_slots
        self.eos = eos_id
        from repro.serving.serve_step import make_decode_step
        self._step = jax.jit(make_decode_step(model, cfg))
        self.cache = model.init_cache(batch_slots, cache_len)
        self.slots: list[Optional[Request]] = [None] * batch_slots
        self.feed_pos = [0] * batch_slots
        self.step_count = jnp.zeros((), jnp.int32)

    def run(self, requests: list[Request], max_steps: int = 10_000
            ) -> list[Request]:
        queue = list(requests)
        done: list[Request] = []
        cur = jnp.zeros((self.b, 1), jnp.int32)
        for _ in range(max_steps):
            # fill empty slots
            for i in range(self.b):
                if self.slots[i] is None and queue:
                    self.slots[i] = queue.pop(0)
                    self.feed_pos[i] = 0
            if all(s is None for s in self.slots) and not queue:
                break
            # choose the next input token per slot
            toks = np.zeros((self.b, 1), np.int32)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                if self.feed_pos[i] < len(req.prompt):
                    toks[i, 0] = req.prompt[self.feed_pos[i]]
                else:
                    toks[i, 0] = (req.out[-1] if req.out else 0)
            nxt, logits, self.cache = self._step(
                self.params, self.cache, jnp.asarray(toks), self.step_count)
            self.step_count = self.step_count + 1
            nxt = np.asarray(nxt)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                if self.feed_pos[i] < len(req.prompt) - 1:
                    self.feed_pos[i] += 1          # still consuming prompt
                    continue
                self.feed_pos[i] += 1
                tok = int(nxt[i, 0])
                req.out.append(tok)
                if tok == self.eos or len(req.out) >= req.max_new:
                    req.done = True
                    done.append(req)
                    self.slots[i] = None
        # NOTE: slot recycling reuses cache rows; correctness for mixed-age
        # rows relies on causal masking by each row's own write position.
        # For strict isolation, reset per-slot cache rows here (kept simple).
        return done
