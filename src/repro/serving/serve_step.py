"""serve_step builders: prefill (batch -> logits + primed cache) and decode
(one token with a KV cache of the assigned length).

The decode builder is what the ``decode_32k`` / ``long_500k`` dry-run cells
lower: one new token against a cache of ``seq_len`` (ring-buffer-bounded for
local-attention layers, O(1) recurrent state for RG-LRU/RWKV — which is the
whole sub-quadratic story of those archs; EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig


def make_decode_step(model, cfg: ArchConfig, *, greedy: bool = True):
    """(params, cache, tokens (B,1), step) -> (next_token (B,1), logits, cache)."""
    def decode_step(params, cache, tokens, step):
        positions = None
        if cfg.mrope_sections is not None:
            b = tokens.shape[0]
            positions = jnp.broadcast_to(step, (3, b, 1)).astype(jnp.int32)
        logits, cache = model.decode(params, cache, tokens,
                                     positions=positions)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        return nxt, logits, cache
    return decode_step


def make_prefill(model, cfg: ArchConfig):
    """(params, batch) -> logits.  (Cache priming for the serving engine is
    done token-batched via decode for correctness; the prefill path here is
    the throughput-shape the dry-run lowers.)"""
    def prefill(params, batch):
        if cfg.family == "audio":
            logits, _ = model.forward(params, batch["tokens"], batch["frames"])
        else:
            logits, _ = model.forward(params, batch["tokens"],
                                      positions=batch.get("positions"),
                                      patches=batch.get("patches"))
        return logits
    return prefill
