"""Opt-in observability substrate (docs/telemetry.md).

    from repro.telemetry import Telemetry, write_trace, render_report

    tel = Telemetry()
    res = simulate(plan, x, CGRA, fabric=rf, telemetry=tel)
    print(render_report(tel))          # fabric heatmap + stall attribution
    write_trace(tel, "run.trace.json") # open in ui.perfetto.dev

The sink is exact (counters sum bit-for-bit to the simulator's aggregate
stats, parity-gated across both engines) and free when absent (``telemetry=
None`` keeps the engines on their uninstrumented hot paths).  The mapping
auto-tuner records a search span per evaluation into the same sink
(``explore(..., telemetry=tel)``), so one trace file can hold a whole sweep.
"""
from repro.telemetry.attribution import (CycleAccounting, attribute,
                                         render_attribution, stage_label)
from repro.telemetry.metrics import (append_history, case_records,
                                     load_history)
from repro.telemetry.probe import (ST_FIRED, ST_INACTIVE, ST_INPUT_STARVED,
                                   ST_MEM_ARB, ST_NET_WAIT,
                                   ST_OUTPUT_BLOCKED, STALL_CAUSES,
                                   STATE_NAMES, Telemetry,
                                   format_stall_summary)
from repro.telemetry.report import (bottleneck_table, render_report,
                                    utilization_grid)
from repro.telemetry.trace import trace_events, validate_trace, write_trace

__all__ = ["Telemetry", "STALL_CAUSES", "STATE_NAMES", "ST_INACTIVE",
           "ST_FIRED", "ST_INPUT_STARVED", "ST_OUTPUT_BLOCKED", "ST_MEM_ARB",
           "ST_NET_WAIT", "format_stall_summary", "trace_events",
           "write_trace", "validate_trace", "utilization_grid",
           "bottleneck_table", "render_report", "CycleAccounting",
           "attribute", "render_attribution", "stage_label",
           "case_records", "append_history", "load_history"]
