"""Cycle-accounting profiler: where did a run's cycles go?

:func:`attribute` consumes a finished :class:`repro.telemetry.Telemetry`
sink (the exclusive per-node per-cycle states of docs/telemetry.md) and
decomposes the measured run into an **exact** accounting:

* **phases** — pipeline *fill* (cycles before the first store fired),
  *steady* state, and *drain* (cycles after the last load fired), derived
  from the sink's fire-timeline envelope.  ``fill + steady + drain ==
  SimResult.cycles`` always, by construction.
* **causes** — the roofline gap attributed to the four stall causes
  (``repro.telemetry.STALL_CAUSES``: input-starved / output-blocked /
  memory-arbitration / network-contention), in node-cycles.  Together with
  fired and inactive node-cycles these tile ``cycles * n_nodes`` exactly.
* **stages** — the same breakdown rolled up per mapping pipeline stage
  (ReaderBank / TapChain / AddTree / WriterBank / SyncTree — the paper's
  §III worker pipeline, recovered from ``Node.stage`` + op).
* **critical path** — a source→sink chain through the DFG extracted from
  the fire timelines: starting at the completion node, each step walks to
  the predecessor whose *last* fire is latest, i.e. the chain that kept
  the run alive longest.
* **bottleneck** — one label (``fill-bound`` / ``memory-bound`` /
  ``network-bound`` / ``capacity-bound`` / ``starved`` /
  ``compute-bound``) summarizing the dominant term; the tuner records it
  per evaluation and surfaces it on the Pareto front.

Everything here is a *pure function of the sink's exact counters*, which
both engines fill identically (the PR 6 parity gates) — so the
decomposition is bit-identical across interp and vector by construction,
and ``tests/test_attribution.py`` gates it end-to-end anyway.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.telemetry.probe import STALL_CAUSES, Telemetry

__all__ = ["CycleAccounting", "attribute", "render_attribution",
           "stage_label", "STAGE_ORDER"]

#: canonical render order of the mapping pipeline stages
STAGE_ORDER = ("ReaderBank", "TapChain", "AddTree", "WriterBank", "SyncTree")

_STAGE_BY_TAG = {"reader": "ReaderBank", "writer": "WriterBank",
                 "sync": "SyncTree"}


def stage_label(stage: str, op: str) -> str:
    """Map a node's ``(Node.stage, op)`` onto the paper's pipeline stage.
    ``compute`` nodes split into the TapChain (filter/mul/mac/imux — the
    per-axis tap pipelines and their splice muxes) and the AddTree
    (cross-axis ``add`` reduction)."""
    if stage == "compute":
        return "AddTree" if op == "add" else "TapChain"
    if stage in _STAGE_BY_TAG:
        return _STAGE_BY_TAG[stage]
    return stage.capitalize() if stage else "Other"


@dataclasses.dataclass
class CycleAccounting:
    """The exact decomposition of one run (see the module docstring)."""
    run: str
    cycles: int
    n_nodes: int
    phases: dict                # {"fill": int, "steady": int, "drain": int}
    causes: dict                # stall node-cycles per STALL_CAUSES entry
    fired: int                  # total fired node-cycles
    inactive: int               # total inactive (retired/unobserved) slots
    stages: dict                # stage -> {"nodes", "fired", "inactive", ...}
    critical_path: list         # source->sink node dicts (see attribute())
    bottleneck: str

    def as_dict(self) -> dict:
        return {"run": self.run, "cycles": self.cycles,
                "n_nodes": self.n_nodes, "phases": dict(self.phases),
                "causes": dict(self.causes), "fired": self.fired,
                "inactive": self.inactive,
                "stages": {k: dict(v) for k, v in self.stages.items()},
                "critical_path": [dict(d) for d in self.critical_path],
                "bottleneck": self.bottleneck}


def _phases(tel: Telemetry) -> dict:
    """fill/steady/drain from the fire-timeline envelope.  Exactness
    contract: the three terms are clamped to sum to ``cycles`` exactly."""
    cycles = tel.cycles
    first_out = min((int(tel.first_fire[nid])
                     for nid, op in enumerate(tel.node_ops)
                     if op == "store" and tel.first_fire[nid] > 0),
                    default=cycles + 1)
    last_in = max((int(tel.last_fire[nid])
                   for nid, op in enumerate(tel.node_ops) if op == "load"),
                  default=0)
    fill = max(0, min(first_out - 1, cycles))
    drain = max(0, min(cycles - last_in, cycles - fill))
    return {"fill": fill, "steady": cycles - fill - drain, "drain": drain}


def _critical_path(tel: Telemetry) -> list:
    """Walk the DFG backwards from the completion node along latest-last-fire
    predecessors; ties break to the lowest nid so the path is deterministic
    (and therefore engine-independent, like everything else here)."""
    nodes = tel.plan.dfg.nodes
    fired = [nid for nid in range(tel.n_nodes) if tel.fires_total[nid] > 0]
    if not fired:
        return []
    sink = next((n.nid for n in nodes
                 if n.op == "cmp" and tel.fires_total[n.nid] > 0),
                max(fired, key=lambda nid: (int(tel.last_fire[nid]), -nid)))
    path = []
    seen = set()
    nid = sink
    while nid is not None and nid not in seen and len(path) <= tel.n_nodes:
        seen.add(nid)
        st = tel.stall_totals[nid]
        tot = int(st.sum())
        path.append({
            "name": tel.node_names[nid], "op": tel.node_ops[nid],
            "stage": stage_label(nodes[nid].stage, nodes[nid].op),
            "first_fire": int(tel.first_fire[nid]),
            "last_fire": int(tel.last_fire[nid]),
            "fires": int(tel.fires_total[nid]), "stalled": tot,
            "cause": STALL_CAUSES[int(st.argmax())] if tot else None})
        preds = [e.src.nid for e in nodes[nid].in_edges
                 if tel.fires_total[e.src.nid] > 0 and e.src.nid not in seen]
        nid = (min(preds, key=lambda p: (-int(tel.last_fire[p]), p))
               if preds else None)
    path.reverse()
    return path


def _bottleneck(cycles: int, phases: dict, causes: dict) -> str:
    if cycles <= 0:
        return "compute-bound"
    if 2 * (phases["fill"] + phases["drain"]) >= cycles:
        return "fill-bound"
    if not any(causes.values()):
        return "compute-bound"
    label = {"input_starved": "starved", "output_blocked": "capacity-bound",
             "memory_arbitration": "memory-bound",
             "network_contention": "network-bound"}
    top = max(STALL_CAUSES, key=lambda c: causes.get(c, 0))
    return label[top]


def attribute(tel: Telemetry, result=None) -> CycleAccounting:
    """Decompose a finished run.  ``result`` (the run's ``SimResult``) is
    optional; when given, the exact-sum contract against ``result.cycles``
    is asserted here instead of merely in the tests."""
    if not tel.attached:
        raise ValueError("attribute() needs a sink that observed a run "
                         "(simulate(..., telemetry=tel) first)")
    if not tel.finished:
        raise ValueError("attribute() needs a finished run "
                         "(the engine did not reach finish())")
    cycles, n = tel.cycles, tel.n_nodes
    nodes = tel.plan.dfg.nodes

    causes = {c: int(tel.stall_totals[:, i].sum())
              for i, c in enumerate(STALL_CAUSES)}
    fired = int(tel.fires_total.sum())
    inactive = cycles * n - fired - sum(causes.values())

    stages: dict[str, dict] = {}
    for nid in range(n):
        lab = stage_label(nodes[nid].stage, nodes[nid].op)
        row = stages.setdefault(
            lab, {"nodes": 0, "fired": 0, "inactive": 0,
                  **{c: 0 for c in STALL_CAUSES}})
        row["nodes"] += 1
        row["fired"] += int(tel.fires_total[nid])
        stalled = 0
        for i, c in enumerate(STALL_CAUSES):
            v = int(tel.stall_totals[nid, i])
            row[c] += v
            stalled += v
        row["inactive"] += cycles - int(tel.fires_total[nid]) - stalled

    phases = _phases(tel)
    acct = CycleAccounting(
        run=tel.run_label, cycles=cycles, n_nodes=n, phases=phases,
        causes=causes, fired=fired, inactive=inactive, stages=stages,
        critical_path=_critical_path(tel),
        bottleneck=_bottleneck(cycles, phases, causes))

    # the exact-sum contract, checked on every call (cheap):
    assert sum(phases.values()) == cycles, (phases, cycles)
    assert inactive >= 0, "states overflow cycles*n_nodes — engine drift?"
    tiled = sum(v["fired"] + v["inactive"]
                + sum(v[c] for c in STALL_CAUSES)
                for v in stages.values())
    assert tiled == cycles * n, (tiled, cycles * n)
    if result is not None and result.cycles != cycles:
        raise AssertionError(
            f"sink saw {cycles} cycles but SimResult says {result.cycles}")
    return acct


def render_attribution(acct: CycleAccounting) -> str:
    """Terminal view of one accounting: phase bar, cause shares, the
    per-stage table, and the critical path."""
    c = max(1, acct.cycles)
    lines = [f"cycle accounting: {acct.run} — {acct.cycles} cycles, "
             f"bottleneck: {acct.bottleneck}",
             "  phases: " + "  ".join(
                 f"{k}={v} ({100 * v / c:.1f}%)"
                 for k, v in acct.phases.items())]
    active = max(1, acct.cycles * acct.n_nodes - acct.inactive)
    lines.append("  stall causes (node-cycles, % of non-retired): "
                 + (" ".join(f"{k}={v} ({100 * v / active:.1f}%)"
                             for k, v in acct.causes.items() if v)
                    or "none"))
    order = [s for s in STAGE_ORDER if s in acct.stages] + sorted(
        s for s in acct.stages if s not in STAGE_ORDER)
    lines.append(f"  {'stage':<12}{'nodes':>6}{'fired':>10}{'inactive':>10}"
                 + "".join(f"{cz.split('_')[0]:>10}" for cz in STALL_CAUSES))
    for s in order:
        v = acct.stages[s]
        lines.append(f"  {s:<12}{v['nodes']:>6}{v['fired']:>10}"
                     f"{v['inactive']:>10}"
                     + "".join(f"{v[cz]:>10}" for cz in STALL_CAUSES))
    if acct.critical_path:
        lines.append("  critical path (source -> sink by last fire):")
        for d in acct.critical_path:
            stall = (f", stalled {d['stalled']} ({d['cause']})"
                     if d["stalled"] else "")
            lines.append(f"    {d['name']} [{d['stage']}] fires "
                         f"{d['first_fire']}..{d['last_fire']} "
                         f"x{d['fires']}{stall}")
    return "\n".join(lines)
