"""Benchmark metrics layer: fingerprinted records in an append-only history.

The BENCH_*.json snapshots are overwritten in place on every refresh, so by
themselves they hold no trajectory.  This module turns each artifact case
into one **fingerprinted record** and appends it to ``BENCH_history.jsonl``
(one JSON object per line, append-only, committed to the repo), so the perf
trajectory across PRs — and across CI runs inside one PR — is queryable:

* ``benchmarks/observatory.py`` renders trend + attribution reports from it;
* ``benchmarks/bench_diff.py --trend N`` gates a refreshed artifact against
  the last N matching records instead of a single previous snapshot;
* ``benchmarks/overhead_check.py`` gates the disabled-telemetry wall bound
  against the rolling history median.

Record shape (``HISTORY_VERSION``)::

    {"v": 1, "schema": "bench_pr4/v1", "config": "smoke", "case": "2d",
     "fingerprint": "<sha1/16 of schema+config+case+identity keys>",
     "ts": 1723118400.0, "source": "BENCH_pr4.json",
     "counters": {...integer-valued, deterministic...},
     "walls": {...float-valued, machine-load measurements...},
     "meta": {...identity: grid, workers, bottleneck labels, ...}}

Numeric classification is the same rule ``bench_diff`` uses: ints (non-bool)
are deterministic counters, floats are walls/derived measurements.  Nested
case dicts (the BENCH_pr5 explore artifacts) are flattened into dotted
paths first (:func:`flatten_case`).  The module is stdlib-only so the
benchmark scripts can import it without the simulator stack.
"""
from __future__ import annotations

import hashlib
import json
import os
import time

__all__ = ["HISTORY_VERSION", "DEFAULT_HISTORY", "flatten_case",
           "fingerprint", "case_records", "append_history", "load_history",
           "history_for", "trend_values", "record_problem"]

HISTORY_VERSION = 1
DEFAULT_HISTORY = "BENCH_history.jsonl"


def _is_int(v) -> bool:
    return isinstance(v, int) and not isinstance(v, bool)


def flatten_case(case: dict, prefix: str = "") -> dict:
    """Flatten nested dicts into dotted paths (``best.cycles``); lists and
    scalars are atomic leaves.  Shared with ``bench_diff``'s intersection
    compare so both layers agree on what a "key" is."""
    out: dict = {}
    for k, v in case.items():
        path = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(flatten_case(v, f"{path}."))
        else:
            out[path] = v
    return out


def fingerprint(schema: str, config: str, case: str, meta: dict) -> str:
    """Stable identity of one measured case: what it *is*, not what it
    scored.  Two records with equal fingerprints are the same experiment
    and therefore trend-comparable."""
    ident = json.dumps({"schema": schema, "config": config, "case": case,
                        "meta": meta}, sort_keys=True)
    return hashlib.sha1(ident.encode()).hexdigest()[:16]


def case_records(artifact: dict, *, source: str = "",
                 ts: float | None = None) -> list[dict]:
    """One history record per case of a loaded BENCH_*.json artifact."""
    schema = artifact.get("schema", "?")
    config = artifact.get("config", "?")
    ts = time.time() if ts is None else ts
    records = []
    for name in sorted(artifact.get("cases", {})):
        flat = flatten_case(artifact["cases"][name])
        counters = {k: v for k, v in flat.items() if _is_int(v)}
        walls = {k: v for k, v in flat.items()
                 if isinstance(v, float) and not isinstance(v, bool)}
        meta = {k: v for k, v in flat.items()
                if k not in counters and k not in walls}
        ident = {k: meta[k] for k in
                 ("grid", "radii", "workers", "kind", "ops", "engines")
                 if k in meta}
        records.append({
            "v": HISTORY_VERSION, "schema": schema, "config": config,
            "case": name, "fingerprint": fingerprint(schema, config, name,
                                                     ident),
            "ts": round(ts, 3), "source": source,
            "counters": counters, "walls": walls, "meta": meta})
    return records


def append_history(path: str, records: list[dict]) -> int:
    """Append records as JSONL (the file is append-only by convention —
    rewriting it erases the trajectory the trend gate runs on)."""
    if not records:
        return 0
    with open(path, "a") as f:
        for r in records:
            f.write(json.dumps(r, sort_keys=True) + "\n")
    return len(records)


def load_history(path: str) -> list[dict]:
    """All records, in append (= chronological) order.  Blank or
    unparseable lines are skipped — the history must survive a torn
    append, not abort every consumer forever."""
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                continue
            if isinstance(rec, dict) and "case" in rec:
                out.append(rec)
    return out


def history_for(records: list[dict], schema: str, config: str,
                case: str) -> list[dict]:
    """The trend line for one experiment, chronological order."""
    return [r for r in records
            if r.get("schema") == schema and r.get("config") == config
            and r.get("case") == case]


def trend_values(records: list[dict], key: str, *, last: int | None = None,
                 kind: str = "counters") -> list:
    """The last ``last`` values of one counter/wall along a trend line
    (records missing the key — or carrying a malformed/unknown payload,
    see :func:`record_problem` — are skipped, so schema growth is
    painless)."""
    vals = [r[kind][key] for r in records
            if isinstance(r.get(kind), dict) and key in r[kind]]
    return vals[-last:] if last else vals


def record_problem(rec: dict) -> str | None:
    """Why one history record can't be trended — ``None`` when well-formed.

    The history is append-only and shared by several producers, so
    consumers (observatory report, overhead gate, trend gate) must treat
    records from a newer version or with a partial/unknown payload shape
    (e.g. a throughput record that has no ``counters``) as *data to skip
    with a named warning*, never as a reason to crash."""
    v = rec.get("v")
    if not isinstance(v, int) or v > HISTORY_VERSION:
        return f"unknown history version {v!r}"
    for kind in ("counters", "walls", "meta"):
        if kind in rec and not isinstance(rec[kind], dict):
            return f"{kind!r} is not a mapping"
    if "counters" not in rec and "walls" not in rec:
        return "no counters/walls payload"
    return None
