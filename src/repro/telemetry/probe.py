"""Telemetry sink: per-node / per-link probes + tuner search spans.

A :class:`Telemetry` object is an opt-in instrumentation sink passed to
``repro.core.simulator.simulate(..., telemetry=)`` (both engines feed it) and
to ``repro.explore.explore(..., telemetry=)`` (the tuner records one span per
evaluation).  The contract with the engines:

* **zero cost when absent** — engines take ``telemetry=None`` and guard every
  probe with one local ``is not None`` check; the disabled path must stay
  within the BENCH_pr4 wall-clock envelope (ci.sh gates this with
  ``benchmarks/bench_diff.py``).
* **exact** — the telemetry counters are not estimates: summed, they equal
  the engine's own aggregate stats bit-for-bit (``totals()`` vs ``SimResult``
  / ``RawStats``; parity-gated in ``tests/test_telemetry.py``).
* **engine-agnostic** — the interpreter records scalar per-cycle events, the
  vector engine batches whole per-cycle state arrays (and multiplies stall
  counts through its event-skip), but both leave identical telemetry: same
  per-node fire timelines, same stall attribution, same per-link bookings.

Every node gets one exclusive state per observed cycle.  **This table is
the canonical stall-state taxonomy** — the engines' classifiers
(``repro.core.engine.interp``/``vector``), the attribution layer
(``repro.telemetry.attribution``) and docs/telemetry.md all reference it
rather than restating it:

====================  ======================================================
``ST_INACTIVE``       retired (addr exhausted / sync emitted / cmp fired)
``ST_FIRED``          consumed tokens this cycle (incl. filter drops, sync
                      count-ticks — the same events the fire counters count)
``ST_INPUT_STARVED``  an input queue is empty and nothing is in flight to it
``ST_OUTPUT_BLOCKED`` inputs ready but a bounded output queue is full
                      (for ``imux`` only the pattern-selected input port
                      counts toward starvation/net-wait)
``ST_MEM_ARB``        a load/store with data+space that lost the rotating
                      memory-port arbitration (credit < 1 element this cycle)
``ST_NET_WAIT``       input empty but tokens are riding the network toward
                      it (network-contention / transit latency)
====================  ======================================================

Per-link telemetry is recorded at booking time (producer side): one word per
hop (sums to ``token_hops``) and the store-and-forward wait per booking
(sums to ``stall_cycles``), plus — when ``timeline`` is on — the per-cycle
slot occupancy each contended link, for the Perfetto counter tracks.
"""
from __future__ import annotations

import time

import numpy as np

__all__ = ["Telemetry", "STALL_CAUSES", "STATE_NAMES", "ST_INACTIVE",
           "ST_FIRED", "ST_INPUT_STARVED", "ST_OUTPUT_BLOCKED", "ST_MEM_ARB",
           "ST_NET_WAIT", "format_stall_summary", "summary_from_state"]

ST_INACTIVE, ST_FIRED, ST_INPUT_STARVED, ST_OUTPUT_BLOCKED, ST_MEM_ARB, \
    ST_NET_WAIT = range(6)

STATE_NAMES = ("inactive", "fire", "input_starved", "output_blocked",
               "memory_arbitration", "network_contention")
#: the four attributed stall causes (states ST_INPUT_STARVED..ST_NET_WAIT)
STALL_CAUSES = STATE_NAMES[ST_INPUT_STARVED:]


def format_stall_summary(summary: dict | None) -> str:
    """Render a stall-attribution summary (see ``Telemetry.stall_summary`` /
    the engines' deadlock path) into the one-line form both engines append to
    ``SimDeadlock`` messages — it must be engine-independent, so it is built
    only from the (parity-checked) summary dict."""
    if not summary:
        return ""
    counts = summary.get("cause_counts", {})
    win = summary.get("window_cycles")
    tag = f"last {win} cycles" if win else "final cycle"
    if not any(counts.values()) and not summary.get("nodes"):
        return f"; stall attribution ({tag}): no stalls recorded"
    head = " ".join(f"{c}={n}" for c, n in counts.items() if n)
    nodes = "; ".join(f"{d['name']}({d['op']}): {d['cause']}"
                      for d in summary.get("nodes", [])[:8])
    return f"; stall attribution ({tag}): [{head}] top blocked: {nodes}"


def summary_from_state(state: np.ndarray, names, ops) -> dict:
    """One-cycle stall-attribution summary — the diagnostic the engines
    build on deadlock when *no* telemetry sink is attached.  Same dict shape
    as :meth:`Telemetry.stall_summary`, derived from a single classified
    state array, so both engines (which agree on the state by the parity
    contract) render identical diagnostics."""
    counts = {c: int((state == ST_INPUT_STARVED + i).sum())
              for i, c in enumerate(STALL_CAUSES)}
    nodes = [{"name": names[nid], "op": ops[nid],
              "cause": STATE_NAMES[int(state[nid])], "stalled_cycles": 1}
             for nid in np.nonzero(state >= ST_INPUT_STARVED)[0][:8].tolist()]
    return {"window_cycles": None, "cause_counts": counts, "nodes": nodes}


class Telemetry:
    """Instrumentation sink for one simulation run (+ any number of spans).

    ``timeline=False`` keeps only the exact counters (per-node fires, stall
    attribution totals, per-link words/stalls) and drops the interval /
    per-slot-occupancy history the trace exporter needs — use it when a run
    is too long to hold its full timeline.
    """

    def __init__(self, *, timeline: bool = True):
        self.timeline = timeline
        self.spans: list[dict] = []
        self._t0 = time.perf_counter()
        self.attached = False
        self.run_label = ""
        self.cycles = 0                 # set by attach()/finish()

    # ------------------------------------------------------------------ runs
    def attach(self, plan, fabric=None) -> None:
        """Bind the sink to one plan (+ optional routed fabric) and reset all
        per-run state.  Called by ``simulate()`` before the engine starts;
        a sink holds exactly one run (spans accumulate across attaches)."""
        g = plan.dfg
        nodes = g.nodes
        self.attached = True
        self.plan = plan
        self.fabric = fabric
        self.run_label = getattr(g, "name", "run")
        self.node_names = [n.name for n in nodes]
        self.node_ops = [n.op for n in nodes]
        self.node_groups = self._groups(nodes, fabric)
        n = len(nodes)
        self.n_nodes = n
        self.fires_total = np.zeros(n, dtype=np.int64)
        self.stall_totals = np.zeros((n, 4), dtype=np.int64)
        # fire-timeline envelope (cycle of first/last fire; 0 = never fired)
        # — kept even with timeline=False so the attribution layer's
        # fill/drain decomposition works on counter-only sinks
        self.first_fire = np.zeros(n, dtype=np.int64)
        self.last_fire = np.zeros(n, dtype=np.int64)
        self._cur_state = np.full(n, -1, dtype=np.int64)
        self._since = np.ones(n, dtype=np.int64)
        self.intervals: list[tuple[int, int, int, int]] = []
        self.last_cycle = 0
        self.cycles = 0
        self.finished = False
        # link inventory (network-aware runs only)
        if fabric is not None:
            self.link_ids = fabric.link_index()
            self.link_names = fabric.link_names()
            nl = len(self.link_ids)
        else:
            self.link_ids = {}
            self.link_names = []
            nl = 0
        self.link_words = np.zeros(nl, dtype=np.int64)
        self.link_stalls = np.zeros(nl, dtype=np.int64)
        self.link_occ: dict[int, dict[int, int]] = {}

    @staticmethod
    def _groups(nodes, fabric):
        """Track-grouping labels: the PE coordinate on placed runs, the
        ``stage/worker`` pipeline otherwise (see docs/telemetry.md)."""
        if fabric is not None:
            coords = fabric.placement.coords
            return [f"PE{coords[n.nid]}" for n in nodes]
        return [f"{n.stage or 'stage'}/w{n.worker}" for n in nodes]

    # --------------------------------------------------------- engine probes
    def observe(self, cycle: int, state: np.ndarray) -> None:
        """Record one simulated cycle: ``state[nid]`` is the node's exclusive
        ``ST_*`` code for ``cycle``.  The array is consumed (copied)."""
        fired = state == ST_FIRED
        self.fires_total += fired
        if fired.any():
            self.last_fire[fired] = cycle
            new = fired & (self.first_fire == 0)
            if new.any():
                self.first_fire[new] = cycle
        st = self.stall_totals
        for c in range(4):
            st[:, c] += state == ST_INPUT_STARVED + c
        if self.timeline:
            cur = self._cur_state
            changed = np.nonzero(state != cur)[0]
            if len(changed):
                since = self._since
                iv = self.intervals
                for nid in changed.tolist():
                    if cur[nid] >= 0:
                        iv.append((nid, int(cur[nid]), int(since[nid]),
                                   cycle))
                    since[nid] = cycle
                cur[changed] = state[changed]
        else:
            self._cur_state[:] = state
        self.last_cycle = cycle

    def observe_repeat(self, k: int) -> None:
        """The engine fast-forwarded ``k`` cycles in which state provably
        could not change (vector event-skip): multiply the standing stall
        attribution instead of re-observing each cycle."""
        cur = self._cur_state
        st = self.stall_totals
        for c in range(4):
            st[:, c] += k * (cur == ST_INPUT_STARVED + c)
        self.last_cycle += k

    def link_book(self, lid: int, slot: int, waited: int) -> None:
        """One token booked one hop: it crosses link ``lid`` at cycle
        ``slot`` after ``waited`` cycles of store-and-forward contention."""
        if not 0 <= lid < len(self.link_words):
            raise ValueError(
                f"unknown link id {lid} (link inventory has "
                f"{len(self.link_words)} links — was the sink attached with "
                f"the fabric the engine is booking against?)")
        self.link_words[lid] += 1
        self.link_stalls[lid] += waited
        if self.timeline:
            occ = self.link_occ.get(lid)
            if occ is None:
                occ = self.link_occ[lid] = {}
            occ[slot] = occ.get(slot, 0) + 1

    def finish(self, cycles: int) -> None:
        """Close the run (also called on the deadlock path, so aborted runs
        still export a valid trace): flush open state intervals."""
        self.cycles = cycles
        self.finished = True
        if self.timeline:
            cur, since = self._cur_state, self._since
            for nid in range(self.n_nodes):
                if cur[nid] >= 0 and self.last_cycle + 1 > since[nid]:
                    self.intervals.append((nid, int(cur[nid]),
                                           int(since[nid]),
                                           self.last_cycle + 1))
                    since[nid] = self.last_cycle + 1

    # -------------------------------------------------------------- counters
    def totals(self) -> dict:
        """Aggregate view of the probes — must equal the engine's own stats
        bit-for-bit (the parity gate): fires by op, loads/stores/flops from
        per-node fires, token_hops/stall_cycles from per-link bookings."""
        # imported here, not at module top: the engines import this module's
        # state constants, so a top-level repro.core import would make
        # `import repro.telemetry` order-dependent (circular)
        from repro.core.dfg import FLOPS_PER_OP
        fires: dict[str, int] = {}
        loads = stores = flops = 0
        for nid, op in enumerate(self.node_ops):
            f = int(self.fires_total[nid])
            if not f:
                continue
            fires[op] = fires.get(op, 0) + f
            if op == "load":
                loads += f
            elif op == "store":
                stores += f
            flops += f * FLOPS_PER_OP.get(op, 0)
        return {"cycles": self.cycles, "fires": fires,
                "fires_total": int(self.fires_total.sum()),
                "loads": loads, "stores": stores, "flops": flops,
                "stall_attribution": {
                    c: int(self.stall_totals[:, i].sum())
                    for i, c in enumerate(STALL_CAUSES)},
                "token_hops": int(self.link_words.sum()),
                "stall_cycles": int(self.link_stalls.sum())}

    def fire_cycles(self, nid: int) -> list[tuple[int, int]]:
        """The node's fire timeline as ``[t0, t1)`` runs of consecutive
        fired cycles (requires ``timeline=True``)."""
        return [(t0, t1) for (n, s, t0, t1) in self.intervals
                if n == nid and s == ST_FIRED]

    def stall_summary(self, window: int | None = None) -> dict:
        """Per-cause attribution over the last ``window`` cycles (whole run
        when None): cause counts in node-cycles plus the most-stalled nodes.
        This is what ``SimDeadlock`` diagnostics embed."""
        if not self.attached:           # no run: empty (renders as a stub)
            return {"window_cycles": None,
                    "cause_counts": {c: 0 for c in STALL_CAUSES},
                    "nodes": []}
        if window and self.timeline:
            lo = max(1, self.last_cycle + 1 - window)
            per = np.zeros((self.n_nodes, 4), dtype=np.int64)
            for nid, s, t0, t1 in self.intervals:
                if s >= ST_INPUT_STARVED and t1 > lo:
                    per[nid, s - ST_INPUT_STARVED] += t1 - max(t0, lo)
            cur, since = self._cur_state, self._since
            if not self.finished:           # open runs up to last_cycle
                for nid in range(self.n_nodes):
                    s = int(cur[nid])
                    if s >= ST_INPUT_STARVED:
                        t0 = max(int(since[nid]), lo)
                        per[nid, s - ST_INPUT_STARVED] += \
                            self.last_cycle + 1 - t0
        else:
            per = self.stall_totals
            window = None
        order = np.argsort(-per.sum(axis=1), kind="stable")
        nodes = []
        for nid in order[:8].tolist():
            tot = int(per[nid].sum())
            if not tot:
                break
            cause = STALL_CAUSES[int(per[nid].argmax())]
            nodes.append({"name": self.node_names[nid],
                          "op": self.node_ops[nid], "cause": cause,
                          "stalled_cycles": tot})
        return {"window_cycles": window,
                "cause_counts": {c: int(per[:, i].sum())
                                 for i, c in enumerate(STALL_CAUSES)},
                "nodes": nodes}

    # ----------------------------------------------------------------- spans
    def now(self) -> float:
        """Seconds since this sink was created (the span timebase)."""
        return time.perf_counter() - self._t0

    def span(self, name: str, *, cat: str = "span", t0: float | None = None,
             dur: float = 0.0, track: str = "spans", **args) -> dict:
        """Record one structured span (tuner evaluations, prune decisions,
        …).  ``t0``/``dur`` in seconds on the :meth:`now` timebase; extra
        keyword arguments become the span's ``args`` payload."""
        sp = {"name": name, "cat": cat, "track": track,
              "t0": self.now() if t0 is None else t0, "dur": dur,
              "args": args}
        self.spans.append(sp)
        return sp
