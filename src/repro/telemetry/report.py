"""Text reports over a :class:`Telemetry` sink.

Two renderers, both pure functions of the recorded counters (no timeline
needed, so they work on ``Telemetry(timeline=False)`` runs too):

* :func:`utilization_grid` — the physical fabric as an ASCII heatmap, one
  cell per PE, shaded by fire-cycles / total-cycles of the instructions
  placed there (ideal runs fall back to a per-worker/stage table).
* :func:`bottleneck_table` — top-K nodes by attributed stall cycles, with
  the cause breakdown, plus the top contended links — this is the "why is
  this mapping routed-bound" answer the tuner's finalists need.
"""
from __future__ import annotations

import numpy as np

from repro.telemetry.probe import STALL_CAUSES, Telemetry

__all__ = ["utilization_grid", "bottleneck_table", "render_report"]

_SHADES = " .:-=+*#%@"


def _shade(frac: float) -> str:
    return _SHADES[min(len(_SHADES) - 1, int(frac * (len(_SHADES) - 1)
                                             + 0.5))]


def utilization_grid(tel: Telemetry) -> str:
    """ASCII fabric heatmap (placed runs) or worker/stage utilization table
    (ideal runs); utilization = fired cycles / simulated cycles."""
    if not tel.attached:
        return "utilization: no run attached"
    cyc = max(1, tel.cycles)
    if tel.fabric is not None:
        topo = tel.fabric.topo
        coords = tel.fabric.placement.coords
        busy: dict[tuple, int] = {}
        for nid in range(tel.n_nodes):
            c = coords[nid]
            busy[c] = busy.get(c, 0) + int(tel.fires_total[nid])
        lines = [f"fabric utilization ({topo.rows}x{topo.cols}; "
                 f"shade = fire-cycles/cycle, max {_SHADES[-1]!r} = 100%)"]
        for r in range(topo.rows):
            row = "".join(
                _shade(min(1.0, busy.get((r, c), 0) / cyc))
                if (r, c) in busy else "·"
                for c in range(topo.cols))
            lines.append(f"  {r:>3} |{row}|")
        used = [min(1.0, b / cyc) for b in busy.values()]
        lines.append(f"  {len(busy)} PEs used, mean busy "
                     f"{100 * sum(used) / max(1, len(used)):.1f}% of "
                     f"{tel.cycles} cycles")
        return "\n".join(lines)
    # ideal mode: aggregate by worker/stage group
    busy_g: dict[str, int] = {}
    n_g: dict[str, int] = {}
    for nid, g in enumerate(tel.node_groups):
        busy_g[g] = busy_g.get(g, 0) + int(tel.fires_total[nid])
        n_g[g] = n_g.get(g, 0) + 1
    lines = ["worker/stage utilization (ideal run; busy% = mean "
             "fire-cycles/cycle over the group's instructions)"]
    for g in sorted(busy_g):
        frac = busy_g[g] / (cyc * n_g[g])
        bar = _shade(min(1.0, frac)) * max(1, int(min(1.0, frac) * 20))
        lines.append(f"  {g:<16} {100 * frac:5.1f}% |{bar}")
    return "\n".join(lines)


def bottleneck_table(tel: Telemetry, k: int = 10) -> str:
    """Top-``k`` stall-attribution table: which nodes lost the most cycles,
    and to what — plus the most contended links."""
    if not tel.attached:
        return "bottlenecks: no run attached (no stalls recorded)"
    per = tel.stall_totals
    order = np.argsort(-per.sum(axis=1), kind="stable")[:k]
    lines = [f"top-{k} bottlenecks (stalled cycles by cause; "
             f"run = {tel.cycles} cycles)",
             f"  {'node':<22}{'group':<14}{'total':>8}  "
             + "".join(f"{c.split('_')[0]:>10}" for c in STALL_CAUSES)]
    any_row = False
    for nid in order.tolist():
        tot = int(per[nid].sum())
        if tot == 0:
            break
        any_row = True
        lines.append(
            f"  {tel.node_names[nid][:21]:<22}"
            f"{tel.node_groups[nid][:13]:<14}{tot:>8}  "
            + "".join(f"{int(per[nid, i]):>10}"
                      for i in range(len(STALL_CAUSES))))
    if not any_row:
        lines.append("  (no stalls recorded)")
    hot = np.argsort(-tel.link_stalls, kind="stable")[:5]
    rows = [(int(l), int(tel.link_stalls[l]), int(tel.link_words[l]))
            for l in hot.tolist() if tel.link_stalls[l] > 0]
    if rows:
        lines.append("  contended links (stall-cycles / words carried):")
        for lid, st, w in rows:
            lines.append(f"    {tel.link_names[lid]:<24} {st:>8} / {w}")
    return "\n".join(lines)


def render_report(tel: Telemetry, k: int = 10) -> str:
    """Full text report: totals, heatmap, bottleneck attribution.  A sink
    that never observed a run renders a stub instead of raising — report
    paths run on failure/cleanup codepaths too."""
    if not tel.attached:
        return ("telemetry: no run attached — no stalls recorded "
                f"({len(tel.spans)} span(s))")
    t = tel.totals()
    head = (f"telemetry: {tel.run_label} — {t['cycles']} cycles, "
            f"{t['fires_total']} fires, {t['loads']} loads, "
            f"{t['stores']} stores, token_hops={t['token_hops']}, "
            f"net stall_cycles={t['stall_cycles']}\n"
            f"stall attribution (node-cycles): "
            + " ".join(f"{c}={n}"
                       for c, n in t["stall_attribution"].items()))
    return "\n".join([head, utilization_grid(tel), bottleneck_table(tel, k)])
