"""Chrome/Perfetto ``trace_event`` export for a :class:`Telemetry` sink.

Open the written file in https://ui.perfetto.dev (or ``chrome://tracing``).
The mapping from probes to tracks:

* one process group per PE (placed runs: ``PE(r, c)``) or per worker/stage
  pipeline (ideal runs: ``reader/w0`` …), with one thread track per node
  (instruction) inside it — slices are the node's state intervals (``fire``
  runs and the four attributed stall causes; ``inactive`` stretches are
  omitted).  Timebase: 1 simulated cycle = 1 µs.
* one counter track per contended link (any link that ever made a token
  wait) sampling its per-cycle word occupancy, so the hot links from the
  stall-attribution table are visually obvious.
* one process for tuner search spans (``repro.explore`` evaluations), on the
  wall-clock timebase — a whole sweep becomes one inspectable artifact.

Events are emitted globally sorted by timestamp with integer ``ts``/``dur``
— :func:`validate_trace` checks that (plus the required keys) and is run by
the tests and by ``benchmarks/run.py --trace``.
"""
from __future__ import annotations

import json

from repro.telemetry.probe import (ST_FIRED, ST_INACTIVE, STATE_NAMES,
                                   Telemetry)

__all__ = ["trace_events", "write_trace", "validate_trace"]

_PID_SPANS = 1                      # tuner/search spans
_PID_LINKS = 2                      # per-link occupancy counters
_PID_GROUP0 = 10                    # first PE / worker-stage group


def _node_events(tel: Telemetry) -> list[dict]:
    evs: list[dict] = []
    group_pid: dict[str, int] = {}
    for nid, g in enumerate(tel.node_groups):
        if g not in group_pid:
            pid = _PID_GROUP0 + len(group_pid)
            group_pid[g] = pid
            evs.append({"ph": "M", "pid": pid, "ts": 0,
                        "name": "process_name", "args": {"name": g}})
            evs.append({"ph": "M", "pid": pid, "ts": 0,
                        "name": "process_sort_index",
                        "args": {"sort_index": pid}})
        evs.append({"ph": "M", "pid": group_pid[g], "tid": nid, "ts": 0,
                    "name": "thread_name",
                    "args": {"name": f"{tel.node_names[nid]} "
                                     f"({tel.node_ops[nid]})"}})
    for nid, state, t0, t1 in tel.intervals:
        if state == ST_INACTIVE:
            continue
        evs.append({"ph": "X", "pid": group_pid[tel.node_groups[nid]],
                    "tid": nid, "ts": t0, "dur": t1 - t0,
                    "name": STATE_NAMES[state],
                    "cat": "fire" if state == ST_FIRED else "stall"})
    return evs


def _link_events(tel: Telemetry) -> list[dict]:
    evs: list[dict] = []
    contended = [lid for lid in range(len(tel.link_names))
                 if tel.link_stalls[lid] > 0]
    if contended:
        # "links" declares the inventory size: validate_trace rejects any
        # counter sample whose lid falls outside it (a booking against a
        # link the fabric does not have)
        evs.append({"ph": "M", "pid": _PID_LINKS, "ts": 0,
                    "name": "process_name",
                    "args": {"name": "links (contended)",
                             "links": len(tel.link_names)}})
    for lid in contended:
        name = (f"link {tel.link_names[lid]} "
                f"(stall={int(tel.link_stalls[lid])})")
        occ = tel.link_occ.get(lid, {})
        # sample every occupied slot, and drop back to 0 when a busy slot's
        # successor is idle, so the counter reads as per-cycle occupancy
        samples: dict[int, int] = {}
        for slot, words in occ.items():
            samples[slot] = words
        for slot in list(samples):
            if slot + 1 not in samples:
                samples[slot + 1] = 0
        for slot in sorted(samples):
            evs.append({"ph": "C", "pid": _PID_LINKS, "ts": slot,
                        "name": name,
                        "args": {"words": samples[slot], "lid": lid}})
    return evs


def _span_events(tel: Telemetry) -> list[dict]:
    evs: list[dict] = []
    tracks: dict[str, int] = {}
    if tel.spans:
        evs.append({"ph": "M", "pid": _PID_SPANS, "ts": 0,
                    "name": "process_name", "args": {"name": "tuner"}})
    for sp in tel.spans:
        track = sp.get("track", "spans")
        if track not in tracks:
            tid = len(tracks)
            tracks[track] = tid
            evs.append({"ph": "M", "pid": _PID_SPANS, "tid": tid, "ts": 0,
                        "name": "thread_name", "args": {"name": track}})
        evs.append({"ph": "X", "pid": _PID_SPANS, "tid": tracks[track],
                    "ts": int(sp["t0"] * 1e6),
                    "dur": max(1, int(sp["dur"] * 1e6)),
                    "name": sp["name"], "cat": sp.get("cat", "span"),
                    "args": sp.get("args", {})})
    return evs


def trace_events(tel: Telemetry) -> list[dict]:
    """Flatten the sink into ``trace_event`` dicts, globally ts-sorted
    (metadata first)."""
    meta: list[dict] = []
    evs: list[dict] = []
    parts = [_span_events(tel)]
    if tel.attached:
        parts += [_node_events(tel), _link_events(tel)]
    for part in parts:
        for e in part:
            (meta if e["ph"] == "M" else evs).append(e)
    evs.sort(key=lambda e: (e["ts"], e.get("pid", 0), e.get("tid", 0)))
    return meta + evs


def write_trace(tel: Telemetry, path: str) -> dict:
    """Write the Perfetto JSON trace; returns the written object."""
    obj = {"traceEvents": trace_events(tel),
           "displayTimeUnit": "ms",
           "metadata": {"tool": "repro.telemetry",
                        "run": tel.run_label,
                        "cycles": tel.cycles,
                        "clock": "1 cycle = 1 us (sim tracks); "
                                 "wall us (tuner spans)"}}
    with open(path, "w") as f:
        json.dump(obj, f, indent=0, sort_keys=True)
        f.write("\n")
    return obj


def validate_trace(obj: dict | list) -> int:
    """Schema check: required keys per phase, integer non-negative
    timestamps, non-negative durations, monotonic (ts-sorted) event order,
    no two overlapping *exclusive* intervals (fire/stall slices) on one
    node track, and every link-counter sample inside the declared link
    inventory.  Returns the number of non-metadata events; raises
    ValueError naming the violation on the first one."""
    evs = obj["traceEvents"] if isinstance(obj, dict) else obj
    last_ts = None
    n = 0
    n_links = None                        # declared by the links process
    track_end: dict[tuple, int] = {}      # (pid, tid) -> exclusive end ts
    for i, e in enumerate(evs):
        ph = e.get("ph")
        if ph not in ("M", "X", "C", "B", "E", "i", "I"):
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        if "pid" not in e or "name" not in e:
            raise ValueError(f"event {i}: missing pid/name: {e}")
        ts = e.get("ts")
        if not isinstance(ts, int) or ts < 0:
            raise ValueError(f"event {i}: bad ts {ts!r} (want int >= 0)")
        if ph == "M":
            if (e["name"] == "process_name"
                    and "links" in e.get("args", {})):
                n_links = e["args"]["links"]
            continue
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, int) or dur < 0:
                raise ValueError(f"event {i}: bad dur {dur!r}")
            if e.get("cat") in ("fire", "stall"):
                # per-node state slices are exclusive by the telemetry
                # contract: one state per node per cycle
                key = (e["pid"], e.get("tid"))
                end = track_end.get(key)
                if end is not None and ts < end:
                    raise ValueError(
                        f"event {i}: overlapping exclusive intervals on "
                        f"pid={key[0]} tid={key[1]} ({e['name']!r} starts "
                        f"at {ts} before the previous slice ends at {end})")
                track_end[key] = max(end or 0, ts + dur)
        if ph == "C":
            if "args" not in e:
                raise ValueError(f"event {i}: counter without args")
            lid = e["args"].get("lid")
            if lid is not None and (n_links is None
                                    or not 0 <= lid < n_links):
                raise ValueError(
                    f"event {i}: unknown link id {lid} (declared link "
                    f"inventory: {n_links})")
        if last_ts is not None and ts < last_ts:
            raise ValueError(
                f"event {i}: timestamps not monotonic ({ts} < {last_ts})")
        last_ts = ts
        n += 1
    return n
