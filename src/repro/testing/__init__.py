"""Test-support utilities shipped with the library (no third-party deps).

``repro.testing.minihyp`` is a minimal, deterministic stand-in for the
`hypothesis` property-testing API so the tier-1 property sweep runs (rather
than skips) in environments where ``hypothesis`` cannot be installed.
"""
