"""A tiny, deterministic subset of the `hypothesis` API (fallback shim).

The tier-1 property sweep (``tests/test_property.py``) is written against
hypothesis.  Some CI containers cannot install extra packages, and skipping
the sweep silently drops the strongest invariant tests in the suite — so
this module implements just enough of the API for the sweep to *run*:

* strategies: ``integers``, ``floats``, ``lists``, ``tuples``,
  ``sampled_from``, ``booleans``, ``composite``
* decorators: ``given`` (positional strategies), ``settings``
  (``max_examples`` honoured, ``deadline`` ignored)

Differences from real hypothesis — by design, not accident:

* **No shrinking.**  A failing example is reported verbatim (the values are
  embedded in the raised ``AssertionError``), not minimized.
* **Deterministic.**  Example ``i`` of test ``f`` is drawn from
  ``sha256(f.__qualname__, i)`` — every run explores the same points, so CI
  failures reproduce locally without a database.
* **No assume/target/example decorators** — the sweep doesn't use them.

When real hypothesis is installed, ``tests/test_property.py`` prefers it;
this shim only keeps the sweep alive without it.  Example count can be
globally capped with the ``REPRO_MINIHYP_EXAMPLES`` env var (CI knob).
"""
from __future__ import annotations

import functools
import hashlib
import inspect
import os
import random


class Strategy:
    """A value generator: ``sample(rng) -> value``."""

    def __init__(self, sample_fn, label: str = "strategy"):
        self._sample = sample_fn
        self.label = label

    def sample(self, rng: random.Random):
        return self._sample(rng)

    def __repr__(self):
        return f"minihyp.{self.label}"


def integers(min_value: int, max_value: int) -> Strategy:
    if min_value > max_value:
        raise ValueError("integers: min_value > max_value")
    return Strategy(lambda rng: rng.randint(min_value, max_value),
                    f"integers({min_value},{max_value})")


def floats(min_value: float, max_value: float, *, allow_nan: bool = False,
           allow_infinity: bool = False, width: int = 64) -> Strategy:
    del allow_nan, allow_infinity  # bounded draws are always finite here

    def draw(rng: random.Random) -> float:
        v = rng.uniform(min_value, max_value)
        if width == 32:        # round-trip through float32 like hypothesis
            import struct
            v = struct.unpack("f", struct.pack("f", v))[0]
            v = min(max(v, min_value), max_value)
        return v
    return Strategy(draw, f"floats({min_value},{max_value})")


def booleans() -> Strategy:
    return Strategy(lambda rng: bool(rng.getrandbits(1)), "booleans()")


def lists(elements: Strategy, *, min_size: int = 0,
          max_size: int = 10) -> Strategy:
    return Strategy(
        lambda rng: [elements.sample(rng)
                     for _ in range(rng.randint(min_size, max_size))],
        f"lists({elements.label})")


def tuples(*strategies: Strategy) -> Strategy:
    return Strategy(lambda rng: tuple(s.sample(rng) for s in strategies),
                    "tuples(...)")


def sampled_from(seq) -> Strategy:
    pool = list(seq)
    if not pool:
        raise ValueError("sampled_from: empty sequence")
    return Strategy(lambda rng: pool[rng.randrange(len(pool))],
                    "sampled_from(...)")


def composite(fn):
    """``@composite def strat(draw, *args): ...`` — returns a strategy
    factory, exactly like hypothesis's signature."""
    @functools.wraps(fn)
    def factory(*args, **kwargs) -> Strategy:
        def draw_value(rng: random.Random):
            return fn(lambda s: s.sample(rng), *args, **kwargs)
        return Strategy(draw_value, f"composite:{fn.__name__}")
    return factory


def settings(**kwargs):
    """Record settings on the test function; ``given`` reads them.  Only
    ``max_examples`` has effect (``deadline`` etc. are accepted+ignored)."""
    def deco(fn):
        fn._minihyp_settings = dict(kwargs)
        return fn
    return deco


def _example_rng(qualname: str, index: int) -> random.Random:
    seed = int.from_bytes(
        hashlib.sha256(f"{qualname}:{index}".encode()).digest()[:8], "big")
    return random.Random(seed)


def given(*strategies: Strategy):
    """Run the wrapped test once per deterministic example, passing drawn
    values positionally after any pytest-supplied args."""
    if not strategies:
        raise ValueError("given() needs at least one strategy")

    def deco(fn):
        n = getattr(fn, "_minihyp_settings", {}).get("max_examples", 25)
        env_cap = os.environ.get("REPRO_MINIHYP_EXAMPLES")
        if env_cap:
            n = min(n, max(1, int(env_cap)))

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            qual = f"{fn.__module__}.{fn.__qualname__}"
            for i in range(n):
                rng = _example_rng(qual, i)
                values = [s.sample(rng) for s in strategies]
                try:
                    fn(*args, *values, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"minihyp falsified {fn.__qualname__} on example "
                        f"{i}/{n}: args={values!r}: "
                        f"{type(e).__name__}: {e}") from e
        # hide the drawn parameters from pytest's fixture resolution (the
        # strategies supply them), like hypothesis does
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        wrapper.minihyp = True
        return wrapper
    return deco


class _StrategiesNamespace:
    """``from repro.testing.minihyp import strategies as st`` mirror."""
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    lists = staticmethod(lists)
    tuples = staticmethod(tuples)
    sampled_from = staticmethod(sampled_from)
    composite = staticmethod(composite)


strategies = _StrategiesNamespace()
