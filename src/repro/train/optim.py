"""Optimizer substrate: AdamW + cosine schedule + global-norm clipping +
optional gradient compression (error feedback), pure JAX (no optax in this
environment).

Optimizer state is a pytree mirroring the params (m, v per leaf), so the same
logical sharding rules apply — m/v inherit each param's sharding (FSDP'd
optimizer state = ZeRO-style).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.collectives import compress_decompress, init_ef


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    compression: str = "none"          # none | int8 | topk
    topk_frac: float = 0.01


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    ef: Optional[Any]                  # error-feedback state (or None)


def init_opt_state(params, cfg: OptConfig) -> AdamWState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    ef = init_ef(params) if cfg.compression != "none" else None
    return AdamWState(step=jnp.zeros((), jnp.int32), m=z,
                      v=jax.tree.map(jnp.copy, z), ef=ef)


def schedule(step: jax.Array, cfg: OptConfig) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state: AdamWState, cfg: OptConfig
                  ) -> tuple[Any, AdamWState]:
    """One AdamW step (with optional compression + EF before the moment
    updates — modelling a compressed all-reduce; DESIGN.md §5.4)."""
    ef = state.ef
    if cfg.compression != "none":
        grads, ef = compress_decompress(grads, ef, method=cfg.compression,
                                        topk_frac=cfg.topk_frac)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    lr = schedule(state.step, cfg)
    b1, b2 = cfg.betas

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** (state.step + 1))
        vh = v / (1 - b2 ** (state.step + 1))
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:       # no decay on norms/bias
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    return new_p, AdamWState(step=state.step + 1, m=new_m, v=new_v, ef=ef)
