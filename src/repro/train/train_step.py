"""train_step / eval_step builders.

``make_train_step`` returns a jit-ready pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
with optional microbatch gradient accumulation (a ``lax.scan`` over
microbatches — bounds activation memory and the blast radius of stragglers)
and remat policy threaded into the model's layer scan.

Sharding is applied by the caller (launch/dryrun.py, launch/train.py) via the
logical trees from models/params.py.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.transformer import xent_loss
from repro.train.optim import OptConfig, apply_updates

AUX_WEIGHT = 0.01      # MoE load-balance loss weight


def make_loss_fn(model, cfg: ArchConfig, remat: str = "none"):
    def loss_fn(params, batch):
        if cfg.family == "audio":
            logits, aux = model.forward(params, batch["tokens"],
                                        batch["frames"], remat=remat)
        else:
            logits, aux = model.forward(params, batch["tokens"],
                                        positions=batch.get("positions"),
                                        patches=batch.get("patches"),
                                        remat=remat)
        # next-token prediction: shift labels left
        labels = batch.get("labels", batch["tokens"])
        loss = xent_loss(logits[:, :-1, :], labels[:, 1:])
        return loss + AUX_WEIGHT * aux, (loss, aux)
    return loss_fn


def make_train_step(model, cfg: ArchConfig, opt_cfg: OptConfig, *,
                    remat: str = "dots", microbatches: int = 1):
    loss_fn = make_loss_fn(model, cfg, remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (_, (loss, aux)), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0] if x.ndim >= 1 else 1
                lead = -1 if x.ndim == 0 else b // microbatches
                if x.ndim >= 2 and x.shape[0] == 3:   # mrope positions (3,B,S)
                    return jnp.moveaxis(
                        x.reshape(3, microbatches, lead, *x.shape[2:]), 1, 0)
                return x.reshape(microbatches, lead, *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_body(carry, mbatch):
                g_acc, l_acc, a_acc = carry
                (_, (loss, aux)), grads = grad_fn(params, mbatch)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss, a_acc + aux), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, aux), _ = jax.lax.scan(
                acc_body, (zero_g, jnp.zeros(()), jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss, aux = loss / microbatches, aux / microbatches

        params, opt_state = apply_updates(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss.astype(jnp.float32),
                   "aux_loss": aux.astype(jnp.float32),
                   "step": opt_state.step}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model, cfg: ArchConfig):
    loss_fn = make_loss_fn(model, cfg)

    def eval_step(params, batch):
        _, (loss, aux) = loss_fn(params, batch)
        return {"loss": loss, "aux_loss": aux}
    return eval_step
