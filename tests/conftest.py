"""Shared fixtures. NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see the real (single) device; only launch/dryrun.py forces 512
host devices, and the multi-device tests spawn subprocesses."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
