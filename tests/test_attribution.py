"""Attribution + metrics gates (PR 8, docs/telemetry.md).

The cycle-accounting profiler's two hard contracts:

* **exactness** — ``fill + steady + drain == SimResult.cycles`` and the
  fired/inactive/stall-cause node-cycles tile ``cycles * n_nodes``, on
  every case, both modes.
* **engine bit-identity** — ``attribute()`` is a pure function of the
  parity-gated telemetry sink, so the whole accounting (phases, causes,
  stage table, critical path, bottleneck label) must serialize identically
  for the interpreter and the compiled vector engine.

Plus the metrics layer (fingerprinted history records), the observatory /
overhead-check scripts, the tuner's bottleneck labels, and the routed
auto-capacity regression gate (satellite 1).
"""
import json

import numpy as np
import pytest

from repro.core import CGRA, map_1d, map_2d, simulate
from repro.core.spec import StencilSpec, heat_2d, paper_stencil_2d
from repro.fabric import (FabricTopology, apply_routed_capacities, place,
                          route)
from repro.program import hdiff_program, lower, two_stage_heat
from repro.telemetry import (STALL_CAUSES, CycleAccounting, Telemetry,
                             attribute, render_attribution, stage_label)
from repro.telemetry.attribution import STAGE_ORDER

ENGINES = ("interp", "vector")


def _accounts(mk_plan, x, routed, timeline=False):
    """attribute() both engines' runs of the same case."""
    out = []
    for engine in ENGINES:
        plan = mk_plan()
        fab = None
        if routed:
            fab = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
        tel = Telemetry(timeline=timeline)
        res = simulate(plan, x, CGRA, fabric=fab, engine=engine,
                       telemetry=tel)
        out.append((attribute(tel, res), res, tel))
    return out


def _assert_exact(acct: CycleAccounting, res):
    assert sum(acct.phases.values()) == res.cycles == acct.cycles
    assert all(v >= 0 for v in acct.phases.values())
    tiled = acct.fired + acct.inactive + sum(acct.causes.values())
    assert tiled == acct.cycles * acct.n_nodes
    for row in acct.stages.values():
        per_stage = (row["fired"] + row["inactive"]
                     + sum(row[c] for c in STALL_CAUSES))
        assert per_stage == acct.cycles * row["nodes"]
    assert sum(r["nodes"] for r in acct.stages.values()) == acct.n_nodes


CASES = {}


def _case_1d(rng):
    spec = StencilSpec((240,), (2,),
                       (tuple((rng.normal(size=5) / 5).tolist()),),
                       dtype="float64")
    return lambda: map_1d(spec, workers=4), rng.normal(size=240)


def _case_2d(rng):
    spec = paper_stencil_2d(ny=30, nx=48, r=12)
    return lambda: map_2d(spec, workers=8), rng.normal(size=(30, 48))


def _case_program(rng):
    prog = two_stage_heat(24, 32)
    ins = {f: rng.normal(size=prog.grid_shape) for f in prog.in_fields}
    x = lower(prog, workers=4).pack_inputs(ins)
    return lambda: lower(prog, workers=4), x


@pytest.mark.parametrize("case", ["1d", "2d", "program"])
@pytest.mark.parametrize("routed", [False, True])
def test_attribution_exact_and_engine_identical(rng, case, routed):
    mk, x = {"1d": _case_1d, "2d": _case_2d, "program": _case_program}[
        case](rng)
    (aa, ra, _), (ab, rb, _) = _accounts(mk, x, routed)
    # bit-identical across engines, including through JSON serialization
    assert aa.as_dict() == ab.as_dict()
    assert json.dumps(aa.as_dict(), sort_keys=True) == \
        json.dumps(ab.as_dict(), sort_keys=True)
    for acct, res in ((aa, ra), (ab, rb)):
        _assert_exact(acct, res)
    # routed runs attribute network time; ideal runs never can
    if not routed:
        assert aa.causes["network_contention"] == 0
    # counter-only sinks (timeline=False) reach the same accounting
    (ac, _, _), _ = _accounts(mk, x, routed, timeline=False)
    assert ac.as_dict() == aa.as_dict()


def test_phase_decomposition_semantics(rng):
    """fill ends before the first store; drain starts after the last load;
    a pipeline long enough to stream has nonzero steady state (ideal)."""
    mk, x = _case_2d(rng)
    (acct, res, tel), _ = _accounts(mk, x, routed=False)
    stores = [nid for nid, op in enumerate(tel.node_ops) if op == "store"
              and tel.fires_total[nid] > 0]
    first_store = min(int(tel.first_fire[nid]) for nid in stores)
    assert acct.phases["fill"] == first_store - 1
    assert acct.phases["steady"] > 0
    loads = [nid for nid, op in enumerate(tel.node_ops) if op == "load"]
    last_load = max(int(tel.last_fire[nid]) for nid in loads)
    assert acct.phases["drain"] == res.cycles - last_load


def test_stage_labels_cover_pipeline(rng):
    mk, x = _case_2d(rng)
    (acct, _, _), _ = _accounts(mk, x, routed=False)
    assert set(acct.stages) == set(STAGE_ORDER)
    assert stage_label("compute", "add") == "AddTree"
    assert stage_label("compute", "mac") == "TapChain"
    assert stage_label("compute", "imux") == "TapChain"
    assert stage_label("reader", "load") == "ReaderBank"
    assert stage_label("writer", "store") == "WriterBank"
    assert stage_label("sync", "cmp") == "SyncTree"


def test_critical_path_is_causal_chain(rng):
    mk, x = _case_2d(rng)
    (acct, res, tel), _ = _accounts(mk, x, routed=True)
    path = acct.critical_path
    assert len(path) >= 3
    # source -> sink: starts at a reader, ends at the completion side
    assert path[-1]["stage"] == "SyncTree"
    assert path[0]["stage"] == "ReaderBank"
    assert path[-1]["last_fire"] == res.cycles
    # every consecutive pair is a real DFG edge (the chain is causal in
    # graph structure; last_fire need not be monotone — a producer can
    # keep firing after its consumer retires)
    by_name = {n.name: n for n in tel.plan.dfg.nodes}
    for a, b in zip(path, path[1:]):
        dst = by_name[b["name"]]
        assert any(e.src.name == a["name"] for e in dst.in_edges)
    for d in path:
        assert d["fires"] > 0
        if d["stalled"]:
            assert d["cause"] in STALL_CAUSES


def test_bottleneck_labels():
    from repro.telemetry.attribution import _bottleneck
    ph = {"fill": 10, "steady": 80, "drain": 10}
    assert _bottleneck(100, {"fill": 60, "steady": 20, "drain": 20},
                       {}) == "fill-bound"
    assert _bottleneck(100, ph, {c: 0 for c in STALL_CAUSES}) == \
        "compute-bound"
    assert _bottleneck(100, ph, {"input_starved": 5}) == "starved"
    assert _bottleneck(100, ph, {"output_blocked": 9,
                                 "input_starved": 2}) == "capacity-bound"
    assert _bottleneck(100, ph, {"memory_arbitration": 9}) == "memory-bound"
    assert _bottleneck(100, ph, {"network_contention": 9,
                                 "input_starved": 3}) == "network-bound"


def test_attribute_rejects_unfinished_and_mismatched(rng):
    tel = Telemetry()
    with pytest.raises(ValueError, match="observed a run"):
        attribute(tel)
    mk, x = _case_1d(rng)
    (acct, res, tel), _ = _accounts(mk, x, routed=False)

    class FakeRes:
        cycles = res.cycles + 1
    with pytest.raises(AssertionError, match="SimResult says"):
        attribute(tel, FakeRes())


def test_render_attribution_smoke(rng):
    mk, x = _case_2d(rng)
    (acct, _, _), _ = _accounts(mk, x, routed=True)
    text = render_attribution(acct)
    assert "cycle accounting" in text and "critical path" in text
    for stage in STAGE_ORDER:
        assert stage in text


# ---------------------------------------------------------------------------
# metrics layer: fingerprinted records, append-only history
# ---------------------------------------------------------------------------
def test_metrics_records_and_history(tmp_path):
    from repro.telemetry.metrics import (append_history, case_records,
                                         fingerprint, flatten_case,
                                         history_for, load_history,
                                         trend_values)
    assert flatten_case({"a": 1, "b": {"c": 2.5, "d": {"e": "x"}}}) == \
        {"a": 1, "b.c": 2.5, "b.d.e": "x"}
    art = {"schema": "bench_pr4/v1", "config": "smoke",
           "cases": {"2d": {"cycles_routed": 642, "vector_wall_s": 0.3,
                            "grid": [30, 48], "workers": 8,
                            "engines": ["interp", "vector"]}}}
    recs = case_records(art, source="BENCH_pr4.json", ts=1000.0)
    assert len(recs) == 1
    r = recs[0]
    assert r["counters"] == {"cycles_routed": 642, "workers": 8}
    assert r["walls"] == {"vector_wall_s": 0.3}
    assert r["meta"]["grid"] == [30, 48]
    # fingerprint = identity, not score: same experiment, changed counters
    art2 = json.loads(json.dumps(art))
    art2["cases"]["2d"]["cycles_routed"] = 999
    assert case_records(art2, ts=2000.0)[0]["fingerprint"] == \
        r["fingerprint"]
    assert fingerprint("s", "c", "x", {}) != fingerprint("s", "c", "y", {})

    hist = str(tmp_path / "h.jsonl")
    assert append_history(hist, recs) == 1
    append_history(hist, case_records(art2, ts=2000.0))
    with open(hist, "a") as f:                  # torn append survives
        f.write('{"broken json\n\n')
    loaded = load_history(hist)
    assert len(loaded) == 2
    line = history_for(loaded, "bench_pr4/v1", "smoke", "2d")
    assert trend_values(line, "cycles_routed") == [642, 999]
    assert trend_values(line, "cycles_routed", last=1) == [999]
    assert trend_values(line, "vector_wall_s", kind="walls") == [0.3, 0.3]


def test_observatory_append_and_report(tmp_path, capsys):
    from benchmarks.observatory import main as obs
    art = {"schema": "bench_pr4/v1", "config": "smoke",
           "cases": {"2d": {"cycles_routed": 642, "vector_wall_s": 0.3,
                            "bottleneck": "fill-bound",
                            "stall_breakdown": {"input_starved": 10,
                                                "network_contention": 30},
                            "phases": {"fill": 438, "steady": 0,
                                       "drain": 204}}}}
    a = tmp_path / "BENCH_x.json"
    a.write_text(json.dumps(art))
    hist = str(tmp_path / "h.jsonl")
    assert obs(["append", str(a), "--history", hist]) == 0
    art["cases"]["2d"]["cycles_routed"] = 600
    a.write_text(json.dumps(art))
    assert obs(["append", str(a), "--history", hist]) == 0
    assert obs(["report", "--history", hist]) == 0
    out = capsys.readouterr().out
    assert "cycles_routed: 600" in out
    assert "bottleneck: fill-bound" in out
    assert "network_contention" in out
    # partial artifacts never enter the trajectory
    art["errors"] = {"3d": "boom"}
    a.write_text(json.dumps(art))
    assert obs(["append", str(a), "--history", hist]) == 1


def test_overhead_check_gates_against_history(tmp_path, monkeypatch):
    import benchmarks.overhead_check as oc
    hist = str(tmp_path / "h.jsonl")
    monkeypatch.setattr(oc, "measure", lambda repeats: (0.40, 642))
    assert oc.main(["--history", hist]) == 0    # seeds the trend
    assert oc.main(["--history", hist]) == 0    # equal to median: pass
    monkeypatch.setattr(oc, "measure", lambda repeats: (0.40 * 1.05, 642))
    assert oc.main(["--history", hist, "--atol", "0"]) == 1  # >2% creep
    monkeypatch.setattr(oc, "measure", lambda repeats: (0.40, 642))
    from repro.telemetry.metrics import load_history
    n_before = len(load_history(hist))
    assert oc.main(["--history", hist, "--no-append"]) == 0
    assert len(load_history(hist)) == n_before


# ---------------------------------------------------------------------------
# tuner threading: bottleneck labels on evaluations (tentpole)
# ---------------------------------------------------------------------------
def test_explore_labels_bottlenecks(tmp_path):
    from repro.core.spec import heat_2d as _heat
    from repro.explore import Budget, SpaceOptions, explore

    res = explore(_heat(18, 36, dtype="float64"), CGRA,
                  options=SpaceOptions(workers=(2, 4), capacities=("auto",),
                                       fabrics=((8, 8, "mesh"),),
                                       place_seeds=(0,)),
                  budget=Budget(routed_finalists=2),
                  cache=str(tmp_path / "c.json"),
                  telemetry=Telemetry())
    labels = {"fill-bound", "compute-bound", "starved", "capacity-bound",
              "memory-bound", "network-bound"}
    assert res.front
    for pt in res.front + res.ideal_points:
        assert pt.bottleneck in labels
        assert pt.as_dict()["bottleneck"] == pt.bottleneck
    # cached replays carry the label too
    res2 = explore(_heat(18, 36, dtype="float64"), CGRA,
                   options=SpaceOptions(workers=(2, 4), capacities=("auto",),
                                        fabrics=((8, 8, "mesh"),),
                                        place_seeds=(0,)),
                   budget=Budget(routed_finalists=2),
                   cache=str(tmp_path / "c.json"))
    assert res2.stats["cache"]["hits"] > 0
    for pt in res2.front:
        assert pt.cached and pt.bottleneck in labels
    assert {p.config: p.bottleneck for p in res2.front} == \
        {p.config: p.bottleneck for p in res.front}


def test_point_from_cache_tolerates_old_entries():
    """Cache entries written before PR 8 have no bottleneck key."""
    from repro.explore.search import _point_from_cache
    from repro.explore.space import MappingConfig
    ent = {"cycles": 10, "pes": 5, "chan": 2, "gflops": 1.0,
           "sim_cycles": 10}
    pt = _point_from_cache(MappingConfig(workers=2), ent, routed=False)
    assert pt.bottleneck == "" and pt.cached


# ---------------------------------------------------------------------------
# satellite 1: routed auto-capacity from hop depths
# ---------------------------------------------------------------------------
def test_apply_routed_capacities_grows_bounded_edges_only():
    prog = hdiff_program(20, 28)
    plan = lower(prog, workers=4, auto_capacity=True)
    rf = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
    from repro.fabric.route import edge_key
    before = {edge_key(e): e.capacity for e in plan.dfg.edges()}
    grown = apply_routed_capacities(rf, slack=1)
    assert grown > 0
    hops_max = rf.stats()["hops_max"]
    for e in plan.dfg.edges():
        old = before[edge_key(e)]
        hops = len(rf.routes.get(edge_key(e), ()))
        if old is None:
            assert e.capacity is None            # unbounded stays unbounded
        elif hops:
            assert e.capacity == old + hops + 1  # hop depth + slack
            assert e.capacity - old <= hops_max + 1   # no overshoot
        else:
            assert e.capacity == old             # local edges untouched


def test_routed_hdiff_auto_capacity_regression(rng):
    """Satellite 1 regression gate: routed hdiff with auto (bounded)
    capacities must complete without deadlock in bounded cycles, match the
    unbounded-capacity output bit-for-bit, and not run slower than the
    un-grown bounded mapping (the back-pressure the hop term removes)."""
    prog = hdiff_program(20, 28)
    ins = {f: rng.normal(size=prog.grid_shape) for f in prog.in_fields}

    def run(auto, grow, engine="vector"):
        plan = lower(prog, workers=4, auto_capacity=auto)
        x = plan.pack_inputs(ins)
        rf = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
        if grow:
            apply_routed_capacities(rf)
        return simulate(plan, x, CGRA, fabric=rf, engine=engine,
                        max_cycles=100_000)

    unbounded = run(False, False)
    plain = run(True, False)
    grown = run(True, True)
    assert np.array_equal(grown.output, unbounded.output)
    assert grown.cycles <= plain.cycles          # hop term only helps
    assert grown.cycles < 100_000                # no deadlock/timeout
    # engine parity holds for the grown capacities too
    grown_i = run(True, True, engine="interp")
    assert grown_i.cycles == grown.cycles
    assert np.array_equal(grown_i.output, grown.output)


def test_compile_presize_is_hop_aware(rng):
    """The vector engine's ring presize accounts for transit depth; the
    simulation semantics must not change (presize is an allocation hint)."""
    spec = heat_2d(18, 24, dtype="float64")
    x = rng.normal(size=(18, 24))

    def run(routed):
        plan = map_2d(spec, workers=3, auto_capacity=True)
        fab = None
        if routed:
            fab = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
        return simulate(plan, x, CGRA, fabric=fab, engine="vector")

    ideal, routed = run(False), run(True)
    assert ideal.cycles > 0 and routed.cycles >= ideal.cycles
