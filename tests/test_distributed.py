"""Multi-device tests: halo-exchange stencils == single-device oracle,
int8_psum, logical sharding rules.  Device-count-dependent tests run in a
subprocess with --xla_force_host_platform_device_count=8 so the main pytest
process keeps its single real device (per assignment)."""
import json
import os
import subprocess
import sys
from types import SimpleNamespace

import pytest

from repro.distributed.sharding import resolve_spec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.spec import StencilSpec
from repro.core.reference import stencil_reference_np
from repro.distributed.halo import (distributed_stencil1d,
                                    distributed_stencil2d,
                                    distributed_stencil3d)
from repro.distributed.collectives import int8_psum
from repro.distributed.sharding import make_mesh_compat, shard_map_compat

out = {}
mesh = make_mesh_compat((2, 4), ("pod", "data"))
rng = np.random.default_rng(0)

spec = StencilSpec((512,), (3,), (tuple((rng.normal(size=7)/7).tolist()),),
                   dtype="float32", timesteps=2)
f = distributed_stencil1d(spec, mesh, axis="data")
x = rng.normal(size=512).astype(np.float32)
out["d1"] = bool(np.allclose(np.asarray(f(jnp.asarray(x))),
                             stencil_reference_np(x, spec), atol=1e-5))

cx = rng.normal(size=5)/5; cx[2] = 0.0
spec2 = StencilSpec((64, 96), (2, 2),
                    (tuple((rng.normal(size=5)/5).tolist()), tuple(cx)),
                    dtype="float32", timesteps=2)
f2 = distributed_stencil2d(spec2, mesh, axes=("pod", "data"))
x2 = rng.normal(size=(64, 96)).astype(np.float32)
out["d2"] = bool(np.allclose(np.asarray(f2(jnp.asarray(x2))),
                             stencil_reference_np(x2, spec2), atol=1e-5))

cz3 = rng.normal(size=3)/3
cy3 = rng.normal(size=3)/3; cy3[1] = 0.0
cx3 = rng.normal(size=3)/3; cx3[1] = 0.0
spec3 = StencilSpec((16, 32, 48), (1, 1, 1),
                    (tuple(cz3), tuple(cy3), tuple(cx3)),
                    dtype="float32", timesteps=2)
f3 = distributed_stencil3d(spec3, mesh, axes=("pod", "data"))
x3 = rng.normal(size=(16, 32, 48)).astype(np.float32)
out["d3"] = bool(np.allclose(np.asarray(f3(jnp.asarray(x3))),
                             stencil_reference_np(x3, spec3), atol=1e-5))

mesh1 = make_mesh_compat((8,), ("d",))
xq = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
g = jax.jit(shard_map_compat(lambda v: int8_psum(v, "d"), mesh=mesh1,
                          in_specs=P("d"), out_specs=P("d")))
y = g(xq)
true = jnp.sum(xq, axis=0)
out["psum_rel"] = float(jnp.abs(y[0] - true).max() / jnp.abs(true).max())
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def subproc_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_distributed_stencil1d_matches_oracle(subproc_results):
    assert subproc_results["d1"]


def test_distributed_stencil2d_matches_oracle(subproc_results):
    assert subproc_results["d2"]


def test_distributed_stencil3d_matches_oracle(subproc_results):
    assert subproc_results["d3"]


def test_int8_psum_accuracy(subproc_results):
    assert subproc_results["psum_rel"] < 0.05


# ---- sharding rules (mesh-shape only; no devices needed) -------------------
MESH = SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16})


def test_rules_batch_over_pod_and_data():
    assert resolve_spec((256, 4096), ("batch", None), MESH) == \
        __import__("jax").sharding.PartitionSpec(("pod", "data"))


def test_rules_divisibility_fallback():
    P = __import__("jax").sharding.PartitionSpec
    # kv_heads=8 cannot split 16 -> replicated
    assert resolve_spec((8, 128), ("kv_heads", None), MESH) == P()
    # odd vocab -> replicated
    assert resolve_spec((49155, 1024), ("vocab", "fsdp"), MESH) == \
        P(None, "data")
    # heads=96 divides 16
    assert resolve_spec((96, 128), ("heads", None), MESH) == P("model")


def test_rules_no_axis_reuse():
    P = __import__("jax").sharding.PartitionSpec
    # both dims want 'model'; second falls back
    got = resolve_spec((32, 32), ("heads", "mlp"), MESH)
    assert got == P("model")


def test_inference_rules_keep_tp_drop_fsdp():
    from repro.distributed.sharding import INFERENCE_RULES
    P = __import__("jax").sharding.PartitionSpec
    # fsdp dim replicated at serving; TP dims unchanged
    assert resolve_spec((4096, 4096), ("fsdp", "mlp"), MESH,
                        INFERENCE_RULES) == P(None, "model")
    assert resolve_spec((4096, 4096), ("fsdp", "mlp"), MESH) == \
        P("data", "model")


def test_cache_seq_and_expert_cap_fallbacks():
    P = __import__("jax").sharding.PartitionSpec
    # kv_heads=8 can't take model=16 -> the cache *positions* take it
    got = resolve_spec((128, 8, 32768, 128),
                       ("batch", "kv_heads", "cache_seq", None), MESH)
    assert got == P(("pod", "data"), None, "model")
    # 32 experts take model -> capacity falls back to replicated
    got = resolve_spec((128, 32, 160, 1024),
                       ("batch", "experts", "expert_cap", None), MESH)
    assert got == P(("pod", "data"), "model")
    # 40 experts can't -> capacity takes model (granite-3b case)
    got = resolve_spec((128, 40, 160, 1024),
                       ("batch", "experts", "expert_cap", None), MESH)
    assert got == P(("pod", "data"), None, "model")
