"""Cross-validation: compiled vector engine vs the interpreter oracle.

Every observable of :func:`repro.core.simulate` must be *identical* across
``engine="interp"`` and ``engine="vector"``: cycle counts, per-op and
per-node fire counts, load/store/flop totals, queue-occupancy telemetry,
network hop/stall stats, and bit-identical output grids — on single-op
mappings of every rank, temporal layers, program pipelines (including the
imux re-interleave fallback), ideal and routed, bounded and unbounded
queues, plus the failure paths (deadlock, max_cycles)."""
import numpy as np
import pytest

from repro.core import CGRA, SimDeadlock, map_1d, map_2d, map_3d, simulate
from repro.core.spec import (StencilSpec, heat_2d, heat_3d, paper_stencil_2d)
from repro.fabric import FabricTopology, place, route
from repro.program import (CombineOp, StencilOp, StencilProgram,
                           hdiff_program, lower, two_stage_heat)

ENGINES = ("interp", "vector")


def _coeffs(rng, r):
    return tuple((rng.normal(size=2 * r + 1) / (2 * r + 1)).tolist())


def run_both(mk_plan, x, routed=False, **kw):
    """Simulate a freshly-built plan once per engine (+ fresh routes)."""
    out = []
    for engine in ENGINES:
        plan = mk_plan()
        fab = None
        if routed:
            fab = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
        out.append((plan, simulate(plan, x, CGRA, fabric=fab, engine=engine,
                                   **kw)))
    return out


def assert_identical(case):
    (plan_i, a), (plan_v, b) = case
    assert a.cycles == b.cycles
    assert a.fires == b.fires
    assert (a.loads, a.stores, a.flops) == (b.loads, b.stores, b.flops)
    assert a.max_queue_total == b.max_queue_total
    assert a.output.shape == b.output.shape
    assert a.output.tobytes() == b.output.tobytes()      # bit-identical
    # per-node fire counts (PE utilization) must agree node-for-node
    fa = {n.name: n.fires for n in plan_i.dfg.nodes}
    fb = {n.name: n.fires for n in plan_v.dfg.nodes}
    assert fa == fb
    if a.fabric is not None:
        assert a.fabric["token_hops"] == b.fabric["token_hops"]
        assert a.fabric["stall_cycles"] == b.fabric["stall_cycles"]


@pytest.mark.parametrize("routed", [False, True])
@pytest.mark.parametrize("n,r,w", [(120, 1, 3), (240, 2, 4), (510, 8, 6)])
def test_1d_identical(rng, n, r, w, routed):
    spec = StencilSpec((n,), (r,), (_coeffs(rng, r),), dtype="float64")
    assert_identical(run_both(lambda: map_1d(spec, workers=w),
                              rng.normal(size=n), routed=routed))


@pytest.mark.parametrize("routed", [False, True])
def test_2d_identical(rng, routed):
    spec = paper_stencil_2d(ny=30, nx=48, r=12)
    assert_identical(run_both(lambda: map_2d(spec, workers=8),
                              rng.normal(size=(30, 48)), routed=routed))


@pytest.mark.parametrize("routed", [False, True])
def test_3d_identical(rng, routed):
    spec = heat_3d(10, 12, 16, dtype="float64")
    assert_identical(run_both(lambda: map_3d(spec, workers=8),
                              rng.normal(size=(10, 12, 16)), routed=routed))


def test_temporal_identical(rng):
    spec = StencilSpec((360,), (2,), (_coeffs(rng, 2),), dtype="float64",
                       timesteps=3)
    assert_identical(run_both(lambda: map_1d(spec, workers=3),
                              rng.normal(size=360)))


def test_bounded_queues_identical(rng):
    """auto_capacity plans exercise the bounded-queue (out_free) path."""
    spec = heat_2d(18, 24, dtype="float64")
    assert_identical(run_both(
        lambda: map_2d(spec, workers=3, auto_capacity=True),
        rng.normal(size=(18, 24))))


def test_mem_efficiency_identical(rng):
    spec = StencilSpec((300,), (3,), (_coeffs(rng, 3),), dtype="float64")
    assert_identical(run_both(lambda: map_1d(spec, workers=5),
                              rng.normal(size=300), mem_efficiency=0.8))


@pytest.mark.parametrize("routed", [False, True])
@pytest.mark.parametrize("mk", [lambda: two_stage_heat(24, 32),
                                lambda: hdiff_program(24, 32)])
def test_program_identical(mk, routed):
    prog = mk()
    rng = np.random.default_rng(1)
    ins = {f: rng.normal(size=prog.grid_shape) for f in prog.in_fields}
    x = lower(prog, workers=4).pack_inputs(ins)
    assert_identical(run_both(lambda: lower(prog, workers=4), x,
                              routed=routed))


@pytest.mark.parametrize("routed", [False, True])
def test_program_remux_identical(routed):
    """Mismatched per-op worker counts insert the imux re-interleave."""
    prog = two_stage_heat(24, 32)
    rng = np.random.default_rng(1)
    ins = {f: rng.normal(size=prog.grid_shape) for f in prog.in_fields}
    workers = {"heat1": 2, "heat2": 4}
    x = lower(prog, workers=workers).pack_inputs(ins)
    assert_identical(run_both(lambda: lower(prog, workers=workers), x,
                              routed=routed))


def test_program_multi_output_identical():
    """Fan-out + two output fields: several cmp completion nodes."""
    lap = StencilOp("lap", heat_2d(20, 24, dtype="float64"), "inp", "lapf")
    mix = CombineOp("mix", ("inp", "lapf"), (1.0, -4.0), "mixf")
    prog = StencilProgram("twoout", [lap, mix], outputs=["lapf", "mixf"],
                          grid_shape=(20, 24), dtype="float64")
    rng = np.random.default_rng(2)
    ins = {f: rng.normal(size=prog.grid_shape) for f in prog.in_fields}
    x = lower(prog, workers=4).pack_inputs(ins)
    assert_identical(run_both(lambda: lower(prog, workers=4), x))


def test_wpc2_fabric_identical(rng):
    """words_per_cycle > 1 links exercise the general (word-counting)
    booking path instead of the wpc==1 fast path."""
    spec = paper_stencil_2d(ny=30, nx=48, r=12)
    x = rng.normal(size=(30, 48))
    out = []
    for engine in ENGINES:
        plan = map_2d(spec, workers=8)
        topo = FabricTopology.mesh(16, 16, words_per_cycle=2)
        fab = route(place(plan, topo, seed=0))
        out.append((plan, simulate(plan, x, CGRA, fabric=fab,
                                   engine=engine)))
    assert_identical(out)


def test_deadlock_identical(rng):
    """Starved queue capacities deadlock both engines at the same cycle
    with the same blocked-node diagnostic."""
    spec = heat_2d(18, 24, dtype="float64")
    x = rng.normal(size=(18, 24))
    msgs = []
    for engine in ENGINES:
        plan = map_2d(spec, workers=3, queue_capacity=1)
        with pytest.raises(SimDeadlock) as ei:
            simulate(plan, x, CGRA, max_cycles=200_000, engine=engine)
        msgs.append(str(ei.value))
    assert msgs[0] == msgs[1]
    assert "deadlock at cycle" in msgs[0]


def test_max_cycles_identical(rng):
    spec = StencilSpec((120,), (1,), ((0.25, 0.5, 0.25),), dtype="float64")
    x = rng.normal(size=120)
    for engine in ENGINES:
        plan = map_1d(spec, workers=3)
        with pytest.raises(SimDeadlock, match="exceeded max_cycles=10"):
            simulate(plan, x, CGRA, max_cycles=10, engine=engine)


def test_vector_faster_on_routed_program():
    """The point of the compiled engine: wall-clock on a routed program
    pipeline.  Deliberately loose (best-of-2, 1.2x) so a loaded CI host
    cannot flake it — BENCH_pr4.json tracks the real speedup, >=5x on the
    full-size pr3 cases."""
    import time
    prog = two_stage_heat(24, 32)
    rng = np.random.default_rng(1)
    ins = {f: rng.normal(size=prog.grid_shape) for f in prog.in_fields}
    x = lower(prog, workers=4).pack_inputs(ins)
    walls = {}
    for engine in ENGINES:
        best = float("inf")
        for _ in range(2):
            plan = lower(prog, workers=4)
            fab = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
            t0 = time.perf_counter()
            simulate(plan, x, CGRA, fabric=fab, engine=engine)
            best = min(best, time.perf_counter() - t0)
        walls[engine] = best
    assert walls["interp"] > 1.2 * walls["vector"], walls


# ---------------------------------------------------------------------------
# compile-then-mutate hazard (PR 5): recapacity after compile_plan() must
# invalidate the cached tables, never silently simulate with stale ones
# ---------------------------------------------------------------------------
def test_stale_compiled_plan_detected_and_recompiled(rng):
    from repro.core.engine import (StaleCompiledPlanError, compile_plan,
                                   compiled_for)
    from repro.core.mapping import apply_min_capacities

    spec = StencilSpec((96,), (2,), (_coeffs(rng, 2),), dtype="float64")
    plan = map_1d(spec, workers=3)
    cp = compiled_for(plan)
    assert cp.is_current()
    assert compiled_for(plan) is cp                   # cache hit, same tables

    apply_min_capacities(plan.dfg, plan.min_capacities)
    assert not cp.is_current()                        # version bump caught
    with pytest.raises(StaleCompiledPlanError):
        cp.require_current()
    cp2 = compiled_for(plan)                          # transparent recompile
    assert cp2 is not cp and cp2.is_current()

    # raw capacity writes without mark_mutated() are caught by the
    # capacity-signature check, not just the version counter
    cp3 = compile_plan(plan)
    next(plan.dfg.edges()).capacity = 9
    assert not cp3.is_current()


def test_interp_vector_parity_after_recapacity(rng):
    """Simulate unbounded with the vector engine (populating the compile
    cache), then apply the analytic minimum capacities to the *same* plan
    and re-simulate: the second run must see the bounded queues — identical
    to a fresh interp run of an identically-recapacitied plan."""
    from repro.core.mapping import apply_min_capacities

    spec = StencilSpec((140,), (2,), (_coeffs(rng, 2),), dtype="float64")
    x = rng.normal(size=140)

    def mk_bounded():
        p = map_1d(spec, workers=4)
        apply_min_capacities(p.dfg, p.min_capacities)
        return p

    plan = map_1d(spec, workers=4)
    unbounded_cycles = simulate(plan, x, CGRA, engine="vector").cycles
    apply_min_capacities(plan.dfg, plan.min_capacities)    # mutate in place
    res_mutated = simulate(plan, x, CGRA, engine="vector")

    res_interp = simulate(mk_bounded(), x, CGRA, engine="interp")
    res_vector = simulate(mk_bounded(), x, CGRA, engine="vector")
    assert res_mutated.cycles == res_interp.cycles == res_vector.cycles
    # (max_queue_total deliberately accumulates across runs of one plan
    # object, so only the fresh-plan runs are compared on it)
    assert res_interp.max_queue_total == res_vector.max_queue_total
    assert res_mutated.output.tobytes() == res_interp.output.tobytes()
    # the recapacity actually changed the timing (the hazard was observable)
    assert res_mutated.cycles != unbounded_cycles
