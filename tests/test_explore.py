"""The mapping auto-tuner (repro.explore): lattice, pruning, Pareto, search.

Covers the subsystem contract end to end: canonical config hashing (the
cache key), roofline/feasibility pruning with recorded reasons, budgeted
vector-engine evaluation with failure capture (deadlocks, fabric overflow),
Pareto-front soundness, the always-measured analytical baseline, and the
persistent eval cache that makes ci.sh reruns free.
"""
import json

import pytest

from repro.core import CGRA, Machine
from repro.core.spec import StencilSpec, heat_2d, star_3d
from repro.explore import (Budget, EvalCache, EvalPoint, MappingConfig,
                           SpaceOptions, SpecTarget, analytic_config,
                           assert_non_dominated, best_point, dominates,
                           enumerate_space, explore, pareto_front,
                           prune_reason, prune_space, tile_candidates)


def small_1d(n=60, r=1):
    coeffs = tuple([1.0 / (2 * r + 1)] * (2 * r + 1))
    return StencilSpec((n,), (r,), (coeffs,), dtype="float64")


# ---------------------------------------------------------------------------
# pareto.py
# ---------------------------------------------------------------------------
def test_dominates_semantics():
    assert dominates((1, 1, 1), (2, 2, 2))
    assert dominates((1, 2, 3), (1, 2, 4))
    assert not dominates((1, 2, 3), (1, 2, 3))      # equal: no domination
    assert not dominates((1, 5), (2, 4))            # trade-off: incomparable
    with pytest.raises(ValueError):
        dominates((1, 2), (1, 2, 3))


def test_pareto_front_and_best():
    pts = [(10, 5, 0), (8, 9, 0), (10, 5, 0), (12, 4, 0), (11, 9, 9)]
    front = pareto_front(pts)
    assert front == [(10, 5, 0), (8, 9, 0), (10, 5, 0), (12, 4, 0)]
    assert_non_dominated(front)
    assert best_point(front) == (8, 9, 0)           # lexicographic: cycles
    with pytest.raises(AssertionError):
        assert_non_dominated(pts)                   # (11,9,9) is dominated
    assert pareto_front([]) == []
    with pytest.raises(ValueError):
        best_point([])


# ---------------------------------------------------------------------------
# space.py
# ---------------------------------------------------------------------------
def test_config_canonical_key_scopes():
    scope = {"target": "t", "machine": "m"}
    a = MappingConfig(workers=4, fabric=(16, 16, "mesh"), place_seed=1)
    b = MappingConfig(workers=4, fabric=(8, 8, "torus"), place_seed=2)
    c = MappingConfig(workers=5)
    # ideal keys ignore physical knobs -> routed variants share one ideal eval
    assert a.key(scope, ideal=True) == b.key(scope, ideal=True)
    assert a.key(scope) != b.key(scope)
    assert a.key(scope, ideal=True) != c.key(scope, ideal=True)
    assert a.key(scope) != a.key({"target": "other", "machine": "m"})
    with pytest.raises(ValueError):
        MappingConfig(workers=2, capacity="bogus")
    with pytest.raises(ValueError):
        MappingConfig(workers=2, capacity=0)


def test_enumerate_space_seeds_analytic():
    target = SpecTarget(heat_2d(12, 24, dtype="float64"))
    configs, analytic = enumerate_space(
        target, CGRA, SpaceOptions(workers=(1, 2)))
    assert analytic in configs                      # seeded even if missing
    assert analytic.workers not in (1, 2) or configs[0].workers in (1, 2)
    # analytical choice is feasible: divides the innermost extent
    assert 24 % analytic.workers == 0


def test_analytic_config_clamps_to_divisor():
    # inner extent 26: the roofline pick (4 for this spec) doesn't divide it,
    # so the seed clamps down to the largest feasible worker count
    spec = heat_2d(12, 26, dtype="float64")
    cfg = analytic_config(SpecTarget(spec), CGRA)
    assert 26 % cfg.workers == 0 and cfg.workers >= 1


def test_tile_candidates_ladder():
    spec = heat_2d(64, 128, dtype="float64")
    tiles = tile_candidates(spec, (1, 4096, 16384, 1 << 30))
    assert len(tiles) == len(set(tiles))            # distinct
    for t in tiles:
        if t is not None:
            assert len(t) == 2 and all(b >= 1 for b in t)
    assert None in tiles                            # 1<<30 holds the grid


# ---------------------------------------------------------------------------
# prune.py
# ---------------------------------------------------------------------------
def test_prune_reasons():
    target = SpecTarget(heat_2d(12, 24, dtype="float64"),
                        workload_timesteps=2)
    opts = SpaceOptions()
    assert prune_reason(target, CGRA,
                        MappingConfig(workers=5), opts) == "indivisible"
    assert prune_reason(target, CGRA, MappingConfig(workers=24),
                        opts) == "no-interior"
    assert prune_reason(target, CGRA, MappingConfig(workers=2, temporal=3),
                        opts) == "temporal"
    assert prune_reason(target, CGRA,
                        MappingConfig(workers=2, tile=(2, 24)),
                        opts) == "tile-degenerate"   # 2 - 2*1*1 < 1
    small = Machine("m", clock_ghz=1.0, num_macs=8, bw_gbps=100.0,
                    peak_gflops=16.0)
    assert prune_reason(target, small, MappingConfig(workers=4),
                        opts) == "mac-overflow"
    ok = MappingConfig(workers=4)
    assert prune_reason(target, CGRA, ok, opts) is None


def test_prune_roofline_excess_exempts_analytic():
    target = SpecTarget(small_1d(200, 2))
    opts = SpaceOptions(worker_slack=0)
    analytic = analytic_config(target, CGRA)
    big = MappingConfig(workers=analytic.workers + 1)
    kept, log = prune_space(target, CGRA, [analytic, big], opts,
                            keep=analytic)
    assert analytic in kept
    assert ("roofline-excess" in log.reasons) == (big not in kept)
    assert log.as_dict() == log.reasons


# ---------------------------------------------------------------------------
# cache.py
# ---------------------------------------------------------------------------
def test_eval_cache_roundtrip(tmp_path):
    p = tmp_path / "cache.json"
    c = EvalCache(p)
    assert c.get("k") is None and c.misses == 1
    c.put("k", {"cycles": 7})
    c.save()
    c2 = EvalCache(p)
    assert c2.get("k") == {"cycles": 7} and c2.hits == 1
    # corrupted file degrades to an empty cache, never raises
    p.write_text("{not json")
    assert len(EvalCache(p)) == 0
    # schema mismatch likewise
    p.write_text(json.dumps({"schema": "other/v9", "entries": {"k": {}}}))
    assert len(EvalCache(p)) == 0


# ---------------------------------------------------------------------------
# search.py — ideal mode
# ---------------------------------------------------------------------------
def test_explore_ideal_end_to_end():
    res = explore(small_1d(), CGRA,
                  options=SpaceOptions(workers=(1, 2, 3, 4)), verify=True)
    # 4 requested + the always-seeded analytical config (w*=6 here)
    assert res.stats["n_measured"] == len(res.points) == 5
    assert_non_dominated(res.front, key=EvalPoint.objectives)
    assert res.analytic is not None
    assert res.best().cycles <= res.analytic.cycles
    assert res.best().cycles == min(p.cycles for p in res.points)
    # more workers strictly reduces cycles on this memory-light case
    by_w = {p.config.workers: p.cycles for p in res.points}
    assert by_w[4] < by_w[1]
    # every point carries the instruction count as its PE objective
    assert all(p.pes > 0 and p.max_channel_load == 0 for p in res.points)


def test_explore_verifies_numerics_against_oracle():
    """verify=True cross-checks each measured output against the reference
    oracle — exercised here both for plain and temporal configs."""
    res = explore(small_1d(80), CGRA, workload_timesteps=2,
                  options=SpaceOptions(workers=(2, 3), temporal=(1, 2)),
                  verify=True)
    temporals = {p.config.temporal for p in res.points}
    assert temporals == {1, 2}
    # a fused pass covers two sweeps: workload cycles halve-ish vs repeats
    one = min(p.cycles for p in res.points if p.config.temporal == 1)
    two = min(p.cycles for p in res.points if p.config.temporal == 2)
    assert two < one


def test_explore_budget_stops_after_analytic():
    res = explore(small_1d(), CGRA,
                  options=SpaceOptions(workers=(1, 2, 3, 4)),
                  budget=Budget(max_evals=1))
    assert res.stats["n_measured"] == 1
    assert res.stats["n_budget_skipped"] >= 3
    assert res.analytic is not None            # the baseline spends first
    assert res.front == [res.analytic]


def test_explore_cache_makes_rerun_free(tmp_path):
    p = tmp_path / "evals.json"
    kw = dict(options=SpaceOptions(workers=(1, 2, 3)))
    first = explore(small_1d(), CGRA, cache=EvalCache(p), **kw)
    n0 = first.stats["n_measured"]
    assert n0 == len(first.points) > 0
    again = explore(small_1d(), CGRA, cache=EvalCache(p), **kw)
    assert again.stats["n_measured"] == 0
    assert again.stats["n_cached"] == n0
    assert [p2.objectives() for p2 in again.points] == \
        [p1.objectives() for p1 in first.points]
    # a different machine must not hit the same entries
    other = explore(small_1d(), Machine("m2", 1.0, 128, 50.0, 256.0),
                    cache=EvalCache(p), **kw)
    assert other.stats["n_measured"] > 0


def test_explore_records_deadlock_as_failure():
    """A fixed queue capacity below the mandatory-buffering bound is doomed;
    the static gate (default on) rejects it before any simulation — with a
    repair hint — and the failure is cached; with the gate off the engine
    discovers the same deadlock dynamically."""
    spec = heat_2d(10, 20, dtype="float64")    # 2D: outer-axis gate >> 1
    cache = EvalCache()
    opts = SpaceOptions(workers=(2,), capacities=(1, "auto"))
    res = explore(spec, CGRA, options=opts, cache=cache)
    reasons = [f["reason"] for f in res.failures]
    assert any(r.startswith("static-capacity") for r in reasons), reasons
    assert res.stats["static_pruned"] > 0
    assert res.front                          # the auto config still wins
    assert all(p.config.capacity == "auto" for p in res.front)
    # the failure is cached: a rerun skips the doomed config entirely,
    # replaying the capacity-repair hint from the cache record
    res2 = explore(spec, CGRA, options=opts, cache=cache)
    cached = [f for f in res2.failures if f.get("cached")]
    assert cached and all(f["suggested_capacities"] for f in cached)
    # gate off: the engine pays for the same discovery dynamically
    res3 = explore(spec, CGRA, options=opts, cache=EvalCache(),
                   static_verify=False)
    reasons3 = [f["reason"] for f in res3.failures]
    assert any(r.startswith("deadlock") for r in reasons3), reasons3
    assert res3.stats["static_pruned"] == 0
    # and the gate never changes the search outcome
    assert sorted(p.objectives() for p in res3.points) == \
        sorted(p.objectives() for p in res.points)


def test_static_gate_hint_replays_onto_rebuilt_plan():
    """eids are deterministic per config: the JSON-string hint a cached
    failure replays applies cleanly to a freshly rebuilt plan and makes it
    complete."""
    from repro.analysis import apply_suggested_capacities
    from repro.core import map_2d, simulate

    spec = heat_2d(10, 20, dtype="float64")
    res = explore(spec, CGRA,
                  options=SpaceOptions(workers=(2,), capacities=(1, "auto")),
                  cache=EvalCache())
    fail = next(f for f in res.failures
                if f["reason"].startswith("static-capacity"))
    hint = fail["suggested_capacities"]
    assert all(isinstance(k, str) for k in hint)   # JSON-stable form
    plan = map_2d(spec, workers=2, queue_capacity=1)
    assert apply_suggested_capacities(plan, hint) > 0
    import numpy as np
    x = np.random.default_rng(0).normal(size=spec.grid_shape)
    simulate(plan, x, CGRA, max_cycles=2_000_000)  # deadlock would raise


def test_static_paranoia_mode():
    """static_paranoia simulates every statically-rejected config and
    asserts it really deadlocks — it must pass on a true deadlock and the
    results must match the non-paranoid run."""
    spec = heat_2d(10, 20, dtype="float64")
    opts = SpaceOptions(workers=(2,), capacities=(1, "auto"))
    res = explore(spec, CGRA, options=opts, cache=EvalCache(),
                  static_paranoia=True)
    assert res.stats["static_pruned"] > 0
    base = explore(spec, CGRA, options=opts, cache=EvalCache())
    assert sorted(p.objectives() for p in res.points) == \
        sorted(p.objectives() for p in base.points)


def test_static_gate_batched_stage1():
    """The batched jax stage 1 applies the same static gate at lane-build
    time: same pruned reasons, same survivors as the sequential path."""
    spec = heat_2d(10, 20, dtype="float64")
    opts = SpaceOptions(workers=(2,), capacities=(1, "auto"))
    seq = explore(spec, CGRA, options=opts, cache=EvalCache())
    bat = explore(spec, CGRA, options=opts, cache=EvalCache(),
                  budget=Budget(batch_size=4))
    assert bat.stats["static_pruned"] == seq.stats["static_pruned"] > 0
    assert sorted(p.sim_cycles for p in bat.ideal_points) == \
        sorted(p.sim_cycles for p in seq.ideal_points)


def test_static_semantics_scopes_cache(tmp_path):
    """Entries taken under the static gate must not replay for a run with
    the gate off (and vice versa): static_semantics is part of the scope,
    exactly like a verifier version bump would be."""
    p = str(tmp_path / "cache.json")
    spec = heat_2d(10, 20, dtype="float64")
    opts = SpaceOptions(workers=(2,), capacities=(1, "auto"))
    first = explore(spec, CGRA, options=opts, cache=EvalCache(p))
    assert first.stats["n_measured"] > 0
    # same gate: full replay
    again = explore(spec, CGRA, options=opts, cache=EvalCache(p))
    assert again.stats["n_measured"] == 0
    # gate off = different verifier semantics: nothing replays
    off = explore(spec, CGRA, options=opts, cache=EvalCache(p),
                  static_verify=False)
    assert off.stats["n_measured"] > 0


# ---------------------------------------------------------------------------
# search.py — routed mode
# ---------------------------------------------------------------------------
def test_explore_routed_finalists():
    res = explore(heat_2d(12, 24, dtype="float64"), CGRA,
                  options=SpaceOptions(workers=(2, 4),
                                       fabrics=((12, 12, "mesh"),),
                                       place_seeds=(0, 1)),
                  budget=Budget(routed_finalists=2))
    assert res.points and all(p.routed for p in res.points)
    assert all(p.max_channel_load > 0 for p in res.points)
    assert_non_dominated(res.front, key=EvalPoint.objectives)
    assert res.analytic is not None and res.analytic.routed
    assert res.best().cycles <= res.analytic.cycles
    # routed PEs-used is a physical count, below the instruction total
    ideal_pes = {p.config.workers: p.pes for p in res.ideal_points}
    for p in res.points:
        assert p.pes <= ideal_pes[p.config.workers]
    # the ideal stage still ran (and is reported) for every kept config
    # (the analytical w=4 coincides with a requested worker count)
    assert len(res.ideal_points) == 2


def test_explore_fabric_overflow_recorded():
    """A fabric too small for the plan must surface as a recorded failure,
    not a crash — and leave the front empty when nothing fits."""
    res = explore(small_1d(40), CGRA,
                  options=SpaceOptions(workers=(3,),
                                       fabrics=((2, 2, "mesh"),)))
    assert res.points == [] and res.front == []
    assert any("fabric-slots" in f["reason"] for f in res.failures)


# ---------------------------------------------------------------------------
# program targets
# ---------------------------------------------------------------------------
def test_explore_program_target():
    from repro.program import two_stage_heat

    prog = two_stage_heat(12, 24)
    res = explore(prog, CGRA, options=SpaceOptions(workers=(2, 4)),
                  verify=True)
    assert res.target == prog.name
    assert len(res.points) == 2
    assert_non_dominated(res.front, key=EvalPoint.objectives)
    assert res.best().cycles <= res.analytic.cycles
    # temporal/tile knobs are inert for programs: enumerating them anyway
    # must not change the lattice
    res2 = explore(prog, CGRA,
                   options=SpaceOptions(workers=(2, 4), temporal=(1, 2),
                                        tiles=(None, (4, 8))))
    assert res2.stats["n_kept"] == res.stats["n_kept"]


def test_explore_star3d_smoke():
    res = explore(star_3d(8, 10, 12, r=1), CGRA,
                  options=SpaceOptions(workers=(1, 2, 4)))
    assert len(res.points) == 4      # + the analytical seed (w*=3 here)
    assert res.best().cycles <= res.analytic.cycles


def test_explore_timeout_not_poisoned_across_budgets(tmp_path):
    """A max_cycles timeout under a tiny per-sim guard must not be replayed
    from cache as a permanent failure once the guard is raised — the guard
    is part of the cache scope (code-review regression)."""
    cache_path = tmp_path / "evals.json"
    spec = small_1d(120)
    opts = SpaceOptions(workers=(2,))
    starved = explore(spec, CGRA, options=opts,
                      budget=Budget(sim_max_cycles=5),
                      cache=EvalCache(cache_path))
    reasons = [f["reason"] for f in starved.failures]
    assert starved.points == [] and any(
        r.startswith("timeout") for r in reasons), reasons
    # the starved timeouts consumed budget: they are not free retries
    assert starved.stats["sim_cycles_total"] > 0
    # same cache, sane guard: the config is re-measured, not replayed failed
    healthy = explore(spec, CGRA, options=opts,
                      cache=EvalCache(cache_path))
    assert healthy.points and not healthy.failures
    assert healthy.stats["n_measured"] == len(healthy.points)
