"""Physical fabric subsystem: deterministic placement, capability/slot
legality, legal XY mesh routes, channel-overflow failure, and network-aware
simulation that reproduces the reference numerics exactly."""
import numpy as np
import pytest

from repro.core import CGRA, map_1d, map_2d, simulate
from repro.core.reference import stencil_reference_np
from repro.core.spec import (StencilSpec, heat_2d, paper_stencil_1d,
                             paper_stencil_2d)
from repro.fabric import (FabricTopology, PlacementError, RouteError,
                          op_class, place, placed_assembly, placed_dot,
                          route, xy_route)


def _spec1d(rng, n=240, r=2):
    c = tuple((rng.normal(size=2 * r + 1) / (2 * r + 1)).tolist())
    return StencilSpec((n,), (r,), (c,), dtype="float64")


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------
def test_placement_deterministic(rng):
    plan = map_1d(_spec1d(rng), workers=4)
    topo = FabricTopology.mesh(8, 8)
    a = place(plan, topo, seed=3)
    b = place(plan, topo, seed=3)
    assert a.coords == b.coords
    assert a.weighted_hops() == b.weighted_hops()


def test_placement_capability_and_slots(rng):
    plan = map_2d(heat_2d(18, 24, dtype="float64"), workers=3)
    topo = FabricTopology.mesh(8, 8)
    pl = place(plan, topo, seed=0)
    occ = {}
    for n in plan.dfg.nodes:
        c = pl.coords[n.nid]
        assert topo.capable(c, n.op), (n.name, n.op, c)
        occ[c] = occ.get(c, 0) + 1
    for c, k in occ.items():
        assert k <= topo.pes[c].slots
    # memory ops live where the memory ports are: the fabric boundary
    for n in plan.dfg.nodes:
        if op_class(n.op) == "mem":
            r, c = pl.coords[n.nid]
            assert r in (0, topo.rows - 1) or c in (0, topo.cols - 1)


def test_placement_annealing_improves_seed(rng):
    plan = map_1d(_spec1d(rng), workers=4)
    topo = FabricTopology.mesh(8, 8)
    seeded = place(plan, topo, seed=0, anneal_iters=0)
    annealed = place(plan, topo, seed=0)
    assert annealed.weighted_hops() <= seeded.weighted_hops()


def test_placement_overflow_raises(rng):
    plan = map_1d(_spec1d(rng), workers=4)
    with pytest.raises(PlacementError):
        place(plan, FabricTopology.mesh(2, 2, slots=1), seed=0)


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------
def test_routes_are_legal_mesh_paths(rng):
    plan = map_1d(_spec1d(rng), workers=4)
    topo = FabricTopology.mesh(8, 8)
    pl = place(plan, topo, seed=0)
    rf = route(pl)
    for e in plan.dfg.edges():
        links = rf.route_for(e)
        src, dst = pl.coords[e.src.nid], pl.coords[e.dst.nid]
        assert len(links) == topo.distance(src, dst)   # XY routes are minimal
        cur = src
        for lk in links:
            assert lk in topo.links                    # every hop is a wire
            assert lk[0] == cur
            cur = lk[1]
        assert cur == dst


def test_torus_wraps_shorter():
    topo = FabricTopology.torus_grid(8, 8)
    assert topo.distance((0, 0), (0, 7)) == 1
    assert len(xy_route(topo, (0, 0), (0, 7))) == 1
    mesh = FabricTopology.mesh(8, 8)
    assert mesh.distance((0, 0), (0, 7)) == 7


def test_route_channel_overflow_fails_loudly(rng):
    plan = map_2d(heat_2d(18, 24, dtype="float64"), workers=3)
    topo = FabricTopology.mesh(8, 8, channels=1)
    pl = place(plan, topo, seed=0)
    with pytest.raises(RouteError):
        route(pl)
    rf = route(pl, strict=False)                      # inspectable overload
    assert rf.stats()["max_channel_load"] > 1


# ---------------------------------------------------------------------------
# network-aware simulation
# ---------------------------------------------------------------------------
def test_network_sim_1d_exact_and_no_faster(rng):
    spec = _spec1d(rng)
    x = rng.normal(size=spec.grid_shape[0])
    ideal = simulate(map_1d(spec, workers=4), x, CGRA)
    plan = map_1d(spec, workers=4)
    rf = route(place(plan, FabricTopology.mesh(8, 8), seed=0))
    routed = simulate(plan, x, CGRA, fabric=rf)
    assert np.array_equal(ideal.output, routed.output)  # bit-identical
    assert np.allclose(routed.output, stencil_reference_np(x, spec))
    assert routed.cycles >= ideal.cycles
    assert routed.fabric is not None
    for key in ("hops_mean", "max_channel_load", "pe_utilization",
                "token_hops", "stall_cycles", "hotspots"):
        assert key in routed.fabric
    assert "fabric:" in routed.summary()


def test_network_sim_2d_exact_and_no_faster(rng):
    spec = heat_2d(18, 24, dtype="float64")
    x = rng.normal(size=(18, 24))
    ideal = simulate(map_2d(spec, workers=3), x, CGRA)
    plan = map_2d(spec, workers=3)
    rf = route(place(plan, FabricTopology.mesh(8, 8), seed=1))
    routed = simulate(plan, x, CGRA, fabric=rf)
    assert np.array_equal(ideal.output, routed.output)
    assert np.allclose(routed.output, stencil_reference_np(x, spec))
    assert routed.cycles >= ideal.cycles
    assert routed.fabric["token_hops"] > 0


def test_tighter_bandwidth_is_slower(rng):
    """Halving every link's words/cycle can only add contention stalls."""
    spec = _spec1d(rng, n=120, r=1)
    x = rng.normal(size=120)
    runs = {}
    for wpc in (4, 1):
        plan = map_1d(spec, workers=3)
        topo = FabricTopology.mesh(6, 6, words_per_cycle=wpc)
        rf = route(place(plan, topo, seed=0))
        runs[wpc] = simulate(plan, x, CGRA, fabric=rf)
    assert np.array_equal(runs[4].output, runs[1].output)
    assert runs[1].cycles >= runs[4].cycles
    assert runs[1].fabric["stall_cycles"] >= runs[4].fabric["stall_cycles"]


# ---------------------------------------------------------------------------
# the paper's mappings on the paper's 16x16 fabric
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mk", [
    lambda: map_1d(paper_stencil_1d(n=4800, rx=8), workers=8),
    lambda: map_2d(paper_stencil_2d(ny=32, nx=64, r=12), workers=8),
])
def test_paper_mappings_place_and_route_16x16(mk):
    plan = mk()
    topo = FabricTopology.mesh(16, 16)
    pl = place(plan, topo, seed=0)
    rf = route(pl)                                    # strict: must fit
    s = rf.stats()
    assert s["max_channel_load"] <= 32
    assert 0 < s["pe_utilization"] <= 1
    assert s["hops_mean"] > 0


# ---------------------------------------------------------------------------
# configuration export
# ---------------------------------------------------------------------------
def test_config_exports_carry_coordinates(rng):
    plan = map_1d(_spec1d(rng, n=60, r=1), workers=2)
    rf = route(place(plan, FabricTopology.mesh(6, 6), seed=0))
    asm = placed_assembly(rf)
    assert "PE(" in asm and "route=[" in asm and "hops=" in asm
    dot = placed_dot(rf)
    assert "pos=" in dot and "digraph" in dot


def test_route_directions_on_two_wide_mesh():
    """On a 2-wide/2-tall *mesh* the torus wrap-delta (|d| == n-1 == 1)
    collides with the opposite direction; W/N hops must not read as E/S."""
    from repro.fabric.config import _direction
    mesh = FabricTopology.mesh(4, 2)
    assert _direction(((0, 1), (0, 0)), mesh) == "W"
    assert _direction(((0, 0), (0, 1)), mesh) == "E"
    tall = FabricTopology.mesh(2, 4)
    assert _direction(((1, 0), (0, 0)), tall) == "N"
    assert _direction(((0, 0), (1, 0)), tall) == "S"
    torus = FabricTopology.torus_grid(4, 4)
    assert _direction(((0, 0), (0, 3)), torus) == "W"   # wrap west
    assert _direction(((0, 3), (0, 0)), torus) == "E"   # wrap east


def test_place_restarts_never_worse_than_single_seed():
    """Restartable placement (PR 5): best-of-N seeds can only improve the
    weighted hop count over the N=1 run with the same base seed, and stays
    deterministic."""
    from repro.core import map_2d
    from repro.core.spec import heat_2d
    from repro.fabric import FabricTopology, place

    spec = heat_2d(10, 16, dtype="float64")
    topo = FabricTopology.mesh(10, 10)
    single = place(map_2d(spec, workers=4), topo, seed=0)
    multi = place(map_2d(spec, workers=4), topo, seed=0, restarts=3)
    assert multi.weighted_hops() <= single.weighted_hops()
    again = place(map_2d(spec, workers=4), topo, seed=0, restarts=3)
    assert again.coords == multi.coords and again.seed == multi.seed
    import pytest
    with pytest.raises(ValueError):
        place(map_2d(spec, workers=4), topo, restarts=0)
