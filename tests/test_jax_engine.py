"""Cross-validation + batching: jitted jax engine vs the vector engine.

The jax engine (``engine="jax"``) must be *bit-identical* to the vector
engine on every ideal-mode observable — cycle counts, per-node fires,
load/store/flop totals, queue-occupancy telemetry and output grids — on
single-op mappings of every rank, temporal layers, program pipelines
(including the imux re-interleave), bounded and unbounded queues, derated
memory bandwidth, and the failure paths (deadlock, max_cycles).  On top of
that, the *batched* entry point (``simulate_batch`` / ``Budget.batch_size``)
must pad mixed-shape configs to a common shape without changing any lane's
result, report per-lane failures as values (one deadlocking lane never
poisons its siblings), refuse what it can't express (fabric, telemetry),
and key its EvalCache entries under its own engine semantics so batched
results are never replayed as vector results or vice versa.
"""
import numpy as np
import pytest

from repro.core import CGRA, SimDeadlock, map_1d, map_2d, map_3d, simulate
from repro.core.simulator import simulate_batch
from repro.core.spec import (StencilSpec, heat_2d, heat_3d, paper_stencil_2d)
from repro.program import (CombineOp, StencilOp, StencilProgram,
                           hdiff_program, lower, two_stage_heat)

ENGINES = ("vector", "jax")


def _coeffs(rng, r):
    return tuple((rng.normal(size=2 * r + 1) / (2 * r + 1)).tolist())


def run_both(mk_plan, x, **kw):
    """Simulate a freshly-built plan once per engine (ideal mode only —
    the jax engine cannot route)."""
    return [(plan, simulate(plan, x, CGRA, engine=engine, **kw))
            for engine in ENGINES
            for plan in (mk_plan(),)]


def assert_identical(case):
    (plan_v, a), (plan_j, b) = case
    assert a.cycles == b.cycles
    assert a.fires == b.fires
    assert (a.loads, a.stores, a.flops) == (b.loads, b.stores, b.flops)
    assert a.max_queue_total == b.max_queue_total
    assert a.output.shape == b.output.shape
    assert a.output.tobytes() == b.output.tobytes()      # bit-identical
    fa = {n.name: n.fires for n in plan_v.dfg.nodes}
    fb = {n.name: n.fires for n in plan_j.dfg.nodes}
    assert fa == fb


@pytest.mark.parametrize("n,r,w", [(120, 1, 3), (240, 2, 4), (510, 8, 6)])
def test_1d_identical(rng, n, r, w):
    spec = StencilSpec((n,), (r,), (_coeffs(rng, r),), dtype="float64")
    assert_identical(run_both(lambda: map_1d(spec, workers=w),
                              rng.normal(size=n)))


def test_2d_identical(rng):
    spec = paper_stencil_2d(ny=30, nx=48, r=12)
    assert_identical(run_both(lambda: map_2d(spec, workers=8),
                              rng.normal(size=(30, 48))))


def test_3d_identical(rng):
    spec = heat_3d(10, 12, 16, dtype="float64")
    assert_identical(run_both(lambda: map_3d(spec, workers=8),
                              rng.normal(size=(10, 12, 16))))


def test_temporal_identical(rng):
    spec = StencilSpec((360,), (2,), (_coeffs(rng, 2),), dtype="float64",
                       timesteps=3)
    assert_identical(run_both(lambda: map_1d(spec, workers=3),
                              rng.normal(size=360)))


def test_bounded_queues_identical(rng):
    """auto_capacity plans exercise the bounded-queue (out_ok) path."""
    spec = heat_2d(18, 24, dtype="float64")
    assert_identical(run_both(
        lambda: map_2d(spec, workers=3, auto_capacity=True),
        rng.normal(size=(18, 24))))


def test_mem_efficiency_identical(rng):
    spec = StencilSpec((300,), (3,), (_coeffs(rng, 3),), dtype="float64")
    assert_identical(run_both(lambda: map_1d(spec, workers=5),
                              rng.normal(size=300), mem_efficiency=0.8))


@pytest.mark.parametrize("mk", [lambda: two_stage_heat(24, 32),
                                lambda: hdiff_program(24, 32)])
def test_program_identical(mk):
    prog = mk()
    rng = np.random.default_rng(1)
    ins = {f: rng.normal(size=prog.grid_shape) for f in prog.in_fields}
    x = lower(prog, workers=4).pack_inputs(ins)
    assert_identical(run_both(lambda: lower(prog, workers=4), x))


def test_program_remux_identical():
    """Mismatched per-op worker counts insert the imux re-interleave."""
    prog = two_stage_heat(24, 32)
    rng = np.random.default_rng(1)
    ins = {f: rng.normal(size=prog.grid_shape) for f in prog.in_fields}
    workers = {"heat1": 2, "heat2": 4}
    x = lower(prog, workers=workers).pack_inputs(ins)
    assert_identical(run_both(lambda: lower(prog, workers=workers), x))


def test_program_multi_output_identical():
    """Fan-out + two output fields: several cmp completion nodes."""
    lap = StencilOp("lap", heat_2d(20, 24, dtype="float64"), "inp", "lapf")
    mix = CombineOp("mix", ("inp", "lapf"), (1.0, -4.0), "mixf")
    prog = StencilProgram("twoout", [lap, mix], outputs=["lapf", "mixf"],
                          grid_shape=(20, 24), dtype="float64")
    rng = np.random.default_rng(2)
    ins = {f: rng.normal(size=prog.grid_shape) for f in prog.in_fields}
    x = lower(prog, workers=4).pack_inputs(ins)
    assert_identical(run_both(lambda: lower(prog, workers=4), x))


def test_deadlock_and_timeout_identical(rng):
    """Failure paths: message text, cycle count and flags must match the
    vector engine byte for byte."""
    spec = heat_2d(18, 24, dtype="float64")
    x = rng.normal(size=(18, 24))

    def deadlock(engine):
        with pytest.raises(SimDeadlock) as ei:
            simulate(map_2d(spec, workers=4, queue_capacity=1), x, CGRA,
                     engine=engine)
        return str(ei.value), ei.value.cycles, ei.value.timed_out

    assert deadlock("vector") == deadlock("jax")

    def timeout(engine):
        with pytest.raises(SimDeadlock) as ei:
            simulate(map_2d(spec, workers=4), x, CGRA, engine=engine,
                     max_cycles=50)
        return str(ei.value), ei.value.cycles, ei.value.timed_out

    msg, cycles, timed_out = timeout("jax")
    assert timeout("vector") == (msg, cycles, timed_out)
    assert "exceeded max_cycles=50" in msg and timed_out


def test_unsupported_paths_raise(rng):
    """The jax engine is ideal-mode only: fabric and telemetry raise."""
    from repro.fabric import FabricTopology, place, route
    from repro.telemetry import Telemetry
    spec = heat_2d(18, 24, dtype="float64")
    x = rng.normal(size=(18, 24))
    plan = map_2d(spec, workers=4)
    rf = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
    with pytest.raises(NotImplementedError):
        simulate(plan, x, CGRA, fabric=rf, engine="jax")
    with pytest.raises(NotImplementedError):
        simulate(map_2d(spec, workers=4), x, CGRA, engine="jax",
                 telemetry=Telemetry())


# ---------------------------------------------------------------------------
# padded-batch correctness (satellite)
# ---------------------------------------------------------------------------
def test_batch_mixed_sizes_matches_sequential(rng):
    """A vmap batch mixing node/edge counts (padded to a common shape) must
    produce per-config results identical to B independent vector runs —
    including a deadlocking config, whose lane reports the deadlock as a
    value without poisoning its siblings."""
    spec = heat_2d(18, 24, dtype="float64")
    x = rng.normal(size=(18, 24))

    def mk_items():
        return [(map_2d(spec, workers=2), x),
                (map_2d(spec, workers=4, queue_capacity=1), x),  # deadlocks
                (map_2d(spec, workers=8), x),
                (map_2d(spec, workers=3, auto_capacity=True), x)]

    got_j = simulate_batch(mk_items(), CGRA, engine="jax")
    got_v = simulate_batch(mk_items(), CGRA, engine="vector")
    assert len(got_j) == len(got_v) == 4
    for i, (a, b) in enumerate(zip(got_j, got_v)):
        if i == 1:
            assert isinstance(a, SimDeadlock)
            assert isinstance(b, SimDeadlock)
            assert str(a) == str(b) and a.cycles == b.cycles
            assert not a.timed_out
        else:
            assert a.cycles == b.cycles
            assert a.output.tobytes() == b.output.tobytes()


def test_batch_of_one_matches_single(rng):
    spec = heat_2d(18, 24, dtype="float64")
    x = rng.normal(size=(18, 24))
    (res,) = simulate_batch([(map_2d(spec, workers=4), x)], CGRA,
                            engine="jax")
    ref = simulate(map_2d(spec, workers=4), x, CGRA, engine="vector")
    assert res.cycles == ref.cycles
    assert res.output.tobytes() == ref.output.tobytes()


# ---------------------------------------------------------------------------
# explore integration: Budget.batch_size
# ---------------------------------------------------------------------------
def test_explore_batched_stage1_matches_sequential():
    from repro.explore import Budget, SpaceOptions, explore
    spec = heat_2d(18, 24, dtype="float64")
    opts = SpaceOptions(fabrics=())
    seq = explore(spec, CGRA, options=opts, budget=Budget(), verify=True)
    bat = explore(spec, CGRA, options=opts, budget=Budget(batch_size=8),
                  verify=True)
    key = lambda p: sorted(p.config.canonical().items(),      # noqa: E731
                           key=str)
    s = {str(key(p)): (p.cycles, p.pes) for p in seq.ideal_points}
    b = {str(key(p)): (p.cycles, p.pes) for p in bat.ideal_points}
    assert s == b and s
    assert seq.best().objectives() == bat.best().objectives()


def test_explore_batched_respects_max_evals():
    from repro.explore import Budget, SpaceOptions, explore
    spec = heat_2d(18, 24, dtype="float64")
    res = explore(spec, CGRA, options=SpaceOptions(fabrics=()),
                  budget=Budget(max_evals=3, batch_size=8))
    assert res.stats["n_measured"] <= 3
    assert res.stats["n_budget_skipped"] > 0


def test_explore_batched_routes_finalists_with_vector_engine():
    """Stage 2 (routed finalists) always uses the sequential engine; the
    batched stage 1 must not change what the tuner ultimately picks."""
    from repro.explore import Budget, SpaceOptions, explore
    spec = heat_2d(18, 24, dtype="float64")
    opts = SpaceOptions(fabrics=((16, 16, "mesh"),))
    bat = explore(spec, CGRA, options=opts, budget=Budget(batch_size=8))
    seq = explore(spec, CGRA, options=opts, budget=Budget())
    assert bat.points and all(p.routed for p in bat.points)
    assert bat.best().objectives() == seq.best().objectives()


# ---------------------------------------------------------------------------
# EvalCache engine scoping (satellite)
# ---------------------------------------------------------------------------
def test_cache_cross_engine_miss():
    """Batched-jax results are keyed under the jax engine + semantics
    version, so a sequential vector run on the same cache re-measures
    every config (cross-engine replay is a correctness bug: the scopes
    must never collide)."""
    from repro.explore import Budget, EvalCache, SpaceOptions, explore
    spec = heat_2d(18, 24, dtype="float64")
    opts = SpaceOptions(fabrics=())
    cache = EvalCache(None)
    bat = explore(spec, CGRA, options=opts, budget=Budget(batch_size=8),
                  cache=cache)
    n = bat.stats["n_measured"]
    assert n > 0
    entries_after_batch = len(cache)

    # same cache, same configs, batched again: all replayed, zero measured
    bat2 = explore(spec, CGRA, options=opts, budget=Budget(batch_size=8),
                   cache=cache)
    assert bat2.stats["n_measured"] == 0
    assert len(cache) == entries_after_batch

    # same cache, sequential vector: every config must MISS and re-measure
    seq = explore(spec, CGRA, options=opts, budget=Budget(), cache=cache)
    assert seq.stats["n_measured"] == n
    assert len(cache) == 2 * entries_after_batch
    # and the two engines' measurements agree, each under its own key
    key = lambda p: str(sorted(p.config.canonical().items(),  # noqa: E731
                               key=str))
    assert ({key(p): p.cycles for p in bat.ideal_points}
            == {key(p): p.cycles for p in seq.ideal_points})


def test_engine_semantics_registry():
    """ENGINE_SEMANTICS names every engine and mirrors the jax module."""
    from repro.core.engine import ENGINE_SEMANTICS
    from repro.core.engine import jax_engine
    from repro.core.simulator import ENGINES as ALL_ENGINES
    assert set(ENGINE_SEMANTICS) == set(ALL_ENGINES)
    assert ENGINE_SEMANTICS["jax"] == jax_engine.SEMANTICS
