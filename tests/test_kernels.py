"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs the pure-jnp
oracles (assignment deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv1d.ops import causal_conv1d
from repro.kernels.conv1d.ref import conv1d_ref
from repro.kernels.stencil1d.ops import plan_1d_blocks, stencil1d
from repro.kernels.stencil1d.ref import stencil1d_ref
from repro.kernels.stencil2d.ops import stencil2d
from repro.kernels.stencil2d.ref import stencil2d_ref
from repro.kernels.stencil3d.ops import stencil3d
from repro.kernels.stencil3d.ref import stencil3d_ref
from repro.kernels.swa.ops import sliding_window_attention
from repro.kernels.swa.ref import swa_ref, swa_ref_chunked

TOL = {"float32": 2e-5, "bfloat16": 3e-2}


def _mk(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), dtype)


# --------------------------------------------------------------------------
@pytest.mark.parametrize("b,n,r,t,variant,dtype", [
    (4, 256, 1, 1, "vpu", "float32"),
    (4, 256, 2, 1, "mxu", "float32"),
    (2, 384, 8, 1, "vpu", "float32"),
    (2, 384, 3, 2, "vpu", "float32"),
    (2, 384, 3, 2, "mxu", "float32"),
    (1, 200, 1, 3, "vpu", "float32"),
    (3, 1000, 5, 2, "vpu", "float32"),
    (2, 256, 2, 1, "vpu", "bfloat16"),
    (2, 256, 2, 2, "mxu", "bfloat16"),
])
def test_stencil1d_sweep(rng, b, n, r, t, variant, dtype):
    coeffs = tuple((rng.normal(size=2 * r + 1) / (2 * r + 1)).tolist())
    x = _mk(rng, (b, n), dtype)
    y = stencil1d(x, coeffs, timesteps=t, backend="pallas", variant=variant,
                  block=(min(b, 8), 128))
    yr = stencil1d_ref(x, coeffs, timesteps=t)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=TOL[dtype])


def test_stencil1d_block_planner():
    bb, bn = plan_1d_blocks(n=194400, batch=1, radius=8, timesteps=4)
    assert bn % 128 == 0 and bn >= 8 * 4
    ws = bb * (3 * bn + 2 * (bn + 2 * 32)) * 4
    assert ws <= 8 * 1024 * 1024


# --------------------------------------------------------------------------
@pytest.mark.parametrize("b,ny,nx,ry,rx,t,dtype", [
    (1, 64, 128, 1, 1, 1, "float32"),
    (2, 64, 128, 2, 3, 1, "float32"),
    (1, 48, 96, 1, 1, 2, "float32"),
    (1, 72, 160, 2, 2, 3, "float32"),
    (2, 40, 140, 3, 1, 1, "float32"),
    (1, 64, 128, 1, 1, 2, "bfloat16"),
])
def test_stencil2d_sweep(rng, b, ny, nx, ry, rx, t, dtype):
    cy = tuple((rng.normal(size=2 * ry + 1) / (2 * ry + 1)).tolist())
    cx = rng.normal(size=2 * rx + 1) / (2 * rx + 1)
    cx[rx] = 0.0
    x = _mk(rng, (b, ny, nx), dtype)
    y = stencil2d(x, cy, tuple(cx), timesteps=t, backend="pallas",
                  block=(8, 128))
    yr = stencil2d_ref(x, cy, tuple(cx), timesteps=t)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=TOL[dtype])


# --------------------------------------------------------------------------
@pytest.mark.parametrize("b,hq,hkv,s,d,w,blk,dtype", [
    (1, 4, 4, 256, 32, 64, 64, "float32"),
    (2, 8, 2, 256, 64, 128, 64, "float32"),
    (1, 2, 1, 300, 32, 100, 64, "float32"),      # padded S
    (1, 4, 4, 512, 32, 512, 128, "float32"),     # full-causal window
    (2, 6, 3, 128, 16, 1, 64, "float32"),        # self-only window
    (1, 4, 2, 256, 32, 96, 64, "bfloat16"),
])
def test_swa_sweep(rng, b, hq, hkv, s, d, w, blk, dtype):
    q = _mk(rng, (b, hq, s, d), dtype)
    k = _mk(rng, (b, hkv, s, d), dtype)
    v = _mk(rng, (b, hkv, s, d), dtype)
    y = sliding_window_attention(q, k, v, window=w, backend="pallas",
                                 block=blk)
    yr = swa_ref(q, k, v, window=w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=TOL[dtype])


@pytest.mark.parametrize("s,w", [(256, 64), (300, 100), (128, 128), (200, 48)])
def test_swa_chunked_equals_dense(rng, s, w):
    q = _mk(rng, (2, 4, s, 32), "float32")
    k = _mk(rng, (2, 2, s, 32), "float32")
    v = _mk(rng, (2, 2, s, 32), "float32")
    np.testing.assert_allclose(
        np.asarray(swa_ref_chunked(q, k, v, window=w)),
        np.asarray(swa_ref(q, k, v, window=w)), atol=2e-5)


# --------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,c,k,dtype", [
    (2, 128, 64, 4, "float32"),
    (1, 100, 48, 7, "float32"),
    (3, 256, 128, 2, "float32"),
    (1, 64, 16, 16, "float32"),
    (2, 128, 64, 4, "bfloat16"),
])
def test_conv1d_sweep(rng, b, s, c, k, dtype):
    x = _mk(rng, (b, s, c), dtype)
    w = _mk(rng, (k, c), dtype)
    bias = _mk(rng, (c,), dtype)
    y = causal_conv1d(x, w, bias, backend="pallas", block_s=64, block_c=32)
    yr = conv1d_ref(x, w, bias)
    # bf16: unit-normal taps x inputs -> |y| up to ~4; one bf16 quantum at
    # that magnitude is 0.03, and kernel/ref round at different points.
    atol = 8e-2 if dtype == "bfloat16" else TOL[dtype]
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=atol)


def test_kernels_grad_through_xla_path(rng):
    """The XLA paths are the ones used inside jitted training — they must be
    differentiable."""
    x = _mk(rng, (2, 64), "float32")
    g = jax.grad(lambda a: jnp.sum(stencil1d(a, (0.25, 0.5, 0.25),
                                             backend="xla") ** 2))(x)
    assert np.isfinite(np.asarray(g)).all()


# --------------------------------------------------------------------------
@pytest.mark.parametrize("b,nz,ny,nx,rz,ry,rx,t,dtype", [
    (1, 16, 16, 128, 1, 1, 1, 1, "float32"),
    (2, 16, 32, 128, 2, 1, 3, 1, "float32"),
    (1, 24, 16, 128, 1, 2, 1, 2, "float32"),
    (1, 16, 16, 128, 1, 1, 1, 1, "bfloat16"),
])
def test_stencil3d_sweep(rng, b, nz, ny, nx, rz, ry, rx, t, dtype):
    cz = tuple((rng.normal(size=2 * rz + 1) / (2 * rz + 1)).tolist())
    cy = rng.normal(size=2 * ry + 1) / (2 * ry + 1)
    cy[ry] = 0.0
    cx = rng.normal(size=2 * rx + 1) / (2 * rx + 1)
    cx[rx] = 0.0
    x = _mk(rng, (b, nz, ny, nx), dtype)
    y = stencil3d(x, cz, tuple(cy), tuple(cx), timesteps=t,
                  backend="pallas", block=(8, 16, 128))
    yr = stencil3d_ref(x, cz, tuple(cy), tuple(cx), timesteps=t)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=TOL[dtype])
