"""The dimension-generic mapper: rank-3 exactness + place-and-route, wrapper
parity with the pre-refactor hand-rolled builders, temporal layers at every
rank, and the spec arithmetic fixed in this PR."""
import dataclasses

import numpy as np
import pytest

from repro.core import (CGRA, SimDeadlock, map_1d, map_2d, map_3d, map_nd,
                        simulate)
from repro.core.mapping import StreamSpec, band_keep
from repro.core.reference import stencil_reference_np
from repro.core.spec import StencilSpec, heat_2d, heat_3d, star_3d
from repro.fabric import FabricTopology, place, route


def _coeffs(rng, r):
    return tuple((rng.normal(size=2 * r + 1) / (2 * r + 1)).tolist())


# ---------------------------------------------------------------------------
# rank-3: the mapping the pre-refactor code could not build at all
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec,w", [
    (heat_3d(8, 10, 12, dtype="float64"), 4),
    (star_3d(10, 12, 16, r=2), 4),
])
def test_3d_exact(rng, spec, w):
    plan = map_3d(spec, workers=w)
    x = rng.normal(size=spec.grid_shape)
    res = simulate(plan, x, CGRA)
    assert np.allclose(res.output, stencil_reference_np(x, spec))
    # every element is loaded at most once (readers partition the grid);
    # trailing elements no filter keeps may still be in flight at `done`.
    ngrid = int(np.prod(spec.grid_shape))
    assert ngrid - 2 * w * max(spec.radii) <= res.loads <= ngrid
    interior = int(np.prod(spec.interior_shape))
    assert res.stores == interior
    assert res.flops == interior * spec.flops_per_output


@pytest.mark.parametrize("mk", [
    lambda: map_3d(heat_3d(24, 24, 32, dtype="float64"), workers=8),
    lambda: map_3d(star_3d(20, 20, 32, r=2), workers=8),
])
def test_3d_places_and_routes_16x16(mk):
    plan = mk()
    topo = FabricTopology.mesh(16, 16)
    rf = route(place(plan, topo, seed=0))          # strict: must fit
    s = rf.stats()
    assert s["max_channel_load"] <= s["channel_capacity"]
    assert 0 < s["pe_utilization"] <= 1


def test_3d_routed_sim_bit_identical(rng):
    spec = heat_3d(8, 10, 16, dtype="float64")
    x = rng.normal(size=spec.grid_shape)
    ideal = simulate(map_3d(spec, workers=4), x, CGRA)
    plan = map_3d(spec, workers=4)
    rf = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
    routed = simulate(plan, x, CGRA, fabric=rf)
    assert np.array_equal(ideal.output, routed.output)
    assert routed.cycles >= ideal.cycles


# ---------------------------------------------------------------------------
# wrapper parity: identical PE inventory + sync expectations to the
# pre-refactor map_1d/map_2d builders (closed forms lifted from their code)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,r,w,t", [(120, 1, 3, 1), (240, 2, 4, 1),
                                     (360, 2, 3, 3), (510, 8, 6, 1)])
def test_map_1d_matches_prerefactor_structure(rng, n, r, w, t):
    spec = StencilSpec((n,), (r,), (_coeffs(rng, r),), dtype="float64",
                       timesteps=t)
    plan = map_1d(spec, workers=w)
    assert plan.pe_counts == {
        "addr": 2 * w, "load": w, "filter": t * w * (2 * r + 1),
        "mul": t * w, "mac": t * w * 2 * r, "store": w, "sync": w, "cmp": 1}
    assert plan.sync_expect == [len(range(t * r + c, n - t * r, w))
                                for c in range(w)]
    assert plan.reader_loads == [list(range(k, n, w)) for k in range(w)]
    assert plan.writer_stores == [list(range(t * r + c, n - t * r, w))
                                  for c in range(w)]


@pytest.mark.parametrize("ny,nx,ry,rx,w", [(16, 24, 1, 1, 3), (20, 30, 2, 2, 3),
                                           (24, 25, 3, 1, 5)])
def test_map_2d_matches_prerefactor_structure(rng, ny, nx, ry, rx, w):
    cy = _coeffs(rng, ry)
    cx = list(_coeffs(rng, rx))
    cx[rx] = 0.0
    spec = StencilSpec((ny, nx), (ry, rx), (cy, tuple(cx)), dtype="float64")
    plan = map_2d(spec, workers=w)
    assert plan.pe_counts == {
        "addr": 2 * w, "load": w, "filter": w * (2 * rx + 1 + 2 * ry),
        "mul": 2 * w, "mac": w * (2 * rx + 2 * ry - 1), "add": w,
        "store": w, "sync": w, "cmp": 1}
    assert plan.sync_expect == [
        (ny - 2 * ry) * len(range(rx + c, nx - rx, w)) for c in range(w)]
    # pre-refactor reader/writer index streams, verbatim
    assert plan.reader_loads == [
        [j * nx + i for j in range(ny) for i in range(k, nx, w)]
        for k in range(w)]
    assert plan.writer_stores == [
        [j0 * nx + i for j0 in range(ry, ny - ry)
         for i in range(rx + c, nx - rx, w)] for c in range(w)]


def test_map_2d_rejects_unowned_columns(rng):
    spec = heat_2d(12, 25, dtype="float64")
    with pytest.raises(ValueError, match="Strip-mine"):
        map_2d(spec, workers=4)


def test_map_nd_rejects_outputless_workers():
    with pytest.raises(ValueError, match="own no"):
        map_nd(heat_2d(12, 16, dtype="float64"), workers=16)


def test_unowned_columns_error_names_spec_and_suggests_workers():
    """The divisibility error names the offending spec and proposes the
    largest worker count that does divide the inner extent."""
    with pytest.raises(ValueError) as ei:
        map_nd(heat_2d(12, 24, dtype="float64"), workers=5)
    msg = str(ei.value)
    assert "rank-2 spec (grid_shape=(12, 24))" in msg
    assert "24 % 5 == 4" in msg
    assert "workers=4" in msg            # largest divisor of 24 that is <= 5
    assert "plan_blocks" in msg


def test_outputless_workers_error_names_spec_and_bound():
    """The too-many-workers error names the spec and states the usable
    maximum (interior sites along the innermost axis)."""
    with pytest.raises(ValueError) as ei:
        map_nd(heat_2d(12, 16, dtype="float64"), workers=16)
    msg = str(ei.value)
    assert "grid_shape=(12, 16)" in msg and "radii=(1, 1)" in msg
    assert "only 14 interior sites" in msg
    assert "workers <= 14" in msg


# ---------------------------------------------------------------------------
# temporal layers at rank >= 2 (new: pre-refactor map_2d ignored timesteps)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec,w", [
    (dataclasses.replace(heat_2d(20, 24, dtype="float64"), timesteps=2), 4),
    (dataclasses.replace(heat_3d(12, 12, 12, dtype="float64"), timesteps=2), 3),
])
def test_temporal_layers_nd_exact(rng, spec, w):
    plan = map_nd(spec, workers=w)
    x = rng.normal(size=spec.grid_shape)
    res = simulate(plan, x, CGRA)
    assert np.allclose(res.output, stencil_reference_np(x, spec))
    assert res.loads == int(np.prod(spec.grid_shape))   # I/O only at the ends
    # one compute-layer stack per fused step
    d = spec.ndim
    per_layer_mul = w * d        # one MUL per axis chain
    assert plan.pe_counts["mul"] == spec.timesteps * per_layer_mul


# ---------------------------------------------------------------------------
# mandatory buffering at rank 3: analytic capacities run, starvation deadlocks
# ---------------------------------------------------------------------------
def test_3d_mandatory_buffering(rng):
    spec = heat_3d(8, 10, 12, dtype="float64")
    plan = map_3d(spec, workers=4, auto_capacity=True)
    x = rng.normal(size=spec.grid_shape)
    res = simulate(plan, x, CGRA)
    assert np.allclose(res.output, stencil_reference_np(x, spec))

    starved = map_3d(spec, workers=4, queue_capacity=1)
    with pytest.raises(SimDeadlock):
        simulate(starved, x, CGRA, max_cycles=200_000)


# ---------------------------------------------------------------------------
# stream algebra unit checks
# ---------------------------------------------------------------------------
def test_streamspec_roundtrip():
    s = StreamSpec(((0, 5, 1), (2, 14, 3)))
    assert s.counts == (5, 4)
    assert len(s) == 20
    assert s.coord(0) == (0, 2)
    assert s.coord(5) == (1, 4 * 3 + 2 - 3 * 3)  # position 5 = row 1, digit 1
    flat = s.flat_indices((5, 14))
    assert len(flat) == 20 and flat[0] == 2 and flat[1] == 5


def test_band_keep_windows():
    s = StreamSpec(((0, 6, 1), (1, 13, 4)))      # 6 x 3 stream
    mask = band_keep(s, ((2, 5), (5, 13)))
    assert mask.kept == 3 * 2
    kept = [p for p in range(len(s)) if mask.keep(p)]
    assert len(kept) == mask.kept
    assert kept[0] == mask.lead
    for p in kept:
        q = s.coord(p)
        assert 2 <= q[0] < 5 and 5 <= q[1] < 13


# ---------------------------------------------------------------------------
# spec arithmetic regressions (satellites)
# ---------------------------------------------------------------------------
def test_total_flops_sums_shrinking_interiors():
    spec = StencilSpec((20,), (2,), ((0.1,) * 5,), dtype="float64",
                       timesteps=3)
    per_out = spec.flops_per_output
    assert spec.total_flops() == per_out * ((20 - 4) + (20 - 8) + (20 - 12))
    assert spec.total_flops(1) == per_out * 16          # explicit override
    with pytest.raises(ValueError):
        spec.total_flops(0)                             # old code returned 1x
    # consistency with the fused-AI accounting
    b = 8
    ai = spec.arithmetic_intensity_fused()
    assert abs(ai - spec.total_flops() / (2 * 20 * b)) < 1e-12


def test_bytes_per_elem_lookup():
    assert StencilSpec((8,), (1,), ((1, 1, 1),), dtype="float32").bytes_per_elem == 4
    assert StencilSpec((8,), (1,), ((1, 1, 1),), dtype="float64").bytes_per_elem == 8
    assert StencilSpec((8,), (1,), ((1, 1, 1),), dtype="bfloat16").bytes_per_elem == 2


def test_arithmetic_intensity_delegates_to_total_flops():
    """AI == total_flops / (one read + one write), pinned for the paper's
    benchmark stencils (§VI: 1D ~2.06, 2D ~5.59 flops/byte)."""
    from repro.core.spec import paper_stencil_1d, paper_stencil_2d
    s1 = paper_stencil_1d()                      # 194400, rx=8, f64
    assert s1.arithmetic_intensity() == s1.total_flops(1) / (2 * 194400 * 8)
    assert round(s1.arithmetic_intensity(), 2) == 2.06
    s2 = paper_stencil_2d()                      # 449x960, r=12, f64
    assert s2.arithmetic_intensity() == \
        s2.total_flops(1) / (2 * 449 * 960 * 8)
    assert round(s2.arithmetic_intensity(), 2) == 5.59
    # fused AI: same delegation, float32 path uses bytes_per_elem (4)
    s3 = StencilSpec((40,), (2,), ((0.2,) * 5,), dtype="float32",
                     timesteps=2)
    assert s3.arithmetic_intensity_fused() == \
        s3.total_flops() / (2 * 40 * 4)
