"""Per-arch smoke tests (assignment deliverable (f)): reduced same-family
configs — one forward + one train step on CPU, output shapes + no NaNs; and
decode==forward parity (cache correctness) across families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeSpec, get_reduced_config, list_archs
from repro.models import params as pr
from repro.models.registry import build_model, input_arrays
from repro.models.transformer import xent_loss
from repro.train.optim import OptConfig, init_opt_state
from repro.train.train_step import make_train_step

SMOKE = ShapeSpec("smoke", 32, 2, "train")


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_train_step(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    inp = input_arrays(cfg, SMOKE)

    if cfg.family == "audio":
        logits, aux = model.forward(params, inp["tokens"], inp["frames"])
    else:
        logits, aux = model.forward(params, inp["tokens"],
                                    positions=inp.get("positions"),
                                    patches=inp.get("patches"))
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), "NaN in logits"
    loss = xent_loss(logits, inp["tokens"])
    assert np.isfinite(float(loss))

    opt_cfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(model, cfg, opt_cfg, remat="dots"))
    p2, o2, m = step(params, opt, inp)
    assert np.isfinite(float(m["loss"]))
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved, "train step did not update params"


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "recurrentgemma-2b",
                                  "rwkv6-7b", "qwen3-32b", "qwen2.5-3b",
                                  "granite-moe-1b-a400m", "qwen2-vl-2b"])
def test_decode_matches_forward(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    S = 10
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, S)), jnp.int32)
    kw = {}
    if cfg.family == "vlm":
        # decode parity for the text path (no patches)
        pos = jnp.broadcast_to(jnp.arange(S), (3, 2, S)).astype(jnp.int32)
        kw = {"positions": pos}
    full, _ = model.forward(params, toks, **kw)
    cache = model.init_cache(2, S)
    errs = []
    for t in range(S):
        dkw = {}
        if cfg.family == "vlm":
            dkw = {"positions": jnp.full((3, 2, 1), t, jnp.int32)}
        lg, cache = model.decode(params, cache, toks[:, t:t + 1], **dkw)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 5e-4, f"decode diverges from forward: {max(errs)}"


def test_whisper_decode_matches_forward():
    cfg = get_reduced_config("whisper-tiny")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    S = 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, S)), jnp.int32)
    frames = jnp.asarray(rng.normal(size=(2, cfg.encoder_seq, cfg.d_model))
                         * 0.02, jnp.float32)
    full, _ = model.forward(params, toks, frames)
    enc = model.encode(params, frames)
    cache = model.init_cache(2, S)

    def xkv(bp):
        kk = jnp.einsum("btd,dhk->bthk", enc, bp["cross"]["wk"].astype(enc.dtype))
        vv = jnp.einsum("btd,dhk->bthk", enc, bp["cross"]["wv"].astype(enc.dtype))
        return kk, vv

    ks, vs = jax.vmap(xkv)(params["dec"])
    cache["cross_k"], cache["cross_v"] = ks, vs
    errs = []
    for t in range(S):
        lg, cache = model.decode(params, cache, toks[:, t:t + 1])
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 5e-4


def test_vlm_patch_merge():
    cfg = get_reduced_config("qwen2-vl-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    inp = input_arrays(cfg, SMOKE)
    logits, _ = model.forward(params, inp["tokens"], patches=inp["patches"],
                              positions=inp["positions"])
    # changing a patch changes prefix logits
    p2 = inp["patches"].at[:, 0, :].add(1.0)
    logits2, _ = model.forward(params, inp["tokens"], patches=p2,
                               positions=inp["positions"])
    assert not np.allclose(np.asarray(logits[:, 0]), np.asarray(logits2[:, 0]))


def test_param_spec_shapes_match_init():
    cfg = get_reduced_config("qwen3-32b")
    model = build_model(cfg)
    structs = pr.shape_tree(model.specs(), cfg.param_dtype)
    params = model.init(jax.random.PRNGKey(0))
    for s, p in zip(jax.tree.leaves(structs), jax.tree.leaves(params)):
        assert s.shape == p.shape and s.dtype == p.dtype
