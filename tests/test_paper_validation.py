"""Paper §VI / §VIII arithmetic reproduced exactly (EXPERIMENTS.md
§Paper-validation)."""
import pytest

from repro.core import CGRA, V100, analyze, crossover_timesteps
from repro.core.roofline import worker_demand_gflops
from repro.core.spec import paper_stencil_1d, paper_stencil_2d


def test_1d_arithmetic_intensity():
    s = paper_stencil_1d()
    # paper: (16*2+1)*(194400-16)/((194400+194400)*8) = 2.06
    assert abs(s.arithmetic_intensity() - 2.06) < 0.01


def test_2d_arithmetic_intensity():
    s = paper_stencil_2d()
    # paper: (48*2+1)*((449-24)*(960-24))/((2*960*449)*8) = 5.59
    assert abs(s.arithmetic_intensity() - 5.59) < 0.01


def test_cgra_compute_peak():
    assert abs(CGRA.peak_gflops - 614.4) < 1e-9      # 2*256*1.2


def test_1d_roofline_and_worker_selection():
    s = paper_stencil_1d()
    r = analyze(s, CGRA)
    assert abs(r.bw_bound_gflops - 206.2) < 0.5      # paper: 206
    assert r.workers == 6                            # paper: 6 workers
    assert abs(r.worker_demand_gflops - 237.6) < 0.1 # paper: 237.6
    assert r.bound == "memory"


def test_2d_roofline_and_worker_fit():
    s = paper_stencil_2d()
    r = analyze(s, CGRA)
    assert s.macs_per_worker == 49                   # 48 MAC + 1 MUL
    assert r.workers == 5                            # paper: 5 fit
    assert abs(worker_demand_gflops(s, CGRA, 5) - 582.0) < 0.1
    assert abs(r.achievable_gflops - 559.5) < 1.0    # paper: 559


def test_table1_speedup_ratios():
    """16 CGRA tiles vs V100, using the paper's own % -of-peak figures."""
    cgra16 = CGRA.scaled(16)
    s1, s2 = paper_stencil_1d(), paper_stencil_2d()
    # 1D: 91% of CGRA peak vs 90% of V100 peak -> 1.9x
    cgra_1d = analyze(s1, cgra16).achievable_gflops * 0.91
    v100_1d = analyze(s1, V100).achievable_gflops * 0.90
    assert abs(cgra_1d / v100_1d - 1.9) < 0.1
    # 2D: 78% vs 48% -> ~3.0x (paper: 3.03)
    cgra_2d = analyze(s2, cgra16).achievable_gflops * 0.78
    v100_2d = analyze(s2, V100).achievable_gflops * 0.48
    assert abs(cgra_2d / v100_2d - 3.03) < 0.15
    # and the paper's 2.3 TFLOPS on V100 for stencil2D
    assert abs(v100_2d / 1000 - 2.3) < 0.05


def test_v100_2d_roofline_peak():
    s2 = paper_stencil_2d()
    r = analyze(s2, V100)
    assert abs(r.achievable_gflops / 1000 - 4.8) < 0.1   # paper: 4.8 TFLOPS


def test_fusion_crossover_exists():
    s1 = paper_stencil_1d()
    t = crossover_timesteps(s1, CGRA, workers=6)
    assert t == 3      # AI 2.06 -> needs ~3 fused steps to hit 614 GFLOPS


# ---------------------------------------------------------------------------
# PR 5 regression: the physical-fit cap in select_workers is recorded, not
# silent — while the paper's pinned counts stay uncapped and warning-free.
# ---------------------------------------------------------------------------
def test_paper_worker_choices_are_uncapped():
    import warnings

    from repro.core.roofline import select_workers

    with warnings.catch_warnings():
        warnings.simplefilter("error")       # any RuntimeWarning -> failure
        assert select_workers(paper_stencil_1d(), CGRA) == 6
        assert select_workers(paper_stencil_2d(), CGRA) == 5
    r1 = analyze(paper_stencil_1d(), CGRA)
    r2 = analyze(paper_stencil_2d(), CGRA)
    assert not r1.capped and r1.workers_demanded == 6
    assert not r2.capped and r2.workers_demanded == 5


def test_select_workers_cap_warns_and_reports():
    """A machine too small for the bandwidth-limited demand must warn and
    expose both the cap and the uncapped demand on the report."""
    import dataclasses

    import pytest

    from repro.core.roofline import select_workers, workers_demanded

    tiny = dataclasses.replace(CGRA, name="cgra_tiny", num_macs=64)
    s = paper_stencil_2d()                    # 49 MACs/worker -> only 1 fits
    need = workers_demanded(s, tiny)
    assert need > 1
    with pytest.warns(RuntimeWarning, match="exceeds the 1 that physically"):
        w = select_workers(s, tiny)
    assert w == 1
    r = analyze(s, tiny)                      # analyze records, no warning
    assert r.capped and r.workers == 1 and r.workers_demanded == need
    # an explicitly-passed worker count is a choice, not a cap
    r_explicit = analyze(s, tiny, workers=1)
    assert not r_explicit.capped and r_explicit.workers_demanded == need
