"""Stencil program graphs: DAG validation, margin inference, splice
lowering (bit-exact vs the composed oracle), re-interleave fallback,
skew buffering, multi-output sync, 16x16 place-and-route, and the
fused-beats-separate-sweeps pipeline claim."""
import numpy as np
import pytest

from repro.core import CGRA, SimDeadlock, simulate
from repro.core.spec import StencilSpec, heat_2d
from repro.fabric import FabricTopology, place, route
from repro.program import (CombineOp, StencilOp, StencilProgram, field_leads,
                           hdiff_program, lower, program_reference,
                           program_reference_np, simulate_program,
                           two_stage_heat)


def _sim(prog, workers, x, **kw):
    plan = lower(prog, workers, **{k: v for k, v in kw.items()
                                   if k in ("queue_capacity",
                                            "auto_capacity")})
    skw = {k: v for k, v in kw.items()
           if k not in ("queue_capacity", "auto_capacity")}
    res, fields = simulate_program(plan, {prog.in_fields[0]: x}, CGRA, **skw)
    return plan, res, fields


# ---------------------------------------------------------------------------
# IR: validation, scheduling, margin inference
# ---------------------------------------------------------------------------
def test_ir_cycle_detection():
    spec = heat_2d(16, 24, dtype="float64")
    with pytest.raises(ValueError, match="cycle"):
        StencilProgram("cyc", [StencilOp("a", spec, "u", "v"),
                               StencilOp("b", spec, "v", "u")])


def test_ir_single_assignment():
    spec = heat_2d(16, 24, dtype="float64")
    with pytest.raises(ValueError, match="single-assignment"):
        StencilProgram("dup", [StencilOp("a", spec, "u", "v"),
                               StencilOp("b", spec, "u", "v")])


def test_ir_empty_valid_box():
    spec = heat_2d(8, 12, dtype="float64")
    with pytest.raises(ValueError, match="empty valid box"):
        StencilProgram("deep", [StencilOp(f"o{i}", spec, f"f{i}", f"f{i+1}")
                                for i in range(4)])


def test_ir_margins_and_outputs():
    prog = hdiff_program(20, 24)
    m = prog.margins()
    assert m["inp"] == (0, 0)
    assert m["lap"] == (1, 1)
    assert m["flx"] == (2, 2)
    assert m["out"] == (2, 2)        # combine: max of (0,0) and (2,2)
    assert prog.in_fields == ("inp",)
    assert prog.out_fields == ("out",)      # the only unconsumed field
    assert prog.field_interior("out") == (16, 20)
    names = [op.name for op in prog.schedule()]
    assert names.index("lap") < names.index("flx") < names.index("out")
    # the deep branch accumulates site-lead; the external input has none
    leads = field_leads(prog)
    assert leads["inp"] == 0 and leads["flx"] > leads["lap"] > 0


def test_ir_combine_only_needs_grid():
    with pytest.raises(ValueError, match="grid_shape"):
        StencilProgram("c", [CombineOp("add", ("a", "b"), (1.0, 1.0), "c")])
    prog = StencilProgram("c", [CombineOp("add", ("a", "b"), (1.0, 1.0),
                                          "c")],
                          grid_shape=(12, 16), dtype="float64")
    assert prog.grid_shape == (12, 16)


# ---------------------------------------------------------------------------
# lowering: bit-exact pipelines vs the composed oracle
# ---------------------------------------------------------------------------
def test_two_stage_heat_exact(rng):
    prog = two_stage_heat(18, 24)
    x = rng.normal(size=(18, 24))
    plan, res, fields = _sim(prog, 3, x)
    ref = program_reference_np(prog, {"u": x})
    np.testing.assert_allclose(fields["u2"], ref["u2"], atol=1e-9)
    # fused: the grid is read exactly once, no intermediate store/reload
    assert res.loads == 18 * 24
    assert res.stores == int(np.prod(prog.field_interior("u2")))
    assert plan.pe_counts["cmp"] == 1


def test_branching_combine_exact(rng):
    """laplacian + flux -> output: the hdiff fan-out/join, with the analytic
    skew buffers (auto_capacity) and with unbounded queues."""
    prog = hdiff_program(20, 24)
    x = rng.normal(size=(20, 24))
    ref = program_reference_np(prog, {"inp": x})
    for auto in (False, True):
        plan, res, fields = _sim(prog, 4, x, auto_capacity=auto,
                                 max_cycles=2_000_000)
        np.testing.assert_allclose(fields["out"], ref["out"], atol=1e-9)
        assert res.loads == 20 * 24          # fan-out still loads once


def test_skew_starved_combine_deadlocks(rng):
    """Below the computed inter-operator skew buffer the shared producer
    deadlocks behind the deep branch — the buffers are *mandatory*."""
    prog = hdiff_program(20, 24)
    x = rng.normal(size=(20, 24))
    plan = lower(prog, workers=4, queue_capacity=2)
    with pytest.raises(SimDeadlock):
        simulate(plan, plan.pack_inputs({"inp": x}), CGRA,
                 max_cycles=200_000)


def test_remux_worker_mismatch_exact(rng):
    """Producer workers != consumer workers: explicit re-interleave buffers
    (imux + strided filters), both directions, still bit-exact."""
    spec = heat_2d(16, 24, dtype="float64")
    prog = StencilProgram("mm", [StencilOp("a", spec, "u", "v"),
                                 StencilOp("b", spec, "v", "w")])
    x = rng.normal(size=(16, 24))
    ref = program_reference_np(prog, {"u": x})
    for wa, wb in ((2, 3), (4, 2)):
        plan = lower(prog, workers={"a": wa, "b": wb}, auto_capacity=True)
        assert plan.pe_counts.get("imux", 0) == wb
        res, fields = simulate_program(plan, {"u": x}, CGRA,
                                       max_cycles=2_000_000)
        np.testing.assert_allclose(fields["w"], ref["w"], atol=1e-9)


def test_multi_output_multi_sync(rng):
    """Two output fields: one WriterBank + SyncTree (cmp) each; the sim runs
    until *all* completions fire and unpacks both fields."""
    spec = StencilSpec((60,), (2,), ((.1, .2, .4, .2, .1),), dtype="float64")
    prog = StencilProgram("mo", [StencilOp("a", spec, "u", "v"),
                                 StencilOp("b", spec, "v", "w")],
                          outputs=["v", "w"])
    plan = lower(prog, workers=2, auto_capacity=True)
    assert plan.pe_counts["cmp"] == 2
    assert plan.out_shape == (2, 60)
    x = rng.normal(size=60)
    res, fields = simulate_program(plan, {"u": x}, CGRA)
    ref = program_reference_np(prog, {"u": x})
    np.testing.assert_allclose(fields["v"], ref["v"], atol=1e-9)
    np.testing.assert_allclose(fields["w"], ref["w"], atol=1e-9)


def test_jnp_oracle_matches_np(rng):
    prog = hdiff_program(16, 24, dtype="float32")
    x = rng.normal(size=(16, 24)).astype(np.float32)
    ref_np = program_reference_np(prog, {"inp": x})
    ref_j = program_reference(prog, {"inp": x})
    np.testing.assert_allclose(np.asarray(ref_j["out"]), ref_np["out"],
                               atol=1e-4)


def test_timestepped_op_in_program(rng):
    """A StencilOp may itself fuse timesteps; margins scale with t*r."""
    import dataclasses
    spec = dataclasses.replace(heat_2d(20, 24, dtype="float64"), timesteps=2)
    prog = StencilProgram("t2", [StencilOp("a", spec, "u", "v")])
    assert prog.margins()["v"] == (2, 2)
    x = rng.normal(size=(20, 24))
    plan, res, fields = _sim(prog, 4, x, auto_capacity=True)
    ref = program_reference_np(prog, {"u": x})
    np.testing.assert_allclose(fields["v"], ref["v"], atol=1e-9)


def _random_dag(seed: int):
    """A random 2-to-4-op rank-1/2 DAG (chains, fan-out, combines) — the
    same shape as the hypothesis strategy in test_property.py, but seeded
    stdlib randomness so it always runs (hypothesis is an optional dep)."""
    import random

    rnd = random.Random(seed)
    d = rnd.randint(1, 2)
    w = rnd.randint(1, 3)
    shape = (rnd.randint(11, 14), 24)[-d:]
    ops, fields, margin = [], ["f0"], {"f0": 0}
    for i in range(rnd.randint(2, 4)):
        src = rnd.choice(fields[-2:])
        out = f"f{i + 1}"
        if rnd.random() < 1 / 3 and len(fields) >= 2:
            other = rnd.choice(fields)
            ops.append(CombineOp(f"op{i}", (src, other),
                                 (rnd.uniform(-1, 1), rnd.uniform(-1, 1)),
                                 out))
            margin[out] = max(margin[src], margin[other])
        else:
            budget = 4 - margin[src]
            if budget < 1:
                break
            radii = tuple(rnd.randint(0 if d > 1 else 1, min(2, budget))
                          for _ in range(d))
            if not any(radii):
                radii = (1,) * d
            coeffs = tuple(tuple(rnd.uniform(-1, 1)
                                 for _ in range(2 * r + 1)) for r in radii)
            ops.append(StencilOp(f"op{i}", StencilSpec(
                shape, radii, coeffs, dtype="float64"), src, out))
            margin[out] = margin[src] + max(radii)
        fields.append(out)
    return StencilProgram("fuzz", ops, grid_shape=shape,
                          dtype="float64"), w


@pytest.mark.parametrize("seed", range(20))
def test_random_dag_exact_and_auto_capacity_liveness(seed):
    """Seeded random DAGs: fused outputs equal the composed oracle and the
    analytic capacities (per-op mandatory buffering + inter-operator skew)
    never deadlock; external inputs are loaded exactly once."""
    prog, w = _random_dag(seed)
    rng = np.random.default_rng(seed)
    inputs = {f: rng.normal(size=prog.grid_shape) for f in prog.in_fields}
    plan = lower(prog, workers=w, auto_capacity=True)
    res, fields = simulate_program(plan, inputs, CGRA,
                                   max_cycles=2_000_000)  # deadlock -> raise
    ref = program_reference_np(prog, inputs)
    for f in prog.out_fields:
        np.testing.assert_allclose(fields[f], ref[f], atol=1e-9)
    assert res.loads == len(prog.in_fields) * int(np.prod(prog.grid_shape))


# ---------------------------------------------------------------------------
# physical fabric integration
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mk", [lambda: two_stage_heat(24, 32),
                                lambda: hdiff_program(24, 32)])
def test_program_places_and_routes_16x16(mk):
    prog = mk()
    plan = lower(prog, workers=4)
    rf = route(place(plan, FabricTopology.mesh(16, 16), seed=0))  # strict
    s = rf.stats()
    assert s["max_channel_load"] <= s["channel_capacity"]
    assert 0 < s["pe_utilization"] <= 1


def test_program_routed_sim_bit_identical_and_fused_wins(rng):
    """The acceptance claim: one fused pipeline, routed on the 16x16 mesh,
    is bit-identical to ideal mode and strictly faster than running its ops
    as separate store-to-memory sweeps."""
    prog = two_stage_heat(24, 32)
    x = rng.normal(size=(24, 32))
    ideal, _ = simulate_program(lower(prog, workers=4), {"u": x}, CGRA)
    plan = lower(prog, workers=4)
    rf = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
    routed, fields = simulate_program(plan, {"u": x}, CGRA, fabric=rf)
    assert np.array_equal(ideal.output, routed.output)
    assert routed.cycles >= ideal.cycles
    ref = program_reference_np(prog, {"u": x})
    np.testing.assert_allclose(fields["u2"], ref["u2"], atol=1e-9)
    # separate sweeps: each op as its own single-op program, cycles summed
    separate = 0
    for op in prog.schedule():
        solo = StencilProgram(f"solo_{op.name}", [op],
                              grid_shape=prog.grid_shape, dtype=prog.dtype)
        pl = lower(solo, workers=4)
        ins = {f: rng.normal(size=prog.grid_shape) for f in solo.in_fields}
        separate += simulate_program(pl, ins, CGRA)[0].cycles
    assert ideal.cycles < separate
