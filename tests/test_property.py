"""Hypothesis property tests on system invariants (deliverable (c)).

Stencil invariants:
  * linearity: S(ax + by) == a S(x) + b S(y)
  * shift equivariance in the interior
  * constant-field response = sum(coeffs) * c on the interior
  * kernel == oracle on arbitrary shapes/radii
Mapping invariants (the paper's interleave/filter algebra):
  * reader streams partition the grid exactly
  * every filter's keep-window lies inside its reader stream
  * sync expectations sum to the interior size
Explorer invariants (repro.explore):
  * a Pareto front is internally non-dominated and covers its inputs
  * the measured best never loses to any measured point on cycles

Runs under real ``hypothesis`` when installed (preferred: shrinking, example
database); otherwise under the deterministic shim
:mod:`repro.testing.minihyp`, so the sweep never silently skips.
"""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # containers where hypothesis can't be installed
    from repro.testing.minihyp import given, settings, strategies as st

from repro.core import CGRA, simulate
from repro.core.mapping import map_1d, map_nd
from repro.core.reference import stencil_reference_np
from repro.core.spec import StencilSpec
from repro.kernels.stencil1d.ops import stencil1d
from repro.kernels.stencil1d.ref import stencil1d_ref

SET = dict(max_examples=25, deadline=None)


@st.composite
def spec_1d(draw):
    r = draw(st.integers(1, 4))
    n = draw(st.integers(max(8 * r + 2, 24), 160))
    coeffs = tuple(
        draw(st.lists(st.floats(-1, 1, allow_nan=False, width=32),
                      min_size=2 * r + 1, max_size=2 * r + 1)))
    return StencilSpec((n,), (r,), (coeffs,), dtype="float32")


@given(spec_1d(), st.integers(0, 2 ** 31 - 1))
@settings(**SET)
def test_linearity(spec, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=spec.grid_shape).astype(np.float32)
    y = rng.normal(size=spec.grid_shape).astype(np.float32)
    a, b = 1.7, -0.4
    lhs = stencil_reference_np(a * x + b * y, spec)
    rhs = a * stencil_reference_np(x, spec) + b * stencil_reference_np(y, spec)
    np.testing.assert_allclose(lhs, rhs, atol=1e-4)


@given(spec_1d(), st.integers(1, 5), st.integers(0, 2 ** 31 - 1))
@settings(**SET)
def test_shift_equivariance_interior(spec, shift, seed):
    rng = np.random.default_rng(seed)
    (n,) = spec.grid_shape
    (r,) = spec.radii
    x = rng.normal(size=n).astype(np.float32)
    xs = np.roll(x, shift)
    y, ys = stencil_reference_np(x, spec), stencil_reference_np(xs, spec)
    lo, hi = r + shift, n - r
    np.testing.assert_allclose(ys[lo:hi], y[lo - shift:hi - shift], atol=1e-4)


@given(spec_1d(), st.floats(-3, 3, allow_nan=False, width=32))
@settings(**SET)
def test_constant_field(spec, c):
    (n,) = spec.grid_shape
    (r,) = spec.radii
    y = stencil_reference_np(np.full(n, c, np.float32), spec)
    expect = c * sum(spec.coeffs[0])
    np.testing.assert_allclose(y[r:n - r], expect, atol=1e-3)
    assert np.all(y[:r] == 0) and np.all(y[n - r:] == 0)


@given(spec_1d(), st.integers(0, 2 ** 31 - 1), st.integers(1, 2))
@settings(**SET)
def test_kernel_matches_oracle(spec, seed, t):
    rng = np.random.default_rng(seed)
    (n,) = spec.grid_shape
    if spec.radii[0] * t * 2 >= n:
        return
    x = jnp.asarray(rng.normal(size=(1, n)), jnp.float32)
    y = stencil1d(x, spec.coeffs[0], timesteps=t, backend="pallas",
                  block=(1, 128))
    yr = stencil1d_ref(x, spec.coeffs[0], timesteps=t)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)


@st.composite
def spec_nd_and_workers(draw):
    """Random rank-1/2/3 specs with legal workers/timesteps for map_nd."""
    d = draw(st.integers(1, 3))
    t = draw(st.integers(1, 2))
    w = draw(st.integers(1, 4))
    radii = tuple(draw(st.integers(1, 2)) for _ in range(d))
    shape = []
    for b, r in enumerate(radii):
        if b == d - 1:
            # inner extent: multiple of w (rank>=2), interior >= w workers
            lo = -(-(2 * r * t + w) // w)
            n = w * draw(st.integers(lo, lo + 4)) if d > 1 else \
                draw(st.integers(2 * r * t + w, 2 * r * t + w + 20))
        else:
            n = draw(st.integers(2 * r * t + 1, 2 * r * t + 7))
        shape.append(n)
    coeffs = tuple(
        tuple(draw(st.lists(st.floats(-1, 1, allow_nan=False, width=32),
                            min_size=2 * r + 1, max_size=2 * r + 1)))
        for r in radii)
    spec = StencilSpec(tuple(shape), radii, coeffs, dtype="float64",
                       timesteps=t)
    return spec, w


@given(spec_nd_and_workers(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_map_nd_exact_and_auto_capacity_liveness(sw, seed):
    """map_nd over random rank-1/2/3 specs: the simulated output equals the
    oracle and the analytic min-capacities (auto_capacity=True) never
    deadlock — the §III-B mandatory-buffering bound is *sufficient*."""
    spec, w = sw
    rng = np.random.default_rng(seed)
    x = rng.normal(size=spec.grid_shape)
    plan = map_nd(spec, workers=w, auto_capacity=True)
    res = simulate(plan, x, CGRA, max_cycles=2_000_000)   # deadlock -> raise
    np.testing.assert_allclose(res.output, stencil_reference_np(x, spec),
                               atol=1e-9)
    # reader streams partition the grid; writers partition the fused interior
    seen = sorted(i for loads in plan.reader_loads for i in loads)
    assert seen == list(range(int(np.prod(spec.grid_shape))))
    assert sum(plan.sync_expect) == int(np.prod(spec.interior_shape_fused))


@st.composite
def program_dag(draw):
    """Random 2-to-4-op rank-1/2 stencil-program DAGs: chains with fan-out
    into stencil and combine consumers, margins kept inside the grid."""
    from repro.program import CombineOp, StencilOp, StencilProgram

    d = draw(st.integers(1, 2))
    w = draw(st.integers(1, 3))
    # inner extent divisible by any w in 1..3; room for total margin <= 4
    shape = (draw(st.integers(11, 14)), 24)[-d:]
    n_ops = draw(st.integers(2, 4))
    ops, fields, margin = [], ["f0"], {"f0": 0}
    for i in range(n_ops):
        # bias toward recent fields so chains get deep enough to need skew
        src = draw(st.sampled_from(fields[-2:]))
        out = f"f{i + 1}"
        kind = draw(st.sampled_from(["stencil", "stencil", "combine"]))
        if kind == "combine" and len(fields) >= 2:
            other = draw(st.sampled_from(fields))
            c1, c2 = (draw(st.floats(-1, 1, allow_nan=False, width=32))
                      for _ in range(2))
            ops.append(CombineOp(f"op{i}", (src, other), (c1, c2), out))
            margin[out] = max(margin[src], margin[other])
        else:
            budget = 4 - margin[src]
            if budget < 1:
                break
            radii = tuple(draw(st.integers(0 if d > 1 else 1,
                                           min(2, budget)))
                          for _ in range(d))
            if not any(radii):
                radii = (1,) * d
            coeffs = tuple(
                tuple(draw(st.lists(
                    st.floats(-1, 1, allow_nan=False, width=32),
                    min_size=2 * r + 1, max_size=2 * r + 1)))
                for r in radii)
            spec = StencilSpec(shape, radii, coeffs, dtype="float64")
            ops.append(StencilOp(f"op{i}", spec, src, out))
            margin[out] = margin[src] + max(radii)
        fields.append(out)
    if not any(isinstance(op, StencilOp) for op in ops):
        r1 = (1,) * d
        spec = StencilSpec(shape, r1, ((0.5, -1.0, 0.5),) * d,
                           dtype="float64")
        ops.append(StencilOp("opx", spec, fields[-1], "fx"))
    return StencilProgram("fuzz", ops, grid_shape=shape,
                          dtype="float64"), w


@given(program_dag(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_program_dag_exact_and_auto_capacity_liveness(pw, seed):
    """Random stencil-program DAGs: the fused pipeline's outputs equal the
    composed oracle and the analytic capacities (per-op mandatory buffering
    + inter-operator skew) never deadlock."""
    from repro.program import (lower, program_reference_np, simulate_program)

    prog, w = pw
    rng = np.random.default_rng(seed)
    inputs = {f: rng.normal(size=prog.grid_shape)
              for f in prog.in_fields}
    plan = lower(prog, workers=w, auto_capacity=True)
    res, fields = simulate_program(plan, inputs, CGRA,
                                   max_cycles=2_000_000)  # deadlock -> raise
    ref = program_reference_np(prog, inputs)
    for f in prog.out_fields:
        np.testing.assert_allclose(fields[f], ref[f], atol=1e-9)
    # external inputs are loaded exactly once each, fan-out or not
    assert res.loads == len(prog.in_fields) * int(
        np.prod(prog.grid_shape))


@given(st.integers(24, 200), st.integers(1, 4), st.integers(1, 6))
@settings(**SET)
def test_mapping_interleave_algebra(n, r, w):
    if n <= 2 * r:
        return
    coeffs = tuple([1.0 / (2 * r + 1)] * (2 * r + 1))
    spec = StencilSpec((n,), (r,), (coeffs,), dtype="float64")
    plan = map_1d(spec, workers=w)
    # reader streams partition [0, n)
    seen = sorted(i for loads in plan.reader_loads for i in loads)
    assert seen == list(range(n))
    # writers partition the interior
    outs = sorted(i for ws in plan.writer_stores for i in ws)
    assert outs == list(range(r, n - r))
    # sync expectations match writer loads
    assert plan.sync_expect == [len(ws) for ws in plan.writer_stores]
    # every filter keep-window fits its source stream (0^m 1^n 0^p wellformed)
    for nd in plan.dfg.nodes:
        if nd.op == "filter":
            src_len = len(plan.reader_loads[0])  # streams differ by <=1
            assert nd.params["m"] + nd.params["n"] <= src_len + 1


# ---------------------------------------------------------------------------
# explorer invariants (PR 5: repro.explore)
# ---------------------------------------------------------------------------
@given(st.lists(st.tuples(st.integers(0, 40), st.integers(0, 40),
                          st.integers(0, 40)), min_size=0, max_size=40))
@settings(**SET)
def test_pareto_front_sound_and_complete(points):
    """The front is internally non-dominated, and every input point is
    either on the front or dominated by a front member."""
    from repro.explore import assert_non_dominated, dominates, pareto_front

    front = pareto_front(points)
    assert_non_dominated(front)
    front_set = set(front)
    for p in points:
        assert p in front_set or any(dominates(f, p) for f in front)


@st.composite
def explore_case(draw):
    """Tiny random 1D specs + a random worker ladder for the explorer."""
    from repro.core.spec import StencilSpec

    r = draw(st.integers(1, 2))
    n = draw(st.integers(4 * r + 8, 4 * r + 40))
    coeffs = tuple(
        draw(st.lists(st.floats(-1, 1, allow_nan=False, width=32),
                      min_size=2 * r + 1, max_size=2 * r + 1)))
    spec = StencilSpec((n,), (r,), (coeffs,), dtype="float64")
    workers = tuple(sorted({draw(st.integers(1, 4)) for _ in range(3)}))
    return spec, workers


@given(explore_case())
@settings(max_examples=8, deadline=None)
def test_explorer_front_non_dominated(case):
    """Fuzz the whole tuner loop: the returned Pareto front must be
    internally non-dominated and the best() pick must never lose to any
    measured point on the leading (cycles) objective."""
    from repro.core import CGRA
    from repro.explore import (EvalPoint, SpaceOptions, assert_non_dominated,
                               explore)

    spec, workers = case
    res = explore(spec, CGRA, options=SpaceOptions(workers=workers),
                  verify=True)
    assert res.front, "explorer returned an empty front"
    assert_non_dominated(res.front, key=EvalPoint.objectives)
    assert res.best().cycles == min(p.cycles for p in res.points)
    if res.analytic is not None:
        assert res.best().cycles <= res.analytic.cycles


# ---------------------------------------------------------------------------
# static-verifier soundness (PR 10: repro.analysis.static_verify)
# ---------------------------------------------------------------------------
def _static_roundtrip(plan, x, max_cycles=2_000_000):
    """The soundness oracle: whatever the verifier claims must match what
    the engine does, and a suggested bump must always yield completion."""
    from repro.analysis import apply_suggested_capacities, verify_plan
    from repro.core.engine.common import SimDeadlock

    rep = verify_plan(plan)
    try:
        simulate(plan, x, CGRA, max_cycles=max_cycles)
        engine = "complete"
    except SimDeadlock as e:
        engine = "timeout" if e.timed_out else "deadlock"
    if rep.verdict == "safe":
        # the one unforgivable error: "safe" on a plan that deadlocks
        assert engine == "complete", (rep.describe(), engine)
    elif rep.verdict == "deadlock":
        assert engine == "deadlock", (rep.describe(), engine)
        if rep.suggested_capacities:
            assert apply_suggested_capacities(
                plan, rep.suggested_capacities) > 0
            assert verify_plan(plan).verdict == "safe"
            simulate(plan, x, CGRA, max_cycles=max_cycles)  # must complete
    # verdict "unknown" makes no claim — nothing to check


@given(spec_nd_and_workers(), st.integers(1, 3), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_static_verdict_sound_on_random_specs(sw, cap, seed):
    """Random rank-1/2/3 specs under deliberately under-provisioned fixed
    capacities: the static verdict always matches the engine, and the
    repair hint always completes."""
    from repro.core.mapping import map_nd

    spec, w = sw
    x = np.random.default_rng(seed).normal(size=spec.grid_shape)
    _static_roundtrip(map_nd(spec, workers=w, queue_capacity=cap), x)


@given(program_dag(), st.integers(1, 3), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_static_verdict_sound_on_random_programs(pw, cap, seed):
    """Random stencil-program DAGs (fan-out, combines, skew buffers) under
    starved capacities: same soundness contract as the spec sweep."""
    from repro.program import lower

    prog, w = pw
    rng = np.random.default_rng(seed)
    plan = lower(prog, workers=w, queue_capacity=cap)
    x = plan.pack_inputs({f: rng.normal(size=prog.grid_shape)
                          for f in prog.in_fields})
    _static_roundtrip(plan, x)


@given(spec_nd_and_workers(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_verify_static_preflight_matches_engine(sw, seed):
    """simulate(verify="static") either raises StaticDeadlock (and the
    suggested bump completes) or simulates to the oracle-exact result —
    never a dynamic deadlock slipping past the pre-flight."""
    from repro.analysis import StaticDeadlock, apply_suggested_capacities
    from repro.core.mapping import map_nd

    spec, w = sw
    x = np.random.default_rng(seed).normal(size=spec.grid_shape)
    plan = map_nd(spec, workers=w, queue_capacity=1)
    try:
        res = simulate(plan, x, CGRA, max_cycles=2_000_000, verify="static")
    except StaticDeadlock as e:
        assert e.cycles == 0
        if e.suggested_capacities:
            plan2 = map_nd(spec, workers=w, queue_capacity=1)
            assert apply_suggested_capacities(
                plan2, e.suggested_capacities) > 0
            res = simulate(plan2, x, CGRA, max_cycles=2_000_000,
                           verify="static")
        else:
            return
    np.testing.assert_allclose(res.output, stencil_reference_np(x, spec),
                               atol=1e-9)
