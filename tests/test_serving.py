"""Serving path: batch engine end-to-end, greedy decode determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models.registry import build_model
from repro.serving.engine import BatchEngine, Request
from repro.serving.serve_step import make_decode_step


def test_batch_engine_completes_requests():
    cfg = get_reduced_config("tinyllama-1.1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=6).tolist(),
                    max_new=5) for i in range(5)]
    eng = BatchEngine(model, cfg, params, batch_slots=3, cache_len=64)
    done = eng.run(reqs)
    assert len(done) == 5
    assert all(len(r.out) == 5 for r in done)


def test_greedy_decode_matches_forward_argmax():
    """Greedy continuation from decode equals argmax over teacher-forced
    forward logits when fed the same tokens."""
    cfg = get_reduced_config("qwen2.5-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, 7)), jnp.int32)
    logits, _ = model.forward(params, toks)
    want_next = int(jnp.argmax(logits[0, -1]))

    step = jax.jit(make_decode_step(model, cfg))
    cache = model.init_cache(1, 32)
    nxt = None
    for t in range(7):
        nxt, _, cache = step(params, cache, toks[:, t:t + 1],
                             jnp.asarray(t, jnp.int32))
    assert int(nxt[0, 0]) == want_next
