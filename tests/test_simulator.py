"""CGRA mapping + cycle simulator: numerics vs oracle, buffering bound,
deadlock below it, emitters, utilization sanity."""
import numpy as np
import pytest

from repro.core import CGRA, SimDeadlock, map_1d, map_2d, simulate
from repro.core.mapping import plan_blocks
from repro.core.reference import stencil_reference_np
from repro.core.spec import (StencilSpec, heat_2d, paper_stencil_1d,
                             paper_stencil_2d)


def _coeffs(rng, r):
    return tuple((rng.normal(size=2 * r + 1) / (2 * r + 1)).tolist())


@pytest.mark.parametrize("n,r,w", [(120, 1, 1), (120, 1, 3), (240, 2, 4),
                                   (300, 3, 5), (510, 8, 6)])
def test_1d_exact(rng, n, r, w):
    spec = StencilSpec((n,), (r,), (_coeffs(rng, r),), dtype="float64")
    plan = map_1d(spec, workers=w)
    x = rng.normal(size=n)
    res = simulate(plan, x, CGRA)
    assert np.allclose(res.output, stencil_reference_np(x, spec))
    assert res.loads == n                      # every element loaded ONCE
    assert res.stores == n - 2 * r
    assert res.flops == (n - 2 * r) * spec.flops_per_output


@pytest.mark.parametrize("ny,nx,ry,rx,w", [(16, 24, 1, 1, 3), (20, 30, 2, 2, 3),
                                           (24, 25, 3, 1, 5)])
def test_2d_exact(rng, ny, nx, ry, rx, w):
    cy = _coeffs(rng, ry)
    cx = list(_coeffs(rng, rx))
    cx[rx] = 0.0
    spec = StencilSpec((ny, nx), (ry, rx), (cy, tuple(cx)), dtype="float64")
    plan = map_2d(spec, workers=w)
    x = rng.normal(size=(ny, nx))
    res = simulate(plan, x, CGRA)
    assert np.allclose(res.output, stencil_reference_np(x, spec))
    assert res.loads == ny * nx                # loaded once (the paper claim)


def test_temporal_pipeline_exact(rng):
    spec = StencilSpec((360,), (2,), (_coeffs(rng, 2),), dtype="float64",
                       timesteps=3)
    plan = map_1d(spec, workers=3)
    x = rng.normal(size=360)
    res = simulate(plan, x, CGRA)
    assert np.allclose(res.output, stencil_reference_np(x, spec))
    # layered compute workers: 3 layers x 3 workers x 5 taps of arithmetic
    assert plan.pe_counts["mac"] == 3 * 3 * 4
    assert res.loads == 360                    # I/O only at pipeline ends


def test_mandatory_buffering_measured(rng):
    """§III-B: ~2*ry rows must live in queues; bounded capacities below the
    analytic minimum deadlock."""
    spec = heat_2d(18, 24, dtype="float64")
    plan = map_2d(spec, workers=3, auto_capacity=True)
    x = rng.normal(size=(18, 24))
    res = simulate(plan, x, CGRA)             # analytic capacities suffice
    assert np.allclose(res.output, stencil_reference_np(x, spec))

    starved = map_2d(spec, workers=3, queue_capacity=1)
    with pytest.raises(SimDeadlock):
        simulate(starved, x, CGRA, max_cycles=200_000)


def test_filters_fire_and_drop(rng):
    spec = StencilSpec((120,), (1,), ((0.25, 0.5, 0.25),), dtype="float64")
    plan = map_1d(spec, workers=3)
    res = simulate(plan, rng.normal(size=120), CGRA)
    # every tap's filter consumes the full reader stream
    assert res.fires["filter"] == sum(len(l) for l in plan.reader_loads) * 3


def test_utilization_at_scale(rng):
    """Reduced-size paper 1D stencil should reach >90% of its roofline (the
    paper's cycle-accurate sim reports 91%)."""
    spec = paper_stencil_1d(n=9720, rx=8)
    plan = map_1d(spec, workers=6)
    res = simulate(plan, rng.normal(size=9720), CGRA)
    assert res.pct_of_roofline > 0.90


def test_emitters(rng):
    spec = StencilSpec((60,), (1,), ((0.2, 0.5, 0.3),), dtype="float64")
    plan = map_1d(spec, workers=2)
    dot = plan.dfg.to_dot()
    asm = plan.dfg.to_assembly()
    assert "digraph" in dot and "mac" in dot
    assert "PE0" in asm and "stage=reader" in asm
    # PE accounting: 2 workers x (1 mul + 2 mac) + filters/loads/stores/sync
    assert plan.pe_counts["mul"] == 2
    assert plan.pe_counts["mac"] == 4
    assert plan.mac_pes == 6


def test_block_planner_fits_budget():
    spec = paper_stencil_1d(n=194400, rx=8, dtype="float64")
    bp = plan_blocks(spec, storage_budget_bytes=256 * 1024)
    assert bp.fits
    assert bp.block_shape[0] % 128 == 0
    spec2 = heat_2d(4096, 4096)
    bp2 = plan_blocks(spec2, storage_budget_bytes=8 * 1024 * 1024)
    assert bp2.fits and bp2.working_set_bytes <= 8 * 1024 * 1024


def test_per_node_fires_match_aggregate(rng):
    """Regression (PR 4): filter drops and sync count-ticks must increment
    ``Node.fires`` like every other fire, so per-PE utilization derived from
    per-node counters equals the per-op aggregate."""
    spec = StencilSpec((120,), (1,), ((0.25, 0.5, 0.25),), dtype="float64")
    plan = map_1d(spec, workers=3)
    res = simulate(plan, rng.normal(size=120), CGRA)
    per_node: dict[str, int] = {}
    for nd in plan.dfg.nodes:
        per_node[nd.op] = per_node.get(nd.op, 0) + nd.fires
    assert per_node == res.fires
    # filters consume the whole reader stream; keeps < consumes, and the
    # dropped tokens must be visible in the per-node counters.
    filters = [nd for nd in plan.dfg.nodes if nd.op == "filter"]
    assert sum(nd.fires for nd in filters) > \
        sum(nd.params["keep_count"] for nd in filters)
    # syncs fire once per store token (no double-count on the done emission)
    syncs = [nd for nd in plan.dfg.nodes if nd.op == "sync"]
    assert sum(nd.fires for nd in syncs) == res.stores


def test_mem_efficiency_derates_bandwidth(rng):
    """mem_efficiency scales the memory-port element rate: cycles go up,
    numerics are untouched."""
    spec = paper_stencil_1d(n=1200, rx=8)
    x = rng.normal(size=1200)
    full = simulate(map_1d(spec, workers=6), x, CGRA)
    half = simulate(map_1d(spec, workers=6), x, CGRA, mem_efficiency=0.5)
    assert half.cycles > full.cycles
    # the derated run is memory-bound: it cannot beat the halved port rate
    elems = half.loads + half.stores
    epc_half = 0.5 * CGRA.bw_gbps / CGRA.clock_ghz / 8
    assert half.cycles >= elems / epc_half
    assert np.array_equal(full.output, half.output)
    assert half.gflops < full.gflops


def test_deadlock_diagnostic_names_blocked_nodes(rng):
    """The SimDeadlock message must point at the stuck part of the graph:
    node names with their op kind and queue states."""
    spec = heat_2d(18, 24, dtype="float64")
    plan = map_2d(spec, workers=3, queue_capacity=1)
    with pytest.raises(SimDeadlock) as ei:
        simulate(plan, rng.normal(size=(18, 24)), CGRA, max_cycles=200_000)
    msg = str(ei.value)
    assert "deadlock at cycle" in msg
    assert "(filter)" in msg or "(load)" in msg or "(addr)" in msg
    assert "in=" in msg and "outfull=" in msg
    # it names real nodes of this DFG
    assert any(nd.name in msg for nd in plan.dfg.nodes)


def test_max_cycles_overflow_raises(rng):
    spec = StencilSpec((120,), (1,), ((0.25, 0.5, 0.25),), dtype="float64")
    plan = map_1d(spec, workers=3)
    with pytest.raises(SimDeadlock, match="exceeded max_cycles=25"):
        simulate(plan, rng.normal(size=120), CGRA, max_cycles=25)


def test_3d_oracle_supported(rng):
    """The spec/oracle are rank-generic (paper: 'can be extended to 3D')."""
    cz = (0.2, 0.5, 0.3)
    cy = (0.1, 0.0, 0.2)
    cx = (0.3, 0.0, 0.4)
    spec = StencilSpec((10, 12, 14), (1, 1, 1), (cz, cy, cx), dtype="float64")
    x = rng.normal(size=(10, 12, 14))
    y = stencil_reference_np(x, spec)
    # hand-check one interior point
    j = (4, 5, 6)
    want = sum(c * x[j[0] + k - 1, j[1], j[2]] for k, c in enumerate(cz))
    want += sum(c * x[j[0], j[1] + k - 1, j[2]] for k, c in enumerate(cy))
    want += sum(c * x[j[0], j[1], j[2] + k - 1] for k, c in enumerate(cx))
    assert abs(y[j] - want) < 1e-12
    assert y[0, 0, 0] == 0.0


def test_block_planner_shrinks_to_fit_tight_budget():
    """Regression (PR 5): a budget below the seed block's working set used
    to silently return fits=False; now the block shrinks toward (1, ..., 1)
    and the returned plan always fits."""
    spec = heat_2d(512, 512, dtype="float32")
    # seed block is (8, 128) + halos -> ~4.7 KB; force far below that
    bp = plan_blocks(spec, storage_budget_bytes=600)
    assert bp.fits
    assert bp.working_set_bytes <= 600
    assert all(b >= 1 for b in bp.block_shape)
    assert all(g >= 1 for g in bp.grid)


def test_block_planner_raises_below_minimal_working_set():
    from repro.core.mapping import minimal_working_set_bytes

    spec = paper_stencil_2d(ny=64, nx=128, r=12, dtype="float64")
    minimal = minimal_working_set_bytes(spec)
    with pytest.raises(ValueError) as ei:
        plan_blocks(spec, storage_budget_bytes=minimal - 1)
    assert str(minimal) in str(ei.value)     # message carries the floor


def test_block_planner_exact_boundary_budget():
    """A budget of exactly the (1, ..., 1) working set is satisfiable — the
    planner must return that block, not raise or overshoot."""
    from repro.core.mapping import minimal_working_set_bytes

    spec = paper_stencil_2d(ny=64, nx=128, r=12, dtype="float64")
    minimal = minimal_working_set_bytes(spec)
    bp = plan_blocks(spec, storage_budget_bytes=minimal)
    assert bp.fits and bp.working_set_bytes == minimal
    assert bp.block_shape == (1, 1)


def test_block_planner_1d_tight_budget():
    spec = paper_stencil_1d(n=194400, rx=8, dtype="float64")
    big = plan_blocks(spec, storage_budget_bytes=256 * 1024)
    small = plan_blocks(spec, storage_budget_bytes=4 * 1024)
    assert big.fits and small.fits
    assert small.block_shape[0] < big.block_shape[0]
    assert small.working_set_bytes <= 4 * 1024
