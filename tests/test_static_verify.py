"""The static plan verifier (repro.analysis.static_verify) + lint CLI.

The contract under test, end to end:

* **soundness on the known deadlocks** — every capacity-starved case the
  engine suite proves dynamically (2D/3D mandatory buffering, the hdiff
  skew buffer) is rejected *statically*, with a named counterexample, and
  the verifier's suggested capacity bump turns each one into a completing,
  oracle-exact simulation.
* **no false alarms** — auto-capacity and unbounded plans verify safe (the
  fast min-capacities certificate where the plan records its minima, token
  quiescence otherwise), routed or ideal.
* **throughput bound** — cycles_lb / fill_lb never exceed the measured
  cycle count / attribution fill phase.
* **wiring** — ``simulate(verify="static")`` raises ``StaticDeadlock``
  before burning engine cycles; a dynamic ``SimDeadlock`` carries the same
  repair hint; the lint CLI walks examples/ hooks.
"""
import io
import types

import numpy as np
import pytest

from repro.analysis import (StaticDeadlock, apply_suggested_capacities,
                            lint_plan, throughput_bound, verify_plan)
from repro.core import CGRA, map_2d, map_3d, simulate
from repro.core.dfg import DFG
from repro.core.engine.common import SimDeadlock
from repro.core.reference import stencil_reference_np
from repro.core.spec import heat_2d, heat_3d
from repro.fabric import FabricTopology, place, route
from repro.program import hdiff_program, lower


def _starved_cases():
    """Every deadlock the engine suite proves dynamically, as (name,
    starved plan factory, safe reference input)."""
    h2 = heat_2d(18, 24, dtype="float64")
    h3 = heat_3d(8, 10, 12, dtype="float64")
    hd = hdiff_program(20, 24)
    rng = np.random.default_rng(0)
    return [
        ("heat2d_cap1_w3", lambda: map_2d(h2, workers=3, queue_capacity=1),
         h2, rng.normal(size=h2.grid_shape)),
        ("heat3d_cap1_w4", lambda: map_3d(h3, workers=4, queue_capacity=1),
         h3, rng.normal(size=h3.grid_shape)),
        ("hdiff_cap2_w4", lambda: lower(hd, workers=4, queue_capacity=2),
         hd, rng.normal(size=(20, 24))),
    ]


# ---------------------------------------------------------------------------
# deadlock detection: every engine-proven deadlock is statically rejected
# ---------------------------------------------------------------------------
def test_known_deadlocks_statically_rejected():
    for name, mk, _spec, _x in _starved_cases():
        rep = verify_plan(mk())
        assert rep.verdict == "deadlock", (name, rep.describe())
        assert rep.reason == "static-capacity", name
        assert rep.counterexample is not None, name
        assert rep.counterexample.kind in ("waits-cycle", "starvation-chain")
        assert rep.counterexample.nodes          # named, not anonymous
        assert rep.suggested_capacities, name


def test_suggested_bump_completes_and_matches_oracle():
    """The repair hint is not just plausible — applying it yields a
    completing, bit-exact simulation for every starved case."""
    from repro.program import program_reference_np

    for name, mk, spec, x in _starved_cases():
        plan = mk()
        rep = verify_plan(plan)
        grown = apply_suggested_capacities(plan, rep.suggested_capacities)
        assert grown > 0, name
        assert verify_plan(plan).verdict == "safe", name
        if hasattr(spec, "grid_shape") and not hasattr(spec, "schedule"):
            res = simulate(plan, x, CGRA, max_cycles=2_000_000)
            np.testing.assert_allclose(
                res.output, stencil_reference_np(x, spec), atol=1e-9)
        else:                              # the hdiff program target
            res = simulate(plan, plan.pack_inputs({"inp": x}), CGRA,
                           max_cycles=2_000_000)
            ref = program_reference_np(spec, {"inp": x})
            np.testing.assert_allclose(
                plan.unpack_outputs(res.output)["out"], ref["out"],
                atol=1e-9)


def test_engine_agrees_with_static_verdict():
    """The statically-rejected plans really deadlock (not timeout) when
    simulated — the abstract quiescence matches the engines."""
    for name, mk, _spec, x in _starved_cases():
        plan = mk()
        xin = x if not hasattr(_spec, "schedule") else \
            plan.pack_inputs({"inp": x})
        with pytest.raises(SimDeadlock) as ei:
            simulate(plan, xin, CGRA, max_cycles=500_000)
        assert not ei.value.timed_out, name


def test_safe_plans_verify_safe_auto_and_unbounded(rng):
    spec = heat_2d(18, 24, dtype="float64")
    auto = map_2d(spec, workers=3, auto_capacity=True)
    rep = verify_plan(auto)
    assert rep.ok() and rep.certificate == "min-capacities"
    unbounded = map_2d(spec, workers=3)
    rep_u = verify_plan(unbounded)
    assert rep_u.ok()
    # cross-check: both really complete
    x = rng.normal(size=spec.grid_shape)
    simulate(auto, x, CGRA)
    simulate(unbounded, x, CGRA)


def test_quiescence_path_proves_safety_without_minima(rng):
    """With no recorded analytic minima the fast certificate cannot apply —
    the token-flow replay must prove safety on its own."""
    spec = heat_2d(18, 24, dtype="float64")
    plan = map_2d(spec, workers=3, queue_capacity=64)
    plan.min_capacities = {}               # force the quiescence prover
    rep = verify_plan(plan)
    assert rep.verdict == "safe" and rep.certificate == "quiescence"
    simulate(plan, rng.normal(size=spec.grid_shape), CGRA)


# ---------------------------------------------------------------------------
# simulate() wiring: pre-flight + repair hint on dynamic deadlocks
# ---------------------------------------------------------------------------
def test_simulate_verify_static_preflight(rng):
    spec = heat_2d(18, 24, dtype="float64")
    x = rng.normal(size=spec.grid_shape)
    starved = map_2d(spec, workers=3, queue_capacity=1)
    with pytest.raises(StaticDeadlock) as ei:
        simulate(starved, x, CGRA, verify="static")
    assert ei.value.cycles == 0            # nothing was simulated
    assert ei.value.suggested_capacities
    assert ei.value.report.counterexample is not None
    # safe plan passes the pre-flight and simulates normally
    ok = map_2d(spec, workers=3, auto_capacity=True)
    res = simulate(ok, x, CGRA, verify="static")
    np.testing.assert_allclose(res.output, stencil_reference_np(x, spec),
                               atol=1e-9)
    with pytest.raises(ValueError, match="verify mode"):
        simulate(ok, x, CGRA, verify="dynamic")


def test_dynamic_deadlock_carries_repair_hint(rng):
    """An engine-discovered SimDeadlock is enriched with the verifier's
    suggested_capacities; applying them completes the run."""
    spec = heat_2d(18, 24, dtype="float64")
    x = rng.normal(size=spec.grid_shape)
    plan = map_2d(spec, workers=3, queue_capacity=1)
    with pytest.raises(SimDeadlock) as ei:
        simulate(plan, x, CGRA, max_cycles=200_000)
    hint = ei.value.suggested_capacities
    assert hint
    plan2 = map_2d(spec, workers=3, queue_capacity=1)
    assert apply_suggested_capacities(plan2, hint) > 0
    res = simulate(plan2, x, CGRA, max_cycles=2_000_000)
    np.testing.assert_allclose(res.output, stencil_reference_np(x, spec),
                               atol=1e-9)


def test_apply_suggested_accepts_json_string_keys():
    """Cache records round-trip hints through JSON, stringifying eid keys;
    apply_suggested_capacities must accept them as-is."""
    spec = heat_2d(18, 24, dtype="float64")
    plan = map_2d(spec, workers=3, queue_capacity=1)
    hint = verify_plan(plan).suggested_capacities
    json_hint = {str(k): int(v) for k, v in hint.items()}
    plan2 = map_2d(spec, workers=3, queue_capacity=1)
    assert apply_suggested_capacities(plan2, json_hint) > 0
    assert verify_plan(plan2).verdict == "safe"


# ---------------------------------------------------------------------------
# routed verification
# ---------------------------------------------------------------------------
def test_routed_verdict_matches_ideal(rng):
    """The network never changes the deadlock verdict (module-docstring
    argument); routed lints are clean on a real routed fabric."""
    spec = heat_2d(18, 24, dtype="float64")
    plan = map_2d(spec, workers=3, auto_capacity=True)
    rf = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
    rep = verify_plan(plan, fabric=rf)
    assert rep.ok()
    x = rng.normal(size=spec.grid_shape)
    res = simulate(plan, x, CGRA, fabric=rf, verify="static")
    assert res.cycles >= rep.bound.cycles_lb

    starved = map_2d(spec, workers=3, queue_capacity=1)
    rf2 = route(place(starved, FabricTopology.mesh(16, 16), seed=0))
    assert verify_plan(starved, fabric=rf2).verdict == "deadlock"


# ---------------------------------------------------------------------------
# throughput bound vs measurement
# ---------------------------------------------------------------------------
def test_bound_below_measured_cycles(rng):
    from repro.telemetry import Telemetry, attribute

    spec = heat_2d(18, 24, dtype="float64")
    plan = map_2d(spec, workers=3, auto_capacity=True)
    bound = throughput_bound(plan, machine=CGRA)
    x = rng.normal(size=spec.grid_shape)
    tel = Telemetry(timeline=False)
    res = simulate(plan, x, CGRA, telemetry=tel)
    assert 0 < bound.cycles_lb <= res.cycles
    assert bound.stores == res.stores
    # required fires are completion-necessary: the fair engine may fire a
    # few surplus loads completion never waited on
    assert 0 < bound.loads <= res.loads
    assert bound.ii_lb <= res.cycles / res.stores
    acct = attribute(tel, res)
    assert bound.fill_lb <= acct.phases["fill"] + 1
    assert bound.stage_fill                 # per-stage depths present


def test_bound_routed_at_least_ideal():
    spec = heat_2d(18, 24, dtype="float64")
    plan = map_2d(spec, workers=3, auto_capacity=True)
    ideal = throughput_bound(plan, machine=CGRA)
    rf = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
    routed = throughput_bound(plan, fabric=rf, machine=CGRA)
    assert routed.depth_cycles_lb >= ideal.depth_cycles_lb


# ---------------------------------------------------------------------------
# lints
# ---------------------------------------------------------------------------
def _fake_plan(g):
    return types.SimpleNamespace(dfg=g)


def test_lint_cyclic_dfg():
    g = DFG("cyc")
    a = g.add("copy", "a")
    b = g.add("copy", "b")
    g.connect(a, b)
    g.connect(b, a)
    rep = verify_plan(_fake_plan(g))
    assert rep.verdict == "deadlock" and rep.reason == "static-deadlock"
    assert any(f.kind == "cyclic-dfg" for f in rep.findings)


def test_lint_no_cmp():
    g = DFG("nocmp")
    g.add("addr", "a", count=4)
    rep = verify_plan(_fake_plan(g))
    assert rep.verdict == "deadlock" and rep.certificate == "lint"
    assert any(f.kind == "no-cmp" for f in rep.findings)


def test_lint_zero_capacity_and_sync():
    spec = heat_2d(18, 24, dtype="float64")
    plan = map_2d(spec, workers=3, auto_capacity=True)
    edges = plan.dfg.finalize()
    edges[0].capacity = 0
    findings = lint_plan(plan)
    assert any(f.kind == "zero-capacity" and f.severity == "error"
               for f in findings)
    # break a sync expectation: starved error
    sync = next(nd for nd in plan.dfg.nodes if nd.op == "sync")
    sync.params["expected"] = int(sync.params["expected"]) + 10_000
    findings = lint_plan(plan)
    assert any(f.kind == "sync-starved" for f in findings)


def test_lint_stale_compile_on_real_cache():
    """The stale-compile lint must read the real compiled_for() cache shape
    ((fabric, CompiledPlan) pairs) — simulate to populate it, then mutate."""
    spec = heat_2d(8, 8, dtype="float64")
    plan = map_2d(spec, workers=2, auto_capacity=True)
    x = np.zeros(spec.grid_shape)
    simulate(plan, x, CGRA, engine="vector")
    assert not any(f.kind == "stale-compile" for f in lint_plan(plan))
    plan.dfg.mark_mutated()
    findings = lint_plan(plan)
    assert any(f.kind == "stale-compile" and f.severity == "warning"
               for f in findings)


def test_deadlocked_plan_resimulates_cleanly():
    """A deadlocked interp run must not poison the plan for a retry: apply
    the repair hint to the SAME object and the rerun completes at the
    oracle answer (queues restart from the quiescent marking)."""
    spec = heat_2d(18, 24, dtype="float64")
    plan = map_2d(spec, workers=3, queue_capacity=1)
    x = np.random.default_rng(7).normal(size=spec.grid_shape)
    with pytest.raises(SimDeadlock) as ei:
        simulate(plan, x, CGRA, engine="interp")
    hint = ei.value.suggested_capacities
    assert hint and apply_suggested_capacities(plan, hint) > 0
    res = simulate(plan, x, CGRA, engine="interp")
    np.testing.assert_allclose(res.output, stencil_reference_np(x, spec),
                               atol=1e-9)


def test_lint_splice_geometry():
    """Worker-mismatched program stages force imux re-interleave buffers;
    corrupting one's pattern must trip the splice lints."""
    from repro.program import StencilOp, StencilProgram

    spec = heat_2d(16, 24, dtype="float64")
    prog = StencilProgram("mm", [StencilOp("a", spec, "u", "v"),
                                 StencilOp("b", spec, "v", "w")])
    plan = lower(prog, workers={"a": 2, "b": 3}, auto_capacity=True)
    assert lint_plan(plan) == []           # clean as lowered
    imux = next(nd for nd in plan.dfg.nodes if nd.op == "imux")
    imux.params["pattern"] = list(imux.params["pattern"])[:-1] + [0]
    findings = lint_plan(plan)
    assert any(f.kind in ("splice-geometry", "splice-pattern")
               for f in findings)


def test_lint_routed_slot_conflict():
    """Squeezing a real placement onto a fabric that claims fewer slots per
    PE than the placement used must raise slot-conflict."""
    spec = heat_2d(18, 24, dtype="float64")
    plan = map_2d(spec, workers=3, auto_capacity=True)
    topo = FabricTopology.mesh(16, 16)
    rf = route(place(plan, topo, seed=0))
    assert lint_plan(plan, rf) == []
    per_pe: dict = {}
    for coord in rf.placement.coords.values():
        per_pe[coord] = per_pe.get(coord, 0) + 1
    busiest = max(per_pe.values())
    if busiest < 2:
        pytest.skip("placement never doubles up on this topology")
    import dataclasses
    for coord, pe in list(topo.pes.items()):
        topo.pes[coord] = dataclasses.replace(pe, slots=1)
    findings = lint_plan(plan, rf)
    assert any(f.kind == "slot-conflict" for f in findings)


# ---------------------------------------------------------------------------
# lint CLI
# ---------------------------------------------------------------------------
def test_lint_cli_walks_examples():
    from repro.analysis.lint import lint_paths, main

    out = io.StringIO()
    n_plans, n_failed = lint_paths(["examples"], out=out)
    assert n_plans >= 7 and n_failed == 0, out.getvalue()
    assert main(["examples", "--strict"]) == 0
    assert main(["src/repro/analysis"]) == 1   # no hooks found anywhere
