"""Training substrates: optimizer, compression+EF, data pipeline determinism,
checkpoint atomicity/resume, watchdog exit path."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.distributed.collectives import compress_decompress, init_ef
from repro.train.optim import (AdamWState, OptConfig, apply_updates,
                               init_opt_state, schedule)


def test_adamw_converges_quadratic():
    opt_cfg = OptConfig(lr=0.05, warmup_steps=5, total_steps=200,
                        weight_decay=0.0, clip_norm=0.0)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 8))}
    state = init_opt_state(params, opt_cfg)
    loss_g = jax.jit(jax.value_and_grad(
        lambda p: jnp.mean((p["w"] - target) ** 2)))
    l0 = None
    for _ in range(200):
        loss, g = loss_g(params)
        l0 = l0 or float(loss)
        params, state = apply_updates(params, g, state, opt_cfg)
    assert float(loss) < 1e-3 * l0


def test_compressed_training_still_converges():
    opt_cfg = OptConfig(lr=0.05, warmup_steps=5, total_steps=300,
                        weight_decay=0.0, clip_norm=0.0, compression="int8")
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 8))}
    state = init_opt_state(params, opt_cfg)
    loss_g = jax.jit(jax.value_and_grad(
        lambda p: jnp.mean((p["w"] - target) ** 2)))
    for _ in range(300):
        loss, g = loss_g(params)
        params, state = apply_updates(params, g, state, opt_cfg)
    assert float(loss) < 1e-2


def test_error_feedback_invariant():
    params = {"w": jnp.zeros((16,))}
    ef = init_ef(params)
    rng = np.random.default_rng(0)
    tot_g = jnp.zeros((16,))
    tot_e = jnp.zeros((16,))
    for _ in range(40):
        g = {"w": jnp.asarray(rng.normal(size=16), jnp.float32)}
        eff, ef = compress_decompress(g, ef, method="topk", topk_frac=0.2)
        tot_g = tot_g + g["w"]
        tot_e = tot_e + eff["w"]
    # EF: accumulated effective grad + residual error == accumulated true grad
    np.testing.assert_allclose(np.asarray(tot_g - tot_e),
                               np.asarray(ef["w"].error), atol=1e-4)


def test_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(schedule(jnp.asarray(s), cfg)) for s in range(100)]
    assert lrs[0] < 0.2 and abs(max(lrs) - 1.0) < 0.01
    assert lrs[-1] < 0.2 and lrs[-1] >= 0.09


def test_data_determinism_and_seek():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=4)
    a, b = SyntheticLM(cfg), SyntheticLM(cfg)
    for _ in range(3):
        a.next_batch()
    b.seek(3)
    np.testing.assert_array_equal(a.next_batch()["tokens"],
                                  b.next_batch()["tokens"])


def test_data_host_sharding_partitions_batch():
    full = SyntheticLM(DataConfig(vocab_size=97, seq_len=8, global_batch=4))
    h0 = SyntheticLM(DataConfig(vocab_size=97, seq_len=8, global_batch=4,
                                host_index=0, host_count=2))
    h1 = SyntheticLM(DataConfig(vocab_size=97, seq_len=8, global_batch=4,
                                host_index=1, host_count=2))
    f = full.next_batch()["tokens"]
    np.testing.assert_array_equal(f[:2], h0.next_batch()["tokens"])
    np.testing.assert_array_equal(f[2:], h1.next_batch()["tokens"])


def test_prefetcher_delivers_in_order():
    src = SyntheticLM(DataConfig(vocab_size=50, seq_len=4, global_batch=2))
    ref = SyntheticLM(DataConfig(vocab_size=50, seq_len=4, global_batch=2))
    pf = Prefetcher(src, depth=2)
    try:
        for _ in range(5):
            np.testing.assert_array_equal(pf.next_batch()["tokens"],
                                          ref.next_batch()["tokens"])
    finally:
        pf.close()


def test_checkpoint_atomic_keepn_resume():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep_n=2)
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        for step in (1, 2, 3):
            mgr.save(step, tree, extra={"step": step})
        assert mgr.all_steps() == [2, 3]           # keep-N GC
        restored, extra = mgr.restore(3, tree)
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert extra["step"] == 3
        # no tmp dirs left behind (atomicity)
        assert not [f for f in os.listdir(d) if f.startswith(".tmp")]


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"a": jnp.ones((2, 2))})
        with pytest.raises(ValueError):
            mgr.restore(1, {"a": jnp.ones((3, 3))})


def test_train_driver_end_to_end_with_resume(tmp_path):
    """launch/train.py fault-tolerance loop: run, kill at a checkpoint,
    resume, and verify the loss trajectory continues identically."""
    from repro.launch.train import main as train_main
    ck = str(tmp_path / "ck")
    rc = train_main(["--arch", "tinyllama-1.1b", "--reduced", "--steps", "6",
                     "--batch", "2", "--seq", "32", "--ckpt-dir", ck,
                     "--ckpt-every", "3", "--log-every", "100"])
    assert rc == 0
    mgr = CheckpointManager(ck)
    assert 6 in mgr.all_steps()
    rc = train_main(["--arch", "tinyllama-1.1b", "--reduced", "--steps", "8",
                     "--batch", "2", "--seq", "32", "--ckpt-dir", ck,
                     "--resume", "--log-every", "100"])
    assert rc == 0
    assert 8 in CheckpointManager(ck).all_steps()
