"""Telemetry subsystem gates (docs/telemetry.md).

Three contracts, each load-bearing:

* **engine parity** — the interpreter and the compiled vector engine must
  leave *identical* telemetry: per-node fire timelines, per-cycle stall
  attribution (including through the vector engine's event-skip), and
  per-link words/waits/occupancy.  A drift here means one engine's stall
  story is fiction.
* **exactness** — ``Telemetry.totals()`` must equal the ``SimResult``
  aggregates bit-for-bit, and every node must have exactly one state per
  observed cycle (states partition ``cycles * n_nodes``).
* **harmlessness** — attaching a sink must not change the simulation, and
  the exported Perfetto JSON must validate (schema + monotonic
  timestamps).

Plus the satellites: SimDeadlock stall-attribution diagnostics, tuner
search spans, EvalCache.stats() replay hits, benchmarks/run.py per-case
error isolation + nonzero exit, and the bench_diff comparator.
"""
import json

import numpy as np
import pytest

from repro.core import CGRA, SimDeadlock, map_1d, map_2d, simulate
from repro.core.spec import StencilSpec, heat_2d, paper_stencil_2d
from repro.fabric import FabricTopology, place, route
from repro.program import lower, two_stage_heat
from repro.telemetry import (STALL_CAUSES, STATE_NAMES, Telemetry,
                             trace_events, validate_trace, write_trace)

ENGINES = ("interp", "vector")


def _coeffs(rng, r):
    return tuple((rng.normal(size=2 * r + 1) / (2 * r + 1)).tolist())


def run_both_tel(mk_plan, x, routed=False, **kw):
    """One fresh plan + fresh Telemetry sink per engine."""
    out = []
    for engine in ENGINES:
        plan = mk_plan()
        fab = None
        if routed:
            fab = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
        tel = Telemetry()
        res = simulate(plan, x, CGRA, fabric=fab, engine=engine,
                       telemetry=tel, **kw)
        out.append((plan, res, tel))
    return out


def assert_tel_identical(case):
    """The parity gate: both engines' sinks hold the same telemetry."""
    (_, ra, ta), (_, rb, tb) = case
    assert np.array_equal(ta.fires_total, tb.fires_total)
    assert np.array_equal(ta.first_fire, tb.first_fire)
    assert np.array_equal(ta.last_fire, tb.last_fire)
    assert np.array_equal(ta.stall_totals, tb.stall_totals)
    assert ta.intervals == tb.intervals          # full per-node timelines
    assert np.array_equal(ta.link_words, tb.link_words)
    assert np.array_equal(ta.link_stalls, tb.link_stalls)
    assert ta.link_occ == tb.link_occ
    assert ta.totals() == tb.totals()
    for tel, res in ((ta, ra), (tb, rb)):
        assert_tel_exact(tel, res)


def assert_tel_exact(tel, res):
    """The exactness gate: counters sum to the simulator's own stats."""
    t = tel.totals()
    assert t["cycles"] == res.cycles
    assert t["fires"] == res.fires
    assert (t["loads"], t["stores"], t["flops"]) == \
        (res.loads, res.stores, res.flops)
    if res.fabric is not None:
        assert t["token_hops"] == res.fabric["token_hops"]
        assert t["stall_cycles"] == res.fabric["stall_cycles"]
    else:
        assert t["token_hops"] == t["stall_cycles"] == 0
    # exclusive states partition every observed (node, cycle) slot
    observed = int(tel.fires_total.sum() + tel.stall_totals.sum())
    assert observed <= res.cycles * tel.n_nodes
    per_node = np.zeros(tel.n_nodes, dtype=np.int64)
    for nid, _s, t0, t1 in tel.intervals:
        assert 1 <= t0 < t1 <= res.cycles + 1
        per_node[nid] += t1 - t0
    assert (per_node == res.cycles).all()        # intervals tile every cycle


@pytest.mark.parametrize("routed", [False, True])
def test_1d_telemetry_parity(rng, routed):
    spec = StencilSpec((240,), (2,), (_coeffs(rng, 2),), dtype="float64")
    assert_tel_identical(run_both_tel(lambda: map_1d(spec, workers=4),
                                      rng.normal(size=240), routed=routed))


@pytest.mark.parametrize("routed", [False, True])
def test_2d_telemetry_parity(rng, routed):
    spec = paper_stencil_2d(ny=30, nx=48, r=12)
    assert_tel_identical(run_both_tel(lambda: map_2d(spec, workers=8),
                                      rng.normal(size=(30, 48)),
                                      routed=routed))


@pytest.mark.parametrize("routed", [False, True])
def test_program_telemetry_parity(routed):
    prog = two_stage_heat(24, 32)
    rng = np.random.default_rng(1)
    ins = {f: rng.normal(size=prog.grid_shape) for f in prog.in_fields}
    x = lower(prog, workers=4).pack_inputs(ins)
    assert_tel_identical(run_both_tel(lambda: lower(prog, workers=4), x,
                                      routed=routed))


def test_bounded_queue_telemetry_parity(rng):
    """auto_capacity exercises the output_blocked attribution path."""
    spec = heat_2d(18, 24, dtype="float64")
    case = run_both_tel(lambda: map_2d(spec, workers=3, auto_capacity=True),
                        rng.normal(size=(18, 24)))
    assert_tel_identical(case)
    tel = case[0][2]
    i_blocked = STALL_CAUSES.index("output_blocked")
    assert tel.stall_totals[:, i_blocked].sum() > 0


def test_routed_telemetry_has_network_attribution(rng):
    spec = paper_stencil_2d(ny=30, nx=48, r=12)
    case = run_both_tel(lambda: map_2d(spec, workers=8),
                        rng.normal(size=(30, 48)), routed=True)
    tel, res = case[1][2], case[1][1]
    i_net = STALL_CAUSES.index("network_contention")
    assert tel.stall_totals[:, i_net].sum() > 0
    assert tel.link_words.sum() == res.fabric["token_hops"]
    assert tel.link_stalls.sum() == res.fabric["stall_cycles"]
    assert len(tel.link_occ) > 0                 # per-slot occupancy captured


def test_fire_cycles_timeline(rng):
    spec = StencilSpec((120,), (1,), (_coeffs(rng, 1),), dtype="float64")
    (plan, res, tel), _ = run_both_tel(lambda: map_1d(spec, workers=2),
                                       rng.normal(size=120))
    for node in plan.dfg.nodes:
        runs = tel.fire_cycles(node.nid)
        assert sum(t1 - t0 for t0, t1 in runs) == node.fires
        assert runs == sorted(runs)


def test_telemetry_does_not_perturb(rng):
    spec = paper_stencil_2d(ny=30, nx=48, r=12)
    x = rng.normal(size=(30, 48))
    for routed in (False, True):
        mk = lambda: map_2d(spec, workers=8)            # noqa: E731
        plans = [mk(), mk()]
        fabs = [route(place(p, FabricTopology.mesh(16, 16), seed=0))
                if routed else None for p in plans]
        bare = simulate(plans[0], x, CGRA, fabric=fabs[0], engine="vector")
        inst = simulate(plans[1], x, CGRA, fabric=fabs[1], engine="vector",
                        telemetry=Telemetry())
        assert bare.cycles == inst.cycles
        assert bare.fires == inst.fires
        assert bare.output.tobytes() == inst.output.tobytes()
        if routed:
            assert bare.fabric["token_hops"] == inst.fabric["token_hops"]
            assert bare.fabric["stall_cycles"] == inst.fabric["stall_cycles"]


# ---------------------------------------------------------------------------
# trace export
# ---------------------------------------------------------------------------
def test_trace_export_validates(rng, tmp_path):
    spec = paper_stencil_2d(ny=30, nx=48, r=12)
    plan = map_2d(spec, workers=8)
    fab = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
    tel = Telemetry()
    simulate(plan, rng.normal(size=(30, 48)), CGRA, fabric=fab,
             engine="vector", telemetry=tel)
    path = tmp_path / "run.trace.json"
    obj = write_trace(tel, str(path))
    n = validate_trace(obj)
    assert n > 0
    reread = json.loads(path.read_text())
    assert validate_trace(reread) == n
    evs = reread["traceEvents"]
    # metadata first, then globally monotonic timestamps
    body = [e for e in evs if e["ph"] != "M"]
    ts = [e["ts"] for e in body]
    assert ts == sorted(ts)
    assert all(e["ph"] in ("M", "X", "C", "i") for e in evs)
    groups = {e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "process_name"}
    assert any(g.startswith("PE(") for g in groups)    # one group per PE
    threads = {e["args"]["name"] for e in evs
               if e["ph"] == "M" and e["name"] == "thread_name"}
    assert threads                                     # one track per node


def test_validate_trace_rejects_garbage():
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [{"ph": "X"}]})   # missing keys
    with pytest.raises(ValueError, match="not monotonic"):
        validate_trace({"traceEvents": [
            {"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": 5, "dur": 1,
             "cat": "c"},
            {"ph": "X", "name": "b", "pid": 1, "tid": 1, "ts": 4, "dur": 1,
             "cat": "c"}]})                              # non-monotonic


def test_trace_routed_program_roundtrip(tmp_path):
    """Satellite: a routed *program-DAG* run (remux/imux nodes, contended
    links) exports a trace that round-trips the validator from disk."""
    prog = two_stage_heat(24, 32)
    rng = np.random.default_rng(2)
    ins = {f: rng.normal(size=prog.grid_shape) for f in prog.in_fields}
    plan = lower(prog, workers=4)
    fab = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
    tel = Telemetry()
    simulate(plan, plan.pack_inputs(ins), CGRA, fabric=fab,
             engine="vector", telemetry=tel)
    path = tmp_path / "prog.trace.json"
    obj = write_trace(tel, str(path))
    n = validate_trace(obj)
    assert n > 0
    assert validate_trace(json.loads(path.read_text())) == n
    # the link counter tracks declare their inventory and tag samples
    evs = obj["traceEvents"]
    decl = [e for e in evs if e["ph"] == "M"
            and "links" in e.get("args", {})]
    assert decl and decl[0]["args"]["links"] == len(tel.link_names)
    c_lids = {e["args"]["lid"] for e in evs if e["ph"] == "C"}
    assert c_lids and all(0 <= lid < len(tel.link_names) for lid in c_lids)


def test_validate_trace_overlapping_exclusive_intervals():
    """fire/stall slices on one node track are exclusive by contract;
    tuner spans (other cats) may legitimately overlap after rounding."""
    overlap = [
        {"ph": "X", "name": "fire", "pid": 10, "tid": 0, "ts": 1, "dur": 5,
         "cat": "fire"},
        {"ph": "X", "name": "input_starved", "pid": 10, "tid": 0, "ts": 3,
         "dur": 2, "cat": "stall"}]
    with pytest.raises(ValueError, match="overlapping exclusive intervals"):
        validate_trace({"traceEvents": overlap})
    # same shape on different tracks: fine
    ok = [dict(overlap[0]), {**overlap[1], "tid": 1}]
    assert validate_trace({"traceEvents": ok}) == 2
    # same shape but span-cat events: fine (wall-clock spans can overlap)
    spans = [{**overlap[0], "cat": "tuner"}, {**overlap[1], "cat": "tuner"}]
    assert validate_trace({"traceEvents": spans}) == 2


def test_validate_trace_unknown_link_id():
    decl = {"ph": "M", "pid": 2, "ts": 0, "name": "process_name",
            "args": {"name": "links (contended)", "links": 3}}
    sample = {"ph": "C", "pid": 2, "ts": 1, "name": "link x",
              "args": {"words": 1, "lid": 7}}
    with pytest.raises(ValueError, match="unknown link id 7"):
        validate_trace({"traceEvents": [decl, sample]})
    # a sample with no inventory declared at all is just as invalid
    with pytest.raises(ValueError, match="unknown link id 7"):
        validate_trace({"traceEvents": [sample]})
    assert validate_trace({"traceEvents": [
        decl, {**sample, "args": {"words": 1, "lid": 2}}]}) == 1


def test_link_book_rejects_unknown_lid(rng):
    """The probe itself names the error when an engine books against a
    link outside the attached fabric's inventory."""
    spec = paper_stencil_2d(ny=30, nx=48, r=12)
    plan = map_2d(spec, workers=8)
    fab = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
    tel = Telemetry()
    simulate(plan, rng.normal(size=(30, 48)), CGRA, fabric=fab,
             engine="vector", telemetry=tel)
    with pytest.raises(ValueError, match="unknown link id"):
        tel.link_book(len(tel.link_names) + 5, slot=1, waited=0)


# ---------------------------------------------------------------------------
# failure diagnostics (satellite: deadlock stall attribution)
# ---------------------------------------------------------------------------
def test_deadlock_stall_attribution(rng):
    spec = heat_2d(18, 24, dtype="float64")
    x = rng.normal(size=(18, 24))
    msgs = []
    for engine in ENGINES:
        plan = map_2d(spec, workers=3, queue_capacity=1)
        tel = Telemetry()
        with pytest.raises(SimDeadlock) as ei:
            simulate(plan, x, CGRA, max_cycles=200_000, engine=engine,
                     telemetry=tel)
        e = ei.value
        assert e.stall_summary is not None
        assert e.stall_summary["window_cycles"] == 64
        assert sum(e.stall_summary["cause_counts"].values()) > 0
        assert e.stall_summary["nodes"]          # names the blocked nodes
        assert "stall attribution (last 64 cycles)" in str(e)
        assert not e.timed_out
        msgs.append(str(e))
    assert msgs[0] == msgs[1]                    # engine-parity diagnostic


def test_deadlock_summary_without_sink(rng):
    """No telemetry attached: engines still attribute the final cycle."""
    spec = heat_2d(18, 24, dtype="float64")
    x = rng.normal(size=(18, 24))
    msgs = []
    for engine in ENGINES:
        plan = map_2d(spec, workers=3, queue_capacity=1)
        with pytest.raises(SimDeadlock) as ei:
            simulate(plan, x, CGRA, max_cycles=200_000, engine=engine)
        assert "stall attribution (final cycle)" in str(ei.value)
        assert ei.value.stall_summary is not None
        msgs.append(str(ei.value))
    assert msgs[0] == msgs[1]


def test_timeout_stall_attribution(rng):
    spec = StencilSpec((120,), (1,), ((0.25, 0.5, 0.25),), dtype="float64")
    x = rng.normal(size=120)
    for engine in ENGINES:
        plan = map_1d(spec, workers=3)
        with pytest.raises(SimDeadlock, match="exceeded max_cycles=10") as ei:
            simulate(plan, x, CGRA, max_cycles=10, engine=engine,
                     telemetry=Telemetry())
        assert ei.value.timed_out
        assert ei.value.stall_summary is not None


# ---------------------------------------------------------------------------
# tuner spans + cache stats (satellites)
# ---------------------------------------------------------------------------
def _tiny_search(tmp_path, tel=None):
    from repro.explore import Budget, SpaceOptions, explore
    return explore(
        heat_2d(18, 24, dtype="float64"), CGRA,
        options=SpaceOptions(workers=(2, 3), capacities=("auto",)),
        budget=Budget(), cache=str(tmp_path / "cache.json"),
        telemetry=tel)


def test_explore_records_spans(tmp_path):
    tel = Telemetry()
    res = _tiny_search(tmp_path, tel)
    evals = [s for s in tel.spans if s["cat"] == "tuner"
             and s["track"].startswith("search/")
             and s["track"] != "search/prune"]
    assert len(evals) == res.stats["n_measured"] > 0
    for s in evals:
        assert s["args"]["outcome"] == "measured"
        assert s["args"]["cycles"] > 0
        assert s["args"]["key"] and s["args"]["config"]
        assert s["dur"] >= 0 and s["t0"] >= 0
    assert validate_trace(trace_events(tel)) >= len(evals)

    # second search, same cache: every eval replays as a cache hit
    tel2 = Telemetry()
    _tiny_search(tmp_path, tel2)
    outcomes = {s["args"]["outcome"] for s in tel2.spans
                if s["cat"] == "tuner" and s["track"] != "search/prune"}
    assert outcomes == {"cached"}


def test_eval_cache_stats_replay(tmp_path):
    """Regression gate: a rerun over a warm cache must report hits > 0."""
    res1 = _tiny_search(tmp_path)
    cs1 = res1.stats["cache"]
    assert cs1["hits"] == 0 and cs1["misses"] > 0
    assert cs1["entries"] == cs1["misses"]

    res2 = _tiny_search(tmp_path)
    cs2 = res2.stats["cache"]
    assert cs2["hits"] > 0 and cs2["misses"] == 0
    assert res1.best().cycles == res2.best().cycles


def test_eval_cache_stats_counts_failure_replay(tmp_path):
    from repro.explore import EvalCache
    path = str(tmp_path / "c.json")
    c = EvalCache(path)
    c.put("good", {"cycles": 5})
    c.put("bad", {"failed": "deadlock: x"})
    c.save()
    c2 = EvalCache(path)
    assert c2.get("good") and c2.get("bad") and c2.get("gone") is None
    assert c2.stats() == {"hits": 2, "misses": 1, "failures_replayed": 1,
                          "entries": 2}


# ---------------------------------------------------------------------------
# benchmarks/run.py error isolation + exit status (satellite)
# ---------------------------------------------------------------------------
def test_run_py_isolates_case_failures(tmp_path, monkeypatch):
    from benchmarks import run as bench_run

    calls = []

    def boom(cases, name, *a, **kw):
        calls.append(name)
        if name == "2d":
            raise RuntimeError("injected 2d failure")
        cases[name] = {"cycles_ideal": 1}

    monkeypatch.setattr(bench_run, "_artifact_case", boom)
    cases, errors = bench_run.artifact_cases(True, "vector")
    assert calls == ["1d", "2d", "3d"]           # later cases still ran
    assert set(cases) == {"1d", "3d"}
    assert list(errors) == ["2d"]
    assert "injected 2d failure" in errors["2d"]

    # the writer persists the partial artifact, then propagates the failure
    path = tmp_path / "a.json"
    with pytest.raises(RuntimeError, match="1 case\\(s\\) failed"):
        bench_run._write_snapshot(str(path), "bench_pr2/v1", True, None,
                                  (cases, errors), engine="vector")
    art = json.loads(path.read_text())
    assert set(art["cases"]) == {"1d", "3d"}
    assert "injected 2d failure" in art["errors"]["2d"]

    # and main() turns it into a nonzero exit
    with pytest.raises(SystemExit) as ei:
        bench_run.main(["--artifact", str(tmp_path / "b.json"),
                        "--smoke", "--artifact-only", "--engine", "vector"])
    assert ei.value.code == 1
    assert "errors" in json.loads((tmp_path / "b.json").read_text())


def test_run_py_all_good_exits_zero(tmp_path, monkeypatch):
    from benchmarks import run as bench_run

    def ok(cases, name, *a, **kw):
        cases[name] = {"cycles_ideal": 1}

    monkeypatch.setattr(bench_run, "_artifact_case", ok)
    bench_run.main(["--artifact", str(tmp_path / "a.json"),
                    "--smoke", "--artifact-only"])   # no SystemExit
    art = json.loads((tmp_path / "a.json").read_text())
    assert set(art["cases"]) == {"1d", "2d", "3d"}
    assert "errors" not in art


# ---------------------------------------------------------------------------
# bench_diff (satellite)
# ---------------------------------------------------------------------------
def _pr4_case(**over):
    base = {"cycles_ideal": 189, "cycles_routed": 642,
            "pe_instructions": 833, "stall_cycles": 716046,
            "token_hops": 9000, "vector_wall_s": 0.30}
    base.update(over)
    return base


def _art(tmp_path, name, cases, schema="bench_pr4/v1", config="smoke",
         **extra):
    p = tmp_path / name
    p.write_text(json.dumps({"schema": schema, "config": config,
                             "cases": cases, **extra}))
    return str(p)


def test_bench_diff(tmp_path, capsys):
    from benchmarks.bench_diff import main as bd
    base = {"2d": _pr4_case()}
    a = _art(tmp_path, "a.json", base)
    assert bd([a, a]) == 0

    # integer counters are exact; float walls get the tolerance band
    drift = _art(tmp_path, "b.json", {"2d": _pr4_case(cycles_routed=643)})
    assert bd([a, drift]) == 1
    out = capsys.readouterr().out
    assert "deterministic counter changed 642 -> 643" in out

    wall_ok = _art(tmp_path, "c.json",
                   {"2d": _pr4_case(vector_wall_s=0.36)})
    assert bd([a, wall_ok]) == 0
    wall_bad = _art(tmp_path, "d.json",
                    {"2d": _pr4_case(vector_wall_s=3.0)})
    assert bd([a, wall_bad]) == 1

    # config mismatch (smoke vs full) is never comparable
    full = _art(tmp_path, "e.json", base, config="full")
    assert bd([a, full]) == 1

    # partial artifacts (errors key) fail the gate
    part = _art(tmp_path, "f.json", base, errors={"3d": "boom"})
    assert bd([a, part]) == 1


def test_bench_diff_intersection_and_allowlist(tmp_path, capsys):
    """Keys on one side only warn (schema growth); required counters
    missing on either side fail; volatile pr5 structure is skipped."""
    from benchmarks.bench_diff import main as bd
    a = _art(tmp_path, "a.json", {"2d": _pr4_case()})
    grown = _art(tmp_path, "g.json",
                 {"2d": _pr4_case(bottleneck="network-bound",
                                  stall_breakdown={"input_starved": 3})})
    assert bd([a, grown]) == 0               # new keys: warn, not fail
    out = capsys.readouterr().out
    assert "only in NEW" in out
    assert bd([a, grown, "--strict"]) == 1   # --strict promotes to fail

    # losing a required counter is a broken refresh, not schema evolution
    lost_case = _pr4_case()
    del lost_case["cycles_routed"]
    lost = _art(tmp_path, "l.json", {"2d": lost_case})
    assert bd([a, lost]) == 1
    out = capsys.readouterr().out
    assert "required counter missing in NEW" in out

    # pr5-style artifacts: nested dotted required keys; front/stats are
    # volatile and must not fail even when completely different
    def pr5_case(cycles=1618, front=()):
        return {"analytic": {"cycles": 1700, "pes": 60, "cached": False},
                "best": {"cycles": cycles, "pes": 51,
                         "max_channel_load": 9},
                "front": list(front), "n_points": len(front),
                "stats": {"wall_s": 0.8, "n_measured": 8}}
    p5a = _art(tmp_path, "p5a.json", {"hdiff": pr5_case(front=[{"a": 1}])},
               schema="bench_pr5/v1")
    p5b = _art(tmp_path, "p5b.json", {"hdiff": pr5_case(front=[{"b": 2}])},
               schema="bench_pr5/v1")
    assert bd([p5a, p5b]) == 0
    p5worse = _art(tmp_path, "p5w.json", {"hdiff": pr5_case(cycles=1800)},
                   schema="bench_pr5/v1")
    assert bd([p5a, p5worse]) == 1
    out = capsys.readouterr().out
    assert "best.cycles" in out


def test_bench_diff_trend_gate(tmp_path, capsys):
    """Trend mode: fail only when worse than every one of the last N;
    blessed regressions warn instead of re-firing forever."""
    from benchmarks.bench_diff import main as bd
    from repro.telemetry.metrics import append_history, case_records

    hist = str(tmp_path / "hist.jsonl")

    def art_for(cycles):
        return {"schema": "bench_pr4/v1", "config": "smoke",
                "cases": {"2d": _pr4_case(cycles_routed=cycles)}}

    # empty history: first run seeds the trend (warn, exit 0)
    new = _art(tmp_path, "n.json", {"2d": _pr4_case(cycles_routed=650)})
    assert bd([new, "--trend", "3", "--history", hist]) == 0
    assert "seeds the trend" in capsys.readouterr().out

    for c in (642, 650, 645):
        append_history(hist, case_records(art_for(c), ts=1000.0))

    # equal to the most recent -> clean pass
    ok = _art(tmp_path, "ok.json", {"2d": _pr4_case(cycles_routed=645)})
    assert bd([ok, "--trend", "3", "--history", hist]) == 0
    # within the envelope (650 was blessed earlier) -> warn, pass
    within = _art(tmp_path, "w.json", {"2d": _pr4_case(cycles_routed=648)})
    assert bd([within, "--trend", "3", "--history", hist]) == 0
    assert "within envelope" in capsys.readouterr().out
    # injected regression: worse than max(last 3) -> fail
    bad = _art(tmp_path, "bad.json", {"2d": _pr4_case(cycles_routed=651)})
    assert bd([bad, "--trend", "3", "--history", hist]) == 1
    assert "regression 651 > max(last 3) = 650" in capsys.readouterr().out
    # the window is honest: last 3 of a longer history
    append_history(hist, case_records(art_for(700), ts=1001.0))
    assert bd([bad, "--trend", "3", "--history", hist]) == 0


def test_history_unknown_record_shapes_skip_cleanly(tmp_path, capsys,
                                                    monkeypatch):
    """Satellite: history records with an unknown version or a partial
    payload (e.g. a throughput record without ``counters``) must skip with
    a named warning in the observatory report and the overhead gate —
    never a KeyError/TypeError."""
    import benchmarks.observatory as obs
    import benchmarks.overhead_check as oc
    from repro.telemetry.metrics import (append_history, case_records,
                                         record_problem, trend_values)

    hist = str(tmp_path / "hist.jsonl")
    art = {"schema": "overhead/v1", "config": "smoke",
           "cases": {"2d_routed_vector": {"cycles": 716, "wall_s": 0.31,
                                          "engine": "vector", "repeats": 2}}}
    append_history(hist, case_records(art, source="overhead_check.py"))
    with open(hist, "a") as f:
        for bad in (
                {"v": 99, "schema": "overhead/v1", "config": "smoke",
                 "case": "2d_routed_vector", "counters": {},
                 "walls": {"wall_s": 9.9}},          # future version
                {"v": 1, "schema": "bench_pr9x/v0", "config": "smoke",
                 "case": "sweep",
                 "throughput": {"cfg_per_s": 100.0}},  # payload-less
                {"v": 1, "schema": "overhead/v1", "config": "smoke",
                 "case": "2d_routed_vector", "counters": None,
                 "walls": None}):                    # non-mapping payload
            f.write(json.dumps(bad) + "\n")

    assert record_problem({"v": 1, "counters": {}, "walls": {}}) is None
    assert record_problem({"v": 99}) == "unknown history version 99"
    assert record_problem({"v": 1}) == "no counters/walls payload"
    assert record_problem({"v": 1, "counters": None}) \
        == "'counters' is not a mapping"
    # trend_values itself tolerates non-mapping payloads (version filtering
    # is the consumers' job, via record_problem)
    from repro.telemetry.metrics import load_history
    assert trend_values(load_history(hist), "wall_s",
                        kind="walls") == [0.31, 9.9]

    assert obs.main(["report", "--history", hist]) == 0
    out = capsys.readouterr().out
    assert "WARNING" in out and "unknown history version 99" in out
    assert "no counters/walls payload" in out
    assert "'counters' is not a mapping" in out

    monkeypatch.setattr(oc, "measure", lambda repeats: (0.30, 716))
    assert oc.main(["--history", hist, "--no-append"]) == 0
    out = capsys.readouterr().out
    assert "WARNING" in out and "skipped 2 history record(s)" in out
    # the valid record still anchors the envelope (median of one = 0.31)
    assert "median of last 1 = 0.3100" in out


def test_stall_summary_and_report_crash_proofing(rng):
    """Satellite: empty/window-less summaries and unattached sinks render
    stubs instead of raising — these run on failure/cleanup codepaths."""
    from repro.telemetry import format_stall_summary, render_report
    from repro.telemetry.report import bottleneck_table, utilization_grid

    assert format_stall_summary(None) == ""
    assert format_stall_summary({}) == ""
    empty = {"window_cycles": None,
             "cause_counts": {c: 0 for c in STALL_CAUSES}, "nodes": []}
    assert "no stalls recorded" in format_stall_summary(empty)
    windowed = {"window_cycles": 64,
                "cause_counts": {c: 0 for c in STALL_CAUSES}, "nodes": []}
    assert "no stalls recorded" in format_stall_summary(windowed)
    assert "last 64 cycles" in format_stall_summary(windowed)

    tel = Telemetry()                          # never attached to a run
    assert tel.stall_summary()["window_cycles"] is None
    assert tel.stall_summary()["nodes"] == []
    assert "no run attached" in utilization_grid(tel)
    assert "no stalls recorded" in bottleneck_table(tel)
    assert "no run attached" in render_report(tel)

    # an attached run with zero stalls still renders a stub row
    spec = StencilSpec((60,), (1,), ((0.25, 0.5, 0.25),), dtype="float64")
    plan = map_1d(spec, workers=1)
    tel2 = Telemetry()
    simulate(plan, rng.normal(size=60), CGRA, engine="vector",
             telemetry=tel2)
    if not tel2.stall_totals.sum():
        assert "(no stalls recorded)" in bottleneck_table(tel2)


def test_state_names_cover_constants():
    from repro.telemetry import (ST_FIRED, ST_INACTIVE, ST_INPUT_STARVED,
                                 ST_MEM_ARB, ST_NET_WAIT, ST_OUTPUT_BLOCKED)
    assert len(STATE_NAMES) == 6
    assert STATE_NAMES[ST_INACTIVE] == "inactive"
    assert STATE_NAMES[ST_FIRED] == "fire"
    assert STATE_NAMES[ST_INPUT_STARVED] == "input_starved"
    assert STATE_NAMES[ST_OUTPUT_BLOCKED] == "output_blocked"
    assert STATE_NAMES[ST_MEM_ARB] == "memory_arbitration"
    assert STATE_NAMES[ST_NET_WAIT] == "network_contention"
    assert STALL_CAUSES == STATE_NAMES[2:]
