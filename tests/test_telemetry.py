"""Telemetry subsystem gates (docs/telemetry.md).

Three contracts, each load-bearing:

* **engine parity** — the interpreter and the compiled vector engine must
  leave *identical* telemetry: per-node fire timelines, per-cycle stall
  attribution (including through the vector engine's event-skip), and
  per-link words/waits/occupancy.  A drift here means one engine's stall
  story is fiction.
* **exactness** — ``Telemetry.totals()`` must equal the ``SimResult``
  aggregates bit-for-bit, and every node must have exactly one state per
  observed cycle (states partition ``cycles * n_nodes``).
* **harmlessness** — attaching a sink must not change the simulation, and
  the exported Perfetto JSON must validate (schema + monotonic
  timestamps).

Plus the satellites: SimDeadlock stall-attribution diagnostics, tuner
search spans, EvalCache.stats() replay hits, benchmarks/run.py per-case
error isolation + nonzero exit, and the bench_diff comparator.
"""
import json

import numpy as np
import pytest

from repro.core import CGRA, SimDeadlock, map_1d, map_2d, simulate
from repro.core.spec import StencilSpec, heat_2d, paper_stencil_2d
from repro.fabric import FabricTopology, place, route
from repro.program import lower, two_stage_heat
from repro.telemetry import (STALL_CAUSES, STATE_NAMES, Telemetry,
                             trace_events, validate_trace, write_trace)

ENGINES = ("interp", "vector")


def _coeffs(rng, r):
    return tuple((rng.normal(size=2 * r + 1) / (2 * r + 1)).tolist())


def run_both_tel(mk_plan, x, routed=False, **kw):
    """One fresh plan + fresh Telemetry sink per engine."""
    out = []
    for engine in ENGINES:
        plan = mk_plan()
        fab = None
        if routed:
            fab = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
        tel = Telemetry()
        res = simulate(plan, x, CGRA, fabric=fab, engine=engine,
                       telemetry=tel, **kw)
        out.append((plan, res, tel))
    return out


def assert_tel_identical(case):
    """The parity gate: both engines' sinks hold the same telemetry."""
    (_, ra, ta), (_, rb, tb) = case
    assert np.array_equal(ta.fires_total, tb.fires_total)
    assert np.array_equal(ta.stall_totals, tb.stall_totals)
    assert ta.intervals == tb.intervals          # full per-node timelines
    assert np.array_equal(ta.link_words, tb.link_words)
    assert np.array_equal(ta.link_stalls, tb.link_stalls)
    assert ta.link_occ == tb.link_occ
    assert ta.totals() == tb.totals()
    for tel, res in ((ta, ra), (tb, rb)):
        assert_tel_exact(tel, res)


def assert_tel_exact(tel, res):
    """The exactness gate: counters sum to the simulator's own stats."""
    t = tel.totals()
    assert t["cycles"] == res.cycles
    assert t["fires"] == res.fires
    assert (t["loads"], t["stores"], t["flops"]) == \
        (res.loads, res.stores, res.flops)
    if res.fabric is not None:
        assert t["token_hops"] == res.fabric["token_hops"]
        assert t["stall_cycles"] == res.fabric["stall_cycles"]
    else:
        assert t["token_hops"] == t["stall_cycles"] == 0
    # exclusive states partition every observed (node, cycle) slot
    observed = int(tel.fires_total.sum() + tel.stall_totals.sum())
    assert observed <= res.cycles * tel.n_nodes
    per_node = np.zeros(tel.n_nodes, dtype=np.int64)
    for nid, _s, t0, t1 in tel.intervals:
        assert 1 <= t0 < t1 <= res.cycles + 1
        per_node[nid] += t1 - t0
    assert (per_node == res.cycles).all()        # intervals tile every cycle


@pytest.mark.parametrize("routed", [False, True])
def test_1d_telemetry_parity(rng, routed):
    spec = StencilSpec((240,), (2,), (_coeffs(rng, 2),), dtype="float64")
    assert_tel_identical(run_both_tel(lambda: map_1d(spec, workers=4),
                                      rng.normal(size=240), routed=routed))


@pytest.mark.parametrize("routed", [False, True])
def test_2d_telemetry_parity(rng, routed):
    spec = paper_stencil_2d(ny=30, nx=48, r=12)
    assert_tel_identical(run_both_tel(lambda: map_2d(spec, workers=8),
                                      rng.normal(size=(30, 48)),
                                      routed=routed))


@pytest.mark.parametrize("routed", [False, True])
def test_program_telemetry_parity(routed):
    prog = two_stage_heat(24, 32)
    rng = np.random.default_rng(1)
    ins = {f: rng.normal(size=prog.grid_shape) for f in prog.in_fields}
    x = lower(prog, workers=4).pack_inputs(ins)
    assert_tel_identical(run_both_tel(lambda: lower(prog, workers=4), x,
                                      routed=routed))


def test_bounded_queue_telemetry_parity(rng):
    """auto_capacity exercises the output_blocked attribution path."""
    spec = heat_2d(18, 24, dtype="float64")
    case = run_both_tel(lambda: map_2d(spec, workers=3, auto_capacity=True),
                        rng.normal(size=(18, 24)))
    assert_tel_identical(case)
    tel = case[0][2]
    i_blocked = STALL_CAUSES.index("output_blocked")
    assert tel.stall_totals[:, i_blocked].sum() > 0


def test_routed_telemetry_has_network_attribution(rng):
    spec = paper_stencil_2d(ny=30, nx=48, r=12)
    case = run_both_tel(lambda: map_2d(spec, workers=8),
                        rng.normal(size=(30, 48)), routed=True)
    tel, res = case[1][2], case[1][1]
    i_net = STALL_CAUSES.index("network_contention")
    assert tel.stall_totals[:, i_net].sum() > 0
    assert tel.link_words.sum() == res.fabric["token_hops"]
    assert tel.link_stalls.sum() == res.fabric["stall_cycles"]
    assert len(tel.link_occ) > 0                 # per-slot occupancy captured


def test_fire_cycles_timeline(rng):
    spec = StencilSpec((120,), (1,), (_coeffs(rng, 1),), dtype="float64")
    (plan, res, tel), _ = run_both_tel(lambda: map_1d(spec, workers=2),
                                       rng.normal(size=120))
    for node in plan.dfg.nodes:
        runs = tel.fire_cycles(node.nid)
        assert sum(t1 - t0 for t0, t1 in runs) == node.fires
        assert runs == sorted(runs)


def test_telemetry_does_not_perturb(rng):
    spec = paper_stencil_2d(ny=30, nx=48, r=12)
    x = rng.normal(size=(30, 48))
    for routed in (False, True):
        mk = lambda: map_2d(spec, workers=8)            # noqa: E731
        plans = [mk(), mk()]
        fabs = [route(place(p, FabricTopology.mesh(16, 16), seed=0))
                if routed else None for p in plans]
        bare = simulate(plans[0], x, CGRA, fabric=fabs[0], engine="vector")
        inst = simulate(plans[1], x, CGRA, fabric=fabs[1], engine="vector",
                        telemetry=Telemetry())
        assert bare.cycles == inst.cycles
        assert bare.fires == inst.fires
        assert bare.output.tobytes() == inst.output.tobytes()
        if routed:
            assert bare.fabric["token_hops"] == inst.fabric["token_hops"]
            assert bare.fabric["stall_cycles"] == inst.fabric["stall_cycles"]


# ---------------------------------------------------------------------------
# trace export
# ---------------------------------------------------------------------------
def test_trace_export_validates(rng, tmp_path):
    spec = paper_stencil_2d(ny=30, nx=48, r=12)
    plan = map_2d(spec, workers=8)
    fab = route(place(plan, FabricTopology.mesh(16, 16), seed=0))
    tel = Telemetry()
    simulate(plan, rng.normal(size=(30, 48)), CGRA, fabric=fab,
             engine="vector", telemetry=tel)
    path = tmp_path / "run.trace.json"
    obj = write_trace(tel, str(path))
    n = validate_trace(obj)
    assert n > 0
    reread = json.loads(path.read_text())
    assert validate_trace(reread) == n
    evs = reread["traceEvents"]
    # metadata first, then globally monotonic timestamps
    body = [e for e in evs if e["ph"] != "M"]
    ts = [e["ts"] for e in body]
    assert ts == sorted(ts)
    assert all(e["ph"] in ("M", "X", "C", "i") for e in evs)
    groups = {e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "process_name"}
    assert any(g.startswith("PE(") for g in groups)    # one group per PE
    threads = {e["args"]["name"] for e in evs
               if e["ph"] == "M" and e["name"] == "thread_name"}
    assert threads                                     # one track per node


def test_validate_trace_rejects_garbage():
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [{"ph": "X"}]})   # missing keys
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [
            {"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": 5, "dur": 1,
             "cat": "c"},
            {"ph": "X", "name": "b", "pid": 1, "tid": 1, "ts": 4, "dur": 1,
             "cat": "c"}]})                              # non-monotonic


# ---------------------------------------------------------------------------
# failure diagnostics (satellite: deadlock stall attribution)
# ---------------------------------------------------------------------------
def test_deadlock_stall_attribution(rng):
    spec = heat_2d(18, 24, dtype="float64")
    x = rng.normal(size=(18, 24))
    msgs = []
    for engine in ENGINES:
        plan = map_2d(spec, workers=3, queue_capacity=1)
        tel = Telemetry()
        with pytest.raises(SimDeadlock) as ei:
            simulate(plan, x, CGRA, max_cycles=200_000, engine=engine,
                     telemetry=tel)
        e = ei.value
        assert e.stall_summary is not None
        assert e.stall_summary["window_cycles"] == 64
        assert sum(e.stall_summary["cause_counts"].values()) > 0
        assert e.stall_summary["nodes"]          # names the blocked nodes
        assert "stall attribution (last 64 cycles)" in str(e)
        assert not e.timed_out
        msgs.append(str(e))
    assert msgs[0] == msgs[1]                    # engine-parity diagnostic


def test_deadlock_summary_without_sink(rng):
    """No telemetry attached: engines still attribute the final cycle."""
    spec = heat_2d(18, 24, dtype="float64")
    x = rng.normal(size=(18, 24))
    msgs = []
    for engine in ENGINES:
        plan = map_2d(spec, workers=3, queue_capacity=1)
        with pytest.raises(SimDeadlock) as ei:
            simulate(plan, x, CGRA, max_cycles=200_000, engine=engine)
        assert "stall attribution (final cycle)" in str(ei.value)
        assert ei.value.stall_summary is not None
        msgs.append(str(ei.value))
    assert msgs[0] == msgs[1]


def test_timeout_stall_attribution(rng):
    spec = StencilSpec((120,), (1,), ((0.25, 0.5, 0.25),), dtype="float64")
    x = rng.normal(size=120)
    for engine in ENGINES:
        plan = map_1d(spec, workers=3)
        with pytest.raises(SimDeadlock, match="exceeded max_cycles=10") as ei:
            simulate(plan, x, CGRA, max_cycles=10, engine=engine,
                     telemetry=Telemetry())
        assert ei.value.timed_out
        assert ei.value.stall_summary is not None


# ---------------------------------------------------------------------------
# tuner spans + cache stats (satellites)
# ---------------------------------------------------------------------------
def _tiny_search(tmp_path, tel=None):
    from repro.explore import Budget, SpaceOptions, explore
    return explore(
        heat_2d(18, 24, dtype="float64"), CGRA,
        options=SpaceOptions(workers=(2, 3), capacities=("auto",)),
        budget=Budget(), cache=str(tmp_path / "cache.json"),
        telemetry=tel)


def test_explore_records_spans(tmp_path):
    tel = Telemetry()
    res = _tiny_search(tmp_path, tel)
    evals = [s for s in tel.spans if s["cat"] == "tuner"
             and s["track"].startswith("search/")
             and s["track"] != "search/prune"]
    assert len(evals) == res.stats["n_measured"] > 0
    for s in evals:
        assert s["args"]["outcome"] == "measured"
        assert s["args"]["cycles"] > 0
        assert s["args"]["key"] and s["args"]["config"]
        assert s["dur"] >= 0 and s["t0"] >= 0
    assert validate_trace(trace_events(tel)) >= len(evals)

    # second search, same cache: every eval replays as a cache hit
    tel2 = Telemetry()
    _tiny_search(tmp_path, tel2)
    outcomes = {s["args"]["outcome"] for s in tel2.spans
                if s["cat"] == "tuner" and s["track"] != "search/prune"}
    assert outcomes == {"cached"}


def test_eval_cache_stats_replay(tmp_path):
    """Regression gate: a rerun over a warm cache must report hits > 0."""
    res1 = _tiny_search(tmp_path)
    cs1 = res1.stats["cache"]
    assert cs1["hits"] == 0 and cs1["misses"] > 0
    assert cs1["entries"] == cs1["misses"]

    res2 = _tiny_search(tmp_path)
    cs2 = res2.stats["cache"]
    assert cs2["hits"] > 0 and cs2["misses"] == 0
    assert res1.best().cycles == res2.best().cycles


def test_eval_cache_stats_counts_failure_replay(tmp_path):
    from repro.explore import EvalCache
    path = str(tmp_path / "c.json")
    c = EvalCache(path)
    c.put("good", {"cycles": 5})
    c.put("bad", {"failed": "deadlock: x"})
    c.save()
    c2 = EvalCache(path)
    assert c2.get("good") and c2.get("bad") and c2.get("gone") is None
    assert c2.stats() == {"hits": 2, "misses": 1, "failures_replayed": 1,
                          "entries": 2}


# ---------------------------------------------------------------------------
# benchmarks/run.py error isolation + exit status (satellite)
# ---------------------------------------------------------------------------
def test_run_py_isolates_case_failures(tmp_path, monkeypatch):
    from benchmarks import run as bench_run

    calls = []

    def boom(cases, name, *a, **kw):
        calls.append(name)
        if name == "2d":
            raise RuntimeError("injected 2d failure")
        cases[name] = {"cycles_ideal": 1}

    monkeypatch.setattr(bench_run, "_artifact_case", boom)
    cases, errors = bench_run.artifact_cases(True, "vector")
    assert calls == ["1d", "2d", "3d"]           # later cases still ran
    assert set(cases) == {"1d", "3d"}
    assert list(errors) == ["2d"]
    assert "injected 2d failure" in errors["2d"]

    # the writer persists the partial artifact, then propagates the failure
    path = tmp_path / "a.json"
    with pytest.raises(RuntimeError, match="1 case\\(s\\) failed"):
        bench_run._write_snapshot(str(path), "bench_pr2/v1", True, None,
                                  (cases, errors), engine="vector")
    art = json.loads(path.read_text())
    assert set(art["cases"]) == {"1d", "3d"}
    assert "injected 2d failure" in art["errors"]["2d"]

    # and main() turns it into a nonzero exit
    with pytest.raises(SystemExit) as ei:
        bench_run.main(["--artifact", str(tmp_path / "b.json"),
                        "--smoke", "--artifact-only", "--engine", "vector"])
    assert ei.value.code == 1
    assert "errors" in json.loads((tmp_path / "b.json").read_text())


def test_run_py_all_good_exits_zero(tmp_path, monkeypatch):
    from benchmarks import run as bench_run

    def ok(cases, name, *a, **kw):
        cases[name] = {"cycles_ideal": 1}

    monkeypatch.setattr(bench_run, "_artifact_case", ok)
    bench_run.main(["--artifact", str(tmp_path / "a.json"),
                    "--smoke", "--artifact-only"])   # no SystemExit
    art = json.loads((tmp_path / "a.json").read_text())
    assert set(art["cases"]) == {"1d", "2d", "3d"}
    assert "errors" not in art


# ---------------------------------------------------------------------------
# bench_diff (satellite)
# ---------------------------------------------------------------------------
def _art(tmp_path, name, cases):
    p = tmp_path / name
    p.write_text(json.dumps({"schema": "bench_pr4/v1", "config": "smoke",
                             "cases": cases}))
    return str(p)


def test_bench_diff(tmp_path, capsys):
    from benchmarks.bench_diff import main as bd
    base = {"2d": {"cycles_routed": 642, "vector_wall_s": 0.30,
                   "token_hops": 9000}}
    a = _art(tmp_path, "a.json", base)
    assert bd([a, a]) == 0

    # integer counters are exact; float walls get the tolerance band
    drift = _art(tmp_path, "b.json",
                 {"2d": {"cycles_routed": 643, "vector_wall_s": 0.30,
                         "token_hops": 9000}})
    assert bd([a, drift]) == 1
    out = capsys.readouterr().out
    assert "deterministic counter changed 642 -> 643" in out

    wall_ok = _art(tmp_path, "c.json",
                   {"2d": {"cycles_routed": 642, "vector_wall_s": 0.36,
                           "token_hops": 9000}})
    assert bd([a, wall_ok]) == 0
    wall_bad = _art(tmp_path, "d.json",
                    {"2d": {"cycles_routed": 642, "vector_wall_s": 3.0,
                            "token_hops": 9000}})
    assert bd([a, wall_bad]) == 1

    # config mismatch (smoke vs full) is never comparable
    full = tmp_path / "e.json"
    full.write_text(json.dumps({"schema": "bench_pr4/v1", "config": "full",
                                "cases": base}))
    assert bd([a, str(full)]) == 1

    # partial artifacts (errors key) fail the gate
    part = tmp_path / "f.json"
    part.write_text(json.dumps({"schema": "bench_pr4/v1", "config": "smoke",
                                "cases": base, "errors": {"3d": "boom"}}))
    assert bd([a, str(part)]) == 1


def test_state_names_cover_constants():
    from repro.telemetry import (ST_FIRED, ST_INACTIVE, ST_INPUT_STARVED,
                                 ST_MEM_ARB, ST_NET_WAIT, ST_OUTPUT_BLOCKED)
    assert len(STATE_NAMES) == 6
    assert STATE_NAMES[ST_INACTIVE] == "inactive"
    assert STATE_NAMES[ST_FIRED] == "fire"
    assert STATE_NAMES[ST_INPUT_STARVED] == "input_starved"
    assert STATE_NAMES[ST_OUTPUT_BLOCKED] == "output_blocked"
    assert STATE_NAMES[ST_MEM_ARB] == "memory_arbitration"
    assert STATE_NAMES[ST_NET_WAIT] == "network_contention"
    assert STALL_CAUSES == STATE_NAMES[2:]
